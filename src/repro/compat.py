"""jax version-compat shims (no deps on the rest of the repo).

The repo targets the jax >= 0.5 API surface; this module backfills the few
names that moved since 0.4.x so the same code runs on both:

  * `shard_map` — `jax.shard_map` (new) vs `jax.experimental.shard_map`
  * `CompilerParams` — pallas-TPU params, renamed from `TPUCompilerParams`
  * mesh `AxisType` handling lives in `repro.launch.mesh` (it also needs
    the mesh builders)
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as _pltpu

try:  # jax >= 0.6 exposes it at the top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]

# jax >= 0.5 renamed TPUCompilerParams → CompilerParams
CompilerParams = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
