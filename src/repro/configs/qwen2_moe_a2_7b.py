"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts top-4.

24L d_model=2048 16H (MHA kv=16) expert_ff=1408 vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]. EP impl: 60 experts padded to 64 -> 4/chip on
the 16-way model axis; shared expert ff = 4*1408 = 5632 with sigmoid gate.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    act="silu",
    qkv_bias=True,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        expert_ff=1408,
        num_shared=4,
        shared_ff=5632,
        impl="ep",
    ),
)
