"""Config system: model / shape / parallelism / SpAMM dataclasses + registry.

Every assigned architecture registers a `ModelConfig` in its own module under
`repro.configs`; `get_config(name)` resolves it. Shape cells (train_4k,
prefill_32k, decode_32k, long_500k) are global and paired with every arch.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# SpAMM feature config (the paper's technique as a first-class switch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpammConfig:
    enable: bool = False
    tau: float = 0.0                    # norm-product threshold (paper τ)
    valid_ratio: Optional[float] = None # alternative: target executed fraction
    tile: int = 64                      # LoNum
    block_n: int = 1                    # super-column width in the mm kernel
    backend: str = "auto"               # pallas | interpret | jnp | auto
    bwd: str = "dense"                  # dense | spamm gradient path
    levels: int = 0                     # norm-pyramid coarsening steps for
                                        # hierarchical gating (0 = flat); the
                                        # coarsest gate runs at coarse_tile
    dtype: str = "float32"              # GEMM compute dtype: float32 | bf16 |
                                        # int8 (f32 accumulate always; gating
                                        # stays conservative via widened τ —
                                        # see repro.kernels.quantize)
    moe_bmm: bool = False               # inference-only: run MoE grouped FFNs
                                        # through the batched spamm_bmm path
                                        # (per-expert weight plans; grads flow
                                        # through the gated product, so keep
                                        # False for bwd="dense" training)
    autotune: bool = False              # roofline-autotune block_n/levels/
                                        # bucket per weight at freeze time
                                        # (core.cost); block_n/levels above
                                        # become the tuner's defaults (always
                                        # in its search space)
    tune_profile: Optional[str] = None  # path to a calibrated cost-profile
                                        # JSON (benchmarks/autotune.py
                                        # --calibrate); None = nominal
                                        # per-backend coefficients

    @property
    def coarse_tile(self) -> int:
        """Tile size of the coarsest pyramid level (== tile when flat)."""
        return self.tile * (2 ** self.levels)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0
    shared_ff: int = 0
    impl: str = "tp"                    # "tp": ff-dim TP; "ep": expert-parallel
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001    # load-balancing aux loss


@dataclass(frozen=True)
class SSMConfig:                         # Mamba2 / SSD
    state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_dim: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:                       # RecurrentGemma
    lru_width: int = 0                  # 0 → d_model
    conv_dim: int = 4
    c_exponent: float = 8.0
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")  # 1 attn : 2 rec


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                   # 0 → d_model // num_heads
    act: str = "silu"                   # silu (SwiGLU) | gelu (SwiGLU-gelu) | gelu_mlp
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA window (mixtral, local attn)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: Optional[str] = None      # None | "vision_stub" | "audio_stub"
    subquadratic: bool = False          # eligible for long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            d_ff=128,
            vocab=256,
            head_dim=16,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                expert_ff=32,
                shared_ff=64 if self.moe.num_shared else 0,
                top_k=min(self.moe.top_k, 2),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state=16, head_dim=16, chunk=32)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=64)
            kw["num_layers"] = 3  # one full (rec, rec, attn) group
        if self.sliding_window:
            kw["sliding_window"] = 32
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# shape cells (assigned; identical for all 10 archs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# parallelism / runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = True                   # ZeRO-3 param sharding over data axis
    remat: str = "full"                 # none | dots | full
    scan_layers: bool = True
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    loss_chunk: int = 1024              # chunked-CE seq chunk
    attn_q_chunk: int = 512             # flash q block
    attn_kv_chunk: int = 1024           # flash kv block
    decode_seq_shard: bool = True       # seq-sharded KV decode over model axis
    seq_shard_acts: bool = False        # Megatron-SP: residual stream sharded
                                        # on seq over model (psum → RS+AG)
    grad_compression: str = "none"      # none | int8_ef


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "llava-next-mistral-7b",
    "mamba2-1.3b",
    "starcoder2-7b",
    "granite-34b",
    "codeqwen1.5-7b",
    "qwen2.5-32b",
    "recurrentgemma-9b",
    "qwen2-moe-a2.7b",
    "mixtral-8x22b",
    "musicgen-large",
)

# archs for which long_500k runs (sub-quadratic sequence mixing); the rest
# record a documented skip (see DESIGN.md §6).
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "recurrentgemma-9b", "mixtral-8x22b")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells. 37 runnable + 3 documented skips."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out
