"""llava-next-mistral-7b [vlm] — Mistral-7B backbone of LLaVA-NeXT.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000; anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, S, d_model); the backbone is what this config exercises.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    act="silu",
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    notes="anyres tiling handled by the (stubbed) frontend; full attention",
)
