"""codeqwen1.5-7b [dense] — qwen1.5-arch. 32L d=4096 32H (MHA kv=32) ff=13440 v=92416.

[hf:Qwen/CodeQwen1.5-7B]. SwiGLU, QKV bias (qwen1.5 family trait).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    act="silu",
    qkv_bias=True,
)
