"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. [arXiv:2402.19427]
Local attention window 2048; sub-quadratic -> long_500k runs.
38 layers = 12 x (rec, rec, attn) groups + 2 trailing recurrent layers.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    act="gelu",
    sliding_window=2048,
    rglru=RGLRUConfig(lru_width=4096, conv_dim=4, c_exponent=8.0),
    subquadratic=True,
    notes="head_dim=256 (4096/16); GeGLU MLP; rotary on attention layers only",
)
