"""granite-34b [dense] — llama-arch, code. 88L d=6144 48H (MQA kv=1) ff=24576 v=49152.

[arXiv:2405.04324; hf]. Assignment labels it llama-arch -> SwiGLU + RMSNorm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    act="silu",
    notes="MQA (kv=1): decode uses seq-sharded KV (heads cannot shard)",
)
