"""mixtral-8x22b [moe] — 8 experts top-2, SWA. 56L d=6144 48H (kv=8) ff=16384 v=32768.

[arXiv:2401.04088; hf]. TP impl (ff sharded over model axis); sliding-window
attention (window 4096, per assignment) -> rolling decode cache ->
sub-quadratic -> long_500k runs.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    act="silu",
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=16384, impl="tp"),
    subquadratic=True,
)
