"""starcoder2-7b [dense] — GQA, RoPE. 32L d=4608 36H (kv=4) ff=18432 v=49152.

[arXiv:2402.19173; hf]. StarCoder2 uses a classic 4x GELU MLP (not SwiGLU).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    act="gelu_mlp",
    qkv_bias=True,
    notes="gpt-bigcode lineage: GELU MLP, biases; full attention here",
)
