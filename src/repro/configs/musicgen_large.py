"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048. [arXiv:2306.05284; hf]
EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings; output head predicts one codebook (vocab=2048).
GELU MLP (musicgen uses a standard transformer decoder); RoPE substituted for
the original sinusoidal embedding (positional scheme not under test).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    act="gelu_mlp",
    frontend="audio_stub",
)
