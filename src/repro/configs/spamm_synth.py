"""Synthetic decay-matrix workload config (paper SS4.1) for examples/benches."""
from dataclasses import dataclass

@dataclass(frozen=True)
class SynthConfig:
    n: int = 4096
    tile: int = 64
    decay: str = "algebraic"   # algebraic | exponential
    c: float = 0.1
    lam: float = 0.1
    valid_ratio: float = 0.1

CONFIG = SynthConfig()
