"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality).

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]
Sequence mixing is the chunked SSD algorithm; sub-quadratic -> long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state=128, head_dim=64, expand=2, chunk=256, conv_dim=4),
    subquadratic=True,
    notes="pure SSM; no attention, no MLP (in/out proj + SSD only)",
)
