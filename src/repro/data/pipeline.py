"""Data pipeline: deterministic synthetic token/embedding streams + the
paper's decay-matrix workloads (§4.1 synthesized, §4.3 ergo/VGG-like).

The token stream is seeded per (epoch, step) so a restart from checkpoint
resumes at exactly the batch it would have seen (fault-tolerance contract:
the data state is just `step`).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import spamm as core_spamm


@dataclasses.dataclass
class SyntheticLM:
    """Zipf-ish synthetic token stream with next-token labels."""

    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        v = self.cfg.vocab
        # zipf-like marginal over vocab with a repeating n-gram structure so
        # the LM has something learnable
        base = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1)) % (v - 2)
        period = 1 + (np.arange(self.seq_len + 1) % 17)
        toks = ((base + period[None, :]) % (v - 2)).astype(np.int32) + 1
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if self.cfg.frontend:
            rngj = jax.random.key(hash((self.seed, step)) % (2**31))
            batch = {
                "embeds": 0.02
                * jax.random.normal(
                    rngj, (self.global_batch, self.seq_len, self.cfg.d_model),
                    jnp.float32,
                ),
                "labels": batch["labels"],
            }
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


# ---------------------------------------------------------------------------
# paper workloads
# ---------------------------------------------------------------------------

def synthesized_decay(n: int, seed: int = 0) -> np.ndarray:
    """Paper §4.1: a_ij = 0.1 / (|i-j|^0.1 + 1), sign-randomized."""
    return core_spamm.algebraic_decay(n, c=0.1, lam=0.1, seed=seed)


def ergo_like(n: int, lam: float = 0.7, seed: int = 0) -> np.ndarray:
    """Exponential-decay matrices standing in for the ergo §4.3.1 matrices
    (the real ones come from ErgoSCF water-cluster runs; same decay law)."""
    return core_spamm.exponential_decay(n, c=1.0, lam=lam, seed=seed)


def vgg_im2col_shapes():
    """Paper §4.3.2: (M, K, N) of conv21 and conv31 after im2col."""
    return {"conv21": (128, 576, 25_600), "conv31": (256, 1_152, 6_400)}


def relu_sparse_matrix(m: int, n: int, sparsity: float = 0.55, seed: int = 0):
    """Near-sparse activation-like matrix (paper §1: ReLU ⇒ >50% zeros)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, n)).astype(np.float32)
    thresh = np.quantile(x, sparsity)
    return np.maximum(x - thresh, 0.0)
