"""Gradient compression with error feedback (beyond-paper, DESIGN.md §9).

Int8EF: per-leaf symmetric int8 quantization of gradients with an error-
feedback residual (Seide et al. / EF-SGD): the quantization error of step t
is added back into the gradient of step t+1, preserving convergence. In a
real deployment the int8 payload is what crosses the DP all-reduce (4×
fewer wire bytes on the `data`/`pod` axes); here the quantize/dequantize
pair runs right before the optimizer so the numerics (and the EF state)
are exactly those of the compressed collective.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Int8EF(NamedTuple):
    enabled: bool = True

    def apply(self, grads, state):
        """grads/state['ef']: matching pytrees (f32). Returns (deq, state')."""
        ef = state["ef"]

        def comp(g, e):
            g = g + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq, g - deq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        outs = [comp(g, e) for g, e in zip(flat_g, flat_e)]
        deq = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_ef = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return deq, dict(state, ef=new_ef)

    def wire_bytes_saved(self, grads) -> float:
        total = sum(g.size for g in jax.tree.leaves(grads))
        return total * (4 - 1)  # f32 → int8 payload


# ---------------------------------------------------------------------------
# SpAMM operand-halo compression (pairs with core.distributed.spamm_rowpart)
# ---------------------------------------------------------------------------
# spamm_rowpart replicates B to every device; with compute_dtype != f32 each
# shard's GEMM only ever sees the per-tile-quantized view of B, so the
# broadcast can carry the quantized payload + scale table instead of f32.
# These helpers ARE that wire format: compress on the source, move
# `halo_wire_bytes` bytes, decompress on each shard. The pair is exactly
# kernels.quantize's per-tile quantization, so a shard decompressing the
# halo reproduces bit-for-bit the operand view spamm_rowpart's local plans
# quantize from their full-precision replica (pure function ⇒ broadcast-
# then-quantize ≡ quantize-then-broadcast).

def compress_tiles(x, tile: int, dtype: str = "int8"):
    """Tile-quantized wire format of operand halo `x` (tile-padded 2-D).

    Returns (payload, scales): int8 payload + (gm, gn) f32 scale table for
    dtype="int8"; bf16 payload + None for "bfloat16"; x itself + None for
    "float32" (identity — callers need no special case)."""
    from repro.kernels import quantize as kquant  # deferred: cheap import

    dtype = kquant.canonical_dtype(dtype)
    if dtype == "int8":
        return kquant.quantize_tiles(x, tile)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16), None
    return x, None


def decompress_tiles(payload, scales, tile: int):
    """Inverse of `compress_tiles`: the f32 operand view a shard computes
    with (the quantized view, not the original — that is the point)."""
    from repro.kernels import quantize as kquant  # deferred: cheap import

    if payload.dtype == jnp.int8:
        return kquant.dequantize_tiles(payload, scales, tile)
    return payload.astype(jnp.float32)


def halo_wire_bytes(shape, tile: int, dtype: str = "float32") -> float:
    """Bytes one replica of a (K, N) operand halo moves on the wire in the
    `compress_tiles` format (payload + int8's scale table)."""
    from repro.kernels import quantize as kquant  # deferred: cheap import

    dtype = kquant.canonical_dtype(dtype)
    k, n = shape
    payload = float(k) * float(n) * kquant.dtype_itemsize(dtype)
    if dtype == "int8":
        payload += (k // tile) * (n // tile) * 4.0  # f32 scale table
    return payload
