"""Gradient compression with error feedback (beyond-paper, DESIGN.md §9).

Int8EF: per-leaf symmetric int8 quantization of gradients with an error-
feedback residual (Seide et al. / EF-SGD): the quantization error of step t
is added back into the gradient of step t+1, preserving convergence. In a
real deployment the int8 payload is what crosses the DP all-reduce (4×
fewer wire bytes on the `data`/`pod` axes); here the quantize/dequantize
pair runs right before the optimizer so the numerics (and the EF state)
are exactly those of the compressed collective.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Int8EF(NamedTuple):
    enabled: bool = True

    def apply(self, grads, state):
        """grads/state['ef']: matching pytrees (f32). Returns (deq, state')."""
        ef = state["ef"]

        def comp(g, e):
            g = g + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq, g - deq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        outs = [comp(g, e) for g, e in zip(flat_g, flat_e)]
        deq = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_ef = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return deq, dict(state, ef=new_ef)

    def wire_bytes_saved(self, grads) -> float:
        total = sum(g.size for g in jax.tree.leaves(grads))
        return total * (4 - 1)  # f32 → int8 payload
