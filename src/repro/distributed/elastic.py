"""Elastic scaling + failure handling (DESIGN.md §9).

The contract at 1000+ nodes: when a chip/host drops, the job restarts on the
surviving device set; the runtime must (1) build the largest usable mesh
from what's alive, (2) re-shard the latest checkpoint onto it, (3) resume
the data stream at the checkpointed step. Steps (1)–(2) are implemented and
tested here on CPU fake devices; the detection/respawn layer is the cluster
scheduler's job (GKE/Borg restart policy) — see train.py --resume auto.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import mesh_from_devices
from repro.models import model as M


def best_mesh_shape(n_devices: int, model_parallel: int) -> tuple:
    """Largest (data, model) grid with fixed model parallelism that fits the
    surviving device count (drop stragglers beyond the largest full grid)."""
    model = min(model_parallel, n_devices)
    while n_devices % model:
        model -= 1
    data = n_devices // model
    return (data, model)


def build_elastic_mesh(devices: Optional[Sequence] = None,
                       model_parallel: int = 16) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    data, model = best_mesh_shape(len(devices), model_parallel)
    used = np.array(devices[: data * model]).reshape(data, model)
    return mesh_from_devices(used, ("data", "model"))


def reshard_state(state, cfg, pcfg, new_mesh: Mesh):
    """Re-shard a (params, opt_state) pytree onto a new mesh (after failure
    or scale-up). Works from host arrays or differently-sharded jax.Arrays."""
    params = state["params"]
    pspecs = M.param_pspecs(cfg, pcfg, params)
    from repro.launch.dryrun import sanitize_spec  # divisibility guard

    def put(tree, specs):
        return jax.tree.map(
            lambda x, sp: jax.device_put(
                np.asarray(x),
                NamedSharding(new_mesh, sanitize_spec(new_mesh, sp, x.shape)),
            ),
            tree,
            specs,
            is_leaf=lambda t: not isinstance(t, dict),
        )

    out = dict(state)
    out["params"] = put(params, pspecs)
    if "opt_state" in state:
        os_ = state["opt_state"]
        out["opt_state"] = dict(
            os_,
            mu=put(os_["mu"], pspecs),
            nu=put(os_["nu"], pspecs),
        )
    return out
