"""Checkpointing: atomic, async-capable, restart-from-latest.

Layout:  <dir>/step_<N>/arrays.npz + meta.json   (tmp-dir + os.rename for
atomicity — a crashed save can never be mistaken for a complete one).

On a real multi-host pod each host writes its local shards (the tree is
flattened with jax.experimental.multihost_utils / array addressable shards);
in this single-process container arrays are saved whole. `restore` re-shards
onto whatever mesh the caller provides — which is exactly the elastic-
rescale path (distributed/elastic.py): save at 16×16, restore at 8×16.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's npz has no bfloat16: stored as uint16 bit patterns, restored via
# the dtype of the `like` tree.
def _to_savable(x: np.ndarray) -> np.ndarray:
    if x.dtype == ml_dtypes.bfloat16:
        return x.view(np.uint16)
    return x


def _from_saved(arr: np.ndarray, like_dtype) -> np.ndarray:
    if like_dtype == ml_dtypes.bfloat16 and arr.dtype != ml_dtypes.bfloat16:
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = _to_savable(np.asarray(leaf))
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, state: dict, *, keep: int = 3,
         async_: bool = False, plan_store=None) -> Optional[threading.Thread]:
    """state: arbitrary pytree of arrays (params/opt_state/step/data state).

    `plan_store` (a `repro.plans.store.PlanStore` or its directory path)
    records the precomputed-SpAMM-plan store pointer in the checkpoint
    manifest next to the weights, so a restored server finds its frozen
    plans (`plan_store_pointer`) instead of re-running the planning pass."""
    state = jax.tree.map(lambda x: np.asarray(x), state)  # host copy first
    store_ptr = None
    if plan_store is not None:
        if isinstance(plan_store, str):
            from repro.plans.frozen import PLAN_FORMAT_VERSION  # deferred

            store_ptr = {"path": os.path.abspath(plan_store),
                         "format_version": PLAN_FORMAT_VERSION}
        else:
            store_ptr = plan_store.manifest_pointer()

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {"step": step, "keys": sorted(flat)}
        if store_ptr is not None:
            meta["plan_store"] = store_ptr
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def plan_store_pointer(ckpt_dir: str, step: int) -> Optional[dict]:
    """The plan-store pointer a checkpoint was saved with, or None:
    {"path": <store dir>, "format_version": <int>}. Raises if the recorded
    format version does not match the running code — the pointer exists to
    prevent a restored server from silently executing stale plans."""
    path = os.path.join(ckpt_dir, f"step_{step}", "meta.json")
    if not os.path.isfile(path):
        return None
    with open(path) as f:
        meta = json.load(f)
    ptr = meta.get("plan_store")
    if ptr is None:
        return None
    from repro.plans.frozen import PLAN_FORMAT_VERSION  # deferred

    if ptr.get("format_version") != PLAN_FORMAT_VERSION:
        raise ValueError(
            f"checkpoint step {step} points at a plan store written with "
            f"format version {ptr.get('format_version')!r}; this build "
            f"reads {PLAN_FORMAT_VERSION} — re-run precompute_plans")
    return ptr


def open_plan_store(ckpt_dir: str, step: int):
    """PlanStore from a checkpoint's pointer, or None when it has none."""
    ptr = plan_store_pointer(ckpt_dir, step)
    if ptr is None:
        return None
    from repro.plans.store import PlanStore  # deferred

    return PlanStore(ptr["path"])


def restore(ckpt_dir: str, step: int, like: Any, shardings=None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or SDS).
    `shardings`: optional matching pytree of NamedShardings → device_put
    directly into the (possibly different) target mesh."""
    path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
    data = np.load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for (pth, leaf) in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = _from_saved(data[key], np.dtype(leaf.dtype))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree
