"""AdamW built from scratch (no optax): sharded moments, global-norm clip,
linear-warmup + cosine decay, optional int8 gradient compression with error
feedback (distributed/compression.py) hooked in at the update boundary.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamW(NamedTuple):
    tcfg: TrainConfig
    compression: Optional[object] = None  # distributed.compression.Int8EF

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }
        if self.compression is not None:
            state["ef"] = jax.tree.map(zeros, params)
        return state

    def lr_at(self, step):
        t = self.tcfg
        warm = jnp.minimum(step / jnp.maximum(t.warmup, 1), 1.0)
        prog = jnp.clip(
            (step - t.warmup) / jnp.maximum(t.total_steps - t.warmup, 1), 0.0, 1.0
        )
        return t.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))

    def update(self, params, grads, state, step):
        t = self.tcfg
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        if self.compression is not None:
            grads, state = self.compression.apply(grads, state)

        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, t.grad_clip / jnp.maximum(gnorm, 1e-9))
        step_f = step.astype(jnp.float32) + 1.0
        lr = self.lr_at(step_f)
        bc1 = 1.0 - t.b1 ** step_f
        bc2 = 1.0 - t.b2 ** step_f

        def upd(p, g, mu, nu):
            g = g * scale
            mu = t.b1 * mu + (1.0 - t.b1) * g
            nu = t.b2 * nu + (1.0 - t.b2) * g * g
            mhat = mu / bc1
            nhat = nu / bc2
            delta = mhat / (jnp.sqrt(nhat) + 1e-8) + t.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_mu = jax.tree.leaves(state["mu"])
        flat_nu = jax.tree.leaves(state["nu"])
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_state = dict(
            state,
            mu=jax.tree.unflatten(tdef, [o[1] for o in out]),
            nu=jax.tree.unflatten(tdef, [o[2] for o in out]),
        )
        return new_p, new_state, gnorm
