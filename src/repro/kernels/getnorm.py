"""Pallas TPU get-norm kernel (paper §3.2).

Computes the `normmap`: per-(tile × tile) Frobenius norms of a 2-D array.

TPU adaptation of the paper's reduction design:
  * one grid step reduces one whole LoNum×LoNum tile on the VPU (8×128 lanes);
    the paper's shared-memory tree reduction with sequential addressing has no
    TPU analogue because VMEM has no bank conflicts and the VPU reduces a
    resident tile in one shot.
  * the paper's tensor-core reduction (Eq. 3–4: D = 1·X, D' = D·1) is kept as
    an optional MXU path (`use_mxu=True`): two `lax.dot`s against a ones
    vector/matrix — useful when the tile is large and MXU-aligned.
  * output blocking: each kernel invocation owns one *row* of the normmap
    ((1, grid_k) block revisited across the k grid dimension), so the normmap
    row stays VMEM-resident and is flushed to HBM once — the analogue of the
    paper's "thread 0 writes the result back" without a global sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams
from repro.kernels import quantize as _quant


def _tile_sumsq(sq, *, use_mxu: bool):
    """Reduce one resident (t, t) f32 tile of squares to a scalar — the
    body shared by the plain and fused-quantizing get-norm kernels (one
    reduction implementation ⇒ the fused norms are bit-identical to the
    unfused quantize→dequantize→norms composition)."""
    if use_mxu:
        # Paper Eq. 3–4 on the MXU: row-sum then total via dot against ones.
        t = sq.shape[0]
        ones_col = jnp.ones((t, 1), jnp.float32)
        rows = jax.lax.dot_general(  # (1, t) · (t, t) -> row sums? use X^T·1
            sq, ones_col, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (t, 1) row sums
        total = jax.lax.dot_general(
            ones_col, rows, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (1, 1)
        return total[0, 0]
    return jnp.sum(sq)


def _getnorm_kernel(x_ref, o_ref, *, use_mxu: bool):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    s = _tile_sumsq(x * x, use_mxu=use_mxu)
    o_ref[0, j] = jnp.sqrt(s)


def _getnorm_quant_kernel(x_ref, o_ref, s_ref, *, use_mxu: bool):
    """Fused int8 absmax/scale + get-norm: ONE read of the resident tile
    yields both the per-tile quantization scale and the Frobenius norm OF
    the quantized view (what the int8 kernel will actually multiply).

    Bit-identity with the unfused `quantize_tiles` → `dequantize_tiles` →
    `tile_norms` composition: amax/round/clip are order-independent
    elementwise f32 ops, the int8 codes are integers in [-127, 127] (exactly
    representable in f32, so skipping the int8 round-trip changes nothing),
    and the final reduction is the same `_tile_sumsq` body.
    """
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    scale = (jnp.maximum(jnp.max(jnp.abs(x)), _quant._TINY)
             * jnp.float32(_quant._INV127))
    dq = jnp.clip(jnp.round(x / scale), -127.0, 127.0) * scale
    s = _tile_sumsq(dq * dq, use_mxu=use_mxu)
    o_ref[0, j] = jnp.sqrt(s)
    s_ref[0, j] = scale


def _pool_kernel(n_ref, o_ref):
    """sqrt-sumsq 2×2 pooling: one grid step pools one coarse normmap row.

    Row pairing is a VPU add; column pairing runs as a dot against the
    0/1 pooling matrix (kf // 2 == kc) so the lane-dim reduction stays
    MXU/VPU-friendly (no strided lane slicing)."""
    x = n_ref[...].astype(jnp.float32)          # (2, 2·gkc) fine rows pair
    sq = x * x
    rows = sq[0:1, :] + sq[1:2, :]              # (1, 2·gkc) row-pooled sumsq
    w = rows.shape[1]
    kf = jax.lax.broadcasted_iota(jnp.int32, (w, w // 2), 0)
    kc = jax.lax.broadcasted_iota(jnp.int32, (w, w // 2), 1)
    pool = (kf // 2 == kc).astype(jnp.float32)  # (2·gkc, gkc) column pairing
    s = jax.lax.dot_general(
        rows, pool, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (1, gkc)
    o_ref[0, :] = jnp.sqrt(s[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def pool_norms(normmap: jax.Array, *, interpret: bool = False) -> jax.Array:
    """One norm-pyramid coarsening step via the Pallas pooling kernel.

    normmap: (gm, gk) f32 level-(l-1) normmap; odd dims are zero-padded.
    Returns (⌈gm/2⌉, ⌈gk/2⌉) f32 — sqrt of 2×2 sumsq pooling, i.e. the exact
    Frobenius norm of each 2×2 tile group (one cheap reduction, no re-read of
    the underlying matrix).
    """
    gm, gk = normmap.shape
    pm, pk = gm % 2, gk % 2
    if pm or pk:
        normmap = jnp.pad(normmap, ((0, pm), (0, pk)))
    gmc, gkc = (gm + pm) // 2, (gk + pk) // 2
    return pl.pallas_call(
        _pool_kernel,
        grid=(gmc,),
        in_specs=[pl.BlockSpec((2, 2 * gkc), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, gkc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gmc, gkc), jnp.float32),
        interpret=interpret,
        name="spamm_norm_pool",
    )(normmap)


@functools.partial(
    jax.jit, static_argnames=("tile", "levels", "use_mxu", "interpret")
)
def norm_pyramid(
    x: jax.Array,
    tile: int = 64,
    levels: int = 1,
    *,
    use_mxu: bool = False,
    interpret: bool = False,
):
    """Coarse-to-fine normmap stack: one get-norm pass + `levels` poolings.

    Returns a tuple (finest → coarsest) of `levels + 1` normmaps; entry l is
    the normmap at tile size tile·2^l (grid dims ceil-halved per level).
    """
    maps = [tile_norms(x, tile, use_mxu=use_mxu, interpret=interpret)]
    for _ in range(levels):
        maps.append(pool_norms(maps[-1], interpret=interpret))
    return tuple(maps)


@functools.partial(
    jax.jit, static_argnames=("tile", "use_mxu", "interpret")
)
def tile_norms(
    x: jax.Array,
    tile: int = 64,
    *,
    use_mxu: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Per-tile Frobenius norms via the Pallas get-norm kernel.

    x: (M, K) with M % tile == 0 == K % tile. Returns (M//tile, K//tile) f32.
    """
    m, k = x.shape
    if m % tile or k % tile:
        raise ValueError(f"shape {x.shape} not divisible by tile {tile}")
    gm, gk = m // tile, k // tile
    kernel = functools.partial(_getnorm_kernel, use_mxu=use_mxu)
    return pl.pallas_call(
        kernel,
        grid=(gm, gk),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, gk), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gm, gk), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spamm_getnorm",
    )(x)


@functools.partial(
    jax.jit, static_argnames=("tile", "use_mxu", "interpret")
)
def tile_norms_quant(
    x: jax.Array,
    tile: int = 64,
    *,
    use_mxu: bool = False,
    interpret: bool = False,
):
    """Fused int8-quantization get-norm: per-tile Frobenius norms of the
    int8 quantized VIEW of x plus the per-tile scales, from one read.

    x: (M, K) with M % tile == 0 == K % tile. Returns (norms, scales), both
    (M//tile, K//tile) f32. `norms` is bit-identical to
    `tile_norms(dequantize_tiles(*quantize_tiles(x, tile)), tile)` and
    `scales` to `quantize_tiles(x, tile)[1]` — this kernel just collapses
    the three passes (absmax read, quantize/dequantize write+read, norm
    read) into one, which is how `execute()`-bound int8 plans get their
    activation scales without a separate per-call pass.
    """
    m, k = x.shape
    if m % tile or k % tile:
        raise ValueError(f"shape {x.shape} not divisible by tile {tile}")
    gm, gk = m // tile, k // tile
    kernel = functools.partial(_getnorm_quant_kernel, use_mxu=use_mxu)
    return pl.pallas_call(
        kernel,
        grid=(gm, gk),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((1, gk), lambda i, j: (i, 0)),
            pl.BlockSpec((1, gk), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gm, gk), jnp.float32),
            jax.ShapeDtypeStruct((gm, gk), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spamm_getnorm_quant",
    )(x)
