"""Pure-jnp oracles for the two cuSpAMM kernels (paper §3.2, §3.3).

These are the ground-truth references every Pallas kernel is tested against
(interpret=True on CPU, compiled on TPU). They are also the "jnp backend"
used by the model stack during the CPU dry-run, where Pallas TPU kernels
cannot lower.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tile_norms_ref(x: jax.Array, tile: int) -> jax.Array:
    """Per-tile Frobenius norms (paper Eq. 2, the `normmap`).

    x: (M, K) array, M % tile == 0 and K % tile == 0 (pad upstream).
    Returns (M//tile, K//tile) float32 norms.
    """
    m, k = x.shape
    bm, bk = m // tile, k // tile
    x4 = x.astype(jnp.float32).reshape(bm, tile, bk, tile)
    return jnp.sqrt(jnp.einsum("itjs,itjs->ij", x4, x4))


def pool_norms_ref(normmap: jax.Array, factor: int = 2) -> jax.Array:
    """One norm-pyramid coarsening step: sqrt-of-sumsq `factor`×`factor`
    pooling of a normmap (paper Eq. 2 applied at the next tile size up).

    Because ‖X‖_F² of a coarse tile is exactly the sum of its sub-tiles'
    ‖·‖_F², pooling the *squares* reuses the finest get-norm pass — no second
    sweep over the matrix — and the coarse entry upper-bounds every
    descendant tile norm (the exactness lever of hierarchical gating).

    Supports leading batch dims; the trailing two dims are zero-padded to
    `factor` multiples (zero tiles contribute nothing to the sumsq).
    """
    g1, g2 = normmap.shape[-2:]
    p1, p2 = (-g1) % factor, (-g2) % factor
    if p1 or p2:
        pad = [(0, 0)] * (normmap.ndim - 2) + [(0, p1), (0, p2)]
        normmap = jnp.pad(normmap, pad)
    c1, c2 = (g1 + p1) // factor, (g2 + p2) // factor
    sq = (normmap * normmap).reshape(
        *normmap.shape[:-2], c1, factor, c2, factor
    )
    return jnp.sqrt(jnp.sum(sq, axis=(-3, -1)))


def spamm_mask_ref(norm_a: jax.Array, norm_b: jax.Array, tau: jax.Array) -> jax.Array:
    """bitmap[i, j, k] = normA[i,k] * normB[k,j] >= tau  (paper Alg. 2 lines 3-8)."""
    prod = norm_a[:, None, :] * jnp.swapaxes(norm_b, 0, 1)[None, :, :]
    return prod >= tau


def spamm_matmul_ref(
    a: jax.Array,
    b: jax.Array,
    tau,
    tile: int,
    *,
    precision=None,
) -> jax.Array:
    """Reference SpAMM: C[i,j] = sum_k bitmap[i,j,k] * A[i,k] @ B[k,j].

    a: (M, K), b: (K, N); M, K, N divisible by `tile`.
    Computed as a dense blocked einsum with the mask applied to A-blocks —
    mathematically identical to skipping the products.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    gm, gk, gn = m // tile, k // tile, n // tile
    na = tile_norms_ref(a, tile)  # (gm, gk)
    nb = tile_norms_ref(b, tile)  # (gk, gn)
    mask = spamm_mask_ref(na, nb, jnp.asarray(tau, jnp.float32))  # (gm, gn, gk)
    a4 = a.reshape(gm, tile, gk, tile)
    b4 = b.reshape(gk, tile, gn, tile)
    # out[i p, j q] = sum_{k, s} mask[i,j,k] a[i,p,k,s] b[k,s,j,q]
    out = jnp.einsum(
        "ijk,ipks,ksjq->ipjq",
        mask.astype(a.dtype),
        a4,
        b4,
        precision=precision,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(m, n).astype(jnp.promote_types(a.dtype, jnp.float32))


def spamm_compact_ref(mask: jax.Array):
    """Compact valid-k lists (the paper's `map_offset`, Fig. 3b) — jnp version.

    mask: (gm, gn, gk) bool.
    Returns (kidx, nvalid):
      kidx   (gm, gn, gk) int32 — first nvalid entries are the valid k's in
             ascending order; padding slots repeat the last valid k (or 0 if
             none), so a Pallas index_map revisits the same block (no re-fetch).
      nvalid (gm, gn) int32 — number of valid k's (the paper's validNum).
    """
    gm, gn, gk = mask.shape
    ks = jnp.arange(gk, dtype=jnp.int32)
    nvalid = jnp.sum(mask, axis=-1, dtype=jnp.int32)  # (gm, gn)
    # invalid slots get sentinel gk, sort ascending -> valid ks first, in order
    sentinel = jnp.where(mask, ks[None, None, :], jnp.int32(gk))
    kidx = jnp.sort(sentinel, axis=-1)
    last = jnp.take_along_axis(
        kidx, jnp.maximum(nvalid - 1, 0)[..., None].astype(jnp.int32), axis=-1
    )
    last = jnp.where(nvalid[..., None] > 0, last, 0).astype(jnp.int32)
    t = ks[None, None, :]
    kidx = jnp.where(t < nvalid[..., None], kidx, last).astype(jnp.int32)
    return kidx, nvalid
