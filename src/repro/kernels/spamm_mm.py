"""Pallas TPU multiplication kernel (paper §3.3, Alg. 2/3).

C[i,j] = sum over *valid* k of A[i,k] @ B[k,j], where validity is the norm
test normA[i,k] * normB[k,j] >= tau computed by the get-norm kernel.

TPU-native mapping of the paper's design:

  * paper `map_offset` (Fig. 3b — compacted list of valid k's so the bitmap
    walk is contiguous)  →  an int32 scalar-prefetch table `kidx[i, j, t]`
    (t-th valid k for output tile (i,j)) driving the BlockSpec index_maps.
    Padding slots repeat the last valid k; Pallas' revisiting optimization
    sees an unchanged block index and skips the HBM→VMEM copy, so an invalid
    step costs ~nothing — the same effect as the paper's "prefetch only valid
    blocks" but implemented in the pipeline itself.
  * paper double buffering (half-block prefetch / half-block compute)  →
    Pallas' built-in multi-buffered grid pipeline.
  * paper per-thread register accumulation  →  a persistent f32 VMEM scratch
    accumulator revisited across the (arbitrary) k grid dimension.
  * paper tensor-core path (Alg. 3, fp16 fragments / fp32 accumulator)  →
    bf16 inputs into the MXU via jnp.dot(..., preferred_element_type=f32).

The mask/compaction (paper Alg. 2 lines 3–14) runs as fused XLA ops over the
normmaps — built ONCE per product by `repro.core.plan.plan` into a
`SpammPlan` and handed to this kernel by `repro.core.plan.execute` — because
on TPU the compaction is a cheap O(gm·gn·gk) elementwise+sort pass, not a
per-block recomputation. Serving callers reuse the plan (weight-side
artifacts via `repro.core.plan.WeightPlanCache`) across repeated products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams


def _spamm_mm_kernel(kidx_ref, nv_ref, a_ref, b_ref, o_ref, acc_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # paper Alg. 2 line 19: iterate only over valid products; here invalid
    # trailing steps are masked out (their block fetches are revisits = free).
    @pl.when(t < nv_ref[i, j])
    def _compute():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(t == nt - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile", "out_dtype", "interpret", "block_n"),
)
def spamm_mm(
    a: jax.Array,
    b: jax.Array,
    kidx: jax.Array,
    nvalid: jax.Array,
    *,
    tile: int = 64,
    block_n: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Masked tiled matmul driven by compacted valid-k lists.

    a: (M, K); b: (K, N); kidx: (gm, gn, gk) int32; nvalid: (gm, gn) int32,
    where gm = M//tile, gk = K//tile, gn = N//tile (see spamm_compact_ref).

    block_n: number of consecutive B/C tiles handled per grid step in the N
    dimension (wider MXU blocks → better arithmetic intensity; requires the
    *same* kidx for the grouped j's, i.e. kidx/nvalid built at block_n
    granularity — callers get both from `repro.core.plan.plan`, which
    builds the super-column mask and its compaction in one place).
    Returns C: (M, N) in out_dtype (f32 accumulate regardless of input dtype).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    gm, gk = m // tile, k // tile
    gn = n // (tile * block_n)
    assert kidx.shape == (gm, gn, gk), (kidx.shape, (gm, gn, gk))
    assert nvalid.shape == (gm, gn)

    grid = (gm, gn, gk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, t, kidx, nv: (i, kidx[i, j, t])),
            pl.BlockSpec(
                (tile, tile * block_n), lambda i, j, t, kidx, nv: (kidx[i, j, t], j)
            ),
        ],
        out_specs=pl.BlockSpec(
            (tile, tile * block_n), lambda i, j, t, kidx, nv: (i, j)
        ),
        scratch_shapes=[pltpu.VMEM((tile, tile * block_n), jnp.float32)],
    )
    return pl.pallas_call(
        _spamm_mm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spamm_mm",
    )(kidx, nvalid, a, b)
