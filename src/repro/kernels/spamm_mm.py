"""Pallas TPU multiplication kernel (paper §3.3, Alg. 2/3).

C[i,j] = sum over *valid* k of A[i,k] @ B[k,j], where validity is the norm
test normA[i,k] * normB[k,j] >= tau computed by the get-norm kernel.

TPU-native mapping of the paper's design — two entry points:

`spamm_mm` (dense-grid): walks the full (gm, gn, gk) grid and masks invalid
steps out.

  * paper `map_offset` (Fig. 3b — compacted list of valid k's so the bitmap
    walk is contiguous)  →  an int32 scalar-prefetch table `kidx[i, j, t]`
    (t-th valid k for output tile (i,j)) driving the BlockSpec index_maps.
    Padding slots repeat the last valid k; Pallas' revisiting optimization
    sees an unchanged block index and skips the HBM→VMEM copy, so an invalid
    step costs ~nothing — the same effect as the paper's "prefetch only valid
    blocks" but implemented in the pipeline itself.
  * paper double buffering (half-block prefetch / half-block compute)  →
    Pallas' built-in multi-buffered grid pipeline.
  * paper per-thread register accumulation  →  a persistent f32 VMEM scratch
    accumulator revisited across the (arbitrary) k grid dimension.
  * paper tensor-core path (Alg. 3, fp16 fragments / fp32 accumulator)  →
    bf16 inputs into the MXU via jnp.dot(..., preferred_element_type=f32).

`spamm_mm_worklist` (ragged, the paper-faithful "iterate only valid
products" form): a 1-D grid over the plan's flattened work-list — one grid
step per surviving (i, j, k) triple, Σnvalid steps padded to a bucket
instead of gm·gn·gk. Four scalar-prefetch tables (step_i/step_j/step_k/
step_flags, built once by `repro.core.plan.compact_from_triples`) drive the
BlockSpec index_maps; per-step flag bits init/accumulate/flush the VMEM
accumulator at (i, j)-group boundaries. Output tiles with no valid product
are never visited — the out buffer aliases a zeros array so they stay
exactly zero. Heavily-pruned products therefore stop paying masked-out grid
steps entirely: execution cost is proportional to valid work, which is the
paper's map_offset design carried all the way into the grid shape.

The gating itself (paper Alg. 2 lines 3–14) is built ONCE per product by
`repro.core.plan.plan` into a `SpammPlan` — for concrete operands the
compacted work-list comes straight from the hierarchical descent's
surviving triples (no dense-bitmap sort); traced plans fall back to the
dense `kidx` tables + `spamm_mm`. Serving callers reuse the plan
(weight-side artifacts via `repro.core.plan.WeightPlanCache`) across
repeated products.

Dtype contract (paper Alg. 3's tensor-core path, generalized):

  input dtype × accumulate dtype × flush cast — the accumulator is ALWAYS
  f32 in VMEM regardless of input dtype, and the FLUSH step casts it to
  `out_dtype` exactly once per output tile. Three input precisions:

  * f32:  `spamm_mm_worklist` as-is. MXU accumulates in f32.
  * bf16: the SAME `spamm_mm_worklist` entry point — pass bf16 `a`/`b` and
    the `jnp.dot(..., preferred_element_type=f32)` body feeds the MXU's
    native bf16×bf16→f32 path. No kernel change: the flag-bit step-table
    design is dtype-blind. On inputs exactly representable in bf16 the
    result is bit-identical to the f32 run (each product of two 8-bit
    significands is exact in f32, and the ascending-k accumulation order
    is unchanged); otherwise it differs only by the input rounding, which
    the quantization-aware gate accounts for (kernels/quantize.py).
  * int8: `spamm_mm_worklist_int8` — symmetric per-(tile × tile)-tile
    quantized operands (see kernels/quantize.py: q = clip(round(x/scale)),
    scale = amax/127) with two extra f32 scalar-prefetch tables `a_scale`
    (gm, gk) and `b_scale` (gk, gn_fine; PER FINE TILE even when
    block_n > 1, so the dequantization and the gate's error bound stay at
    tile granularity). Each step does an int8×int8→int32 MXU dot, then
    scales into the f32 accumulator: acc += i32 · a_scale[i,k] ·
    b_scale[k, j·block_n + c] per fine output column group c. The flush
    cast and zero-aliasing behavior are identical to the f32 kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams


def _spamm_mm_kernel(kidx_ref, nv_ref, a_ref, b_ref, o_ref, acc_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # paper Alg. 2 line 19: iterate only over valid products; here invalid
    # trailing steps are masked out (their block fetches are revisits = free).
    @pl.when(t < nv_ref[i, j])
    def _compute():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(t == nt - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile", "out_dtype", "interpret", "block_n"),
)
def spamm_mm(
    a: jax.Array,
    b: jax.Array,
    kidx: jax.Array,
    nvalid: jax.Array,
    *,
    tile: int = 64,
    block_n: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Masked tiled matmul driven by compacted valid-k lists.

    a: (M, K); b: (K, N); kidx: (gm, gn, gk) int32; nvalid: (gm, gn) int32,
    where gm = M//tile, gk = K//tile, gn = N//tile (see spamm_compact_ref).

    block_n: number of consecutive B/C tiles handled per grid step in the N
    dimension (wider MXU blocks → better arithmetic intensity; requires the
    *same* kidx for the grouped j's, i.e. kidx/nvalid built at block_n
    granularity — callers get both from `repro.core.plan.plan`, which
    builds the super-column mask and its compaction in one place).
    Returns C: (M, N) in out_dtype (f32 accumulate regardless of input dtype).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    gm, gk = m // tile, k // tile
    gn = n // (tile * block_n)
    assert kidx.shape == (gm, gn, gk), (kidx.shape, (gm, gn, gk))
    assert nvalid.shape == (gm, gn)

    grid = (gm, gn, gk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, t, kidx, nv: (i, kidx[i, j, t])),
            pl.BlockSpec(
                (tile, tile * block_n), lambda i, j, t, kidx, nv: (kidx[i, j, t], j)
            ),
        ],
        out_specs=pl.BlockSpec(
            (tile, tile * block_n), lambda i, j, t, kidx, nv: (i, j)
        ),
        scratch_shapes=[pltpu.VMEM((tile, tile * block_n), jnp.float32)],
    )
    return pl.pallas_call(
        _spamm_mm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spamm_mm",
    )(kidx, nvalid, a, b)


# step_flags bits (see repro.core.plan.compact_from_triples, which builds the
# tables): INIT zeroes the accumulator (first step of an (i, j) group), ACC
# performs the dot (every real step; bucket-padding steps have no bits set),
# FLUSH writes the accumulator to the output tile (last step of a group).
STEP_INIT, STEP_ACC, STEP_FLUSH = 1, 2, 4


def _spamm_mm_worklist_kernel(
    si_ref, sj_ref, sk_ref, fl_ref, zero_ref, a_ref, b_ref, o_ref, acc_ref
):
    del zero_ref  # only aliased into o_ref so unvisited tiles stay zero
    s = pl.program_id(0)
    f = fl_ref[s]

    @pl.when((f & STEP_INIT) != 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # paper Alg. 2 line 19, taken literally: every grid step IS a valid
    # product (bucket-padding steps revisit the last real blocks — free — and
    # carry no flag bits, so they neither accumulate nor flush).
    @pl.when((f & STEP_ACC) != 0)
    def _compute():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when((f & STEP_FLUSH) != 0)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile", "out_dtype", "interpret", "block_n"),
)
def spamm_mm_worklist(
    a: jax.Array,
    b: jax.Array,
    step_i: jax.Array,
    step_j: jax.Array,
    step_k: jax.Array,
    step_flags: jax.Array,
    *,
    tile: int = 64,
    block_n: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Ragged masked matmul: 1-D grid over the compacted work-list.

    a: (M, K); b: (K, N). step_i/step_j/step_k/step_flags: (S,) int32 tables,
    one entry per surviving (i, j, k) product in (i, j)-grouped ascending-k
    order, S = Σnvalid padded to a bucket (padding entries repeat the last
    real triple with flags 0). Built by `repro.core.plan.compact_from_triples`
    straight from the planner's surviving triples.

    `step_j` is a super-column id when block_n > 1 (each grid step computes a
    (tile, tile·block_n) output block). The grid has length S, NOT gm·gn·gk —
    pruned products cost nothing, and output tiles with no valid k stay zero
    via the aliased zero-initialized output. f32 accumulation in ascending-k
    order makes the result bit-identical to `spamm_mm` on the same mask.
    Returns C: (M, N) in out_dtype.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % tile == 0 and k % tile == 0 and n % (tile * block_n) == 0, (
        a.shape, b.shape, tile, block_n)
    s = step_i.shape[0]
    assert step_j.shape == step_k.shape == step_flags.shape == (s,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(s,),
        in_specs=[
            # zero output seed — same index map as the output so the aliased
            # HBM buffer is simply revisited
            pl.BlockSpec(
                (tile, tile * block_n),
                lambda s, si, sj, sk, fl: (si[s], sj[s]),
            ),
            pl.BlockSpec(
                (tile, tile), lambda s, si, sj, sk, fl: (si[s], sk[s])
            ),
            pl.BlockSpec(
                (tile, tile * block_n),
                lambda s, si, sj, sk, fl: (sk[s], sj[s]),
            ),
        ],
        out_specs=pl.BlockSpec(
            (tile, tile * block_n), lambda s, si, sj, sk, fl: (si[s], sj[s])
        ),
        scratch_shapes=[pltpu.VMEM((tile, tile * block_n), jnp.float32)],
    )
    return pl.pallas_call(
        _spamm_mm_worklist_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        # index 4 counts the scalar-prefetch tables: the zeros operand seeds
        # the output buffer, so (i, j) tiles the work-list never visits are
        # zero rather than uninitialized
        input_output_aliases={4: 0},
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="spamm_mm_worklist",
    )(step_i, step_j, step_k, step_flags,
      jnp.zeros((m, n), out_dtype), a, b)


def _spamm_mm_worklist_int8_kernel(
    si_ref, sj_ref, sk_ref, fl_ref, sa_ref, sb_ref,
    zero_ref, a_ref, b_ref, o_ref, acc_ref, *, block_n: int,
):
    del zero_ref  # only aliased into o_ref so unvisited tiles stay zero
    s = pl.program_id(0)
    f = fl_ref[s]

    @pl.when((f & STEP_INIT) != 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((f & STEP_ACC) != 0)
    def _compute():
        i, j, kk = si_ref[s], sj_ref[s], sk_ref[s]
        # int8 × int8 → int32 on the MXU (the tensor-core IMMA shape of
        # paper Alg. 3), then dequantize into the f32 accumulator
        prod = jax.lax.dot_general(
            a_ref[...], b_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32) * sa_ref[i, kk]
        t = acc_ref.shape[0]
        if block_n == 1:
            acc_ref[...] += prod * sb_ref[kk, j]
        else:
            # b scales are per FINE tile: static unroll over the block_n
            # column groups of the (tile, tile·block_n) super-column block
            sb = jnp.stack(
                [sb_ref[kk, j * block_n + c] for c in range(block_n)]
            )  # (block_n,)
            prod = prod.reshape(t, block_n, t) * sb[None, :, None]
            acc_ref[...] += prod.reshape(t, block_n * t)

    @pl.when((f & STEP_FLUSH) != 0)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile", "out_dtype", "interpret", "block_n"),
)
def spamm_mm_worklist_int8(
    a_q: jax.Array,
    b_q: jax.Array,
    a_scale: jax.Array,
    b_scale: jax.Array,
    step_i: jax.Array,
    step_j: jax.Array,
    step_k: jax.Array,
    step_flags: jax.Array,
    *,
    tile: int = 64,
    block_n: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Int8 ragged masked matmul: the work-list kernel at IMMA precision.

    a_q: (M, K) int8, b_q: (K, N) int8 — symmetric per-(tile × tile)-tile
    quantized (kernels/quantize.py). a_scale: (M//tile, K//tile) f32,
    b_scale: (K//tile, N//tile) f32 — note b_scale is per FINE tile even
    when block_n > 1 (the kernel unrolls the block_n column groups), so the
    gate's per-tile error bound holds at tile granularity. Step tables as in
    `spamm_mm_worklist`. Accumulation is f32 in VMEM (int32 MXU products ×
    scales), cast to out_dtype on FLUSH. C ≈ dequant(a_q) @ dequant(b_q)
    restricted to the work-list: each int32 tile product is EXACT (no f32
    rounding inside the tile dot, unlike running the f32 kernel on the
    dequantized operands), so the two differ only by f32 multiply/add
    rounding — within a few ulps of each other.
    """
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    assert m % tile == 0 and k % tile == 0 and n % (tile * block_n) == 0, (
        a_q.shape, b_q.shape, tile, block_n)
    gm, gk, gn = m // tile, k // tile, n // tile
    assert a_scale.shape == (gm, gk), (a_scale.shape, (gm, gk))
    assert b_scale.shape == (gk, gn), (b_scale.shape, (gk, gn))
    s = step_i.shape[0]
    assert step_j.shape == step_k.shape == step_flags.shape == (s,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(s,),
        in_specs=[
            pl.BlockSpec(
                (tile, tile * block_n),
                lambda s, si, sj, sk, fl, sa, sb: (si[s], sj[s]),
            ),
            pl.BlockSpec(
                (tile, tile), lambda s, si, sj, sk, fl, sa, sb: (si[s], sk[s])
            ),
            pl.BlockSpec(
                (tile, tile * block_n),
                lambda s, si, sj, sk, fl, sa, sb: (sk[s], sj[s]),
            ),
        ],
        out_specs=pl.BlockSpec(
            (tile, tile * block_n),
            lambda s, si, sj, sk, fl, sa, sb: (si[s], sj[s]),
        ),
        scratch_shapes=[pltpu.VMEM((tile, tile * block_n), jnp.float32)],
    )
    kernel = functools.partial(_spamm_mm_worklist_int8_kernel, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        # index 6 counts the 6 scalar-prefetch tables; the zeros operand
        # seeds the aliased output buffer (unvisited tiles stay zero)
        input_output_aliases={6: 0},
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="spamm_mm_worklist_int8",
    )(step_i, step_j, step_k, step_flags, a_scale, b_scale,
      jnp.zeros((m, n), out_dtype), a_q, b_q)
