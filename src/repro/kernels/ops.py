"""Backend registry + jit'd wrappers for the cuSpAMM kernels.

backends (each a `Backend` record in `BACKENDS`):
  "pallas"    — compiled Pallas TPU kernels (requires a real TPU).
  "interpret" — Pallas kernels executed with interpret=True (CPU-correctness
                path; runs the exact kernel body in Python/XLA emulation).
  "jnp"       — pure-jnp oracles from ref.py (used for the CPU dry-run and as
                the differentiable path inside models).
  "auto"      — "pallas" when a TPU is attached, else "jnp".

A `Backend` bundles the two kernel entry points the SpAMM pipeline needs:
`norms` (the §3.2 get-norm kernel) and `matmul` (the §3.3 multiplication
kernel, driven by a prebuilt `repro.core.plan.SpammPlan`'s mask/compaction).
Both `tile_norms` and the plan executor (`repro.core.plan.execute`) dispatch
through this one table — adding a backend means registering one record, not
editing every call site.

The mask/compaction/gating logic itself lives in exactly one place:
`repro.core.plan`. `spamm_matmul` below is a thin plan-then-execute
convenience wrapper kept for the one-shot (unplanned) call shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import getnorm as _getnorm
from repro.kernels import ref as _ref
from repro.kernels import spamm_mm as _spamm_mm


@functools.cache
def _has_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # no backend
        return False


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Backend:
    """One SpAMM execution backend.

    norms(x, tile, use_mxu)                        → (M//tile, K//tile) f32
    matmul(a, b, mask, kidx, nvalid, tile,
           block_n, out_dtype)                     → (M, N) out_dtype
      `mask` is (gm, gn//block_n, gk) bool; `kidx`/`nvalid` the compacted
      valid-k lists at the same granularity (None when needs_compaction is
      False — the executor then gates from `mask` directly).
    needs_compaction: whether `matmul` consumes kidx/nvalid (the Pallas
      kernels do; the jnp masked-einsum oracle does not, so planners skip
      the compaction sort for it).
    pyramid_norms(x, tile, levels, use_mxu)        → tuple of `levels + 1`
      normmaps, finest first — one get-norm pass + `levels` sqrt-sumsq
      pooling reductions (the norm pyramid of hierarchical gating). None
      ⇒ the planner falls back to norms() + the jnp pooling oracle, so
      third-party backends registered before this entry point keep working.
    matmul_worklist(a, b, work, tile, block_n,
                    out_dtype)                     → (M, N) out_dtype
      the ragged execution path: `work` is a `repro.core.plan.SpammWork`
      (flattened per-(i, j) work-list with padded per-step tables) and the
      grid is Σnvalid steps, not gm·gn·gk. None ⇒ the executor falls back
      to `matmul` with the dense mask/kidx, so third-party backends keep
      working unchanged. bf16 execution needs NO separate entry point: the
      executor passes bf16 operands straight into `matmul_worklist`/`matmul`
      (f32 accumulate is the kernels' contract regardless of input dtype).
    matmul_worklist_int8(a_q, b_q, a_scale, b_scale,
                         work, tile, block_n, out_dtype) → (M, N) out_dtype
      the int8 tensor-core path: per-tile-quantized int8 operands + f32
      scale tables (kernels/quantize.py), int8×int8→int32 MXU dots
      dequantized into the f32 accumulator. None ⇒ the executor widens to
      f32 (dequantizes and takes the normal path), so `jnp`/third-party
      backends keep working at identical numerics-of-record.
    norms_quant(x, tile, use_mxu) → (norms, scales), both (M//tile, K//tile)
      f32 — the fused int8 absmax/scale + get-norm kernel: norms of the
      QUANTIZED view plus the per-tile quantization scales from one read.
      None ⇒ `int8_norms_and_scales` composes the unfused
      quantize→dequantize→norms path (bit-identical results either way).
    """
    name: str
    norms: Callable[..., jax.Array]
    matmul: Callable[..., jax.Array]
    needs_compaction: bool = True
    pyramid_norms: Callable[..., tuple] = None
    matmul_worklist: Callable[..., jax.Array] = None
    matmul_worklist_int8: Callable[..., jax.Array] = None
    norms_quant: Callable[..., tuple] = None


def _jnp_norms(x, tile, use_mxu=False):
    del use_mxu  # the einsum oracle has no MXU path
    return _ref.tile_norms_ref(x, tile)


def _jnp_matmul(a, b, mask, kidx, nvalid, tile, block_n, out_dtype):
    del kidx, nvalid
    m, k = a.shape
    _, n = b.shape
    gm, gk, gn = m // tile, k // tile, n // tile
    mask_full = jnp.repeat(mask, block_n, axis=1) if block_n > 1 else mask
    a4 = a.reshape(gm, tile, gk, tile)
    b4 = b.reshape(gk, tile, gn, tile)
    out = jnp.einsum(
        "ijk,ipks,ksjq->ipjq",
        mask_full.astype(jnp.float32).astype(a.dtype),
        a4,
        b4,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(m, n).astype(out_dtype)


def _pallas_norms(interpret):
    def norms(x, tile, use_mxu=False):
        return _getnorm.tile_norms(x, tile, use_mxu=use_mxu, interpret=interpret)

    return norms


def _pallas_pyramid_norms(interpret):
    def pyramid(x, tile, levels, use_mxu=False):
        return _getnorm.norm_pyramid(
            x, tile, levels, use_mxu=use_mxu, interpret=interpret
        )

    return pyramid


def _pallas_matmul(interpret):
    def matmul(a, b, mask, kidx, nvalid, tile, block_n, out_dtype):
        del mask
        return _spamm_mm.spamm_mm(
            a, b, kidx, nvalid,
            tile=tile, block_n=block_n, out_dtype=out_dtype,
            interpret=interpret,
        )

    return matmul


def _pallas_matmul_worklist(interpret):
    def matmul_worklist(a, b, work, tile, block_n, out_dtype):
        return _spamm_mm.spamm_mm_worklist(
            a, b, work.step_i, work.step_j, work.step_k, work.step_flags,
            tile=tile, block_n=block_n, out_dtype=out_dtype,
            interpret=interpret,
        )

    return matmul_worklist


def _pallas_norms_quant(interpret):
    def norms_quant(x, tile, use_mxu=False):
        return _getnorm.tile_norms_quant(
            x, tile, use_mxu=use_mxu, interpret=interpret)

    return norms_quant


def _pallas_matmul_worklist_int8(interpret):
    def matmul_worklist_int8(a_q, b_q, a_scale, b_scale, work, tile, block_n,
                             out_dtype):
        return _spamm_mm.spamm_mm_worklist_int8(
            a_q, b_q, a_scale, b_scale,
            work.step_i, work.step_j, work.step_k, work.step_flags,
            tile=tile, block_n=block_n, out_dtype=out_dtype,
            interpret=interpret,
        )

    return matmul_worklist_int8


BACKENDS = {
    # jnp leaves pyramid_norms unset: the norms() + pool_norms_ref fallback
    # in pyramid_norms() below IS the jnp implementation (one copy to
    # maintain); the Pallas backends register the pooling kernel. It also
    # leaves matmul_worklist unset — the masked einsum already only pays for
    # a dense einsum, and the executor's None-fallback IS the jnp path.
    "jnp": Backend("jnp", _jnp_norms, _jnp_matmul, needs_compaction=False),
    "interpret": Backend("interpret", _pallas_norms(True), _pallas_matmul(True),
                         pyramid_norms=_pallas_pyramid_norms(True),
                         matmul_worklist=_pallas_matmul_worklist(True),
                         matmul_worklist_int8=_pallas_matmul_worklist_int8(True),
                         norms_quant=_pallas_norms_quant(True)),
    "pallas": Backend("pallas", _pallas_norms(False), _pallas_matmul(False),
                      pyramid_norms=_pallas_pyramid_norms(False),
                      matmul_worklist=_pallas_matmul_worklist(False),
                      matmul_worklist_int8=_pallas_matmul_worklist_int8(False),
                      norms_quant=_pallas_norms_quant(False)),
}

VALID_BACKENDS = ("auto", *BACKENDS)


def register_backend(backend: Backend):
    """Extension hook: make a new backend visible to the whole pipeline."""
    BACKENDS[backend.name] = backend


def get_backend(backend: str) -> Backend:
    """Resolve a backend name ("auto" included) to its registry record."""
    if backend == "auto":
        backend = "pallas" if _has_tpu() else "jnp"
    try:
        return BACKENDS[backend]
    except KeyError:
        raise ValueError(f"backend {backend!r} not in {VALID_BACKENDS}") from None


def resolve_backend(backend: str) -> str:
    """Canonical backend name (kept for callers that key on the string)."""
    return get_backend(backend).name


# ---------------------------------------------------------------------------
# kernel wrappers
# ---------------------------------------------------------------------------

def tile_norms(
    x: jax.Array, tile: int = 64, *, backend: str = "auto", use_mxu: bool = False
) -> jax.Array:
    """normmap of x — paper get-norm kernel (§3.2), registry-dispatched."""
    return get_backend(backend).norms(x, tile, use_mxu=use_mxu)


def pyramid_norms(
    x: jax.Array,
    tile: int = 64,
    levels: int = 1,
    *,
    backend: str = "auto",
    use_mxu: bool = False,
) -> tuple:
    """Norm pyramid of x: `levels + 1` normmaps, finest (tile) first, each
    coarser level a sqrt-sumsq 2×2 pooling of the previous (so level l is the
    exact normmap at tile·2^l). Registry-dispatched; backends without a
    pyramid entry point fall back to norms() + the jnp pooling oracle."""
    bk = get_backend(backend)
    if bk.pyramid_norms is not None:
        return bk.pyramid_norms(x, tile, levels, use_mxu=use_mxu)
    maps = [bk.norms(x, tile, use_mxu=use_mxu)]
    for _ in range(levels):
        maps.append(_ref.pool_norms_ref(maps[-1]))
    return tuple(maps)


def int8_norms_and_scales(
    x: jax.Array, tile: int = 64, *, backend: str = "auto",
    use_mxu: bool = False
):
    """(norms, scales) of the int8-quantized view of x — THE entry point
    every int8 planner goes through. Backends with the fused kernel
    (`norms_quant`) pay ONE read of x; others compose the unfused
    quantize → dequantize → norms path. Results are bit-identical either
    way (the int8 codes are exactly representable in f32 and both paths
    share the reduction body), which is what keeps frozen ≡ eager parity
    independent of which backend planned."""
    bk = get_backend(backend)
    if bk.norms_quant is not None:
        return bk.norms_quant(x, tile, use_mxu=use_mxu)
    from repro.kernels import quantize as _quant  # local: keep import light

    q, s = _quant.quantize_tiles(x, tile)
    dq = _quant.dequantize_tiles(q, s, tile)
    return bk.norms(dq, tile, use_mxu=use_mxu), s


def spamm_compact(mask: jax.Array):
    """Compacted valid-k lists from a bitmap — paper map_offset (§3.3)."""
    return _ref.spamm_compact_ref(mask)


def spamm_matmul(
    a: jax.Array,
    b: jax.Array,
    tau,
    *,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    out_dtype=None,
):
    """One-shot SpAMM: `plan` + `execute` fused (see repro.core.plan).

    Shapes (M, K) @ (K, N) with all dims divisible by tile (and N by
    tile*block_n). Use repro.core.spamm.spamm for auto-padding + extras; use
    repro.core.plan.plan/execute directly to amortize the gating phase over
    repeated products with the same operands (serving hot path).
    Returns (C, info) where info carries the normmaps, nvalid and the
    executed-tile fraction (== the paper's valid ratio for this product).
    """
    from repro.core import plan as _plan  # circular-safe (plan imports ops)

    p = _plan.plan(
        a, b, tau,
        tile=tile, block_n=block_n, backend=backend, use_mxu_norm=use_mxu_norm,
    )
    c = _plan.execute(p, a, b, out_dtype=out_dtype)
    return c, p.info()


def spamm_effective_flops(m: int, k: int, n: int, valid_fraction) -> jax.Array:
    """FLOPs actually executed by SpAMM = valid_fraction × dense 2·M·K·N."""
    return valid_fraction * (2.0 * m * k * n)
