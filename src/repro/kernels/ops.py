"""jit'd wrappers for the cuSpAMM kernels with backend dispatch.

backends:
  "pallas"    — compiled Pallas TPU kernels (requires a real TPU).
  "interpret" — Pallas kernels executed with interpret=True (CPU-correctness
                path; runs the exact kernel body in Python/XLA emulation).
  "jnp"       — pure-jnp oracles from ref.py (used for the CPU dry-run and as
                the differentiable path inside models).
  "auto"      — "pallas" when a TPU is attached, else "jnp".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import getnorm as _getnorm
from repro.kernels import ref as _ref
from repro.kernels import spamm_mm as _spamm_mm

VALID_BACKENDS = ("auto", "pallas", "interpret", "jnp")


@functools.cache
def _has_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # no backend
        return False


def resolve_backend(backend: str) -> str:
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend {backend!r} not in {VALID_BACKENDS}")
    if backend == "auto":
        return "pallas" if _has_tpu() else "jnp"
    return backend


def tile_norms(
    x: jax.Array, tile: int = 64, *, backend: str = "auto", use_mxu: bool = False
) -> jax.Array:
    """normmap of x — paper get-norm kernel (§3.2)."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.tile_norms_ref(x, tile)
    return _getnorm.tile_norms(
        x, tile, use_mxu=use_mxu, interpret=(backend == "interpret")
    )


def spamm_compact(mask: jax.Array):
    """Compacted valid-k lists from a bitmap — paper map_offset (§3.3)."""
    return _ref.spamm_compact_ref(mask)


def spamm_matmul(
    a: jax.Array,
    b: jax.Array,
    tau,
    *,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    out_dtype=None,
):
    """End-to-end SpAMM: get-norm → mask/compact → multiplication kernel.

    Shapes (M, K) @ (K, N) with all dims divisible by tile (and N by
    tile*block_n). Use repro.core.spamm.spamm for auto-padding + extras.
    Returns (C, info) where info carries the normmaps, nvalid and the
    executed-tile fraction (== the paper's valid ratio for this product).
    """
    backend = resolve_backend(backend)
    m, k = a.shape
    _, n = b.shape
    gm, gk, gn = m // tile, k // tile, n // tile
    na = tile_norms(a, tile, backend=backend, use_mxu=use_mxu_norm)
    nb = tile_norms(b, tile, backend=backend, use_mxu=use_mxu_norm)
    tau = jnp.asarray(tau, jnp.float32)

    if block_n > 1:
        # group gn into gn//block_n super-columns; a super-column is valid for
        # k if ANY of its member columns is (superset mask keeps exactness).
        assert gn % block_n == 0, (gn, block_n)
        nb_g = nb.reshape(gk, gn // block_n, block_n)
        mask_fine = na[:, None, :, None] * jnp.swapaxes(nb_g, 0, 1)[None] >= tau
        mask = jnp.any(mask_fine, axis=-1)  # (gm, gn//block_n, gk)
    else:
        mask = _ref.spamm_mask_ref(na, nb, tau)

    nvalid_total = jnp.sum(mask, dtype=jnp.int32)
    info = {
        "norm_a": na,
        "norm_b": nb,
        "valid_tiles": nvalid_total,
        "total_tiles": mask.shape[0] * mask.shape[1] * mask.shape[2],
        "valid_fraction": nvalid_total / (mask.shape[0] * mask.shape[1] * mask.shape[2]),
    }

    out_dtype = out_dtype or jnp.float32
    if backend == "jnp":
        if block_n > 1:
            mask_full = jnp.repeat(mask, block_n, axis=1)
        else:
            mask_full = mask
        a4 = a.reshape(gm, tile, gk, tile)
        b4 = b.reshape(gk, tile, gn, tile)
        out = jnp.einsum(
            "ijk,ipks,ksjq->ipjq",
            mask_full.astype(jnp.float32).astype(a.dtype),
            a4,
            b4,
            preferred_element_type=jnp.float32,
        )
        c = out.reshape(m, n).astype(out_dtype)
    else:
        kidx, nvalid = _ref.spamm_compact_ref(mask)
        c = _spamm_mm.spamm_mm(
            a,
            b,
            kidx,
            nvalid,
            tile=tile,
            block_n=block_n,
            out_dtype=out_dtype,
            interpret=(backend == "interpret"),
        )
    return c, info


def spamm_effective_flops(m: int, k: int, n: int, valid_fraction) -> jax.Array:
    """FLOPs actually executed by SpAMM = valid_fraction × dense 2·M·K·N."""
    return valid_fraction * (2.0 * m * k * n)
