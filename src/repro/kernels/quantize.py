"""Per-tile quantization + quantization-aware gate widening for the
mixed-precision worklist kernels (paper Alg. 3 generalized to bf16/int8).

The SpAMM gate decides from *norms of what the kernel will actually
multiply*. When the kernel consumes low-precision operands, two things must
stay consistent:

  1. the norm pyramid is computed (in f32, once, at plan/freeze time) from
     the quantize-dequantized operand view — the exact values the MXU sees —
     so `valid_fraction`, τ-search and load-balance estimates describe the
     executed product, not a phantom f32 one;
  2. the threshold is *widened* (lowered) by the analytic per-tile
     quantization error bound, so the low-precision gate is provably
     conservative: it never drops a tile the f32 gate keeps (the superset
     property pinned by tests/test_spamm_properties.py).

Quantization scheme (int8): symmetric per-(tile × tile_n)-tile scaling,
    scale = max(amax, tiny) / 127,   q = clip(round(x / scale), -127, 127)
so dequantized values are `q * scale` with |error| ≤ scale/2 elementwise and
quantize→dequantize→quantize is idempotent (amax maps to ±127 exactly).
Scales are f32 and ride along as (grid_m, grid_n) tables — the kernel's
scalar-prefetch operands and the `FrozenWeight` artifact's `b_scale` child.

Gate-widening math. With Q(x) the dtype's rounded view of a tile x,
‖Q(x)‖_F ≥ (1 − eps)·‖x‖_F where eps bounds the relative Frobenius error:

  float32:  eps = 0             (identity)
  bfloat16: eps = 2⁻⁸           (unit roundoff, 1+7 significand bits:
                                 elementwise |Q(x)−x| ≤ 2⁻⁸·|x|)
  int8:     eps = √(t·tn)/254   (t·tn tile elements, each off by ≤ scale/2 =
                                 amax/254, so ‖Q(x)−x‖_F ≤ √(t·tn)·amax/254,
                                 and amax ≤ ‖x‖_F; capped at 1)

so if the f32 gate keeps (i, j, k): na·nb ≥ τ, then the quantized norms obey
na_q·nb_q ≥ (1−eps_a)(1−eps_b)·na·nb ≥ τ·(1−eps_a)(1−eps_b) = τ' — gating
the quantized norms at the widened τ' keeps every f32-surviving tile.
τ ≤ 0 keeps *everything* at any precision and is left unwidened (the
multiplicative form would move a negative τ the wrong way).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# canonical dtype names accepted across the pipeline (configs, CLIs, store
# keys); everything resolves through canonical_dtype() before use
_DTYPE_ALIASES = {
    "float32": "float32", "f32": "float32", "fp32": "float32",
    "bfloat16": "bfloat16", "bf16": "bfloat16",
    "int8": "int8", "i8": "int8",
}
COMPUTE_DTYPES = ("float32", "bfloat16", "int8")

# tiny amax floor so all-zero tiles get a harmless nonzero scale instead of
# a divide-by-zero (their q is all zeros either way)
_TINY = 1e-30

# scale = max(amax, tiny) · (1/127) as a multiply by THIS f32 constant, not
# a division by 127: XLA lowers a constant division differently inside a
# compiled (Pallas) kernel body than in eager mode (reciprocal fast-math,
# 1 ulp apart), and the fused getnorm+absmax kernel must produce scales
# bit-identical to this host-side function
_INV127 = float(np.float32(1.0) / np.float32(127.0))


def canonical_dtype(dtype) -> str:
    """Resolve a user-facing dtype spec to one of COMPUTE_DTYPES."""
    if dtype is None:
        return "float32"
    name = dtype if isinstance(dtype, str) else jnp.dtype(dtype).name
    try:
        return _DTYPE_ALIASES[name.lower()]
    except KeyError:
        raise ValueError(
            f"compute dtype {dtype!r} not one of {sorted(set(_DTYPE_ALIASES))}"
        ) from None


def dtype_itemsize(dtype) -> int:
    """Bytes per element moved by the GEMM inputs at this compute dtype."""
    return {"float32": 4, "bfloat16": 2, "int8": 1}[canonical_dtype(dtype)]


# ---------------------------------------------------------------------------
# int8 per-tile quantization
# ---------------------------------------------------------------------------

def tile_absmax(x: jax.Array, tile: int, tile_n: int | None = None) -> jax.Array:
    """Per-(tile × tile_n)-tile max|x|: (M//tile, N//tile_n) f32."""
    tile_n = tile if tile_n is None else tile_n
    m, n = x.shape
    gm, gn = m // tile, n // tile_n
    x4 = jnp.abs(x.astype(jnp.float32)).reshape(gm, tile, gn, tile_n)
    return jnp.max(x4, axis=(1, 3))


def quantize_tiles(
    x: jax.Array,
    tile: int,
    tile_n: int | None = None,
    *,
    scales: jax.Array | None = None,
):
    """Symmetric per-tile int8 quantization of a 2-D operand.

    x: (M, N), M % tile == 0 == N % tile_n. Returns (q, scales) with q (M, N)
    int8 and scales (M//tile, N//tile_n) f32. Pass precomputed `scales`
    (e.g. from a `FrozenWeight`) to reuse them; quantization is a pure
    function of (x, scales), so recomputing gives bit-identical results.
    """
    tile_n = tile if tile_n is None else tile_n
    m, n = x.shape
    gm, gn = m // tile, n // tile_n
    if scales is None:
        scales = (jnp.maximum(tile_absmax(x, tile, tile_n), _TINY)
                  * jnp.float32(_INV127))
    x4 = x.astype(jnp.float32).reshape(gm, tile, gn, tile_n)
    q = jnp.clip(
        jnp.round(x4 / scales[:, None, :, None]), -127.0, 127.0
    ).astype(jnp.int8)
    return q.reshape(m, n), scales


def dequantize_tiles(
    q: jax.Array, scales: jax.Array, tile: int, tile_n: int | None = None
) -> jax.Array:
    """Inverse of quantize_tiles: (M, N) f32 from int8 codes + tile scales."""
    tile_n = tile if tile_n is None else tile_n
    m, n = q.shape
    gm, gn = m // tile, n // tile_n
    q4 = q.astype(jnp.float32).reshape(gm, tile, gn, tile_n)
    return (q4 * scales[:, None, :, None]).reshape(m, n)


def quantized_view(
    x: jax.Array,
    dtype,
    tile: int,
    tile_n: int | None = None,
    *,
    scales: jax.Array | None = None,
) -> jax.Array:
    """The f32 view of what the kernel will actually multiply at `dtype`:
    identity for float32, round-trip through bf16 / per-tile int8 otherwise.
    Norm pyramids for low-precision gating are computed from THIS (in f32),
    so the gate reasons about the executed values."""
    dtype = canonical_dtype(dtype)
    if dtype == "float32":
        return x
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    q, s = quantize_tiles(x, tile, tile_n, scales=scales)
    return dequantize_tiles(q, s, tile, tile_n)


# ---------------------------------------------------------------------------
# quantization-aware gate widening
# ---------------------------------------------------------------------------

def gate_eps(dtype, tile: int, tile_n: int | None = None) -> float:
    """Relative per-tile Frobenius-norm quantization error bound eps such
    that ‖Q(x)‖_F ≥ (1 − eps)·‖x‖_F (see module docstring)."""
    dtype = canonical_dtype(dtype)
    if dtype == "float32":
        return 0.0
    if dtype == "bfloat16":
        return 2.0 ** -8
    tile_n = tile if tile_n is None else tile_n
    # ‖Q(x)−x‖_F ≤ √(t·tn)·scale/2 = √(t·tn)·amax/254 ≤ √(t·tn)·‖x‖_F/254
    return min(1.0, math.sqrt(tile * tile_n) / 254.0)


def widen_tau(tau, dtype, tile: int, tile_n: int | None = None):
    """τ' = τ·(1−eps_a)(1−eps_b) for τ > 0 (τ ≤ 0 gates nothing out at any
    precision and is left alone). Gating quantized norms at τ' provably keeps
    every tile the f32 gate at τ keeps. Both operands are assumed quantized
    at the same dtype; float32 returns τ unchanged."""
    e = gate_eps(dtype, tile, tile_n)
    if e == 0.0:
        return tau
    factor = (1.0 - e) ** 2
    if isinstance(tau, jax.core.Tracer):
        return jnp.where(tau > 0, tau * factor, tau)
    t = float(np.asarray(tau))
    return t * factor if t > 0 else t
