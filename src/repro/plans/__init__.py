"""Frozen-plan runtime + artifact store (the offline half of SpAMM serving).

`frozen.py`    — FrozenWeight / FrozenPlan: weight-side gating artifacts
                 frozen into pytrees that compiled prefill/decode take as
                 jit *arguments* (no get-norm, no dense-bitmap sort in the
                 traced graph).
`store.py`     — PlanStore: content-addressed on-disk store of FrozenWeight
                 artifacts (npz + versioned json manifest).
`precompute.py`— walk a model's gated GEMM weights and populate the store
                 offline (driven by `repro.launch.precompute_plans`).
"""
from repro.plans.frozen import (FrozenPlan, FrozenWeight, PLAN_FORMAT_VERSION,
                                freeze_weight, stack_plans)
from repro.plans.store import PlanStore, PlanStoreError, fingerprint
from repro.plans.precompute import freeze_tree, iter_gated_weights, populate

__all__ = [
    "FrozenPlan", "FrozenWeight", "PLAN_FORMAT_VERSION", "freeze_weight",
    "stack_plans", "PlanStore", "PlanStoreError", "fingerprint",
    "freeze_tree", "iter_gated_weights", "populate",
]
