"""Offline plan precomputation: walk a model's gated GEMM weights and
freeze/store their weight-side plans.

The model zoo gates exactly the GEMMs that go through
`core.module.maybe_spamm_matmul`: the attention projections (wq/wk/wv/wo
under a layer's "mix" subtree) and the MLP matmuls (w1/w3/w2 under "mlp").
MoE expert/shared FFNs are gated too but run inside shard_map with
per-token buffers; they keep the traced gating path and are not frozen
(documented engine limitation — their GEMMs simply fall back).

`freeze_tree` mirrors the params structure at those leaves: a stacked
(L, K, N) leaf becomes a list of per-layer `FrozenWeight`s (what
`stack_plans` later turns into scan inputs), a 2-D leaf a single one.
`populate` is the CLI-facing store writer (`repro.launch.precompute_plans`).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.plans.frozen import FrozenWeight
from repro.plans.store import PlanStore, fingerprint

# leaf name × parent subtree that identifies a gated GEMM weight
GATED_NAMES = ("wq", "wk", "wv", "wo", "w1", "w2", "w3")
GATED_PARENTS = ("mix", "mlp")


def iter_gated_weights(params, _prefix=()):
    """Yield (path_tuple, leaf) for every gated GEMM weight in a params
    pytree: leaves named wq/wk/wv/wo/w1/w2/w3 directly under a "mix" or
    "mlp" subtree. Stacked leaves (leading layer/group dim) are yielded
    whole; callers slice axis 0 per layer."""
    if not isinstance(params, dict):
        return
    for name, sub in params.items():
        path = _prefix + (name,)
        if isinstance(sub, dict):
            yield from iter_gated_weights(sub, path)
        elif (len(path) >= 2 and path[-2] in GATED_PARENTS
              and name in GATED_NAMES and getattr(sub, "ndim", 0) >= 2):
            yield path, sub


def tune_for(w, scfg, *, profile=None, use_mxu: bool = False):
    """Autotune one weight's blocking parameters against the roofline cost
    model: argmin of predicted frozen-call time over block_n × levels ×
    bucket floor, with the config's own (block_n, levels, 16) always in the
    search space (the tuned pick is never predicted slower). `profile` is a
    loaded `core.cost.CostProfile`; None loads `scfg.tune_profile` (or the
    nominal per-backend coefficients)."""
    from repro.core import cost  # deferred: precompute imports stay light

    if profile is None:
        profile = cost.CostProfile.load_or_default(
            getattr(scfg, "tune_profile", None))
    return cost.tune_weight(
        w, scfg.tau, tile=scfg.tile,
        dtype=getattr(scfg, "dtype", "float32"), backend=scfg.backend,
        profile=profile,
        defaults=(scfg.block_n, getattr(scfg, "levels", 0), 16),
        use_mxu=use_mxu)


def _freeze_one(w, scfg, *, cache=None, store: Optional[PlanStore] = None,
                use_mxu: bool = False, tuned=None,
                profile=None) -> FrozenWeight:
    """One weight → FrozenWeight, through the cache/store tiers when given.

    With `scfg.autotune` the artifact is frozen at the TUNED block_n/levels
    (which address it in the store) and carries the `TunedParams` record;
    pass `tuned` explicitly to reuse one tuning across stacked layer slices
    (stacked plans must share static metadata — see `stack_plans`)."""
    if tuned is None and getattr(scfg, "autotune", False):
        tuned = tune_for(w, scfg, profile=profile, use_mxu=use_mxu)
    block_n = tuned.block_n if tuned is not None else scfg.block_n
    levels = (tuned.levels if tuned is not None
              else getattr(scfg, "levels", 0))
    kw = dict(tau=scfg.tau, tile=scfg.tile, block_n=block_n, levels=levels,
              backend=scfg.backend)
    dtype = getattr(scfg, "dtype", "float32")
    if cache is not None:
        return cache.frozen_weight(w, use_mxu=use_mxu, store=store,
                                   dtype=dtype, tuned=tuned, **kw)
    h = fingerprint(w)
    if store is not None:
        # may raise PlanStoreError on stale artifacts
        fw = store.get(h, use_mxu=use_mxu, dtype=dtype, **kw)
        if fw is not None:
            return fw
    fw = FrozenWeight.build(w, use_mxu=use_mxu, weight_hash=h,
                            compute_dtype=dtype, tuned=tuned, **kw)
    if store is not None:
        store.put(fw)
    return fw


def freeze_tree(params, scfg, *, cache=None, store: Optional[PlanStore] = None,
                use_mxu: bool = False):
    """Freeze every gated weight of a params pytree.

    Returns (tree, count): `tree` mirrors the params dict structure at the
    gated leaves, each leaf a `FrozenWeight` (2-D weight) or a list of
    per-layer `FrozenWeight`s (stacked weight); `count` is the number of
    distinct weight matrices frozen. `cache` (a `WeightPlanCache`) is the
    in-memory tier; `store` the persistent one — with a warm store this
    whole walk is load-only, no get-norm pass.

    With `scfg.autotune`, each 2-D weight is tuned individually; a stacked
    leaf is tuned ONCE (from its first slice) and every layer slice is
    frozen at that shared config — stacked per-layer plans must agree on
    block_n/levels/bucket to ride one lax.scan (`stack_plans`)."""
    autotune = getattr(scfg, "autotune", False)
    profile = None
    if autotune:
        from repro.core import cost  # deferred: precompute imports stay light

        profile = cost.CostProfile.load_or_default(
            getattr(scfg, "tune_profile", None))
    count = 0
    tree: dict = {}
    for path, leaf in iter_gated_weights(params):
        if leaf.ndim == 2:
            fz = _freeze_one(leaf, scfg, cache=cache, store=store,
                             use_mxu=use_mxu, profile=profile)
            count += 1
        else:
            # stacked (L, K, N): freeze per layer slice (flattening extra
            # leading dims first keeps hybrid group stacks uniform)
            flat = np.asarray(leaf).reshape(-1, *leaf.shape[-2:])
            tuned = (tune_for(flat[0], scfg, profile=profile,
                              use_mxu=use_mxu) if autotune else None)
            fz = [
                _freeze_one(flat[l], scfg, cache=cache, store=store,
                            use_mxu=use_mxu, tuned=tuned)
                for l in range(flat.shape[0])
            ]
            count += flat.shape[0]
        node = tree
        for name in path[:-1]:
            node = node.setdefault(name, {})
        node[path[-1]] = fz
    return tree, count


def populate(store: PlanStore, params, scfg, *, cache=None,
             use_mxu: bool = False) -> int:
    """Populate `store` with frozen plans for every gated GEMM weight of
    `params` under SpAMM config `scfg`. Returns the number of weights
    processed (store hits + fresh builds)."""
    _, count = freeze_tree(params, scfg, cache=cache, store=store,
                           use_mxu=use_mxu)
    return count
