"""PlanStore: content-addressed on-disk store of FrozenWeight artifacts.

Layout:  <root>/<key>/manifest.json + arrays.npz   (tmp-dir + os.rename,
the checkpoint module's atomicity idiom — a crashed put can never be
mistaken for a complete artifact).

The key is a content address: sha256 over the weight fingerprint AND the
full gating config echo (τ, tile, block_n, levels, resolved backend,
compute dtype, format version). Changing the weight or ANY config field
therefore changes the key — a stale artifact is a clean miss, never a
silent wrong-plan hit. Loads additionally re-validate the manifest: a
format-version mismatch or a backend that is not in the running registry
raises `PlanStoreError` instead of handing compiled serving a plan the
executor cannot honor. A root-level STORE_FORMAT.json marker guards the
whole store: opening a root whose artifacts predate the current format
(e.g. a pre-dtype-keying v1 store, which has no marker) refuses with
`PlanStoreError` instead of reading as all-misses.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cost import TunedParams
from repro.kernels import ops as kops
from repro.kernels import quantize as kquant
from repro.plans.frozen import FrozenWeight, PLAN_FORMAT_VERSION

# Root-level format marker. Keys embed the format version, so artifacts
# written under an older format hash to DIFFERENT keys — without the marker
# a stale (pre-dtype-keying) store would read as all-misses and silently
# trigger a full re-freeze into the same root. The marker makes staleness an
# explicit refusal at open time instead.
_MARKER = "STORE_FORMAT.json"


class PlanStoreError(RuntimeError):
    """An on-disk plan artifact is incompatible with the running code."""


def fingerprint(w) -> str:
    """Content fingerprint of a weight matrix: sha256 over dtype, shape and
    raw bytes (host transfer happens once per weight, offline)."""
    a = np.asarray(w)
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def _config_echo(tau, tile, block_n, levels, backend, use_mxu, dtype) -> dict:
    return {
        # canonicalize through f32: artifacts carry τ as float32, queries
        # often pass the python double — both must address the same key
        "tau": float(np.float32(tau)),
        "tile": int(tile),
        "block_n": int(block_n),
        "levels": int(levels),
        "backend": kops.resolve_backend(backend),
        # the get-norm variant changes the stored normmaps' rounding, so it
        # is part of the content address like every other gate-shaping field
        "use_mxu": bool(use_mxu),
        # the compute dtype changes the stored normmaps (quantized view),
        # the baked gate τ and the scale tables — a first-class key field
        "dtype": kquant.canonical_dtype(dtype),
    }


class PlanStore:
    """Content-addressed FrozenWeight artifacts on disk.

    `get`/`put` address by (weight fingerprint × config echo); `hits`/
    `misses` expose warm-start effectiveness (the acceptance contract:
    misses only while first populating). A `WeightPlanCache` with its
    `store` attribute set uses this as the persistent tier below its
    in-memory map (see `WeightPlanCache.frozen_weight`).
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._check_format()
        self.hits = 0
        self.misses = 0

    def _check_format(self):
        """Refuse stores written under an older format at OPEN time.

        Version is part of each key, so v1 artifacts would never be *hit* —
        they'd read as clean misses and a warm start would silently refreeze
        everything next to the stale dirs. A store root that already holds
        artifacts but no (or a mismatched) marker is therefore an error, not
        a miss; fresh roots get the current marker written."""
        mpath = os.path.join(self.root, _MARKER)
        if os.path.isfile(mpath):
            with open(mpath) as f:
                fmt = json.load(f).get("format_version")
            if fmt != PLAN_FORMAT_VERSION:
                raise PlanStoreError(
                    f"plan store at {self.root!r} was written with format "
                    f"version {fmt!r}; this build reads version "
                    f"{PLAN_FORMAT_VERSION} — re-run precompute_plans into "
                    "a fresh root")
            return
        if self.keys():
            # artifact dirs but no marker: a pre-dtype-keying (format v1)
            # store — refuse rather than silently miss on every load
            raise PlanStoreError(
                f"plan store at {self.root!r} predates compute-dtype keying "
                f"(format version < {PLAN_FORMAT_VERSION}: no {_MARKER}) — "
                "re-run precompute_plans into a fresh root")
        with open(mpath, "w") as f:
            json.dump({"format_version": PLAN_FORMAT_VERSION}, f)

    # -- addressing ---------------------------------------------------------
    @staticmethod
    def key_for(weight_hash: str, *, tau, tile: int, block_n: int,
                levels: int, backend: str, use_mxu: bool = False,
                dtype: str = "float32") -> str:
        echo = _config_echo(tau, tile, block_n, levels, backend, use_mxu,
                            dtype)
        blob = json.dumps({"weight": weight_hash, "cfg": echo,
                           "version": PLAN_FORMAT_VERSION}, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:32]

    def _dir(self, key: str) -> str:
        return os.path.join(self.root, key)

    def keys(self):
        if not os.path.isdir(self.root):
            return []
        return sorted(
            d for d in os.listdir(self.root)
            if not d.startswith(".")  # .tmp_* = crashed/in-progress puts
            and os.path.isfile(os.path.join(self.root, d, "manifest.json"))
        )

    def __len__(self) -> int:
        return len(self.keys())

    def contains(self, weight_hash: str, **cfg) -> bool:
        return os.path.isfile(
            os.path.join(self._dir(self.key_for(weight_hash, **cfg)),
                         "manifest.json"))

    # -- put / get ----------------------------------------------------------
    def put(self, fw: FrozenWeight) -> str:
        """Persist one artifact; returns its key. Atomic (tmp + rename)."""
        assert fw.weight_hash, "FrozenWeight needs a weight_hash to be stored"
        key = self.key_for(fw.weight_hash, **fw.config_key())
        final = self._dir(key)
        tmp = os.path.join(self.root, f".tmp_{key}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {
            "nbmax": np.asarray(fw.nbmax),
            "kj_k": np.asarray(fw.kj_k),
            "kj_j": np.asarray(fw.kj_j),
        }
        if fw.b_scale is not None:
            arrays["b_scale"] = np.asarray(fw.b_scale)
        for l, lv in enumerate(fw.levels):
            arrays[f"level_{l}"] = np.asarray(lv)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "format_version": fw.version,
            "weight_hash": fw.weight_hash,
            **fw.config_key(),
            "num_pyramid_levels": len(fw.levels),
            "wshape": list(fw.wshape),
            "padded": list(fw.padded),
            "arrays": sorted(arrays),
        }
        if fw.tuned is not None:
            # additive payload, deliberately NOT part of the key and NOT a
            # format bump: tuned block_n/levels already address the artifact
            # through the config echo; this records provenance + the bucket
            # floor, and legacy manifests without it load as tuned=None
            manifest["tuned"] = fw.tuned.as_manifest()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return key

    def get(self, weight_hash: str, *, tau, tile: int, block_n: int,
            levels: int, backend: str, use_mxu: bool = False,
            dtype: str = "float32") -> Optional[FrozenWeight]:
        """Load an artifact, or None on miss. Raises `PlanStoreError` when
        an artifact exists but its manifest does not match the running code
        (format version / backend registry) — never silently executes a
        wrong or unexecutable plan."""
        key = self.key_for(weight_hash, tau=tau, tile=tile, block_n=block_n,
                           levels=levels, backend=backend, use_mxu=use_mxu,
                           dtype=dtype)
        path = self._dir(key)
        mpath = os.path.join(path, "manifest.json")
        if not os.path.isfile(mpath):
            self.misses += 1
            return None
        with open(mpath) as f:
            man = json.load(f)
        if man.get("format_version") != PLAN_FORMAT_VERSION:
            raise PlanStoreError(
                f"plan artifact {key} was written with format version "
                f"{man.get('format_version')!r}; this build reads version "
                f"{PLAN_FORMAT_VERSION} — re-run precompute_plans")
        if man.get("backend") not in kops.BACKENDS:
            raise PlanStoreError(
                f"plan artifact {key} targets backend {man.get('backend')!r} "
                f"which is not registered ({sorted(kops.BACKENDS)}) — "
                "re-run precompute_plans against this build")
        data = np.load(os.path.join(path, "arrays.npz"))
        n_levels = int(man["num_pyramid_levels"])
        fw = FrozenWeight(
            jnp.asarray(man["tau"], jnp.float32),
            tuple(jnp.asarray(data[f"level_{l}"]) for l in range(n_levels)),
            jnp.asarray(data["nbmax"]),
            jnp.asarray(data["kj_k"], jnp.int32),
            jnp.asarray(data["kj_j"], jnp.int32),
            jnp.asarray(data["b_scale"]) if "b_scale" in data else None,
            tile=int(man["tile"]), block_n=int(man["block_n"]),
            num_levels=int(man["levels"]), backend=man["backend"],
            wshape=tuple(man["wshape"]), padded=tuple(man["padded"]),
            use_mxu=bool(man.get("use_mxu", False)),
            weight_hash=man["weight_hash"],
            version=int(man["format_version"]),
            compute_dtype=man.get("dtype", "float32"),
            tuned=TunedParams.from_manifest(man.get("tuned")),
        )
        self.hits += 1
        return fw

    def manifest_pointer(self) -> dict:
        """What a checkpoint records next to the weights so a restored
        server finds its precomputed plans (see `checkpoint.save`)."""
        return {"path": os.path.abspath(self.root),
                "format_version": PLAN_FORMAT_VERSION}
