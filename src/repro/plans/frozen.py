"""Frozen weight-side SpAMM plans — gating artifacts as jit *inputs*.

cuSpAMM's weight-side norm hierarchy is a pure function of the (static)
weight matrix, yet a jitted serving step re-derives it inside every compiled
trace: tracers are never cached, so the `WeightPlanCache` amortization only
helps eager callers. This module freezes the weight half of the gating phase
into two pytrees that compiled prefill/decode consume as *data*:

  * `FrozenWeight` — the shape-independent artifact: the weight-side
    `NormPyramid`, the super-column max-norm table, and the weight-admissible
    (k, j) pair list (tiles whose weight norm can pass the τ-test for SOME
    activation; with τ > 0 a zero-norm weight tile can never pass). This is
    what `PlanStore` serializes and `WeightPlanCache` memoizes.
  * `FrozenPlan` — `FrozenWeight.for_rows(gm)`: the artifact specialized to
    an activation row grid, carrying the `SpammWork`-style step tables
    (pair-major, ascending k, bucket-padded) plus the per-step segment
    index tables that let a *traced* activation gate compute the
    INIT/ACC/FLUSH flags with static shapes. Passed as a jit argument, it
    makes the concrete work-list path the only executed path: the compiled
    graph contains the activation-side get-norm and an O(S) gather-compare —
    zero weight-side get-norm ops and zero dense-bitmap sorts.

Exactness: the frozen step tables are a *superset* of every reachable mask
(they enumerate all weight-admissible (i, j, k)); the traced activation gate
`norm_a[i,k] · nbmax[k,j] ≥ τ` re-applies the exact flat test per step
(fp32 multiplication is monotone in each non-negative argument, so the
super-column max commutes with the gate), which keeps the frozen path
bit-identical to the eager `plan()+execute()` pipeline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import TunedParams
from repro.core.plan import NormPyramid, _bucket, pad_to_tile
from repro.kernels import ops as kops
from repro.kernels import quantize as kquant

# Bump when the on-disk/for_rows encoding changes incompatibly: PlanStore
# refuses to load artifacts written under a different version (satellite:
# clear error, never silent wrong-plan execution).
# v2: compute-dtype keying + int8 b_scale tables + quantization-widened
#     gate τ — pre-dtype (v1) stores are refused at PlanStore open.
PLAN_FORMAT_VERSION = 2


@jax.tree_util.register_pytree_node_class
class FrozenWeight:
    """Shape-independent frozen gating artifact of ONE gated weight.

    Array fields (pytree children, all concrete):
      tau      f32 scalar — the τ this artifact was frozen at
      levels   tuple of normmaps, finest (tile) first — the weight-side
               NormPyramid stack (levels[0] is the plain normmap)
      nbmax    (gk, gn//block_n) f32 — per super-column max of levels[0]
               (the traced activation gate tests against this table)
      kj_k/kj_j (W,) int32 — weight-admissible (k, j) tile pairs, sorted by
               (j, k) so `for_rows` emits pair-major ascending-k steps
      b_scale  (gk, gn) f32 per-FINE-tile int8 scales of the padded weight,
               or None for float32/bfloat16 artifacts — frozen at build time
               so serving quantizes the weight bit-identically every start

    Static metadata (aux): tile, block_n, levels (coarsening steps),
    backend (resolved name), wshape (true K, N), padded (Kp, Np),
    weight_hash (content fingerprint, "" when unknown), version,
    compute_dtype — the precision this artifact was frozen for: its normmaps
    describe the QUANTIZED weight view and `for_rows` bakes the
    quantization-widened gate τ into the FrozenPlan (tau here stays the
    REQUESTED τ; it is the store-addressing value) — and `tuned`, the
    `core.cost.TunedParams` record when this artifact's blocking parameters
    came from the roofline autotuner (None for hand-configured artifacts).
    tuned is provenance + the work-list bucket floor `for_rows` pads to; it
    is NOT an addressing field — the tuned block_n/levels already address
    the artifact through the ordinary config echo, and legacy stores
    without the field load as tuned=None.
    """

    def __init__(self, tau, levels, nbmax, kj_k, kj_j, b_scale=None, *,
                 tile: int, block_n: int, num_levels: int, backend: str,
                 wshape: Tuple[int, int], padded: Tuple[int, int],
                 use_mxu: bool = False, weight_hash: str = "",
                 version: int = PLAN_FORMAT_VERSION,
                 compute_dtype: str = "float32",
                 tuned: TunedParams | None = None):
        self.tau = tau
        self.levels = tuple(levels)
        self.nbmax = nbmax
        self.kj_k = kj_k
        self.kj_j = kj_j
        self.b_scale = b_scale
        self.tile = tile
        self.block_n = block_n
        self.num_levels = num_levels
        self.backend = backend
        self.wshape = tuple(wshape)
        self.padded = tuple(padded)
        self.use_mxu = use_mxu
        self.weight_hash = weight_hash
        self.version = version
        self.compute_dtype = compute_dtype
        self.tuned = tuned
        self._rows_cache: dict = {}

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.tau, self.levels, self.nbmax, self.kj_k, self.kj_j,
                    self.b_scale)
        aux = (self.tile, self.block_n, self.num_levels, self.backend,
               self.wshape, self.padded, self.use_mxu, self.weight_hash,
               self.version, self.compute_dtype, self.tuned)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        tau, levels, nbmax, kj_k, kj_j, b_scale = children
        (tile, block_n, num_levels, backend, wshape, padded, use_mxu, wh,
         ver, dtype, tuned) = aux
        return cls(tau, levels, nbmax, kj_k, kj_j, b_scale, tile=tile,
                   block_n=block_n, num_levels=num_levels, backend=backend,
                   wshape=wshape, padded=padded, use_mxu=use_mxu,
                   weight_hash=wh, version=ver, compute_dtype=dtype,
                   tuned=tuned)

    # -- derived ------------------------------------------------------------
    @property
    def pyramid(self) -> NormPyramid:
        return NormPyramid(self.levels, tile=self.tile)

    @property
    def norm_b(self) -> jax.Array:
        return self.levels[0]

    @property
    def grid(self) -> Tuple[int, int]:
        """(gk, gn//block_n) — the weight-side tile grid at super-column
        granularity."""
        return self.nbmax.shape

    @property
    def num_kj(self) -> int:
        """Number of weight-admissible (k, j) pairs (W)."""
        return int(self.kj_k.shape[0])

    @property
    def bucket_floor(self) -> int:
        """The work-list bucket floor `for_rows` pads to — the autotuned
        value when this artifact carries one, else the historical 16."""
        return self.tuned.bucket if self.tuned is not None else 16

    def config_key(self) -> dict:
        """The config echo that (with the weight hash) addresses this
        artifact in a PlanStore — EVERY field that changes the computed
        normmaps or gate must appear here, or a stale artifact would hit."""
        return {
            "tau": float(np.asarray(self.tau)),
            "tile": self.tile,
            "block_n": self.block_n,
            "levels": self.num_levels,
            "backend": self.backend,
            "use_mxu": self.use_mxu,
            "dtype": self.compute_dtype,
        }

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, w, tau, *, tile: int = 64, block_n: int = 1,
              levels: int = 0, backend: str = "auto", use_mxu: bool = False,
              weight_hash: str = "",
              compute_dtype: str = "float32",
              tuned: TunedParams | None = None) -> "FrozenWeight":
        """Freeze the weight side of `x @ w` gating at threshold `tau`.

        Runs the backend's get-norm ONCE (plus `levels` pooling reductions)
        — this is the offline "planning pass" that serving then never pays.

        compute_dtype freezes for low-precision execution: norms come from
        the quantized weight view (f32 norms OF the quantized values, the
        "compute the pyramid in f32 once at freeze time" half of
        quantization-aware gating), int8 stores the per-tile scale table,
        and `for_rows` widens the gate τ (kernels/quantize.py) so the
        low-precision gate is conservative w.r.t. the f32 gate at `tau`.
        """
        bk = kops.get_backend(backend)
        compute_dtype = kquant.canonical_dtype(compute_dtype)
        w = jnp.asarray(w)
        assert w.ndim == 2, w.shape
        k, n = w.shape
        wp = pad_to_tile(w, tile, tile * block_n)
        b_scale = None
        if compute_dtype == "int8":
            # fused absmax/scale + get-norm: quantized-view norms AND the
            # persisted b_scale table from one read of the padded weight
            base, b_scale = kops.int8_norms_and_scales(
                wp, tile, backend=bk.name, use_mxu=use_mxu)
        else:
            wv = (kquant.quantized_view(wp, compute_dtype, tile)
                  if compute_dtype != "float32" else wp)
            base = bk.norms(wv, tile, use_mxu=use_mxu)
        pyr = NormPyramid.from_normmap(base, levels, tile=tile)
        base_np = np.asarray(base, np.float32)
        gk, gnp = base_np.shape
        assert gnp % block_n == 0, (gnp, block_n)
        gnb = gnp // block_n
        nbmax = (base_np.reshape(gk, gnb, block_n).max(2)
                 if block_n > 1 else base_np)
        tau_f = float(np.asarray(tau))
        if tau_f > 0.0:
            # a zero-norm weight super-column can never pass `na·nb ≥ τ>0`
            # for any activation — frozen-safe weight-side pruning
            kk, jj = np.nonzero(nbmax > 0.0)
        else:
            kk, jj = [x.ravel() for x in
                      np.mgrid[0:gk, 0:gnb].astype(np.int64)]
        order = np.lexsort((kk, jj))  # (j asc, k asc) → pair-major steps
        return cls(
            jnp.asarray(tau_f, jnp.float32),
            tuple(jnp.asarray(lv) for lv in pyr.levels),
            jnp.asarray(nbmax),
            jnp.asarray(kk[order], jnp.int32),
            jnp.asarray(jj[order], jnp.int32),
            b_scale,
            tile=tile, block_n=block_n, num_levels=levels, backend=bk.name,
            wshape=(int(k), int(n)),
            padded=(int(wp.shape[0]), int(wp.shape[1])),
            use_mxu=use_mxu, weight_hash=weight_hash,
            compute_dtype=compute_dtype, tuned=tuned,
        )

    # -- shape specialization -----------------------------------------------
    def for_rows(self, gm: int, *, min_steps: int = 0) -> "FrozenPlan":
        """Specialize to an activation row grid of `gm` tiles.

        Emits the step tables pair-major ((i, j) runs contiguous, k
        ascending within a run) exactly like `compact_from_triples`, padded
        to a power-of-two bucket of at least max(`min_steps`,
        `bucket_floor`) — the floor is the autotuned per-weight bucket when
        present; pass a common `min_steps` when plans of several weights
        must stack into one scan input. Padding steps repeat the last real
        triple with the `real` bit clear, so the traced gate can never
        activate them. Cached per (gm, bucket).

        Shape-bucketed serving leans on this cache: the engine rounds its
        slot pool to a power of two (`cost.bucket`), so a sweep of
        arbitrary batch shapes resolves to at most
        `len(cost.bucket_ladder(max_batch, 1))` distinct `gm` values —
        O(buckets) specializations and jit traces, not O(shapes)."""
        return self._specialize(gm, gm, min_steps)

    def slice_rows(self, lo: int, hi: int, *, gm: Optional[int] = None,
                   min_steps: int = 0) -> "FrozenPlan":
        """The per-shard plan of row-tile strip [lo, hi) on a LOCAL grid of
        `gm` tiles (≥ the strip width; default = the width) — what a
        shard_map'd step consumes when a variable-width row partition
        assigns this weight's activation rows [lo·tile, hi·tile) to one
        device, clamp-padded so every shard shares one static shape.

        The step tables enumerate all weight-admissible (k, j) pairs per
        LOCAL row tile 0..hi-lo (a shard's rows are renumbered from 0; the
        weight-side pair list is activation-row-agnostic, so the strip's
        real content depends only on its width — (lo, hi) names the strip
        and validates the cut). Local tiles ≥ hi-lo are clamp padding: no
        step targets them (`real` is clear beyond the strip's steps), so
        pad rows do ZERO gated work — the per-shard work difference IS the
        load-balance mechanism. Pass a common `min_steps` bucket (computed
        at the PADDED width) so per-shard plans of one weight stack; built
        host-side at re-shard time, never in-trace."""
        if not 0 <= lo <= hi:
            raise ValueError(f"bad row strip [{lo}, {hi})")
        width = hi - lo
        gm = width if gm is None else gm
        if gm < width:
            raise ValueError(
                f"local grid {gm} smaller than strip width {width}")
        return self._specialize(width, gm, min_steps)

    def shard_by_offsets(self, offsets, *, width: Optional[int] = None,
                         min_steps: int = 0) -> "FrozenPlan":
        """Stack per-shard `slice_rows` plans of a variable-width partition
        (`offsets` as cut by `schedule.equal_work_partition` / rescaled by
        `schedule.rescale_offsets`, in this weight's row-tile units) into
        ONE FrozenPlan whose children carry a leading shard dim — the
        pytree a shard_map'd step takes with every leaf sharded on dim 0.

        `width` fixes the common local grid (≥ the widest strip; default =
        the widest strip): the engine pins it per wave so every re-cut
        yields identical shapes (recompile-free swap). All shards share one
        step bucket computed at the padded width, so their static metadata
        is identical by construction."""
        offs = np.asarray(offsets, np.int64)
        if offs.ndim != 1 or offs.shape[0] < 2 or offs[0] != 0 \
                or np.any(np.diff(offs) < 1):
            raise ValueError(f"malformed offset table {offs}")
        wmax = int(np.diff(offs).max())
        if width is not None:
            if width < wmax:
                raise ValueError(
                    f"fixed width {width} < widest strip {wmax}")
            wmax = int(width)
        bucket = _bucket(max(wmax * self.num_kj, min_steps),
                         self.bucket_floor)
        shards = [
            self.slice_rows(int(offs[d]), int(offs[d + 1]), gm=wmax,
                            min_steps=bucket)
            for d in range(offs.shape[0] - 1)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)

    def _specialize(self, width: int, gm: int, min_steps: int) -> "FrozenPlan":
        """Shared body of `for_rows` (width == gm) and `slice_rows` (width ≤
        gm: real steps cover local tiles [0, width), tiles beyond are
        untargeted clamp padding)."""
        gk, gnb = self.grid
        w = self.num_kj
        s_real = width * w
        s = _bucket(max(s_real, min_steps), self.bucket_floor)
        key = (width, gm, s)
        hit = self._rows_cache.get(key)
        if hit is not None:
            return hit
        kj_k = np.asarray(self.kj_k, np.int32)
        kj_j = np.asarray(self.kj_j, np.int32)
        if s_real:
            step_i = np.repeat(np.arange(width, dtype=np.int32), w)
            step_j = np.tile(kj_j, width)
            step_k = np.tile(kj_k, width)
            pad = s - s_real
            if pad:
                step_i = np.concatenate([step_i, np.full(pad, step_i[-1])])
                step_j = np.concatenate([step_j, np.full(pad, step_j[-1])])
                step_k = np.concatenate([step_k, np.full(pad, step_k[-1])])
        else:
            step_i = np.zeros(s, np.int32)
            step_j = np.zeros(s, np.int32)
            step_k = np.zeros(s, np.int32)
        step_real = np.zeros(s, bool)
        step_real[:s_real] = True
        # segment (= output pair) runs over the PADDED tables: padding
        # repeats the last real (i, j), so it merges into the final run and
        # the in-trace flag arithmetic needs no special cases
        pair = step_i.astype(np.int64) * gnb + step_j
        new = np.ones(s, bool)
        new[1:] = pair[1:] != pair[:-1]
        starts = np.flatnonzero(new)
        counts = np.diff(np.append(starts, s))
        ends = np.append(starts[1:], s) - 1
        seg_first = np.repeat(starts, counts).astype(np.int32)
        seg_last = np.repeat(ends, counts).astype(np.int32)
        # the FrozenPlan's tau is the GATE threshold: for low-precision
        # artifacts that is the quantization-widened τ' ≤ τ, so the traced
        # gate over quantized norms keeps a superset of the f32-gated set
        # (self.tau stays the requested τ — the store-addressing value)
        gate_tau = kquant.widen_tau(
            float(np.asarray(self.tau)), self.compute_dtype, self.tile)
        fp = FrozenPlan(
            jnp.asarray(gate_tau, jnp.float32), self.levels[0], self.nbmax,
            jnp.asarray(step_i.astype(np.int32)),
            jnp.asarray(step_j.astype(np.int32)),
            jnp.asarray(step_k.astype(np.int32)),
            jnp.asarray(step_real),
            jnp.asarray(seg_first), jnp.asarray(seg_last),
            self.b_scale,
            tile=self.tile, block_n=self.block_n, num_levels=self.num_levels,
            backend=self.backend, gm=gm, gk=gk, gnb=gnb,
            wshape=self.wshape, version=self.version,
            compute_dtype=self.compute_dtype,
        )
        self._rows_cache[key] = fp
        return fp


@jax.tree_util.register_pytree_node_class
class FrozenPlan:
    """A FrozenWeight specialized to one activation row grid — THE pytree a
    jitted prefill/decode step takes as an argument.

    Array fields (children; concrete when built, tracers inside the jit):
      tau          f32 scalar
      norm_b       (gk, gnp) weight-side finest normmap (plan metadata /
                   execute shape contract)
      nbmax        (gk, gnb) per-super-column max norms — the traced gate's
                   weight half
      step_i/j/k   (S,) int32 — pair-major ascending-k step tables over ALL
                   weight-admissible (i, j, k); S = gm·W bucket-padded
      step_real    (S,) bool — clear on bucket padding steps
      seg_first/seg_last (S,) int32 — index of the first/last step of each
                   step's (i, j) segment: what lets the traced activation
                   gate derive INIT/FLUSH flags with pure static-shape
                   cumsum/gather arithmetic
      b_scale      (gk, gnp) f32 int8 weight scale table, or None — rides
                   into the SpammPlan so execute quantizes the weight with
                   the frozen scales (bit-stable across restarts)

    NOTE: `tau` here is the GATE threshold — for low-precision artifacts the
    quantization-widened τ', not the requested τ (which lives on the
    FrozenWeight / in the store address).

    Static metadata (aux): tile, block_n, num_levels, backend, gm, gk, gnb,
    wshape, version, compute_dtype. Leading batch dims on every child are
    allowed (stacked per-layer plans riding a lax.scan — see `stack_plans`).
    """

    def __init__(self, tau, norm_b, nbmax, step_i, step_j, step_k, step_real,
                 seg_first, seg_last, b_scale=None, *, tile: int,
                 block_n: int, num_levels: int, backend: str, gm: int,
                 gk: int, gnb: int, wshape: Tuple[int, int],
                 version: int = PLAN_FORMAT_VERSION,
                 compute_dtype: str = "float32"):
        self.tau = tau
        self.norm_b = norm_b
        self.nbmax = nbmax
        self.step_i = step_i
        self.step_j = step_j
        self.step_k = step_k
        self.step_real = step_real
        self.seg_first = seg_first
        self.seg_last = seg_last
        self.b_scale = b_scale
        self.tile = tile
        self.block_n = block_n
        self.num_levels = num_levels
        self.backend = backend
        self.gm = gm
        self.gk = gk
        self.gnb = gnb
        self.wshape = tuple(wshape)
        self.version = version
        self.compute_dtype = compute_dtype

    def tree_flatten(self):
        children = (self.tau, self.norm_b, self.nbmax, self.step_i,
                    self.step_j, self.step_k, self.step_real, self.seg_first,
                    self.seg_last, self.b_scale)
        aux = (self.tile, self.block_n, self.num_levels, self.backend,
               self.gm, self.gk, self.gnb, self.wshape, self.version,
               self.compute_dtype)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (tile, block_n, num_levels, backend, gm, gk, gnb, wshape, ver,
         dtype) = aux
        return cls(*children, tile=tile, block_n=block_n,
                   num_levels=num_levels, backend=backend, gm=gm, gk=gk,
                   gnb=gnb, wshape=wshape, version=ver, compute_dtype=dtype)

    @property
    def num_steps(self) -> int:
        return self.step_i.shape[-1]


def freeze_weight(w, tau, *, tile: int = 64, block_n: int = 1,
                  levels: int = 0, backend: str = "auto",
                  use_mxu: bool = False, weight_hash: str = "",
                  compute_dtype: str = "float32",
                  tuned: TunedParams | None = None) -> FrozenWeight:
    """Convenience alias for `FrozenWeight.build`."""
    return FrozenWeight.build(w, tau, tile=tile, block_n=block_n,
                              levels=levels, backend=backend, use_mxu=use_mxu,
                              weight_hash=weight_hash,
                              compute_dtype=compute_dtype, tuned=tuned)


def stack_plans(fps) -> FrozenPlan:
    """Stack per-layer FrozenPlans (same static metadata, same bucket — use
    `for_rows(gm, min_steps=...)` with a common bucket) into ONE plan whose
    children carry a leading layer dim: the shape lax.scan slices per step,
    which is how frozen plans ride a scanned-layer prefill."""
    fps = list(fps)
    assert fps, "stack_plans of nothing"
    aux0 = fps[0].tree_flatten()[1]
    for fp in fps[1:]:
        assert fp.tree_flatten()[1] == aux0, (
            "stack_plans needs identical static metadata (shapes/bucket): "
            f"{fp.tree_flatten()[1]} != {aux0}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *fps)
