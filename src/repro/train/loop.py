"""Fault-tolerant training loop (deliverable b/e substrate).

Features exercised by tests/examples on CPU and designed for pods:
  * checkpoint/restart: atomic snapshots every `ckpt_every`, resume-from-
    latest restores params/opt/step and the data stream position;
  * straggler watchdog: per-step wall time vs. rolling median — steps slower
    than `straggler_factor`× median are counted and logged (on a pod this
    feeds the controller's replace-node decision);
  * simulated failure injection (`fail_at_step`) to test the restart path;
  * optional int8+error-feedback gradient compression.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.core import module as spmod
from repro.core import schedule as _schedule
from repro.data.pipeline import SyntheticLM
from repro.distributed.compression import Int8EF
from repro.models import model as M
from repro.models.transformer import NetCtx
from repro.obs import FRACTION_BUCKETS, LATENCY_BUCKETS_S, Observability
from repro.optim.adamw import AdamW


@dataclasses.dataclass
class TrainResult:
    losses: list
    restarts: int
    straggler_steps: int
    final_step: int
    # per-step SpAMM gating stats, one entry per executed step (the same
    # stats the serving engine attaches to Request.out["spamm"]): list of
    # {"step", "valid_fraction", "gated_gemms"} dicts, empty when SpAMM off.
    # Each entry also carries "per_layer": {layer: {valid_fraction,
    # gated_gemms}} — the grad-safe trace-buffer tier threads the per-layer
    # sums through the scan carry, so the breakdown survives value_and_grad.
    # With re-sharding on, each entry also carries the live equal-work
    # partition's predicted "imbalance" (the drift series — None until the
    # first probe) and the cumulative "resharded" event count
    spamm_stats: list = dataclasses.field(default_factory=list)
    # the run's Observability bundle (registry with train_step_seconds /
    # spamm_valid_fraction series, spans around probe + checkpoint I/O) —
    # what launch.train exports via --metrics-out/--trace-out
    obs: Optional[Observability] = None


def train(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    tcfg: TrainConfig,
    ctx: NetCtx,
    *,
    global_batch: int = 8,
    seq_len: int = 128,
    spamm_cfg=None,
    reshard_cfg: Optional[_schedule.ReshardConfig] = None,
    fail_at_step: Optional[int] = None,
    resume: bool = False,
    straggler_factor: float = 3.0,
    log_every: int = 10,
    obs=None,
) -> TrainResult:
    obs = Observability.ensure(obs, process_name="repro-train")
    # step wall-clock lands in the registry (monotonic perf_counter — the
    # old time.time() readout jumped with NTP slews); keep_recent=50 retains
    # the raw samples the straggler watchdog's rolling median reads
    step_h = obs.registry.histogram(
        "train_step_seconds", "optimizer step wall-clock (dispatch + block)",
        buckets=LATENCY_BUCKETS_S, keep_recent=50)
    compression = (
        Int8EF() if pcfg.grad_compression == "int8_ef" else None
    )
    opt = AdamW(tcfg, compression=compression)
    data = SyntheticLM(cfg, global_batch, seq_len, seed=tcfg.seed)

    start_step = 0
    if resume and (last := ckpt.latest_step(tcfg.ckpt_dir)) is not None:
        like = {
            "params": jax.eval_shape(
                lambda k: M.init_params(cfg, pcfg, k), jax.random.key(tcfg.seed)
            ),
        }
        params = ckpt.restore(tcfg.ckpt_dir, last, like)["params"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = opt.init(params)  # moments restored below if present
        try:
            like_full = {"params": like["params"], "opt_state": jax.eval_shape(opt.init, like["params"])}
            full = ckpt.restore(tcfg.ckpt_dir, last, like_full)
            params = jax.tree.map(jnp.asarray, full["params"])
            opt_state = jax.tree.map(jnp.asarray, full["opt_state"])
        except KeyError:
            pass
        start_step = last
    else:
        params = M.init_params(cfg, pcfg, jax.random.key(tcfg.seed))
        opt_state = opt.init(params)

    # one context for the whole run, so train steps export the SAME gating
    # stats the serving engine attaches to Request.out["spamm"] — carried as
    # step METRICS (loss_fn threads them through the scan carry; callbacks
    # would be dropped under grad)
    spamm_ctx = spmod.as_context(spamm_cfg)
    collect_spamm = spamm_ctx is not None and spamm_ctx.enable
    step_fn = jax.jit(M.make_train_step(cfg, pcfg, ctx, opt, spamm_cfg=spamm_ctx))

    # drift-triggered re-sharding (control plane, same contract as the
    # serving engine): every reshard_cfg.every steps re-probe the coarse V
    # estimate — fresh activation-side norms of the step's token embeddings
    # against the CACHED weight-side norms of the probe weight — and re-cut
    # the equal-work partition when the live cut's predicted imbalance
    # drifts past the fresh cut's. Never touches the computed values.
    resharder = None
    if reshard_cfg is not None and collect_spamm and reshard_cfg.every > 0:
        resharder = _schedule.ReshardController(
            _schedule.resolve_reshard_devices(reshard_cfg, ctx.mesh,
                                              ctx.batch_axes))

    def probe_reshard(step, batch):
        # `model.reshard_probe` is the shared probe body (same drift
        # behavior as the serving engine); frontend archs feed embedding
        # rows directly instead of tokens
        if "tokens" in batch:
            M.reshard_probe(resharder, spamm_ctx, params, step,
                            tokens=np.asarray(batch["tokens"]).reshape(-1))
        else:
            M.reshard_probe(resharder, spamm_ctx, params, step,
                            x=jnp.asarray(batch["embeds"]).reshape(
                                -1, cfg.d_model))

    losses, spamm_stats = [], []
    stragglers = 0
    restarts = 1 if resume and start_step else 0
    step = start_step
    m_vf = (obs.registry.histogram(
        "spamm_valid_fraction", labelnames=("phase", "layer", "site"),
        buckets=FRACTION_BUCKETS) if obs.enabled and collect_spamm else None)
    while step < tcfg.total_steps:
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = data.batch_at(step)
        t0 = time.perf_counter()
        t0_ns = time.perf_counter_ns()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.int32(step)
        )
        loss = float(metrics["loss"])
        obs.tracer.add_complete("train_step", t0_ns, time.perf_counter_ns(),
                                step=step)
        if resharder is not None and resharder.due(step):
            with obs.span("reshard_probe", step=step):
                probe_reshard(step, batch)
            if obs.enabled:
                resharder.publish(obs.registry)
        sp = None
        if collect_spamm and "spamm_valid_fraction" in metrics:
            n_gemms = int(metrics["spamm_gated_gemms"])
            sp = {"step": step,
                  "valid_fraction": (float(metrics["spamm_valid_fraction"])
                                     if n_gemms else None),
                  "gated_gemms": n_gemms}
            if "spamm_layer_valid_fraction" in metrics:
                lvf = np.asarray(metrics["spamm_layer_valid_fraction"])
                lvc = np.asarray(metrics["spamm_layer_gated_gemms"])
                sp["per_layer"] = {
                    int(i): {"valid_fraction": (float(lvf[i]) if lvc[i]
                                                else None),
                             "gated_gemms": int(lvc[i])}
                    for i in range(lvf.shape[0])}
                if m_vf is not None:
                    for i in range(lvf.shape[0]):
                        if lvc[i]:
                            m_vf.observe(float(lvf[i]), phase="train",
                                         layer=int(i), site="")
            if resharder is not None:
                sp["imbalance"] = resharder.live_imbalance
                sp["resharded"] = resharder.resharded
                # same readout the sharded serving engine places by: the
                # live cut and its per-strip predicted loads
                offs = resharder.offsets
                loads = resharder.live_loads
                sp["offsets"] = (None if offs is None
                                 else [int(o) for o in np.asarray(offs)])
                sp["loads"] = (None if loads is None
                               else [float(x) for x in loads])
            spamm_stats.append(sp)
        dt = time.perf_counter() - t0
        step_h.observe(dt)
        # straggler watchdog: rolling median over the histogram's retained
        # raw samples (keep_recent=50) — the registry is the one owner of
        # step durations now, no shadow list to drift out of sync
        med = float(np.median(step_h.recent()))
        if step_h.count() > 5 and dt > straggler_factor * med:
            stragglers += 1
        losses.append(loss)
        if log_every and step % log_every == 0:
            extra = ""
            if sp is not None and sp["valid_fraction"] is not None:
                extra = (f" spamm_valid {sp['valid_fraction']:.3f} "
                         f"({sp['gated_gemms']} gemms)")
            print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms){extra}",
                  flush=True)
        step += 1
        if tcfg.ckpt_every and step % tcfg.ckpt_every == 0:
            with obs.span("checkpoint_save", step=step):
                ckpt.save(
                    tcfg.ckpt_dir, step,
                    {"params": params, "opt_state": opt_state},
                    async_=False,
                )
    return TrainResult(losses, restarts, stragglers, step, spamm_stats,
                       obs=obs)
