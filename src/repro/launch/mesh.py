"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

Also the home of the `AxisType` compat shim: jax >= 0.5 grew
`jax.sharding.AxisType` and `jax.make_mesh(..., axis_types=...)`; on
jax 0.4.x neither exists (every axis is implicitly "auto"). All mesh
construction in this repo goes through `make_mesh` / `mesh_from_devices`
below so the same code runs on both.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType

    _AXIS_TYPES = True
except ImportError:  # jax 0.4.x: axes are implicitly auto-sharded
    AxisType = None
    _AXIS_TYPES = False

from repro.models.transformer import NetCtx


def make_mesh(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """`jax.make_mesh` with every axis auto-sharded, on any jax version."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _AXIS_TYPES:
        kw["axis_types"] = (AxisType.Auto,) * len(axis_shapes)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def mesh_from_devices(device_array, axis_names) -> Mesh:
    """`Mesh(devices, names)` with auto axes where the jax version has them."""
    if _AXIS_TYPES:
        return Mesh(device_array, axis_names,
                    axis_types=(AxisType.Auto,) * len(axis_names))
    return Mesh(device_array, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod slice, 256 chips) or 2×16×16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (tests/smokes)."""
    return make_mesh((1, 1), ("data", "model"))


def make_ctx(mesh) -> NetCtx:
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return NetCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model")
