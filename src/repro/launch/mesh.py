"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

from repro.models.transformer import NetCtx


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod slice, 256 chips) or 2×16×16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh():
    """Single-device mesh with the production axis names (tests/smokes)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def make_ctx(mesh) -> NetCtx:
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return NetCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model")
