"""Post-SPMD HLO analyzer for the dry-run roofline.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE (verified in
this container: a 10-step scan of matmuls reports 1 matmul of FLOPs), which
would understate every scanned-layer model by ~L×. This walker parses the
optimized HLO text (`compiled.as_text()`) and:

  * multiplies while-loop bodies by their trip count (from the
    `known_trip_count` backend_config; fallback: max s32 constant in the
    loop condition; fallback 1 + warning),
  * counts dot FLOPs from operand shapes + contraction/batch dims
    (recursing through fusions / whiles / calls / conditionals),
  * estimates HBM traffic as Σ over top-level ops of (unique operand bytes +
    output bytes) under a no-fusion-reuse model (fusions = one kernel),
  * collects collective ops (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute) with operand bytes, estimated per-chip
    wire bytes (ring model), and replica-group sizes — the collective
    roofline term and the §Dry-run "collective schedule".

Everything here is per-device: the HLO is the SPMD-partitioned module, so
shapes are already the per-chip shards.
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(shape_str: str) -> Tuple[List[int], str]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return [], "f32"
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str            # operands + attributes (raw tail of the line)
    operands: List[str]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_operands(rest: str) -> List[str]:
    # operands are inside the leading (...) — cut at the matching paren
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(rest[:end])


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and "->" in line:
                cur = Computation(m.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4),
                        _parse_operands(m.group(4)))
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    return comps


_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_DIMS_ATTR_RE = re.compile(r"(\w+_contracting_dims)=\{([\d,]*)\}")
_BATCH_ATTR_RE = re.compile(r"(\w+_batch_dims)=\{([\d,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    lhs = comp.by_name.get(ins.operands[0])
    rhs = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
    if lhs is None or rhs is None:
        out_dims, _ = shape_dims(ins.shape)
        return 2.0 * math.prod(out_dims) if out_dims else 0.0
    ldims, _ = shape_dims(lhs.shape)
    rdims, _ = shape_dims(rhs.shape)
    attrs = dict()
    for m in _DIMS_ATTR_RE.finditer(ins.rest):
        attrs[m.group(1)] = [int(x) for x in m.group(2).split(",") if x]
    for m in _BATCH_ATTR_RE.finditer(ins.rest):
        attrs[m.group(1)] = [int(x) for x in m.group(2).split(",") if x]
    rc = attrs.get("rhs_contracting_dims", [])
    rb = attrs.get("rhs_batch_dims", [])
    rhs_free = math.prod(
        d for i, d in enumerate(rdims) if i not in rc and i not in rb
    ) if rdims else 1
    return 2.0 * math.prod(ldims) * rhs_free


def _group_size(rest: str, default: int) -> int:
    m = _RG_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))  # [groups, group_size]<=[N]
    m = _RG_LIST_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(op: str, in_bytes: int, out_bytes: int, g: int) -> float:
    """Per-chip wire-byte estimate under a ring model."""
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return float(out_bytes) * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * in_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(in_bytes) * (g - 1) / g
    if op == "all-to-all":
        return float(in_bytes) * (g - 1) / g
    if op == "collective-permute":
        return float(in_bytes)
    return 0.0


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota",
}

# pure data-movement / dtype-staging ops: a fusion made only of these does no
# arithmetic. On the CPU backend, bf16 legalization inserts many f32 staging
# fusions of this kind that would not exist on TPU (bf16 is MXU-native), so
# bytes are reported split into "math" and "staging" components.
_MOVE_OPS = {
    "convert", "bitcast", "copy", "reshape", "transpose", "broadcast",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "reverse", "parameter", "constant", "tuple", "get-tuple-element", "iota",
}


class HloAnalysis:
    def __init__(self, text: str, num_devices: int):
        self.comps = parse_hlo(text)
        self.num_devices = num_devices
        self.warnings: List[str] = []
        entry = None
        for name, c in self.comps.items():
            if name.startswith("main") or ".main" in name or entry is None:
                if entry is None or "main" in name:
                    entry = c
        self.entry = entry
        self.flops = 0.0
        self.bytes_hbm = 0.0
        self.bytes_staging = 0.0
        self.collectives: List[dict] = []
        self.byte_contribs: Dict[str, float] = defaultdict(float)
        self._walk(self.entry, 1.0, set())

    def _trip_count(self, ins: Instr) -> float:
        m = _TRIP_RE.search(ins.rest)
        if m:
            return float(m.group(1))
        cm = _COND_RE.search(ins.rest)
        if cm and cm.group(1) in self.comps:
            consts = []
            cond = self.comps[cm.group(1)]
            for ci in cond.instrs:
                consts += [int(x) for x in _CONST_RE.findall(
                    f"{ci.shape} constant{ci.rest}" if ci.op == "constant" else "")]
                # fused conds: look one level down
                mm = _CALLS_RE.search(ci.rest)
                if mm and mm.group(1) in self.comps:
                    for cj in self.comps[mm.group(1)].instrs:
                        if cj.op == "constant":
                            consts += [int(x) for x in
                                       re.findall(r"constant\((\d+)\)", cj.rest)]
                if ci.op == "constant":
                    consts += [int(x) for x in re.findall(r"constant\((\d+)\)",
                                                          ci.rest)]
            if consts:
                return float(max(consts))
        self.warnings.append(f"while {ins.name}: unknown trip count, using 1")
        return 1.0

    def _walk(self, comp: Computation, mult: float, stack: frozenset | set):
        if comp is None or comp.name in stack:
            return
        stack = set(stack) | {comp.name}
        for ins in comp.instrs:
            if ins.op == "dot" or ins.op == "convolution":
                self.flops += mult * _dot_flops(ins, comp)
                b = mult * self._io_bytes(ins, comp)
                self.bytes_hbm += b
                self.byte_contribs[f"dot {ins.shape[:40]}"] += b
            elif ins.op == "fusion":
                called = self._called(ins)
                if called is not None:
                    self._walk_fusion_dots(called, mult, stack)
                b = mult * self._io_bytes(ins, comp)
                self.bytes_hbm += b
                if called is not None and all(
                    i.op in _MOVE_OPS for i in called.instrs
                ):
                    self.bytes_staging += b
                self.byte_contribs[f"fusion {ins.name[:50]}"] += b
            elif ins.op == "while":
                trip = self._trip_count(ins)
                body = self._called(ins)
                if body is not None:
                    self._walk(body, mult * trip, stack)
            elif ins.op in ("call", "custom-call", "async-start"):
                called = self._called(ins)
                if called is not None:
                    self._walk(called, mult, stack)
                else:
                    self.bytes_hbm += mult * self._io_bytes(ins, comp)
            elif ins.op == "conditional":
                called = self._called(ins)
                if called is not None:
                    self._walk(called, mult, stack)
            elif ins.op in COLLECTIVES or (
                ins.op.endswith("-start") and ins.op[:-6] in COLLECTIVES
            ):
                base_op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                in_b = sum(
                    shape_bytes(comp.by_name[o].shape)
                    for o in ins.operands if o in comp.by_name
                )
                out_b = shape_bytes(ins.shape)
                g = _group_size(ins.rest, self.num_devices)
                self.collectives.append({
                    "op": base_op,
                    "mult": mult,
                    "in_bytes": in_b,
                    "out_bytes": out_b,
                    "group": g,
                    "wire_bytes": mult * _wire_bytes(base_op, in_b, out_b, g),
                })
            elif ins.op not in _SKIP_BYTES_OPS:
                b = mult * self._io_bytes(ins, comp)
                self.bytes_hbm += b
                if ins.op in _MOVE_OPS:
                    self.bytes_staging += b
                self.byte_contribs[f"{ins.op} {ins.shape[:40]}"] += b

    def _walk_fusion_dots(self, comp: Computation, mult: float, stack):
        """Inside fusions only dots/whiles contribute extra (bytes counted at
        the fusion boundary)."""
        if comp is None or comp.name in stack:
            return
        stack = set(stack) | {comp.name}
        for ins in comp.instrs:
            if ins.op == "dot" or ins.op == "convolution":
                self.flops += mult * _dot_flops(ins, comp)
            elif ins.op == "fusion" or ins.op in ("call", "conditional"):
                self._walk_fusion_dots(self._called(ins), mult, stack)
            elif ins.op == "while":
                trip = self._trip_count(ins)
                self._walk(self._called(ins), mult * trip, stack)

    def _called(self, ins: Instr) -> Optional[Computation]:
        m = _CALLS_RE.search(ins.rest)
        return self.comps.get(m.group(1)) if m else None

    _CHAIN_OPS = ("bitcast", "convert", "copy", "reshape", "transpose")

    def _partial_access_bytes(self, comp: Computation, name: str,
                              depth: int = 0) -> Optional[float]:
        """If value `name` is only consumed through dynamic-slice / gather /
        DUS-operand-0 (possibly via bitcast/convert/copy chains), return the
        effective touched bytes; else None (full read)."""
        if depth > 6:
            return None
        uses = [i for i in comp.instrs if name in i.operands]
        if not uses:
            return 0.0
        total = 0.0
        for u in uses:
            if u.op in ("dynamic-slice", "gather") and u.operands[0] == name:
                total += shape_bytes(u.shape)
            elif u.op == "dynamic-update-slice" and u.operands[0] == name:
                upd = comp.by_name.get(u.operands[1]) if len(u.operands) > 1 else None
                total += shape_bytes(upd.shape) if upd else shape_bytes(u.shape)
            elif u.op in self._CHAIN_OPS:
                sub = self._partial_access_bytes(comp, u.name, depth + 1)
                if sub is None:
                    return None
                # a convert of the full buffer is itself full-size work —
                # but XLA fuses these chains; bill the downstream touch size
                total += sub
            else:
                return None
        return total

    def _sliced_params(self, comp: Computation) -> Dict[int, float]:
        """parameter index → effective read bytes for partially-accessed
        parameters (per-layer slices of stacked buffers etc.)."""
        eff: Dict[int, float] = {}
        for ins in comp.instrs:
            if ins.op != "parameter":
                continue
            m = re.match(r"(\d+)\)", ins.rest)
            if not m:
                continue
            b = self._partial_access_bytes(comp, ins.name)
            if b is not None:
                eff[int(m.group(1))] = b
        return eff

    def _fusion_dus_updates(self, comp: Computation) -> float:
        return sum(
            shape_bytes(comp.by_name[i.operands[1]].shape)
            for i in comp.instrs
            if i.op == "dynamic-update-slice" and len(i.operands) > 1
            and i.operands[1] in comp.by_name
        )

    def _io_bytes(self, ins: Instr, comp: Computation) -> float:
        # aliasing/slicing-aware models for partial-access ops
        if ins.op in ("dynamic-slice", "gather"):
            return 2.0 * shape_bytes(ins.shape)
        if ins.op == "dynamic-update-slice":
            upd = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
            return 2.0 * (shape_bytes(upd.shape) if upd else shape_bytes(ins.shape))

        sliced: Dict[int, float] = {}
        called = self._called(ins) if ins.op == "fusion" else None
        out_b = shape_bytes(ins.shape)
        if called is not None:
            sliced = self._sliced_params(called)
            upd_b = self._fusion_dus_updates(called)
            if upd_b and any(
                comp.by_name.get(o) is not None
                and shape_bytes(comp.by_name[o].shape) == out_b
                for o in ins.operands
            ):
                # output aliases an input buffer (loop-state DUS): bill the
                # updated region, not the whole buffer
                out_b = min(out_b, 2.0 * upd_b)
        seen = set()
        in_b = 0.0
        for oi, o in enumerate(ins.operands):
            if o in seen or o not in comp.by_name:
                continue
            seen.add(o)
            src = comp.by_name[o]
            if src.op in ("constant",) and shape_bytes(src.shape) <= 8:
                continue
            b = shape_bytes(src.shape)
            if oi in sliced:
                b = min(b, sliced[oi])
            in_b += b
        return float(out_b + in_b)

    # ------------------------------------------------------------------
    def collective_summary(self) -> dict:
        agg = defaultdict(lambda: {"count": 0.0, "in_bytes": 0.0, "wire_bytes": 0.0})
        for c in self.collectives:
            a = agg[c["op"]]
            a["count"] += c["mult"]
            a["in_bytes"] += c["mult"] * c["in_bytes"]
            a["wire_bytes"] += c["wire_bytes"]
        return dict(agg)

    def top_bytes(self, k=15):
        return sorted(self.byte_contribs.items(), key=lambda x: -x[1])[:k]

    def totals(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.bytes_hbm,
            "hbm_staging_bytes_per_device": self.bytes_staging,
            "hbm_math_bytes_per_device": self.bytes_hbm - self.bytes_staging,
            "collective_wire_bytes_per_device": sum(
                c["wire_bytes"] for c in self.collectives
            ),
            "collectives": self.collective_summary(),
            "warnings": self.warnings[:20],
        }
