"""Offline plan precomputation driver: populate a PlanStore for a model.

  PYTHONPATH=src python -m repro.launch.precompute_plans --arch musicgen-large \
      --reduced --plan-store /tmp/plans --tau 0.05 --spamm-tile 16

Walks every gated GEMM weight of the model (attention wq/wk/wv/wo + MLP
w1/w3/w2 across all layers) and freezes its weight-side SpAMM plan into the
content-addressed store; a serving engine launched with the same params and
SpAMM config (`repro.launch.serve --plan-store ...`) then warm-starts with
store hits only — no planning pass, no weight get-norm.

Params here come from the same seeded init the serve driver uses, so the
content fingerprints match; a production deployment would load them from a
checkpoint instead (the checkpoint records the store pointer — see
`repro.checkpoint.checkpoint.save(plan_store=...)`).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.plans.precompute import populate
from repro.plans.store import PlanStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plan-store", required=True,
                    help="store directory (created if missing)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tau", type=float, required=True)
    ap.add_argument("--spamm-tile", type=int, default=32)
    ap.add_argument("--spamm-backend", default="auto")
    ap.add_argument("--spamm-levels", type=int, default=0)
    ap.add_argument("--spamm-dtype", default="float32",
                    choices=("float32", "bfloat16", "bf16", "int8"),
                    help="GEMM compute dtype the plans are frozen for "
                         "(quantized norms + widened gate τ; int8 also "
                         "stores the per-tile weight scale tables)")
    ap.add_argument("--block-n", type=int, default=1)
    ap.add_argument("--autotune", action="store_true",
                    help="roofline-autotune block_n/levels/bucket per weight "
                         "(core.cost) instead of freezing at the flags above "
                         "— the flags become the tuner's defaults, always in "
                         "its search space")
    ap.add_argument("--tune-profile", default=None,
                    help="calibrated cost-profile JSON (benchmarks/autotune "
                         "--calibrate); default: nominal per-backend "
                         "coefficients")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pcfg = ParallelConfig(
        compute_dtype="float32", remat="none", decode_seq_shard=False,
        attn_q_chunk=64, attn_kv_chunk=64,
    )
    make_ctx(make_host_mesh())  # same init path as serve (device layout)
    params = M.init_params(cfg, pcfg, jax.random.key(args.seed))
    scfg = SpammConfig(enable=True, tau=args.tau, tile=args.spamm_tile,
                       backend=args.spamm_backend, levels=args.spamm_levels,
                       block_n=args.block_n, dtype=args.spamm_dtype,
                       autotune=args.autotune, tune_profile=args.tune_profile)
    store = PlanStore(args.plan_store)
    t0 = time.time()
    n = populate(store, params, scfg)
    dt = time.time() - t0
    tuned_note = " (autotuned block_n/levels/bucket)" if args.autotune else ""
    print(f"precomputed {n} weight plans into {args.plan_store} "
          f"({store.hits} already present, {store.misses} built) "
          f"in {dt:.2f}s — {len(store)} artifacts total{tuned_note}")


if __name__ == "__main__":
    main()
