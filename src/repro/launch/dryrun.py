import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, record memory/cost/HLO-derived roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell it writes experiments/dryrun/<mesh>/<arch>__<shape>.json with:
  memory_analysis (bytes/device), cost_analysis, HLO-walker totals (FLOPs,
  HBM bytes, collective schedule with trip-count multipliers), and the
  analytic MODEL_FLOPS (6·N·D / 6·N_active·D or serve equivalents).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ParallelConfig, SHAPES, TrainConfig, cells, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.models import model as M
from repro.models.transformer import NetCtx
from repro.optim.adamw import AdamW

# v5e-ish hardware model (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link (wire-byte model already per chip)


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop sharding on dims the axis sizes don't divide (e.g. vocab 50280 on
    a 16-way axis): argument shardings must divide evenly; GSPMD still
    re-shards internal ops as it sees fit."""
    out = []
    for i, entry in enumerate(list(spec) + [None] * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(entry if shape[i] % n == 0 else None)
    return P(*out)


def shard_tree(mesh, spec_tree, shape_tree):
    """SDS tree with NamedShardings attached."""
    return jax.tree.map(
        lambda sd, sp: sds(
            sd.shape, sd.dtype,
            NamedSharding(mesh, sanitize_spec(mesh, sp, sd.shape)),
        ),
        shape_tree,
        spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def batch_specs(cfg, shape, mesh, batch_axes):
    ba = batch_axes if batch_axes else None
    gb, s = shape.global_batch, shape.seq_len
    if cfg.frontend:
        inp = {
            "embeds": sds((gb, s, cfg.d_model), jnp.bfloat16,
                          NamedSharding(mesh, P(ba, None, None)))
        }
    else:
        inp = {
            "tokens": sds((gb, s), jnp.int32, NamedSharding(mesh, P(ba, None)))
        }
    return inp


def model_flops_estimate(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D per generated/prefilled token
    (N = active params, excluding embed table; attention ignored — this is
    the standard 6ND yardstick the task prescribes)."""
    d, l = cfg.d_model, cfg.num_layers
    if cfg.family == "ssm":
        import repro.models.ssm as S
        dims = S.ssm_dims(cfg.ssm, d)
        per_layer = d * dims.proj_out + dims.d_inner * d
    elif cfg.family == "hybrid":
        w = cfg.rglru.lru_width or d
        hd, hq, hk = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
        attn = d * hd * (hq + 2 * hk) + hq * hd * d
        rec = 3 * d * w + 2 * (w // 16) * w  # in×2 + out + blockdiag gates
        mlp = 3 * d * cfg.d_ff
        n_attn = cfg.num_layers // 3
        n_rec = cfg.num_layers - n_attn
        per_layer = 0.0
        total = n_attn * (attn + mlp) + n_rec * (rec + mlp)
        n_active = total + cfg.vocab * d  # + unembed
        toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mult = 6.0 if shape.kind == "train" else 2.0
        return mult * n_active * toks
    else:
        hd, hq, hk = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
        attn = d * hd * (hq + 2 * hk) + hq * hd * d
        if cfg.moe is not None:
            mcfg = cfg.moe
            ffn = 3 * d * mcfg.expert_ff * mcfg.top_k
            if mcfg.num_shared:
                ffn += 3 * d * mcfg.shared_ff
            ffn += d * mcfg.num_experts  # router
        else:
            n_mats = 3 if cfg.act in ("silu", "gelu") else 2
            ffn = n_mats * d * cfg.d_ff
        per_layer = attn + ffn
    n_active = l * per_layer + cfg.vocab * d  # + unembed (embed lookup ~free)
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * toks


def build_cell(arch: str, shape_name: str, mesh, pcfg: ParallelConfig,
               spamm_cfg=None):
    """Returns (lowered, meta) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ctx = make_ctx(mesh)
    ndata = 1
    for a in ctx.batch_axes:
        ndata *= mesh.shape[a]
    if shape.global_batch % ndata:
        ctx = NetCtx(mesh=mesh, batch_axes=None, model_axis="model")
        ndata = 1
    model_axis_size = mesh.shape["model"]

    params_shape = jax.eval_shape(
        lambda k: M.init_params(cfg, pcfg, k, model_axis_size), jax.random.key(0)
    )
    pspecs = M.param_pspecs(cfg, pcfg, params_shape)
    params_sds = shard_tree(mesh, pspecs, params_shape)

    if shape.kind == "train":
        opt = AdamW(TrainConfig())
        opt_shape = jax.eval_shape(opt.init, params_shape)
        opt_specs = {"mu": pspecs, "nu": pspecs}
        opt_sds = shard_tree(mesh, opt_specs, opt_shape)
        inp = batch_specs(cfg, shape, mesh, ctx.batch_axes)
        ba = ctx.batch_axes if ctx.batch_axes else None
        inp["labels"] = sds((shape.global_batch, shape.seq_len), jnp.int32,
                            NamedSharding(mesh, P(ba, None)))
        step = M.make_train_step(cfg, pcfg, ctx, opt, spamm_cfg=spamm_cfg)
        fn = jax.jit(step)
        with mesh:
            lowered = fn.lower(params_sds, opt_sds, inp,
                               sds((), jnp.int32, NamedSharding(mesh, P())))
    elif shape.kind == "prefill":
        inp = batch_specs(cfg, shape, mesh, ctx.batch_axes)
        step = M.make_prefill_step(cfg, pcfg, ctx)
        fn = jax.jit(step)
        with mesh:
            lowered = fn.lower(params_sds, inp)
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, pcfg, shape.global_batch, shape.seq_len)
        )
        cspecs = M.cache_pspecs(cfg, pcfg, cache_shape,
                                batch_axes=ctx.batch_axes or ("data",),
                                model_axis="model",
                                batch_replicated=ctx.batch_axes is None)
        cache_sds = shard_tree(mesh, cspecs, cache_shape)
        ba = ctx.batch_axes if ctx.batch_axes else None
        if cfg.frontend:
            tok = sds((shape.global_batch, 1, cfg.d_model), jnp.bfloat16,
                      NamedSharding(mesh, P(ba, None, None)))
        else:
            tok = sds((shape.global_batch, 1), jnp.int32,
                      NamedSharding(mesh, P(ba, None)))
        step = M.make_decode_step(cfg, pcfg, ctx)
        fn = jax.jit(step)
        with mesh:
            lowered = fn.lower(params_sds, tok, cache_sds,
                               sds((), jnp.int32, NamedSharding(mesh, P())))
    return lowered, {"cfg": cfg, "shape": shape}


def run_cell(arch, shape_name, multi_pod, pcfg, out_dir):
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered, meta = build_cell(arch, shape_name, mesh, pcfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    an = hlo_analysis.HloAnalysis(txt, ndev)
    totals = an.totals()

    mf = model_flops_estimate(meta["cfg"], meta["shape"])
    flops_dev = totals["flops_per_device"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": totals["hbm_bytes_per_device"] / HBM_BW,
        "collective_s": totals["collective_wire_bytes_per_device"] / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": ndev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "xla_cost_analysis_flops": cost.get("flops"),
        "hlo": totals,
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dom,
            "model_flops_global": mf,
            "model_flops_per_device": mf / ndev,
            "useful_flops_ratio": (mf / ndev) / flops_dev if flops_dev else None,
            "step_time_bound_s": max(terms.values()),
        },
    }
    fn = f"{out_dir}/{out['mesh']}/{arch}__{shape_name}.json"
    os.makedirs(os.path.dirname(fn), exist_ok=True)
    with open(fn, "w") as f:
        json.dump(out, f, indent=1)
    print(
        f"[OK] {arch} × {shape_name} ({out['mesh']}): compile={t_compile:.0f}s "
        f"peak={out['memory']['temp_bytes']/2**30:.2f}GiB/dev "
        f"terms(c/m/coll)={terms['compute_s']:.3e}/{terms['memory_s']:.3e}/"
        f"{terms['collective_s']:.3e}s dom={dom}",
        flush=True,
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--loss-chunk", type=int, default=1024)
    ap.add_argument("--seq-shard-acts", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the output dir")
    args = ap.parse_args()

    pcfg = ParallelConfig(remat=args.remat, param_dtype=args.param_dtype,
                          fsdp=not args.no_fsdp,
                          attn_q_chunk=args.q_chunk,
                          attn_kv_chunk=args.kv_chunk,
                          loss_chunk=args.loss_chunk,
                          seq_shard_acts=args.seq_shard_acts)
    if args.tag:
        args.out = args.out.rstrip("/") + "_" + args.tag
    todo = []
    if args.all:
        todo = [(a, s) for a, s, skip in cells() if not skip]
    else:
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, pcfg, args.out)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                print(f"[FAIL] {arch} × {shape} mp={mp}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed")


if __name__ == "__main__":
    main()
