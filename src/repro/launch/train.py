"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
      --steps 200 --batch 8 --seq 256 [--spamm --valid-ratio 0.3] \
      [--resume auto] [--reduced]

On a pod this is the per-host entrypoint (jax.distributed.initialize is
called when JAX_COORDINATOR is set); on CPU it runs the same code on a
1×1 mesh. `--resume auto` restarts from the latest checkpoint — combined
with the cluster scheduler's restart policy this is the node-failure story
(see DESIGN.md §9, tests/test_train_loop.py for the injected-failure test).
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax

from repro.configs import ParallelConfig, SpammConfig, TrainConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh, make_production_mesh
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--spamm", action="store_true",
                    help="enable SpAMM on all eligible GEMMs")
    ap.add_argument("--tau", type=float, default=0.0)
    ap.add_argument("--spamm-tile", type=int, default=64)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--reshard-every", type=int, default=0,
                    help="drift-triggered re-sharding probe cadence in "
                         "train steps; 0 = off (needs --spamm)")
    ap.add_argument("--reshard-devices", type=int, default=0,
                    help="strips to cut (0 = the mesh's data-axis extent)")
    ap.add_argument("--reshard-threshold", type=float, default=1.2,
                    help="re-cut when the live partition's predicted "
                         "imbalance exceeds the fresh cut's by this factor")
    ap.add_argument("--metrics-out", default=None,
                    help="write the run's metrics registry here as a "
                         "Prometheus text dump (train_step_seconds, "
                         "per-layer spamm_valid_fraction, reshard series)")
    ap.add_argument("--trace-out", default=None,
                    help="write the run's host-side spans here as Chrome-"
                         "trace JSON (load in Perfetto / about://tracing)")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pcfg = ParallelConfig(
        compute_dtype="float32" if not args.production_mesh else "bfloat16",
        remat="none" if args.reduced else "full",
        attn_q_chunk=64, attn_kv_chunk=64, loss_chunk=128,
        decode_seq_shard=False,
        grad_compression=args.grad_compression,
    )
    tcfg = TrainConfig(
        lr=args.lr, total_steps=args.steps, warmup=min(100, args.steps // 10),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    ctx = make_ctx(mesh)
    spamm_cfg = (
        SpammConfig(enable=True, tau=args.tau, tile=args.spamm_tile,
                    backend="auto")
        if args.spamm else None
    )
    reshard_cfg = None
    if args.reshard_every > 0:
        from repro.core.schedule import ReshardConfig

        reshard_cfg = ReshardConfig(
            num_devices=args.reshard_devices, every=args.reshard_every,
            drift_threshold=args.reshard_threshold)
    from repro.obs import Observability

    obs = Observability(process_name="repro-train")
    res = train(
        cfg, pcfg, tcfg, ctx,
        global_batch=args.batch, seq_len=args.seq, spamm_cfg=spamm_cfg,
        reshard_cfg=reshard_cfg,
        resume=(args.resume == "auto"),
        obs=obs,
    )
    print(
        f"done: steps={res.final_step} first_loss={res.losses[0]:.4f} "
        f"last_loss={res.losses[-1]:.4f} stragglers={res.straggler_steps}"
    )
    if res.spamm_stats:
        fracs = [s["valid_fraction"] for s in res.spamm_stats
                 if s["valid_fraction"] is not None]
        if fracs:
            print(f"spamm: mean_valid_fraction={sum(fracs)/len(fracs):.3f} "
                  f"gated_gemms/step={res.spamm_stats[-1]['gated_gemms']}")
        last = res.spamm_stats[-1]
        if "resharded" in last:
            imb = last["imbalance"]
            imb_s = f"{imb:.3f}" if imb is not None else "n/a"
            print(f"reshard: events={last['resharded']} "
                  f"partition_imbalance={imb_s}")
    if args.metrics_out:
        print(f"metrics -> {obs.write_metrics(args.metrics_out)}")
    if args.trace_out:
        print(f"trace -> {obs.write_trace(args.trace_out)}")
    if args.metrics_out or args.trace_out:
        print(obs.summary_table())


if __name__ == "__main__":
    main()
