"""Serving driver: load/init a model, serve batched greedy generation.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large --reduced \
      --num-requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="draw each request's prompt length uniformly from "
                         "[prompt_len/2, prompt_len] instead of one uniform "
                         "length — exercises the chunked slot scheduler "
                         "(attention stacks; implies chunked prefill)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: advance prompts C tokens per "
                         "engine step at ONE static shape, interleaved with "
                         "decode (C % spamm-tile == 0 when gating). Default "
                         "auto: chunk only for mixed-length batches; 0 "
                         "disables chunking (mixed lengths then rejected)")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="cap the chunked scheduler's concurrent slot pool "
                         "(power-of-two bucketed); below --num-requests the "
                         "queue drives admission into freed slots between "
                         "decode steps")
    ap.add_argument("--spamm-tau", type=float, default=None,
                    help="enable SpAMM norm-gated GEMMs at this τ — prefill "
                         "AND decode gate (decode through frozen plans); "
                         "one SpammContext per engine")
    ap.add_argument("--spamm-tile", type=int, default=32)
    ap.add_argument("--spamm-backend", default="auto")
    ap.add_argument("--spamm-block-n", type=int, default=1,
                    help="super-column width of the mm kernel; must match "
                         "the value the plan store was precomputed with, or "
                         "every lookup misses and plans are rebuilt")
    ap.add_argument("--spamm-levels", type=int, default=0,
                    help="norm-pyramid coarsening steps for hierarchical "
                         "gating (0 = flat); coarse tile = tile · 2^levels")
    ap.add_argument("--spamm-dtype", default="float32",
                    choices=("float32", "bfloat16", "bf16", "int8"),
                    help="GEMM compute dtype for the gated GEMMs (f32 "
                         "accumulate; gate stays a conservative superset of "
                         "the f32 gate via the widened τ). Must match the "
                         "plan store's precompute dtype or every lookup "
                         "misses")
    ap.add_argument("--spamm-autotune", action="store_true",
                    help="roofline-autotune block_n/levels/bucket per weight "
                         "at freeze time (core.cost); --spamm-block-n/"
                         "--spamm-levels become the tuner's defaults. Must "
                         "match the plan store's precompute setting or "
                         "lookups miss (tuned params address the artifacts)")
    ap.add_argument("--spamm-tune-profile", default=None,
                    help="calibrated cost-profile JSON for --spamm-autotune "
                         "(benchmarks/autotune --calibrate)")
    ap.add_argument("--plan-store", default=None,
                    help="on-disk PlanStore directory of precomputed frozen "
                         "weight plans (populate offline with "
                         "repro.launch.precompute_plans); the engine warm-"
                         "starts from it instead of running a planning pass")
    ap.add_argument("--no-freeze-plans", action="store_true",
                    help="legacy in-trace gating (weight normmaps re-derived "
                         "inside the compiled prefill; decode GEMMs fall "
                         "back to dense — decode only gates through frozen "
                         "plans) instead of frozen plans as jit inputs")
    ap.add_argument("--reshard-every", type=int, default=0,
                    help="drift-triggered re-sharding probe cadence in "
                         "engine steps (prefill + decode); 0 = off; needs "
                         "--spamm-tau. The engine maintains the equal-work "
                         "row partition a pod feeds to "
                         "distributed.spamm_rowpart(offsets=)")
    ap.add_argument("--reshard-devices", type=int, default=0,
                    help="strips to cut (0 = the mesh's data-axis extent)")
    ap.add_argument("--reshard-threshold", type=float, default=1.2,
                    help="re-cut when the live partition's predicted "
                         "imbalance exceeds the fresh cut's by this factor")
    ap.add_argument("--reshard-level", type=int, default=0,
                    help="norm-pyramid level of the re-sharding probe "
                         "estimate (coarser = cheaper)")
    ap.add_argument("--spamm-mesh-devices", type=int, default=0,
                    help="pod-sharded serving: run the compiled steps under "
                         "shard_map over a 1-D mesh of this many devices, "
                         "the batch rows cut by the live equal-work offsets "
                         "(needs --spamm-tau + frozen plans; batch and "
                         "prompt length must be multiples of --spamm-tile)")
    ap.add_argument("--spamm-shard-width", type=int, default=0,
                    help="static per-shard width in request GROUPS (of "
                         "--spamm-tile requests each); 0 = 2·ceil(groups/"
                         "devices). Caps how far the equal-work cut can "
                         "skew without a recompile")
    ap.add_argument("--metrics-out", default=None,
                    help="write the run's metrics registry here as a "
                         "Prometheus text dump (TTFT/decode latency "
                         "histograms, per-layer gated-GEMM series, plan "
                         "cache/store and reshard counters)")
    ap.add_argument("--trace-out", default=None,
                    help="write the run's host-side spans here as Chrome-"
                         "trace JSON (freeze, plan assembly, prefill, "
                         "decode steps, reshard probes; load in Perfetto)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pcfg = ParallelConfig(
        compute_dtype="float32", remat="none", decode_seq_shard=False,
        attn_q_chunk=64, attn_kv_chunk=64,
    )
    mesh = make_host_mesh()
    ctx = make_ctx(mesh)
    params = M.init_params(cfg, pcfg, jax.random.key(args.seed))
    spamm_cfg = None
    if args.spamm_tau is not None:
        spamm_cfg = SpammConfig(enable=True, tau=args.spamm_tau,
                                tile=args.spamm_tile,
                                backend=args.spamm_backend,
                                block_n=args.spamm_block_n,
                                levels=args.spamm_levels,
                                dtype=args.spamm_dtype,
                                autotune=args.spamm_autotune,
                                tune_profile=args.spamm_tune_profile)
    reshard_cfg = None
    if args.reshard_every > 0:
        if spamm_cfg is None:
            print("warning: --reshard-every needs --spamm-tau (the probe "
                  "estimates gated work); re-sharding stays OFF")
        from repro.core.schedule import ReshardConfig

        reshard_cfg = ReshardConfig(
            num_devices=args.reshard_devices, every=args.reshard_every,
            drift_threshold=args.reshard_threshold, level=args.reshard_level)
    from repro.obs import Observability

    obs = Observability(process_name="repro-serve")
    eng = Engine(cfg, pcfg, ctx, params, max_len=args.max_len,
                 spamm_cfg=spamm_cfg, plan_store=args.plan_store,
                 freeze_plans=not args.no_freeze_plans,
                 reshard_cfg=reshard_cfg,
                 mesh_devices=args.spamm_mesh_devices,
                 shard_max_width=args.spamm_shard_width or None,
                 prefill_chunk=args.prefill_chunk,
                 max_slots=args.max_slots,
                 obs=obs)

    rng = np.random.default_rng(args.seed)
    if args.mixed_lengths:
        plens = rng.integers(max(1, args.prompt_len // 2),
                             args.prompt_len + 1,
                             size=args.num_requests)
    else:
        plens = np.full(args.num_requests, args.prompt_len)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for n in plens
    ]
    t0 = time.time()
    outs = eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12].tolist()}")
    sp = reqs[0].out.get("spamm") if reqs[0].out else None
    if sp is not None:
        vf = sp["valid_fraction"]
        vf_s = f"{vf:.3f}" if vf is not None else "n/a"
        dvf = sp.get("decode_valid_fraction")
        dvf_s = f"{dvf:.3f}" if dvf is not None else "n/a"
        print(f"  spamm: valid_fraction={vf_s} gated_gemms={sp['gated_gemms']} "
              f"decode_valid_fraction={dvf_s} "
              f"decode_gated_gemms={sp['decode_gated_gemms']} "
              f"cache={sp['plan_cache_hits']}h/{sp['plan_cache_misses']}m")
        lat = sp.get("latency")
        if lat is not None:
            # engine-measured per-phase latency (TTFT from wave start to
            # first token; decode stats over the wave's inter-token gaps)
            ttft = lat.get("ttft_s")
            line = (f"  latency: ttft="
                    + (f"{ttft * 1e3:.1f}ms" if ttft is not None else "n/a"))
            if lat.get("decode_steps"):
                line += (f" decode mean={lat['decode_mean_s'] * 1e3:.1f}ms"
                         f" p50={lat['decode_p50_s'] * 1e3:.1f}ms"
                         f" p95={lat['decode_p95_s'] * 1e3:.1f}ms"
                         f" ({lat['decode_steps']} steps)")
            print(line)
        cres = sp.get("cost_residual")
        if cres:
            for phase, c in cres.items():
                print(f"  cost[{phase}]: predicted={c['predicted_s']:.4f}s "
                      f"measured={c['measured_s']:.4f}s "
                      f"log2_residual={c['log2_ratio']:+.2f}")
        gb = sp.get("gemm_bytes_moved")
        dgb = sp.get("decode_gemm_bytes_moved")
        if gb is not None or dgb is not None:
            gb_s = f"{gb/1e6:.3f}MB" if gb is not None else "n/a"
            dgb_s = f"{dgb/1e6:.3f}MB" if dgb is not None else "n/a"
            print(f"  spamm dtype={sp.get('compute_dtype', 'float32')}: "
                  f"prefill_gemm_bytes={gb_s} decode_gemm_bytes={dgb_s}")
        if "plan_store_hits" in sp:
            print(f"  plan_store: {sp['plan_store_hits']}h/"
                  f"{sp['plan_store_misses']}m")
        if "resharded" in sp:
            imb = sp["partition_imbalance"]
            imb_s = f"{imb:.3f}" if imb is not None else "n/a"
            print(f"  reshard: events={sp['resharded']} "
                  f"probes={sp['reshard_probes']} "
                  f"partition_imbalance={imb_s}")
            offs = eng.partition_offsets
            if offs is None:
                print("  partition: unsharded (no live cut yet)")
            else:
                offs = np.asarray(offs)
                rows = np.diff(offs)
                loads = eng._resharder.live_loads
                for d in range(rows.shape[0]):
                    ld = f"{loads[d]:.3f}" if loads is not None else "n/a"
                    print(f"    strip {d}: rows [{offs[d]}, {offs[d + 1]}) "
                          f"({int(rows[d])} rows) predicted_load={ld}")
        else:
            print("  partition: unsharded (no reshard controller attached)")
        lay = eng.shard_layout
        if lay is not None:
            # lockstep mesh: the per-step wall-clock is the slowest shard's;
            # the engine's own decode-step histogram is the measurement now
            # (reshard stalls included), the per-shard layout shows where
            # the rows sat
            o = lay["offsets"]
            ms = (lat or {}).get("decode_mean_s")
            ms_s = (f"{ms * 1e3:.1f} ms/step (lockstep)" if ms is not None
                    else "n/a ms/step")
            print(f"  pod-sharded over {args.spamm_mesh_devices} devices: "
                  f"{ms_s}, slot_width={lay['slot_width']} reqs/shard")
            for d, n in enumerate(lay["real"]):
                print(f"    shard {d}: reqs [{o[d]}, {o[d + 1]}) "
                      f"({n} live, {lay['slot_width'] - n} pad slots)")
    if args.metrics_out:
        print(f"metrics -> {obs.write_metrics(args.metrics_out)}")
    if args.trace_out:
        print(f"trace -> {obs.write_trace(args.trace_out)}")
    if args.metrics_out or args.trace_out:
        print(obs.summary_table())


if __name__ == "__main__":
    main()
