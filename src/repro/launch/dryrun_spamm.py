import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""SpAMM-at-scale dry-run (the paper's own technique on the production mesh).

Lowers the distributed SpAMM variants on the 16×16 pod slice for an
N=32768 algebraic-decay workload (paper §4.1's largest size):
  * rowpart/contiguous — paper §3.4 multi-GPU scheme verbatim
  * rowpart/cyclic     — + §3.5.1 load balance
  * 2d                 — beyond-paper SUMMA-style (K sharded, psum_scatter)

The jnp backend's HLO computes the DENSE masked product (XLA cost = dense);
the Pallas kernel on TPU executes only valid tiles, so the compute term is
also reported scaled by the calibrated valid_ratio ("effective").

  PYTHONPATH=src python -m repro.launch.dryrun_spamm [--n 32768] [--ratio 0.1]
"""
import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed, spamm as cs
from repro.core.tau_search import search_tau
from repro.kernels import ref
from repro.launch import hlo_analysis
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS, sds
from repro.launch.mesh import make_production_mesh


def calibrate_tau(n_small: int, tile: int, target_ratio: float) -> float:
    """τ→ratio is ~size-stable for the §4.1 decay law (paper Table 1 shows a
    slow drift of τ with N); calibrate on a host-feasible size."""
    a = jnp.asarray(cs.algebraic_decay(n_small, seed=0))
    na = ref.tile_norms_ref(a, tile)
    tau, res = search_tau(na, na, target_ratio)
    return float(tau), float(res.achieved_ratio)


def run_variant(name, fn, specs, n, mesh, tau, ratio, out_dir):
    a_sds = sds((n, n), jnp.float32, NamedSharding(mesh, specs[0]))
    b_sds = sds((n, n), jnp.float32, NamedSharding(mesh, specs[1]))
    with mesh:
        lowered = jax.jit(fn).lower(a_sds, b_sds)
        compiled = lowered.compile()
    an = hlo_analysis.HloAnalysis(compiled.as_text(), 256)
    t = an.totals()
    dense_compute = t["flops_per_device"] / PEAK_FLOPS
    terms = {
        "compute_dense_s": dense_compute,
        "compute_effective_s": dense_compute * ratio,  # Pallas path skips tiles
        "memory_s": t["hbm_bytes_per_device"] / HBM_BW,
        "memory_effective_s": t["hbm_bytes_per_device"] / HBM_BW * ratio,
        "collective_s": t["collective_wire_bytes_per_device"] / ICI_BW,
    }
    out = {
        "variant": name,
        "n": n,
        "tau": tau,
        "valid_ratio": ratio,
        "roofline": terms,
        "collectives": t["collectives"],
        "memory": {
            "argument_bytes": compiled.memory_analysis().argument_size_in_bytes,
            "peak_bytes": compiled.memory_analysis().peak_memory_in_bytes,
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(f"{out_dir}/{name}.json", "w") as f:
        json.dump(out, f, indent=1)
    coll = {k: f"{v['wire_bytes']/1e9:.2f}GB" for k, v in t["collectives"].items()}
    print(
        f"[OK] spamm/{name}: dense_c={terms['compute_dense_s']*1e3:.2f}ms "
        f"eff_c={terms['compute_effective_s']*1e3:.2f}ms "
        f"mem={terms['memory_s']*1e3:.1f}ms coll={terms['collective_s']*1e3:.2f}ms "
        f"{coll}",
        flush=True,
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32768)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--ratio", type=float, default=0.10)
    ap.add_argument("--out", default="experiments/dryrun_spamm")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2×16×16: pod axis joins data as extra row partition"
                         " (the paper's 'distributed GPUs' future work)")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tau, ratio = calibrate_tau(4096, args.tile, args.ratio)
    print(f"calibrated tau={tau:.4f} → ratio≈{ratio:.3f} (N=4096 proxy)")

    def rowpart(sched):
        def fn(a, b):
            c, frac = distributed.spamm_rowpart(
                a, b, tau, mesh, axis="data", tile=args.tile, backend="jnp",
                schedule=sched)
            return c
        return fn

    row_axes = ("pod", "data") if args.multi_pod else "data"

    def twod(a, b):
        c, frac = distributed.spamm_2d(
            a, b, tau, mesh, row_axis=row_axes, tile=args.tile, backend="jnp")
        return c

    n = args.n
    if args.multi_pod:
        run_variant("2d_multipod", twod,
                    (P(row_axes, "model"), P("model", None)), n, mesh, tau,
                    ratio, args.out)
        return
    run_variant("rowpart_contiguous", rowpart("contiguous"),
                (P("data", None), P(None, None)), n, mesh, tau, ratio, args.out)
    run_variant("rowpart_cyclic", rowpart("cyclic"),
                (P("data", None), P(None, None)), n, mesh, tau, ratio, args.out)
    run_variant("2d_psum_scatter", twod,
                (P("data", "model"), P("model", None)), n, mesh, tau, ratio,
                args.out)

    # c4: bf16 operands (paper Alg.3 fp16 fragments → TPU-native bf16):
    # halves every byte term (HBM + wire); MXU accumulates f32.
    def twod_bf16(a, b):
        c, frac = distributed.spamm_2d(
            a.astype(jnp.bfloat16), b.astype(jnp.bfloat16), tau, mesh,
            tile=args.tile, backend="jnp")
        return c

    a_sds = sds((n, n), jnp.bfloat16, NamedSharding(mesh, P("data", "model")))
    b_sds = sds((n, n), jnp.bfloat16, NamedSharding(mesh, P("model", None)))
    with mesh:
        lowered = jax.jit(lambda a, b: distributed.spamm_2d(
            a, b, tau, mesh, tile=args.tile, backend="jnp")[0]).lower(a_sds, b_sds)
        compiled = lowered.compile()
    an = hlo_analysis.HloAnalysis(compiled.as_text(), 256)
    t = an.totals()
    dense_compute = t["flops_per_device"] / PEAK_FLOPS
    out = {
        "variant": "2d_bf16", "n": n, "tau": tau, "valid_ratio": ratio,
        "roofline": {
            "compute_dense_s": dense_compute,
            "compute_effective_s": dense_compute * ratio,
            "memory_s": t["hbm_bytes_per_device"] / HBM_BW,
            "memory_effective_s": t["hbm_bytes_per_device"] / HBM_BW * ratio,
            "collective_s": t["collective_wire_bytes_per_device"] / ICI_BW,
        },
        "collectives": t["collectives"],
    }
    with open(f"{args.out}/2d_bf16.json", "w") as f:
        json.dump(out, f, indent=1)
    r = out["roofline"]
    print(f"[OK] spamm/2d_bf16: dense_c={r['compute_dense_s']*1e3:.2f}ms "
          f"eff_c={r['compute_effective_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.1f}ms "
          f"coll={r['collective_s']*1e3:.2f}ms", flush=True)


if __name__ == "__main__":
    main()
