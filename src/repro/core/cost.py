"""Roofline-calibrated analytic cost model + kernel autotuner.

The paper's speedup hinges on blocking parameters that match the memory
hierarchy, yet the pipeline hardcodes `block_n`, pyramid `levels` and the
worklist bucket floor. This module makes parameter choice an explicit
bytes/flops computation per kernel, calibrated per machine:

  * **Counts** (`predict_counts`, `gemm_bytes`, `gemm_flops`) — the analytic
    per-kernel work of one SpAMM call: surviving work-list steps × tile
    footprints for `spamm_mm_worklist`/`_int8` (dtype itemsize-aware — the
    same formula as `SpammPlan.bytes_moved()`, which delegates here), the
    activation get-norm read, pyramid pooling reads, and the gate-product
    evaluations of flat vs hierarchical planning (a host simulation of the
    coarse-to-fine descent, counting candidates per level).
  * **Coefficients** (`CostCoeffs`, `CostProfile`, `calibrate`) — machine
    numbers that turn counts into seconds: sustained bytes/s, dot flops/s,
    per-grid-step launch overhead, per-call base overhead and host gate-op
    rate. `calibrate` fits them from measured wall-clock of the real
    kernels (`benchmarks/kernels_micro.py`-style timings: get-norm sweeps +
    work-list executes across τ) by non-negative least squares, and
    `CostProfile` persists them as JSON keyed by backend × device kind.
  * **Tuner** (`tune`, `tune_weight`) — per-weight argmin of predicted call
    time over `block_n` × pyramid `levels` × bucket floor. The hardcoded
    defaults are always in the search space, so the tuned pick is never
    predicted slower than them. The result is a `TunedParams` record that
    `FrozenWeight` carries as an aux field (persisted through `PlanStore`),
    so tuning amortizes exactly like the rest of the frozen-plan runtime.

Nothing here imports `core.plan` at module level (plan imports this module
for `bucket`/`gemm_bytes`); the calibration pass imports it lazily.
"""
from __future__ import annotations

import json
import os
import socket
import time
from typing import Mapping, NamedTuple, Optional, Sequence

import numpy as np

from repro.kernels import quantize as kquant

COST_SCHEMA_VERSION = 1

# representative activation row-tile grid the offline tuner prices calls at
# when the caller has no real shape in hand (precompute time: serving row
# grids are not known yet). Documented, deterministic — NOT a fit parameter.
DEFAULT_TUNE_GM = 8


def bucket(n: int, minimum: int = 16) -> int:
    """Pad a step count to a power-of-two bucket of at least `minimum` so
    the jitted ragged kernel compiles once per bucket, not once per distinct
    Σnvalid. THE bucket function — `core.plan._bucket` and
    `FrozenWeight.for_rows` both resolve through it; the tuner searches
    over `minimum` (the worklist bucket floor)."""
    return max(minimum, 1 << max(n - 1, 0).bit_length())


def bucket_ladder(n_max: int, minimum: int = 16) -> list:
    """Every bucket `bucket(n, minimum)` can return for n in [1, n_max] —
    the power-of-two ladder from `bucket(1)` up to `bucket(n_max)`. Its
    LENGTH is the compile-count bound for shape-bucketed serving: a sweep
    over arbitrary batch/slot counts ≤ n_max compiles at most
    `len(bucket_ladder(n_max, m))` distinct bucketed shapes (the serving
    engine's `trace_counts` guard asserts against exactly this)."""
    lo = bucket(1, minimum)
    hi = bucket(max(int(n_max), 1), minimum)
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * 2)
    return out


# ---------------------------------------------------------------------------
# machine coefficients
# ---------------------------------------------------------------------------

class CostCoeffs(NamedTuple):
    """Per-(backend × device kind) machine coefficients, all in base SI
    units (bytes/s, flops/s, seconds). `calibrated` is False on the nominal
    fallback table below — the tuner still works (deterministically) but
    predictions are order-of-magnitude, not fitted."""
    bytes_per_s: float       # sustained memory bandwidth of the kernels
    flops_per_s: float       # sustained dot throughput (MXU / XLA dot)
    step_overhead_s: float   # per work-list grid step dispatch overhead
    base_overhead_s: float   # fixed per-call overhead (launch + Python)
    gate_ops_per_s: float    # host gate-product evaluations per second
    calibrated: bool = False


# Nominal fallbacks per backend when no calibration profile is attached.
# interpret's per-step overhead dominates everything (the kernel body runs
# step-by-step under emulation); pallas numbers are v5e-litepod-ish; jnp is
# a single fused XLA CPU einsum. Calibration replaces these.
DEFAULT_COEFFS = {
    "pallas": CostCoeffs(8.0e11, 2.0e14, 2.0e-7, 5.0e-6, 1.0e10),
    "interpret": CostCoeffs(2.0e9, 1.0e10, 4.0e-5, 3.0e-4, 2.0e8),
    "jnp": CostCoeffs(2.0e10, 5.0e10, 5.0e-7, 5.0e-5, 2.0e8),
}


def device_kind() -> str:
    """Kind string of device 0 ("cpu", "TPU v5e", ...), or "none"."""
    try:
        import jax

        d = jax.devices()[0]
        return str(getattr(d, "device_kind", None) or d.platform)
    except Exception:  # no backend at all
        return "none"


def profile_key(backend: str, kind: Optional[str] = None) -> str:
    return f"{backend}/{kind if kind is not None else device_kind()}"


class CostProfile:
    """Calibrated coefficients keyed by backend × device kind, persisted as
    JSON (`{"schema": 1, "entries": {"interpret/cpu": {...}}, "meta": ...}`).

    `coeffs(backend)` falls back to the nominal `DEFAULT_COEFFS` table when
    the exact key is missing, then to any entry of the same backend — a
    profile calibrated on one host still beats nominals on a sibling."""

    def __init__(self, entries: Optional[dict] = None, meta: Optional[dict] = None):
        self.entries: dict = dict(entries or {})
        self.meta = dict(meta or {})

    def put(self, backend: str, coeffs: CostCoeffs, kind: Optional[str] = None):
        self.entries[profile_key(backend, kind)] = coeffs

    def coeffs(self, backend: str, kind: Optional[str] = None) -> CostCoeffs:
        key = profile_key(backend, kind)
        hit = self.entries.get(key)
        if hit is not None:
            return hit
        prefix = backend + "/"
        for k in sorted(self.entries):
            if k.startswith(prefix):
                return self.entries[k]
        return DEFAULT_COEFFS.get(backend, DEFAULT_COEFFS["jnp"])

    def key_used(self, backend: str, kind: Optional[str] = None) -> str:
        """The profile key `coeffs` resolves (for provenance in TunedParams)."""
        key = profile_key(backend, kind)
        if key in self.entries:
            return key
        prefix = backend + "/"
        for k in sorted(self.entries):
            if k.startswith(prefix):
                return k
        return f"{backend}/<nominal>"

    def save(self, path: str) -> str:
        payload = {
            "schema": COST_SCHEMA_VERSION,
            "entries": {k: v._asdict() for k, v in self.entries.items()},
            "meta": {**self.meta, "hostname": socket.gethostname()},
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "CostProfile":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("schema") != COST_SCHEMA_VERSION:
            raise ValueError(
                f"cost profile {path!r} has schema "
                f"{payload.get('schema')!r}; this build reads "
                f"{COST_SCHEMA_VERSION} — re-run calibration")
        entries = {k: CostCoeffs(**v) for k, v in payload["entries"].items()}
        return cls(entries, payload.get("meta"))

    @classmethod
    def load_or_default(cls, path: Optional[str]) -> "CostProfile":
        """A profile from `path`, or the empty (nominal-fallback) profile
        when path is None/missing — the tuner stays usable and
        deterministic without a calibration run."""
        if path and os.path.isfile(path):
            return cls.load(path)
        return cls()


# ---------------------------------------------------------------------------
# analytic per-kernel counts
# ---------------------------------------------------------------------------

def gemm_bytes(valid_tiles, pairs, tile: int, block_n: int, dtype):
    """GEMM bytes the executed work-list moves — per real step one
    (tile, tile) A block and one (tile, tile·block_n) B block at the
    compute dtype's itemsize, plus one f32 (tile, tile·block_n) output
    flush per active output pair. `SpammPlan.bytes_moved()` delegates here;
    accepts python floats or jnp arrays (pure arithmetic)."""
    isize = kquant.dtype_itemsize(dtype)
    t2 = float(tile * tile)
    return (valid_tiles * (t2 * (1 + block_n) * isize)
            + pairs * (t2 * block_n * 4.0))


def gemm_flops(valid_tiles, tile: int, block_n: int):
    """MXU flops of the executed work-list: one
    (tile, tile) @ (tile, tile·block_n) dot per real step."""
    return valid_tiles * (2.0 * tile * tile * tile * block_n)


class KernelCounts(NamedTuple):
    """Analytic work of ONE SpAMM call at a given parameterization."""
    steps_real: int          # accumulating work-list steps (Σnvalid)
    steps_grid: int          # grid length the kernel actually runs
    pairs: int               # active output (i, j) pairs (flush writes)
    gemm_bytes: float        # work-list operand reads + output flushes
    flops: float             # MXU dot flops over the real steps
    norm_bytes: float        # activation get-norm read (+ pooling reads)
    gate_ops: float          # planner gate-product evaluations


def _pool_norms_np(n: np.ndarray) -> np.ndarray:
    """Numpy twin of `kernels.ref.pool_norms_ref`: sqrt-sumsq 2×2 pooling
    with zero padding at ragged edges (host-side, for count simulation)."""
    gm, gk = n.shape
    pm, pk = gm % 2, gk % 2
    if pm or pk:
        n = np.pad(n, ((0, pm), (0, pk)))
    sq = n.astype(np.float64) ** 2
    pooled = (sq[0::2, 0::2] + sq[1::2, 0::2] + sq[0::2, 1::2]
              + sq[1::2, 1::2])
    return np.sqrt(pooled)


def _descent_gate_ops(na: np.ndarray, nb: np.ndarray, tau: float,
                      levels: int) -> float:
    """Gate-product evaluations of hierarchical planning at `levels`
    coarsening steps: the full coarsest grid plus 8× the survivors of every
    refinement level (levels=0 ⇒ the flat gate's full fine grid). Mirrors
    `core.plan._hier_descend_host`'s work, counting instead of collecting."""
    la, lb = [na], [nb]
    for _ in range(levels):
        la.append(_pool_norms_np(la[-1]))
        lb.append(_pool_norms_np(lb[-1]))
    top = levels
    gm_t, gk_t = la[top].shape
    gn_t = lb[top].shape[1]
    ops = float(gm_t) * gk_t * gn_t
    if levels == 0:
        return ops
    cand = (la[top][:, None, :] * np.swapaxes(lb[top], 0, 1)[None]
            >= tau)
    surv = float(cand.sum())
    for l in range(top - 1, -1, -1):
        ops += 8.0 * surv
        if surv == 0:
            break
        # refine the actual candidate set so per-level survivor counts are
        # exact, not a geometric guess
        gm_l, gk_l = la[l].shape
        gn_l = lb[l].shape[1]
        cand = np.repeat(np.repeat(np.repeat(cand, 2, 0), 2, 1), 2, 2)
        cand = cand[:gm_l, :gn_l, :gk_l]
        cand = cand & (la[l][:, None, :] * np.swapaxes(lb[l], 0, 1)[None]
                       >= tau)
        surv = float(cand.sum())
    return ops


def predict_counts(
    norm_a: np.ndarray,
    norm_b: np.ndarray,
    tau: float,
    *,
    tile: int,
    block_n: int = 1,
    dtype: str = "float32",
    levels: int = 0,
    bucket_min: int = 16,
    mode: str = "eager",
) -> KernelCounts:
    """Analytic call counts for (gm, gk) × (gk, gn) normmaps gated at `tau`.

    The gate here IS `core.plan.gate_mask`'s (any-member super-column
    grouping ≡ max-norm test, fp32 multiply monotone), so on the real
    normmaps the predicted steps/pairs equal the built plan's
    `valid_tiles`/active pairs exactly — the invariant
    `tests/test_cost_model.py` pins against `SpammPlan.bytes_moved()`.

    mode="eager": the grid runs exactly the surviving steps (bucket-padded).
    mode="frozen": the grid enumerates ALL weight-admissible steps
    (gm × pairs-with-nonzero-weight-norm, the `FrozenWeight.for_rows`
    tables) and the traced activation gate turns accumulation on per step —
    step overhead scales with the frozen table, bytes/flops with the
    surviving set. N is zero-padded up to tile·block_n like `pad_to_tile`.
    """
    na = np.asarray(norm_a, np.float64)
    nb = np.asarray(norm_b, np.float64)
    gm, gk = na.shape
    gn = nb.shape[1]
    pad_n = (-gn) % block_n
    if pad_n:
        nb = np.pad(nb, ((0, 0), (0, pad_n)))
        gn += pad_n
    gnb = gn // block_n
    nbmax = nb.reshape(gk, gnb, block_n).max(2) if block_n > 1 else nb
    mask = na[:, None, :] * np.swapaxes(nbmax, 0, 1)[None] >= tau
    v = int(mask.sum())
    pairs = int(mask.any(-1).sum())
    if mode == "frozen":
        if tau > 0.0:
            adm = int((nbmax > 0.0).sum())
        else:
            adm = gk * gnb
        steps_grid = bucket(gm * adm, bucket_min)
    elif mode == "eager":
        steps_grid = bucket(v, bucket_min)
    else:
        raise ValueError(f"mode {mode!r} not in ('eager', 'frozen')")
    norm_bytes = float(gm * tile) * (gk * tile) * 4.0
    lv_bytes, lvl = 0.0, (gm, gk)
    for _ in range(levels):
        lv_bytes += lvl[0] * lvl[1] * 4.0
        lvl = ((lvl[0] + 1) // 2, (lvl[1] + 1) // 2)
    gate_ops = (0.0 if mode == "frozen" else
                _descent_gate_ops(na, nb, tau, levels))
    if mode == "frozen":
        # the traced activation gate is one product-compare per grid step
        gate_ops = float(steps_grid)
    return KernelCounts(
        steps_real=v,
        steps_grid=steps_grid,
        pairs=pairs,
        gemm_bytes=float(gemm_bytes(float(v), float(pairs), tile, block_n,
                                    dtype)),
        flops=float(gemm_flops(float(v), tile, block_n)),
        norm_bytes=norm_bytes + lv_bytes,
        gate_ops=gate_ops,
    )


def predict_time_s(counts: KernelCounts, coeffs: CostCoeffs) -> float:
    """Roofline-style additive model: fixed call overhead + per-step
    dispatch + memory time + compute time + planner gate time. Additive
    (not max-of-terms) because the measured kernels overlap none of these
    phases — calibration fits the same decomposition."""
    return (coeffs.base_overhead_s
            + counts.steps_grid * coeffs.step_overhead_s
            + (counts.gemm_bytes + counts.norm_bytes) / coeffs.bytes_per_s
            + counts.flops / coeffs.flops_per_s
            + counts.gate_ops / coeffs.gate_ops_per_s)


def predict_plan_time_s(plan, coeffs: CostCoeffs):
    """Predicted wall-clock of ONE executed work-list call, computed from a
    (possibly traced) `SpammPlan`'s own fields — the in-trace twin of
    `predict_counts` (frozen mode) → `predict_time_s`.

    Pure jnp-compatible arithmetic: `valid_tiles`/`bytes_moved()` may be
    tracers, so the prediction embeds into the compiled step right next to
    the gate and prices the work-list that EXECUTION actually ran (not a
    planning-time estimate). The cost-residual telemetry taps this value per
    gated GEMM and pairs the per-phase sum with measured wall-clock — the
    feedback loop that surfaces a stale `CostProfile`."""
    gm, gk = plan.norm_a.shape
    if plan.work is not None and plan.work.step_i is not None:
        # frozen/work-list plans: the grid length is the static step-table
        # shape; one traced gate product-compare per grid step
        steps_grid = float(plan.work.step_i.shape[0])
    else:
        # dense-bitmap plans have no static grid; approximate with the
        # (possibly traced) surviving-step count
        steps_grid = plan.valid_tiles * 1.0
    gate_ops = steps_grid
    norm_bytes = float(gm * plan.tile) * (gk * plan.tile) * 4.0
    lv_bytes, lvl = 0.0, (gm, gk)
    for _ in range(plan.levels):
        lv_bytes += lvl[0] * lvl[1] * 4.0
        lvl = ((lvl[0] + 1) // 2, (lvl[1] + 1) // 2)
    flops = gemm_flops(plan.valid_tiles * 1.0, plan.tile, plan.block_n)
    return (coeffs.base_overhead_s
            + steps_grid * coeffs.step_overhead_s
            + (plan.bytes_moved() + norm_bytes + lv_bytes) / coeffs.bytes_per_s
            + flops / coeffs.flops_per_s
            + gate_ops / coeffs.gate_ops_per_s)


def predict_plan_static(plan, coeffs: CostCoeffs):
    """Split `predict_plan_time_s` into its STATIC part, evaluated on host
    at trace time — the zero-graph-cost path the telemetry taps use.

    Every term of the per-call prediction except the executed-work terms is
    a pure function of static plan metadata (normmap shapes, step-table
    length, levels, coefficients): base + step overheads, norm/pyramid
    bytes, gate ops. The two traced quantities — GEMM bytes and valid
    tiles — already ride the telemetry callback as operands (bytes
    directly; valid tiles as valid_fraction × the static total_tiles), so
    the HOST side of the callback can finish the prediction with
    `finish_plan_time_s` and the armed graph stays IDENTICAL to the
    unarmed one (benchmarks/obs_overhead.py holds that line).

    Returns `(const_s, total_tiles, tile, block_n)` host floats, or None
    for plans without static step tables (no frozen work-list — the
    in-trace `predict_plan_time_s` still covers those if a caller wants
    the traced prediction)."""
    if plan.work is None or plan.work.step_i is None:
        return None
    gm, gk = plan.norm_a.shape
    steps_grid = float(plan.work.step_i.shape[0])
    norm_bytes = float(gm * plan.tile) * (gk * plan.tile) * 4.0
    lv_bytes, lvl = 0.0, (gm, gk)
    for _ in range(plan.levels):
        lv_bytes += lvl[0] * lvl[1] * 4.0
        lvl = ((lvl[0] + 1) // 2, (lvl[1] + 1) // 2)
    gmm, gnb, gkk = plan.grid
    const_s = (coeffs.base_overhead_s
               + steps_grid * coeffs.step_overhead_s
               + (norm_bytes + lv_bytes) / coeffs.bytes_per_s
               + steps_grid / coeffs.gate_ops_per_s)
    return (const_s, float(gmm * gnb * gkk), plan.tile, plan.block_n)


def finish_plan_time_s(static, valid_fraction: float, gemm_bytes: float,
                       coeffs: CostCoeffs) -> float:
    """Host-side completion of `predict_plan_static`: add the executed-work
    terms from the callback's concrete operands. By construction equal to
    `predict_plan_time_s` on the same plan (tests pin the identity)."""
    const_s, total_tiles, tile, block_n = static
    flops = gemm_flops(valid_fraction * total_tiles, tile, block_n)
    return (const_s + gemm_bytes / coeffs.bytes_per_s
            + flops / coeffs.flops_per_s)


# ---------------------------------------------------------------------------
# the autotuner
# ---------------------------------------------------------------------------

class TunedParams(NamedTuple):
    """One weight's tuned blocking parameters + provenance. Hashable (a
    NamedTuple of primitives) so it rides `FrozenWeight`'s static aux
    through pytree flattening, and JSON-trivial so `PlanStore` persists it
    in the manifest (legacy manifests without it load as tuned=None)."""
    block_n: int
    levels: int
    bucket: int              # worklist bucket floor (`bucket(minimum=)`)
    predicted_us: float      # predicted per-call time at the tuned params
    default_predicted_us: float  # same model at the hardcoded defaults
    profile_key: str         # coefficients used ("interpret/cpu", ...)

    def as_manifest(self) -> dict:
        return dict(self._asdict())

    @classmethod
    def from_manifest(cls, d: Optional[dict]) -> Optional["TunedParams"]:
        if d is None:
            return None
        return cls(block_n=int(d["block_n"]), levels=int(d["levels"]),
                   bucket=int(d["bucket"]),
                   predicted_us=float(d["predicted_us"]),
                   default_predicted_us=float(d["default_predicted_us"]),
                   profile_key=str(d["profile_key"]))


BLOCK_N_CHOICES = (1, 2, 4)
LEVELS_CHOICES = (0, 1, 2)
BUCKET_CHOICES = (16, 64, 256)


def tune(
    norm_b: np.ndarray,
    tau: float,
    *,
    tile: int,
    dtype: str = "float32",
    coeffs: CostCoeffs,
    profile_key_used: str = "<nominal>",
    gm: int = DEFAULT_TUNE_GM,
    gm_hist: Optional[Mapping[int, float]] = None,
    norm_a: Optional[np.ndarray] = None,
    mode: str = "frozen",
    defaults: tuple = (1, 0, 16),
    block_n_choices: Sequence[int] = BLOCK_N_CHOICES,
    levels_choices: Sequence[int] = LEVELS_CHOICES,
    bucket_choices: Sequence[int] = BUCKET_CHOICES,
) -> TunedParams:
    """Argmin of predicted call time over block_n × levels × bucket floor.

    norm_b: the weight-side FINE normmap of the view the kernel multiplies
    (quantized view for low dtypes). tau: the GATE threshold (already
    widened for low dtypes). norm_a: a representative activation normmap;
    None prices with the all-ones activation (gate reduces to nb ≥ τ —
    deterministic, weight-structure-driven). gm_hist: an observed serving
    row-grid histogram {gm: weight} (`Engine.gm_histogram`) — candidates
    are then scored by the WEIGHTED SUM of predicted times over the grids
    a deployment actually runs instead of the single synthetic `gm`
    (an explicit `norm_a` carries its own grid and takes precedence). The
    defaults triple is always in the search space, so `predicted_us ≤
    default_predicted_us` by construction; ties keep the earliest
    candidate, and candidates are enumerated defaults-first then
    ascending, making the tuner a pure function of (norms, τ, grid
    weights, coefficients).
    """
    nb = np.asarray(norm_b, np.float64)
    gk = nb.shape[0]
    if norm_a is not None:
        na = np.asarray(norm_a, np.float64)
        grids = [(na, 1.0)]
    elif gm_hist:
        grids = [(np.ones((int(g), gk), np.float64), float(w))
                 for g, w in sorted(gm_hist.items()) if w > 0 and g > 0]
        if not grids:
            raise ValueError(f"gm_hist has no usable entries: {gm_hist!r}")
    else:
        grids = [(np.ones((gm, gk), np.float64), 1.0)]

    def predicted(bn: int, lv: int, bk_min: int) -> float:
        t = 0.0
        for na_g, w in grids:
            c = predict_counts(na_g, nb, float(tau), tile=tile, block_n=bn,
                               dtype=dtype, levels=lv, bucket_min=bk_min,
                               mode=mode)
            t += w * predict_time_s(c, coeffs)
        return t

    d_bn, d_lv, d_bk = defaults
    cands = [(int(d_bn), int(d_lv), int(d_bk))]
    for bn in block_n_choices:
        for lv in levels_choices:
            for bk_min in bucket_choices:
                c = (int(bn), int(lv), int(bk_min))
                if c not in cands:
                    cands.append(c)
    best, best_t, default_t = None, None, None
    for c in cands:
        t = predicted(*c)
        if default_t is None:
            default_t = t  # defaults are candidate 0
        if best_t is None or t < best_t:
            best, best_t = c, t
    return TunedParams(block_n=best[0], levels=best[1], bucket=best[2],
                       predicted_us=best_t * 1e6,
                       default_predicted_us=default_t * 1e6,
                       profile_key=profile_key_used)


def tune_weight(
    w,
    tau: float,
    *,
    tile: int,
    dtype: str = "float32",
    backend: str = "auto",
    profile: Optional[CostProfile] = None,
    gm: int = DEFAULT_TUNE_GM,
    gm_hist: Optional[Mapping[int, float]] = None,
    norm_a: Optional[np.ndarray] = None,
    mode: str = "frozen",
    defaults: tuple = (1, 0, 16),
    use_mxu: bool = False,
) -> TunedParams:
    """`tune` for a concrete weight matrix: computes the weight-side
    normmap of the QUANTIZED view (what a low-precision kernel multiplies)
    through the backend's get-norm (the fused int8 getnorm+absmax kernel
    when registered), widens τ by the analytic quantization bound, and
    prices with the profile's coefficients for the resolved backend.
    `gm_hist` (e.g. `Engine.gm_histogram`) prices over the observed
    serving row grids instead of the synthetic `gm`."""
    from repro.core.plan import pad_to_tile  # circular-safe at call time
    from repro.kernels import ops as kops

    bk = kops.get_backend(backend)
    profile = profile or CostProfile()
    coeffs = profile.coeffs(bk.name)
    dtype = kquant.canonical_dtype(dtype)
    import jax.numpy as jnp

    wp = pad_to_tile(jnp.asarray(w), tile)
    if dtype == "int8":
        nb, _ = kops.int8_norms_and_scales(wp, tile, backend=bk.name,
                                           use_mxu=use_mxu)
    elif dtype != "float32":
        nb = bk.norms(kquant.quantized_view(wp, dtype, tile), tile,
                      use_mxu=use_mxu)
    else:
        nb = bk.norms(wp, tile, use_mxu=use_mxu)
    tau_gate = float(np.asarray(kquant.widen_tau(float(tau), dtype, tile)))
    return tune(np.asarray(nb), tau_gate, tile=tile, dtype=dtype,
                coeffs=coeffs, profile_key_used=profile.key_used(bk.name),
                gm=gm, gm_hist=gm_hist, norm_a=norm_a, mode=mode,
                defaults=defaults)


# ---------------------------------------------------------------------------
# calibration: fit coefficients from measured kernel wall-clock
# ---------------------------------------------------------------------------

def _timeit_s(fn, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall-clock seconds per call (block_until_ready)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _nnls_refit(feats: np.ndarray, times: np.ndarray) -> np.ndarray:
    """Least squares with non-negativity enforced by zero-and-refit: solve,
    clamp negative coefficients to zero, refit the surviving columns (one
    pass — the 4-column design cannot oscillate)."""
    x, *_ = np.linalg.lstsq(feats, times, rcond=None)
    keep = x > 0
    if keep.all():
        return x
    out = np.zeros_like(x)
    if keep.any():
        sub, *_ = np.linalg.lstsq(feats[:, keep], times, rcond=None)
        out[keep] = np.maximum(sub, 0.0)
    return out


def calibrate(backend: str = "interpret", *, tile: int = 32,
              sizes: Sequence[int] = (128, 256, 384),
              taus: Sequence[float] = (0.0, 0.02, 0.2),
              seed: int = 0, repeat: int = 3) -> CostCoeffs:
    """Fit machine coefficients from measured kernel wall-clock.

    Samples get-norm runs (pure bandwidth) and work-list executes across τ
    (step count, bytes and flops all varying) on exponential-decay
    matrices, then solves the additive model of `predict_time_s` for
    [base, step_overhead, 1/bandwidth, 1/flops] by non-negative least
    squares. The host gate rate is measured directly on the flat-gate
    product. Wall-clock in, coefficients out — run once per machine and
    persist with `CostProfile.save`.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import plan as cplan  # circular-safe at call time
    from repro.core.spamm import exponential_decay
    from repro.kernels import ops as kops

    bk = kops.get_backend(backend)
    rows_f, times = [], []
    for n in sizes:
        x = jnp.asarray(exponential_decay(n, lam=0.7, seed=seed))
        t = _timeit_s(jax.jit(lambda v, _b=bk: _b.norms(v, tile)), x,
                      repeat=repeat)
        rows_f.append([1.0, 0.0, float(n * n * 4), 0.0])
        times.append(t)
    n = sizes[-1]
    a = jnp.asarray(exponential_decay(n, lam=0.7, seed=seed))
    b = jnp.asarray(exponential_decay(n, lam=0.7, seed=seed + 1))
    for tau in taus:
        for bn in (1, 2):
            p = cplan.plan(a, b, tau, tile=tile, block_n=bn,
                           backend=bk.name)
            t = _timeit_s(lambda p=p: cplan.execute(p, a, b), repeat=repeat)
            v = float(p.valid_tiles)
            pairs = float(np.sum(np.asarray(p.nvalid) > 0))
            steps = (float(p.work.step_i.shape[0])
                     if p.work is not None and p.work.step_i is not None
                     else v)
            rows_f.append([1.0, steps,
                           float(gemm_bytes(v, pairs, tile, bn, "float32")),
                           float(gemm_flops(v, tile, bn))])
            times.append(t)
    feats = np.asarray(rows_f, np.float64)
    x = _nnls_refit(feats, np.asarray(times, np.float64))
    base, step, inv_bw, inv_fl = x
    nominal = DEFAULT_COEFFS.get(bk.name, DEFAULT_COEFFS["jnp"])
    # gate rate: host flat-gate products per second, measured directly
    gm = gk = gn = max(sizes) // tile
    na = np.abs(np.random.default_rng(seed).normal(size=(gm, gk)))
    nb = np.abs(np.random.default_rng(seed + 1).normal(size=(gk, gn)))
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        (na[:, None, :] * nb.T[None] >= 0.5).sum()
    gate_rate = reps * gm * gk * gn / max(time.perf_counter() - t0, 1e-9)
    return CostCoeffs(
        bytes_per_s=(1.0 / inv_bw) if inv_bw > 0 else nominal.bytes_per_s,
        flops_per_s=(1.0 / inv_fl) if inv_fl > 0 else nominal.flops_per_s,
        step_overhead_s=float(step) if step > 0 else nominal.step_overhead_s,
        base_overhead_s=float(base) if base > 0 else nominal.base_overhead_s,
        gate_ops_per_s=float(gate_rate),
        calibrated=True,
    )
