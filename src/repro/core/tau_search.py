"""valid-ratio → τ search (paper §3.5.2).

Users of non-scientific applications specify `valid_ratio` (fraction of
sub-matrix products actually executed) instead of the numerical threshold τ.
Per the paper: binary search over [0, k·ave] where ave is the mean norm
product, k the expansion coefficient starting at 1 and incremented whenever
the upper bound cannot satisfy the demand; iteration count and tolerance are
user-bounded. Implemented as a lax.while_loop so it jits and shards.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import spamm as _spamm


class TauSearchResult(NamedTuple):
    tau: jax.Array
    achieved_ratio: jax.Array
    iterations: jax.Array


@functools.partial(jax.jit, static_argnames=("max_iters",))
def search_tau(
    norm_a: jax.Array,
    norm_b: jax.Array,
    target_ratio,
    *,
    tol: float = 0.01,
    max_iters: int = 20,
):
    """Find τ s.t. valid_ratio(τ) ≈ target_ratio. Returns (tau, result).

    valid_ratio is monotone non-increasing in τ; ratio(0)=1, ratio(∞)=0.
    """
    target = jnp.asarray(target_ratio, jnp.float32)
    # mean norm product without materializing the product tensor:
    # mean_{i,j,k} na[i,k]·nb[k,j] = (1/(gm·gn·gk)) Σ_k (Σ_i na[i,k])(Σ_j nb[k,j])
    gm, gk = norm_a.shape
    _, gn = norm_b.shape
    ave = jnp.sum(jnp.sum(norm_a, 0) * jnp.sum(norm_b, 1)) / (gm * gn * gk)

    def ratio(tau):
        return _spamm.valid_ratio_of(norm_a, norm_b, tau).astype(jnp.float32)

    # --- expand upper bound: k ← k+1 until ratio(k·ave) <= target (paper) ---
    def exp_cond(state):
        k, _ = state
        return jnp.logical_and(ratio(k * ave) > target, k < 1024.0)

    def exp_body(state):
        k, it = state
        return k + 1.0, it + 1

    k, exp_iters = jax.lax.while_loop(exp_cond, exp_body, (jnp.float32(1.0), jnp.int32(0)))

    # --- binary search in [0, k·ave], tracking the best candidate seen ---
    def bin_cond(state):
        lo, hi, it, best_tau, best_r = state
        return jnp.logical_and(it < max_iters,
                               jnp.abs(best_r - target) > tol)

    def bin_body(state):
        lo, hi, it, best_tau, best_r = state
        mid = 0.5 * (lo + hi)
        r = ratio(mid)
        better = jnp.abs(r - target) < jnp.abs(best_r - target)
        best_tau = jnp.where(better, mid, best_tau)
        best_r = jnp.where(better, r, best_r)
        # ratio too high → τ too small → move lo up
        lo = jnp.where(r > target, mid, lo)
        hi = jnp.where(r > target, hi, mid)
        return lo, hi, it + 1, best_tau, best_r

    mid0 = 0.5 * k * ave
    r0 = ratio(mid0)
    lo, hi, iters, tau, r = jax.lax.while_loop(
        bin_cond, bin_body,
        (jnp.float32(0.0), k * ave, jnp.int32(1), mid0, r0),
    )
    res = TauSearchResult(tau=tau, achieved_ratio=r, iterations=iters + exp_iters)
    return tau, res
