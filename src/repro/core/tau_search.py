"""valid-ratio → τ search (paper §3.5.2), flat and coarse-first.

Users of non-scientific applications specify `valid_ratio` (fraction of
sub-matrix products actually executed) instead of the numerical threshold τ.
Per the paper: binary search over [0, k·ave] where ave is the mean norm
product, k the expansion coefficient starting at 1 and incremented whenever
the upper bound cannot satisfy the demand; iteration count and tolerance are
user-bounded. Implemented as a lax.while_loop so it jits and shards.

`search_tau_pyramid` is the hierarchical variant: it brackets τ on the
COARSEST normmaps first (grids 4^L smaller per side, so every ratio
evaluation there is ~16^L cheaper) and only then descends to the fine level,
bisecting inside the coarse bracket. The descent is justified by the pyramid
invariant: every fine-valid (i, j, k) has all its coarse ancestors valid, so
ratio_fine(τ) ≤ ratio_coarse(τ) for every τ and the coarse τ reaching the
target upper-bounds the fine answer — the fine search never has to expand
its bracket from scratch.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import spamm as _spamm


class TauSearchResult(NamedTuple):
    tau: jax.Array
    achieved_ratio: jax.Array
    iterations: jax.Array


def _mean_norm_product(norm_a: jax.Array, norm_b: jax.Array) -> jax.Array:
    """mean_{i,j,k} na[i,k]·nb[k,j] without materializing the product
    tensor: (1/(gm·gn·gk)) Σ_k (Σ_i na[i,k])(Σ_j nb[k,j]). Zero iff every
    product is zero — the degenerate-operand guard both searches share."""
    gm, gk = norm_a.shape
    _, gn = norm_b.shape
    return jnp.sum(jnp.sum(norm_a, 0) * jnp.sum(norm_b, 1)) / (gm * gn * gk)


def _bisect(norm_a, norm_b, target, lo, hi, tol, max_iters):
    """Binary search for ratio(τ) ≈ target on [lo, hi], tracking the best
    candidate seen. Returns (tau, achieved_ratio, iterations)."""

    def ratio(tau):
        return _spamm.valid_ratio_of(norm_a, norm_b, tau).astype(jnp.float32)

    def bin_cond(state):
        lo_, hi_, it, best_tau, best_r = state
        # hi_ > lo_ guards the degenerate bracket: all-zero operands give
        # [0, 0] (ave == 0 skips expansion) and fp midpoints eventually
        # collapse the bracket — either way further ratio() evaluations
        # cannot move, so stop instead of spinning to max_iters
        return jnp.logical_and(
            hi_ > lo_,
            jnp.logical_and(it < max_iters, jnp.abs(best_r - target) > tol),
        )

    def bin_body(state):
        lo_, hi_, it, best_tau, best_r = state
        mid = 0.5 * (lo_ + hi_)
        r = ratio(mid)
        better = jnp.abs(r - target) < jnp.abs(best_r - target)
        best_tau = jnp.where(better, mid, best_tau)
        best_r = jnp.where(better, r, best_r)
        # ratio too high → τ too small → move lo up
        lo_ = jnp.where(r > target, mid, lo_)
        hi_ = jnp.where(r > target, hi_, mid)
        return lo_, hi_, it + 1, best_tau, best_r

    mid0 = 0.5 * (lo + hi)
    r0 = ratio(mid0)
    _, _, iters, tau, r = jax.lax.while_loop(
        bin_cond, bin_body, (lo, hi, jnp.int32(1), mid0, r0)
    )
    return tau, r, iters


@functools.partial(jax.jit, static_argnames=("max_iters",))
def search_tau(
    norm_a: jax.Array,
    norm_b: jax.Array,
    target_ratio,
    *,
    tol: float = 0.01,
    max_iters: int = 20,
):
    """Find τ s.t. valid_ratio(τ) ≈ target_ratio. Returns (tau, result).

    valid_ratio is monotone non-increasing in τ; ratio(0)=1, ratio(∞)=0.
    """
    target = jnp.asarray(target_ratio, jnp.float32)
    ave = _mean_norm_product(norm_a, norm_b)

    def ratio(tau):
        return _spamm.valid_ratio_of(norm_a, norm_b, tau).astype(jnp.float32)

    # --- expand upper bound: k ← k+1 until ratio(k·ave) <= target (paper) ---
    # ave == 0 (all-zero operands): every norm product is 0, so ratio(k·0) is
    # ratio(0) = 1 forever and the loop would spin to the k < 1024 cap for
    # nothing — early-exit with the [0, 0] bracket, i.e. τ = 0 (the only
    # sensible threshold: τ ≤ 0 keeps everything, τ > 0 keeps nothing).
    def exp_cond(state):
        k, _ = state
        return jnp.logical_and(
            ave > 0.0, jnp.logical_and(ratio(k * ave) > target, k < 1024.0)
        )

    def exp_body(state):
        k, it = state
        return k + 1.0, it + 1

    k, exp_iters = jax.lax.while_loop(
        exp_cond, exp_body, (jnp.float32(1.0), jnp.int32(0))
    )

    tau, r, iters = _bisect(norm_a, norm_b, target,
                            jnp.float32(0.0), k * ave, tol, max_iters)
    res = TauSearchResult(tau=tau, achieved_ratio=r,
                          iterations=iters + exp_iters)
    return tau, res


@functools.partial(
    jax.jit, static_argnames=("max_iters", "coarse_iters")
)
def search_tau_pyramid(
    pyr_a,
    pyr_b,
    target_ratio,
    *,
    tol: float = 0.01,
    max_iters: int = 20,
    coarse_iters: int = 12,
):
    """Coarse-first τ-search over NormPyramids. Returns (tau, result).

    Phase 1 runs the full §3.5.2 search (expansion + bisection) on the
    coarsest normmaps — each ratio evaluation there touches grids 4^L
    smaller per side. Phase 2 bisects on the FINE normmaps inside
    [0, margin·τ_coarse]: by the pyramid invariant ratio_fine ≤ ratio_coarse
    pointwise, so the coarse answer (inflated by a small margin for its own
    tolerance) upper-bounds the fine τ and only the surviving part of the τ
    axis is descended; a doubling guard covers the coarse-tolerance edge.
    """
    na_f, nb_f = pyr_a.levels[0], pyr_b.levels[0]
    na_c, nb_c = pyr_a.levels[-1], pyr_b.levels[-1]
    target = jnp.asarray(target_ratio, jnp.float32)

    # coarse tolerance is the looser of the caller's and 2% (jnp.maximum:
    # `tol` is a tracer when passed explicitly to this jitted function)
    tau_c, res_c = search_tau(
        na_c, nb_c, target,
        tol=jnp.maximum(jnp.asarray(tol, jnp.float32), 0.02),
        max_iters=coarse_iters,
    )

    def ratio(tau):
        return _spamm.valid_ratio_of(na_f, nb_f, tau).astype(jnp.float32)

    # mirror of search_tau's degenerate guard: with an all-zero fine mean
    # product no doubling of hi can ever bring ratio(hi) below a target the
    # operands cannot reach — skip the 8 doubling rounds and collapse the
    # fine bracket to [0, 0] so the bisection returns τ = 0 immediately
    ave_f = _mean_norm_product(na_f, nb_f)

    # τ_c could undershoot by its tolerance; inflate, then double until the
    # fine ratio at hi is at or below target (usually zero iterations).
    hi0 = jnp.where(ave_f > 0.0,
                    jnp.maximum(tau_c * 1.25, jnp.float32(1e-30)),
                    jnp.float32(0.0))

    def g_cond(state):
        hi, it = state
        return jnp.logical_and(
            ave_f > 0.0, jnp.logical_and(ratio(hi) > target, it < 8)
        )

    def g_body(state):
        hi, it = state
        return hi * 2.0, it + 1

    hi, g_iters = jax.lax.while_loop(g_cond, g_body, (hi0, jnp.int32(0)))

    tau, r, iters = _bisect(na_f, nb_f, target,
                            jnp.float32(0.0), hi, tol, max_iters)
    res = TauSearchResult(
        tau=tau, achieved_ratio=r,
        iterations=iters + g_iters + res_c.iterations,
    )
    return tau, res
