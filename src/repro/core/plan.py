"""Plan/execute split for the SpAMM pipeline.

The paper's pipeline has two phases with very different reuse behavior:

  * a cheap **gating** phase — get-norm (§3.2) → bitmap → `map_offset`
    compaction (§3.3) — that depends only on the operands' normmaps and τ;
  * an expensive **multiplication** phase (Alg. 2/3) that consumes the
    gating artifacts and the operand data.

For serving-style workloads the right-hand operand (a weight matrix) is
static across requests, so its half of the gating phase can be planned once
and reused for every token batch — the "preprocess once, multiply many"
structure Acc-SpMM and tSparse use to make sparse tensor-core kernels pay
off. This module is the ONE implementation of the gating phase (mask,
super-column grouping, compaction); every other call site
(`kernels.ops.spamm_matmul`, `core.spamm.spamm`, `core.module.spamm_linear`,
`core.distributed.spamm_rowpart/_2d`) builds a `SpammPlan` here and runs it
through `execute`.

API:
  plan(a, b, tau | valid_ratio=...)  → SpammPlan   (or from precomputed
                                       normmaps via norm_a= / norm_b=)
  execute(plan, a, b)                → C
  WeightPlanCache                    — per-weight gating artifacts, keyed on
                                       weight identity/shape/tile
  spamm_bmm(x, w, tau)               — batched (B,M,K)@(K,N) / (B,K,N) with
                                       the weight-side plan shared across
                                       the batch
"""
from __future__ import annotations

import collections
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# padding helper (shared by every caller that accepts arbitrary shapes)
# ---------------------------------------------------------------------------

def pad_to_tile(x: jax.Array, tile: int) -> jax.Array:
    """Zero-pad the trailing two dims of x up to multiples of `tile`."""
    m, n = x.shape[-2:]
    pm, pn = (-m) % tile, (-n) % tile
    if pm == 0 and pn == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# SpammPlan
# ---------------------------------------------------------------------------

class SpammInfo(NamedTuple):
    tau: jax.Array              # threshold actually used
    valid_fraction: jax.Array   # executed-tile fraction (== paper valid ratio)
    effective_flops: jax.Array  # 2·M·K·N · valid_fraction


@jax.tree_util.register_pytree_node_class
class SpammPlan:
    """Cached gating phase of one SpAMM product.

    Array fields (pytree children — a plan passes through jit/vmap):
      tau         f32 scalar
      norm_a      (gm, gk)  A-side normmap
      norm_b      (gk, gn)  B-side normmap
      mask        (gm, gn//block_n, gk) bool — validity bitmap at
                  super-column granularity (block_n=1 ⇒ per-tile)
      kidx        (gm, gn//block_n, gk) int32 compacted valid-k lists, or
                  None when the backend gates from `mask` directly
      nvalid      (gm, gn//block_n) int32, or None (as above)
      valid_tiles i32 scalar — Σ mask

    Static metadata (aux): tile, block_n, backend (resolved name).
    """

    def __init__(self, tau, norm_a, norm_b, mask, kidx, nvalid, valid_tiles,
                 *, tile: int, block_n: int, backend: str):
        self.tau = tau
        self.norm_a = norm_a
        self.norm_b = norm_b
        self.mask = mask
        self.kidx = kidx
        self.nvalid = nvalid
        self.valid_tiles = valid_tiles
        self.tile = tile
        self.block_n = block_n
        self.backend = backend

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.tau, self.norm_a, self.norm_b, self.mask,
                    self.kidx, self.nvalid, self.valid_tiles)
        return children, (self.tile, self.block_n, self.backend)

    @classmethod
    def tree_unflatten(cls, aux, children):
        tile, block_n, backend = aux
        return cls(*children, tile=tile, block_n=block_n, backend=backend)

    # -- derived quantities -------------------------------------------------
    @property
    def total_tiles(self) -> int:
        gm, gnb, gk = self.mask.shape
        return gm * gnb * gk

    @property
    def valid_fraction(self) -> jax.Array:
        return self.valid_tiles / self.total_tiles

    def info(self) -> dict:
        """The info dict `kernels.ops.spamm_matmul` has always returned."""
        return {
            "norm_a": self.norm_a,
            "norm_b": self.norm_b,
            "valid_tiles": self.valid_tiles,
            "total_tiles": self.total_tiles,
            "valid_fraction": self.valid_fraction,
        }


# ---------------------------------------------------------------------------
# the gating phase — THE single implementation
# ---------------------------------------------------------------------------

def gate_mask(norm_a: jax.Array, norm_b: jax.Array, tau, block_n: int = 1):
    """Validity bitmap from normmaps (paper Alg. 2 lines 3–8).

    block_n > 1 groups gn into gn//block_n super-columns; a super-column is
    valid for k if ANY of its member columns is (superset mask ⇒ exactness).
    Returns (gm, gn//block_n, gk) bool.
    """
    tau = jnp.asarray(tau, jnp.float32)
    if block_n > 1:
        gk, gn = norm_b.shape
        assert gn % block_n == 0, (gn, block_n)
        nb_g = norm_b.reshape(gk, gn // block_n, block_n)
        fine = norm_a[:, None, :, None] * jnp.swapaxes(nb_g, 0, 1)[None] >= tau
        return jnp.any(fine, axis=-1)
    return kref.spamm_mask_ref(norm_a, norm_b, tau)


def _maybe_compact(mask, backend: str):
    """map_offset compaction (§3.3) when the backend's kernel consumes it."""
    if kops.get_backend(backend).needs_compaction:
        return kref.spamm_compact_ref(mask)
    return None, None


def plan(
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    tau=None,
    *,
    valid_ratio=None,
    norm_a: Optional[jax.Array] = None,
    norm_b: Optional[jax.Array] = None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
) -> SpammPlan:
    """Build the gating phase for (M, K) @ (K, N), dims divisible by tile
    (and N by tile·block_n) — pad upstream (see `pad_to_tile` /
    `core.spamm.spamm`).

    Either side may be given as the matrix (positional) or as a precomputed
    normmap (norm_a= / norm_b= keywords; the matrix argument may then be
    omitted). Exactly one of `tau` / `valid_ratio` must be set; valid_ratio
    runs the §3.5.2 τ-search on the normmaps.
    """
    if (tau is None) == (valid_ratio is None):
        raise ValueError("give exactly one of tau / valid_ratio")
    bk = kops.get_backend(backend)
    if norm_a is None:
        if a is None:
            raise ValueError("need `a` or `norm_a`")
        norm_a = bk.norms(a, tile, use_mxu=use_mxu_norm)
    if norm_b is None:
        if b is None:
            raise ValueError("need `b` or `norm_b`")
        norm_b = bk.norms(b, tile, use_mxu=use_mxu_norm)

    if valid_ratio is not None:
        from repro.core.tau_search import search_tau  # circular-safe

        tau, _ = search_tau(norm_a, norm_b, valid_ratio)
    tau = jnp.asarray(tau, jnp.float32)

    mask = gate_mask(norm_a, norm_b, tau, block_n)
    kidx, nvalid = _maybe_compact(mask, bk.name)
    valid_tiles = jnp.sum(mask, dtype=jnp.int32)
    return SpammPlan(tau, norm_a, norm_b, mask, kidx, nvalid, valid_tiles,
                     tile=tile, block_n=block_n, backend=bk.name)


def execute(p: SpammPlan, a: jax.Array, b: jax.Array, *, out_dtype=None):
    """Run the multiplication phase of a prebuilt plan on (a, b).

    a/b must have the tile-padded shapes the plan was built for. Executing
    the same plan twice on the same operands is bit-identical to the
    unplanned `kernels.ops.spamm_matmul` — the plan IS that call's first
    half.
    """
    gm, gk = p.norm_a.shape
    _, gn = p.norm_b.shape
    t = p.tile
    assert a.shape == (gm * t, gk * t), (a.shape, (gm * t, gk * t))
    assert b.shape == (gk * t, gn * t), (b.shape, (gk * t, gn * t))
    bk = kops.get_backend(p.backend)
    return bk.matmul(a, b, p.mask, p.kidx, p.nvalid, p.tile, p.block_n,
                     out_dtype or jnp.float32)


# ---------------------------------------------------------------------------
# per-weight plan cache (serving hot path)
# ---------------------------------------------------------------------------

class _WeightEntry(NamedTuple):
    weight: Any          # strong ref: anchors the id() key (no stale reuse)
    padded: jax.Array
    norms: jax.Array


class WeightPlanCache:
    """Caches the weight-side gating artifacts (tile padding + normmap),
    keyed on weight identity/shape/dtype/tile/backend.

    Serving engines and eager model forward passes call the same weight
    matrix against a stream of activations; the activation-side normmap and
    the bitmap depend on the batch, but the weight normmap (the expensive
    O(K·N) half of get-norm) and the padded copy do not — compute them once
    per weight instead of per token batch.

    Tracers are never cached (inside jit the trace itself is cached, and
    tracer ids are meaningless); the cache is an eager-path optimization.
    LRU-bounded; `hits`/`misses` expose effectiveness for tests/benchmarks.
    """

    def __init__(self, maxsize: int = 256):
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _cacheable(w) -> bool:
        return isinstance(w, (np.ndarray, jax.Array)) and not isinstance(
            w, jax.core.Tracer
        )

    def weight_side(self, w, *, tile: int, backend: str,
                    use_mxu: bool = False):
        """(padded_weight, weight_normmap) for w, cached on identity.

        w may be 2-D (K, N) → normmap (gk, gn), or 3-D batched (B, K, N) —
        the per-expert MoE shape — → normmap (B, gk, gn) from one reshaped
        get-norm pass (row tiles never cross slices after padding)."""
        bk = kops.get_backend(backend)

        def compute():
            wp = pad_to_tile(jnp.asarray(w), tile)
            if wp.ndim == 3:
                bsz, kp, np_ = wp.shape
                nw = bk.norms(wp.reshape(bsz * kp, np_), tile,
                              use_mxu=use_mxu).reshape(bsz, kp // tile, -1)
                return wp, nw
            return wp, bk.norms(wp, tile, use_mxu=use_mxu)

        if not self._cacheable(w):
            return compute()
        key = (id(w), w.shape, str(w.dtype), tile, bk.name, use_mxu)
        ent = self._entries.get(key)
        if ent is not None and ent.weight is w:
            self.hits += 1
            self._entries.move_to_end(key)
            return ent.padded, ent.norms
        self.misses += 1
        wp, nw = compute()
        self._entries[key] = _WeightEntry(w, wp, nw)
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return wp, nw

    def plan_for(self, x_padded, w, tau=None, *, valid_ratio=None,
                 tile: int = 64, block_n: int = 1, backend: str = "auto",
                 use_mxu_norm: bool = False):
        """Full plan for x @ w with the weight side served from the cache.
        x_padded must already be tile-padded. Returns (plan, padded_weight).
        """
        wp, nw = self.weight_side(w, tile=tile, backend=backend,
                                  use_mxu=use_mxu_norm)
        p = plan(x_padded, None, tau, valid_ratio=valid_ratio, norm_b=nw,
                 tile=tile, block_n=block_n, backend=backend,
                 use_mxu_norm=use_mxu_norm)
        return p, wp

    def clear(self):
        self._entries.clear()
        self.hits = self.misses = 0

    def __len__(self):
        return len(self._entries)


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

def spamm_bmm(
    x: jax.Array,
    w: jax.Array,
    tau=None,
    *,
    valid_ratio=None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    out_dtype=None,
    cache: Optional[WeightPlanCache] = None,
):
    """Batched SpAMM: (B, M, K) @ (K, N) or (B, M, K) @ (B, K, N).

    Shared-weight case: the batch dim folds into the row-tile grid — the
    whole batch runs as ONE (B·M, K) @ (K, N) product whose row tiles never
    cross slice boundaries, so the gating is exactly the per-slice gating
    while the weight-side plan (normmap + padding, optionally from `cache`)
    is computed once and shared across the batch. Per-batch-weight case:
    normmaps for every slice come from one reshaped get-norm call, gating is
    vmapped, and the multiplication runs per slice under lax.map (jnp
    backend: vmapped masked einsum).

    Arbitrary shapes are zero-padded to tile multiples and un-padded.
    Returns (C (B, M, N), SpammInfo).
    """
    if (tau is None) == (valid_ratio is None):
        raise ValueError("give exactly one of tau / valid_ratio")
    bsz, m, k = x.shape
    bk = kops.get_backend(backend)
    out_dtype = out_dtype or jnp.float32

    if w.ndim == 2:  # (B, M, K) @ (K, N): fold batch into the row-tile grid
        k2, n = w.shape
        assert k == k2, (x.shape, w.shape)
        xp = pad_to_tile(x, tile)
        mp, kp = xp.shape[1:]
        if cache is not None:
            wp, nw = cache.weight_side(w, tile=tile, backend=backend,
                                       use_mxu=use_mxu_norm)
        else:
            wp = pad_to_tile(w, tile)
            nw = bk.norms(wp, tile, use_mxu=use_mxu_norm)
        x2 = xp.reshape(bsz * mp, kp)
        p = plan(x2, None, tau, valid_ratio=valid_ratio, norm_b=nw,
                 tile=tile, block_n=block_n, backend=backend,
                 use_mxu_norm=use_mxu_norm)
        c = execute(p, x2, wp, out_dtype=out_dtype)
        c = c.reshape(bsz, mp, -1)[:, :m, :n]
        frac = p.valid_fraction
        tau_used = p.tau
    else:  # (B, M, K) @ (B, K, N): per-slice plans, weight norms in one pass
        if valid_ratio is not None:
            raise ValueError("valid_ratio needs a shared weight; pass tau for "
                             "per-batch weights")
        assert w.shape[0] == bsz and w.shape[1] == k, (x.shape, w.shape)
        n = w.shape[2]
        xp = pad_to_tile(x, tile)
        mp, kp = xp.shape[1:]
        gm, gk = mp // tile, kp // tile
        if cache is not None:
            wp, nw = cache.weight_side(w, tile=tile, backend=backend,
                                       use_mxu=use_mxu_norm)
        else:
            wp = pad_to_tile(w, tile)
            np_ = wp.shape[2]
            nw = bk.norms(wp.reshape(bsz * kp, np_), tile,
                          use_mxu=use_mxu_norm).reshape(bsz, gk, -1)
        na = bk.norms(xp.reshape(bsz * mp, kp), tile,
                      use_mxu=use_mxu_norm).reshape(bsz, gm, gk)
        tau_used = jnp.asarray(tau, jnp.float32)
        mask = jax.vmap(lambda a_, b_: gate_mask(a_, b_, tau_used, block_n))(
            na, nw)
        if bk.needs_compaction:
            kidx, nvalid = jax.vmap(kref.spamm_compact_ref)(mask)
            c = jax.lax.map(
                lambda s: bk.matmul(s[0], s[1], s[2], s[3], s[4], tile,
                                    block_n, out_dtype),
                (xp, wp, mask, kidx, nvalid),
            )
        else:
            c = jax.vmap(
                lambda a_, b_, m_: bk.matmul(a_, b_, m_, None, None, tile,
                                             block_n, out_dtype)
            )(xp, wp, mask)
        c = c[:, :m, :n]
        frac = jnp.sum(mask, dtype=jnp.int32) / mask.size

    return c, SpammInfo(
        tau=jnp.asarray(tau_used, jnp.float32),
        valid_fraction=frac,
        effective_flops=frac * (2.0 * bsz * m * k * n),
    )
