"""Plan/execute split for the SpAMM pipeline.

The paper's pipeline has two phases with very different reuse behavior:

  * a cheap **gating** phase — get-norm (§3.2) → bitmap → `map_offset`
    compaction (§3.3) — that depends only on the operands' normmaps and τ;
  * an expensive **multiplication** phase (Alg. 2/3) that consumes the
    gating artifacts and the operand data.

For serving-style workloads the right-hand operand (a weight matrix) is
static across requests, so its half of the gating phase can be planned once
and reused for every token batch — the "preprocess once, multiply many"
structure Acc-SpMM and tSparse use to make sparse tensor-core kernels pay
off. This module is the ONE implementation of the gating phase (mask,
super-column grouping, compaction); every other call site
(`kernels.ops.spamm_matmul`, `core.spamm.spamm`, `core.module.spamm_linear`,
`core.distributed.spamm_rowpart/_2d`) builds a `SpammPlan` here and runs it
through `execute`.

Hierarchical (norm-pyramid) gating: the original SpAMM is a *recursive*
algorithm; the flat one-level gate re-derived here costs O(gm·gn·gk) norm
products regardless of sparsity. Since a coarse tile's Frobenius norm
upper-bounds every sub-tile's norm, a coarse-level τ-test that fails rules
out every fine pair inside it — so a `NormPyramid` (levels of sqrt-sumsq
pooled normmaps) gives *exact* coarse-to-fine pruning: `plan(..., levels=L)`
gates at the coarsest level first and refines only inside surviving coarse
blocks, producing a mask bit-identical to flat gating while plan
construction becomes sub-linear in the pruned region.

Compacted execution (§3.3 map_offset, kept first-class end to end): for
concrete operands the planner never round-trips through a dense bitmap — the
hierarchical descent (or the flat gate's nonzero scan) yields the surviving
(i, j, k) triples directly, and `compact_from_triples` turns them into a
`SpammWork` work-list (per-(i, j) row/col ids, concatenated ascending
k-lists with offsets, and bucket-padded per-step tables) in O(V log V) of
the V SURVIVING triples — no O(gm·gn·gk log gk) sort over the grid. The
Pallas backends execute the work-list on a 1-D grid of Σnvalid steps
(`kernels.spamm_mm.spamm_mm_worklist`); the dense mask becomes a lazy
derived view, materialized only for backends that gate from the bitmap
(jnp masked einsum) or for traced plans, where shapes must be static and
the legacy dense-kidx path (`spamm_compact_ref`) still applies.

API:
  plan(a, b, tau | valid_ratio=...)  → SpammPlan   (or from precomputed
                                       normmaps via norm_a= / norm_b=;
                                       levels=L turns on pyramid gating)
  execute(plan, a, b)                → C
  SpammWork / compact_from_triples   — flattened work-list straight from
                                       the descent's surviving triples
  NormPyramid                        — coarse-to-fine normmap stack
  hier_gate_mask(pyr_a, pyr_b, tau)  — coarse-to-fine mask (≡ gate_mask)
  WeightPlanCache                    — per-weight gating artifacts, keyed on
                                       weight identity/shape/tile/levels
  spamm_bmm(x, w, tau)               — batched (B,M,K)@(K,N) / (B,K,N) with
                                       the weight-side plan shared across
                                       the batch
"""
from __future__ import annotations

import collections
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost as kcost
from repro.kernels import ops as kops
from repro.kernels import quantize as kquant
from repro.kernels import ref as kref
from repro.kernels import spamm_mm as kmm


# ---------------------------------------------------------------------------
# padding helper (shared by every caller that accepts arbitrary shapes)
# ---------------------------------------------------------------------------

def pad_to_tile(x: jax.Array, tile: int, tile_n: Optional[int] = None
                ) -> jax.Array:
    """Zero-pad the trailing two dims of x up to multiples of `tile`.

    tile_n overrides the multiple for the LAST dim — the weight side of a
    block_n > 1 product must pad N to tile·block_n so the super-column
    grouping divides the column grid (`gn % block_n == 0`)."""
    m, n = x.shape[-2:]
    pm, pn = (-m) % tile, (-n) % (tile_n or tile)
    if pm == 0 and pn == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# NormPyramid — coarse-to-fine normmap stack
# ---------------------------------------------------------------------------

# Relative slack applied to τ at coarse levels only: coarse norms are computed
# in fp32 (sqrt of pooled sumsq), so a coarse product can round a hair below a
# fine product it mathematically dominates. The slack widens the candidate set
# (never prunes extra), keeping the level-0 test — which is exactly the flat
# gate — the sole decider of the final mask. Bit-identity to flat gating is
# therefore unconditional; 1e-5 covers the fp32 rounding of several pooling
# levels with orders of magnitude to spare.
_COARSE_SLACK = 1e-5


@jax.tree_util.register_pytree_node_class
class NormPyramid:
    """Coarse-to-fine stack of normmaps for one operand side.

    levels[0] is the plain normmap at `tile`; levels[l] ceil-halves each grid
    dim of levels[l-1] by sqrt-of-sumsq pooling, so levels[l][I, J] is the
    exact Frobenius norm of the (tile·2^l)² block (zero-padded at ragged
    edges) and upper-bounds every descendant tile norm. Built from ONE
    get-norm pass over the matrix plus `num_levels` cheap reductions.

    A pytree (children = the level arrays), so pyramids pass through
    jit/vmap and live in caches exactly like plain normmaps.
    """

    def __init__(self, levels, *, tile: int):
        self.levels = tuple(levels)
        self.tile = tile

    def tree_flatten(self):
        return self.levels, (self.tile,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children, tile=aux[0])

    @property
    def base(self) -> jax.Array:
        """The finest normmap — what flat gating / SpammPlan.norm_* store."""
        return self.levels[0]

    @property
    def coarse(self) -> jax.Array:
        return self.levels[-1]

    @property
    def num_levels(self) -> int:
        """Number of coarsening steps (0 ⇒ just the flat normmap)."""
        return len(self.levels) - 1

    @property
    def coarse_tile(self) -> int:
        return self.tile * (2 ** self.num_levels)

    def extended(self, levels: int) -> "NormPyramid":
        """This pyramid deepened to `levels` coarsening steps (no-op if
        already at least that deep) — pools from the current coarsest."""
        if self.num_levels >= levels:
            return self
        lv = list(self.levels)
        for _ in range(levels - self.num_levels):
            lv.append(kref.pool_norms_ref(lv[-1]))
        return NormPyramid(lv, tile=self.tile)

    @classmethod
    def from_normmap(cls, normmap: jax.Array, levels: int, *, tile: int = 64
                     ) -> "NormPyramid":
        """Pyramid from an existing finest normmap (reuses the get-norm pass
        that produced it; each level is one pooling reduction)."""
        lv = [normmap]
        for _ in range(levels):
            lv.append(kref.pool_norms_ref(lv[-1]))
        return cls(lv, tile=tile)

    @classmethod
    def build(cls, x: jax.Array, levels: int, *, tile: int = 64,
              backend: str = "auto", use_mxu: bool = False) -> "NormPyramid":
        """Pyramid from the matrix via the backend's pyramid_norms kernel."""
        return cls(
            kops.pyramid_norms(x, tile, levels, backend=backend,
                               use_mxu=use_mxu),
            tile=tile,
        )


# ---------------------------------------------------------------------------
# compacted work-list (§3.3 map_offset, straight from the descent)
# ---------------------------------------------------------------------------

# per-step flag bits of the ragged kernel — the kernel module owns them so
# encoder (here) and decoder (kernel body) can never disagree.
STEP_INIT = kmm.STEP_INIT
STEP_ACC = kmm.STEP_ACC
STEP_FLUSH = kmm.STEP_FLUSH


class SpammWork(NamedTuple):
    """Flattened per-(i, j) work-list of one plan — the compacted form of
    the §3.3 map_offset, kept instead of (not re-derived from) the bitmap.

    Pair view (what `info()`/tests consume):
      rows     (P,)   int32 — row-tile id of each active output pair
      cols     (P,)   int32 — super-column id (block_n granularity)
      offsets  (P+1,) int32 — klist[offsets[p]:offsets[p+1]] is pair p's
                              ascending valid-k list
      klist    (V,)   int32 — concatenated valid k's; V = Σnvalid

    Step view (what drives `spamm_mm_worklist`'s 1-D grid; built once here
    so repeated `execute` calls pay nothing — None on plans for backends
    with no ragged executor, which keep an eager bitmap/kidx instead):
      step_i/step_j/step_k  (S,) int32 — per-grid-step block ids, S = V
                            padded to a bucket (padding repeats the last
                            real triple so Pallas revisits, no re-fetch)
      step_flags            (S,) int32 — STEP_INIT/ACC/FLUSH bits; padding
                            steps carry no bits (no accumulate, no flush)

    A NamedTuple of arrays, hence a pytree: plans carrying work pass
    through jit (shapes are static per plan instance).
    """
    rows: jax.Array
    cols: jax.Array
    offsets: jax.Array
    klist: jax.Array
    step_i: jax.Array
    step_j: jax.Array
    step_k: jax.Array
    step_flags: jax.Array

    @property
    def num_pairs(self) -> int:
        return self.rows.shape[0]

    @property
    def num_valid(self) -> int:
        return self.klist.shape[0]


# the ONE bucket function lives in core.cost (the autotuner searches over
# its `minimum`); these aliases keep the historical import path working —
# `bucket_ladder` is the compile-count bound shape-bucketed serving asserts
_bucket = kcost.bucket
bucket_ladder = kcost.bucket_ladder


def compact_from_triples(ii, jj, kk, *, gm: int, gn: int, gk: int,
                         block_n: int = 1, steps: bool = True,
                         assume_sorted: bool = False, bucket_min: int = 16):
    """kidx/nvalid straight from surviving (i, j, k) triples — §3.3
    map_offset compaction WITHOUT materializing or sorting the dense
    (gm, gn, gk) bitmap.

    ii/jj/kk: integer arrays of the surviving triples in any order (the
    hierarchical descent's output, or the flat gate's nonzero scan), with
    jj at FINE column granularity; duplicates after super-column grouping
    are folded. Cost is O(V log V) in the V surviving triples (one fused-key
    argsort + linear passes) — sub-linear in the grid for pruned products,
    vs the legacy `spamm_compact_ref` sort over all gm·gn·gk slots.

    Returns (work: SpammWork of numpy arrays, nvalid: (gm, gn//block_n)
    int32 numpy) — nvalid is the paper's validNum, scattered from the
    work-list (a cheap (gm, gnb) array, NOT the dense bitmap).

    steps=False skips the bucket-padded per-step tables (their fields come
    back None): backends with no ragged executor never read them, so the
    planner saves their construction and device upload on, e.g., the jnp
    serving hot path while the pair view still powers `info()`.

    assume_sorted=True skips the O(V log V) sort for callers whose triples
    already arrive in ascending fused-key, i.e. (i, j, k) row-major, order
    and without duplicates — the flat gate's chunked nonzero scan is one
    (making the flat eager path O(V)); the hierarchical descent is not.

    bucket_min is the power-of-two bucket floor of the per-step tables
    (`core.cost.bucket(v, bucket_min)`): the autotuner raises it per weight
    to cut jit recompiles when successive calls straddle bucket boundaries.
    """
    assert gn % block_n == 0, (gn, block_n)
    gnb = gn // block_n
    ii = np.asarray(ii, np.int64).ravel()
    kk = np.asarray(kk, np.int64).ravel()
    jb = np.asarray(jj, np.int64).ravel()
    if block_n > 1:
        jb = jb // block_n
    # one fused-key sort instead of a 3-key lexsort (~2× on the hot path);
    # int64 keys cannot overflow for any grid whose bitmap would fit memory
    key = (ii * gnb + jb) * gk + kk
    if not assume_sorted:
        key = np.sort(key)
    if block_n > 1 and key.size:
        # member columns of one super-column collapse to the same (i, jb, k)
        keep = np.ones(key.size, bool)
        keep[1:] = key[1:] != key[:-1]
        key = key[keep]
    kk = (key % gk).astype(np.int32)
    pair = key // gk
    jb = (pair % gnb).astype(np.int32)
    ii = (pair // gnb).astype(np.int32)
    v = ii.size
    nvalid = np.zeros((gm, gnb), np.int32)
    step_i = step_j = step_k = step_flags = None
    if steps:
        s = _bucket(v, bucket_min)
        step_i = np.zeros(s, np.int32)
        step_j = np.zeros(s, np.int32)
        step_k = np.zeros(s, np.int32)
        step_flags = np.zeros(s, np.int32)
    if v:
        newpair = np.ones(v, bool)
        newpair[1:] = pair[1:] != pair[:-1]
        starts = np.flatnonzero(newpair).astype(np.int32)
        rows, cols = ii[starts], jb[starts]
        offsets = np.append(starts, np.int32(v)).astype(np.int32)
        nvalid[rows, cols] = np.diff(offsets)
        if steps:
            step_i[:v], step_j[:v], step_k[:v] = ii, jb, kk
            step_i[v:], step_j[v:], step_k[v:] = ii[-1], jb[-1], kk[-1]
            flags = np.full(v, STEP_ACC, np.int32)
            flags[starts] |= STEP_INIT
            flags[np.append(starts[1:], v) - 1] |= STEP_FLUSH
            step_flags[:v] = flags
    else:
        rows = cols = np.zeros(0, np.int32)
        offsets = np.zeros(1, np.int32)
        if steps:
            # no real steps: every grid step maps to output block (0, 0) and
            # on real TPU its VMEM window is copied back at window end even
            # if the kernel never stores — make step 0 init+flush the (zero)
            # accumulator so that block is written with zeros, not garbage
            step_flags[0] = STEP_INIT | STEP_FLUSH
    work = SpammWork(rows=rows, cols=cols, offsets=offsets, klist=kk,
                     step_i=step_i, step_j=step_j, step_k=step_k,
                     step_flags=step_flags)
    return work, nvalid


def kidx_from_work(work: SpammWork, gm: int, gnb: int, gk: int) -> np.ndarray:
    """Dense (gm, gnb, gk) kidx table from a work-list — same layout as
    `spamm_compact_ref` (ascending valid k's first, padding slots repeat the
    last valid k, all-invalid pairs read 0) but built by O(V) scatters, no
    sort over the grid. Only needed for backends whose dense-grid kernel
    consumes kidx but lack a `matmul_worklist` entry point."""
    rows = np.asarray(work.rows)
    cols = np.asarray(work.cols)
    offsets = np.asarray(work.offsets)
    klist = np.asarray(work.klist)
    lastk = np.zeros((gm, gnb), np.int32)
    if klist.size:
        lastk[rows, cols] = klist[offsets[1:] - 1]
    kidx = np.broadcast_to(lastk[:, :, None], (gm, gnb, gk)).copy()
    if klist.size:
        counts = np.diff(offsets)
        t = np.arange(klist.size, dtype=np.int32) - np.repeat(
            offsets[:-1], counts)
        kidx[np.repeat(rows, counts), np.repeat(cols, counts), t] = klist
    return kidx


# ---------------------------------------------------------------------------
# SpammPlan
# ---------------------------------------------------------------------------

class SpammInfo(NamedTuple):
    tau: jax.Array              # threshold actually used
    valid_fraction: jax.Array   # executed-tile fraction (== paper valid ratio)
    effective_flops: jax.Array  # 2·M·K·N · valid_fraction


@jax.tree_util.register_pytree_node_class
class SpammPlan:
    """Cached gating phase of one SpAMM product.

    Array fields (pytree children — a plan passes through jit/vmap):
      tau         f32 scalar
      norm_a      (gm, gk)  A-side normmap
      norm_b      (gk, gn)  B-side normmap
      mask        (gm, gn//block_n, gk) bool — validity bitmap at
                  super-column granularity (block_n=1 ⇒ per-tile). LAZY for
                  work-list plans: stored as None and scattered from `work`
                  only if a caller actually reads it (the ragged executor
                  never does).
      kidx        (gm, gn//block_n, gk) int32 compacted valid-k lists, or
                  None when the backend gates from `mask` directly or
                  executes the work-list
      nvalid      (gm, gn//block_n) int32, or None (as above)
      valid_tiles i32 scalar — Σnvalid (== Σ mask)
      work        SpammWork or None — the §3.3 compacted work-list, present
                  on every concretely-planned product; `execute` drives the
                  ragged kernel from it when the backend has one.
      a_scale     (gm, gk) f32 per-tile int8 scales for A, or None — present
                  only on int8 plans built from the matrix; `execute`
                  recomputes missing scales (quantization is a pure function
                  of the operand, so either way is bit-identical).
      b_scale     (gk, gn) f32 per-FINE-tile int8 scales for B, or None.

    Static metadata (aux): tile, block_n, backend (resolved name), levels
    (pyramid coarsening steps the mask was gated with; 0 = flat — the mask is
    bit-identical either way, `levels` only records how it was built), and
    compute_dtype ("float32" | "bfloat16" | "int8" — the precision `execute`
    feeds the kernel; the plan's τ is already quantization-widened and its
    normmaps describe the quantized operand view, see kernels/quantize.py).
    """

    def __init__(self, tau, norm_a, norm_b, mask, kidx, nvalid, valid_tiles,
                 work=None, a_scale=None, b_scale=None, *, tile: int,
                 block_n: int, backend: str, levels: int = 0,
                 compute_dtype: str = "float32"):
        self.tau = tau
        self.norm_a = norm_a
        self.norm_b = norm_b
        self._mask = mask
        self.kidx = kidx
        self.nvalid = nvalid
        self.valid_tiles = valid_tiles
        self.work = work
        self.a_scale = a_scale
        self.b_scale = b_scale
        self.tile = tile
        self.block_n = block_n
        self.backend = backend
        self.levels = levels
        self.compute_dtype = compute_dtype

    # -- pytree protocol ----------------------------------------------------
    @property
    def _mask_is_derived(self) -> bool:
        """True when the mask is a lazy view over the step tables (ragged-
        executor plans); such plans keep the executable truth in `work`."""
        return self.work is not None and self.work.step_i is not None

    def tree_flatten(self):
        # plans whose mask is a derived cache of the step tables flatten it
        # as None unconditionally: including it once materialized would
        # change the treedef (None leaf → array leaf), silently invalidating
        # jit caches keyed on the plan structure. Mask-primary plans always
        # flatten the real bitmap.
        mask_child = None if self._mask_is_derived else self._mask
        children = (self.tau, self.norm_a, self.norm_b, mask_child,
                    self.kidx, self.nvalid, self.valid_tiles, self.work,
                    self.a_scale, self.b_scale)
        return children, (self.tile, self.block_n, self.backend, self.levels,
                          self.compute_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        tile, block_n, backend, levels, compute_dtype = aux
        return cls(*children, tile=tile, block_n=block_n, backend=backend,
                   levels=levels, compute_dtype=compute_dtype)

    # -- derived quantities -------------------------------------------------
    @property
    def grid(self):
        """(gm, gn//block_n, gk) — from the normmaps, so reading it never
        forces the lazy mask."""
        gm, gk = self.norm_a.shape
        gn = self.norm_b.shape[-1]
        return gm, gn // self.block_n, gk

    @property
    def mask(self) -> jax.Array:
        """The dense validity bitmap — a derived view for work-list plans,
        scattered on first read (jnp masked einsum, tests, V-matrix
        consumers); the compacted `work` is the primary representation.

        Scatters from the STEP view, not the pair view: step tables have
        static shapes, so the build traces under jit (a plan re-entering
        through tree_unflatten carries tracer work arrays), whereas the pair
        view needs dynamic-count repeats. plan()'s eager host scatter (for
        backends built WITHOUT step tables) is the numpy twin of this — a
        change to the work-list encoding must update both.
        """
        if self._mask is None:
            gm, gnb, gk = self.grid
            w = self.work
            real = (w.step_flags & STEP_ACC) != 0
            self._mask = (
                jnp.zeros((gm, gnb, gk), bool)
                .at[w.step_i, w.step_j, w.step_k].max(real)
            )
        return self._mask

    @property
    def total_tiles(self) -> int:
        gm, gnb, gk = self.grid
        return gm * gnb * gk

    @property
    def valid_fraction(self) -> jax.Array:
        return self.valid_tiles / self.total_tiles

    def bytes_moved(self):
        """Analytic GEMM bytes the executed work-list moves at this plan's
        compute dtype: per real step one (tile, tile) A block and one
        (tile, tile·block_n) B block at `compute_dtype` itemsize, plus one
        f32 (tile, tile·block_n) output flush per active output pair. The
        mixed-precision bandwidth lever in one number (ROADMAP: cut decode
        GEMM bytes ~2× on the same work-list); int8 scale tables are a few
        f32 scalars per step and are not counted. Delegates to
        `core.cost.gemm_bytes` — the cost model's GEMM-byte term IS this
        formula (pinned by tests/test_cost_model.py), so the autotuner
        prices exactly what the telemetry reports."""
        nvalid = self.nvalid
        if nvalid is not None:
            pairs = jnp.sum(nvalid > 0, dtype=jnp.int32)
        else:
            pairs = jnp.sum(jnp.any(self.mask, axis=-1), dtype=jnp.int32)
        # float accumulation: byte counts overflow int32 well before any
        # interesting grid does
        return kcost.gemm_bytes(
            self.valid_tiles.astype(jnp.float32), pairs.astype(jnp.float32),
            self.tile, self.block_n, self.compute_dtype)

    def info(self) -> dict:
        """The info dict `kernels.ops.spamm_matmul` has always returned.

        `nvalid` is the per-(i, j) valid-k count (the paper's validNum). The
        compacted copy is reused when the planner built one; traced bitmap
        plans get the same counts summed from the mask.
        """
        nvalid = self.nvalid
        if nvalid is None:
            nvalid = jnp.sum(self.mask, axis=-1, dtype=jnp.int32)
        return {
            "norm_a": self.norm_a,
            "norm_b": self.norm_b,
            "nvalid": nvalid,
            "valid_tiles": self.valid_tiles,
            "total_tiles": self.total_tiles,
            "valid_fraction": self.valid_fraction,
        }


# ---------------------------------------------------------------------------
# the gating phase — THE single implementation
# ---------------------------------------------------------------------------

def gate_mask(norm_a: jax.Array, norm_b: jax.Array, tau, block_n: int = 1):
    """Validity bitmap from normmaps (paper Alg. 2 lines 3–8).

    block_n > 1 groups gn into gn//block_n super-columns; a super-column is
    valid for k if ANY of its member columns is (superset mask ⇒ exactness).
    Returns (gm, gn//block_n, gk) bool.
    """
    tau = jnp.asarray(tau, jnp.float32)
    if block_n > 1:
        gk, gn = norm_b.shape
        assert gn % block_n == 0, (gn, block_n)
        nb_g = norm_b.reshape(gk, gn // block_n, block_n)
        fine = norm_a[:, None, :, None] * jnp.swapaxes(nb_g, 0, 1)[None] >= tau
        return jnp.any(fine, axis=-1)
    return kref.spamm_mask_ref(norm_a, norm_b, tau)


# children of one coarse (i, j, k) triple: the 2×2×2 refinement offsets,
# kept as three separate contiguous columns — strided (N, 3) row layout
# costs ~2.5× on the gather-heavy descent below
_OFF_I = np.array([i for i in (0, 1) for _ in (0, 1) for _ in (0, 1)], np.int32)
_OFF_J = np.array([j for _ in (0, 1) for j in (0, 1) for _ in (0, 1)], np.int32)
_OFF_K = np.array([k for _ in (0, 1) for _ in (0, 1) for k in (0, 1)], np.int32)


def _hier_descend_host(la, lb, tau: float):
    """Sparse coarse-to-fine descent on concrete normmaps (numpy) — returns
    the surviving fine (ii, jj, kk) triples DIRECTLY, i.e. already in the
    compacted form `compact_from_triples` consumes (§3.3: the descent owns
    the valid set; scattering it into a bitmap and re-deriving kidx by
    sorting would throw that away).

    la/lb: per-level np normmaps, finest first. Gates the full (tiny)
    coarsest level, then repeatedly expands only the SURVIVING triples into
    their 2×2×2 children — work is O(coarse grid + surviving candidates), not
    O(gm·gn·gk), which is what makes plan construction sub-linear in the
    pruned region. The level-0 test is the exact flat gate, so the triple
    set is exactly the support of `gate_mask`.
    """
    top = len(la) - 1
    tau_c = tau - _COARSE_SLACK * abs(tau)
    na, nb = la[top], lb[top]
    cand = na[:, None, :] * np.swapaxes(nb, 0, 1)[None] >= (tau_c if top else tau)
    ii, jj, kk = [x.astype(np.int32) for x in np.nonzero(cand)]
    for l in range(top - 1, -1, -1):
        gm_l, gk_l = la[l].shape
        gn_l = lb[l].shape[1]
        if ii.shape[0] == 0:
            break
        i2 = (ii[:, None] * 2 + _OFF_I[None]).ravel()
        j2 = (jj[:, None] * 2 + _OFF_J[None]).ravel()
        k2 = (kk[:, None] * 2 + _OFF_K[None]).ravel()
        # ceil-pooled coarse grids overhang ragged fine edges — drop phantoms
        keep = (i2 < gm_l) & (j2 < gn_l) & (k2 < gk_l)
        if not keep.all():
            i2, j2, k2 = i2[keep], j2[keep], k2[keep]
        vals = la[l][i2, k2] * lb[l][k2, j2]
        s = vals >= (tau if l == 0 else tau_c)
        ii, jj, kk = i2[s], j2[s], k2[s]
    return ii, jj, kk


def _hier_mask_host(la, lb, tau: float) -> np.ndarray:
    """Dense bitmap view of `_hier_descend_host` (kept for `hier_gate_mask`
    callers that want the bitmap; the planner consumes the triples)."""
    ii, jj, kk = _hier_descend_host(la, lb, tau)
    gm, gk = la[0].shape
    gn = lb[0].shape[1]
    mask = np.zeros(gm * gn * gk, bool)
    if ii.shape[0]:
        mask[(ii.astype(np.int64) * gn + jj) * gk + kk] = True
    return mask.reshape(gm, gn, gk)


def _hier_mask_traced(la, lb, tau) -> jax.Array:
    """Dense traceable analogue of `_hier_mask_host` for jit'd callers.

    Upsamples the surviving-candidate set level by level and ANDs it with
    each level's gate. No asymptotic saving inside jit (the arrays stay
    dense), but the same exactness argument applies: the candidate set is a
    superset of the flat mask, and the final level applies the exact flat
    test — so cand ∧ flat ≡ flat, bit-identical.
    """
    top = len(la) - 1
    tau = jnp.asarray(tau, jnp.float32)
    tau_c = tau - _COARSE_SLACK * jnp.abs(tau)
    cand = (la[top][:, None, :] * jnp.swapaxes(lb[top], 0, 1)[None]
            >= (tau_c if top else tau))
    for l in range(top - 1, -1, -1):
        gm_l, gk_l = la[l].shape
        gn_l = lb[l].shape[1]
        cand = jnp.repeat(jnp.repeat(jnp.repeat(cand, 2, 0), 2, 1), 2, 2)
        cand = cand[:gm_l, :gn_l, :gk_l]
        t = tau if l == 0 else tau_c
        cand = cand & (la[l][:, None, :] * jnp.swapaxes(lb[l], 0, 1)[None] >= t)
    return cand


def hier_gate_mask(pyr_a: NormPyramid, pyr_b: NormPyramid, tau,
                   block_n: int = 1):
    """Coarse-to-fine validity bitmap — bit-identical to `gate_mask` on the
    finest normmaps (the exactness invariant: a failing coarse product
    upper-bounds, hence rules out, every fine product inside it).

    Concrete operands take the sparse numpy descent (sub-linear in the
    pruned region — the eager planning hot path) and return a HOST (numpy)
    bitmap, letting the planner count valid tiles without an accelerator
    round-trip; traced operands fall back to a dense but jit-compatible
    refinement returning a traced array.
    """
    levels = min(pyr_a.num_levels, pyr_b.num_levels)
    la = list(pyr_a.levels[: levels + 1])
    lb = list(pyr_b.levels[: levels + 1])
    traced = any(isinstance(x, jax.core.Tracer) for x in la + lb + [tau])
    if traced:
        mask = _hier_mask_traced(la, lb, tau)
    else:
        mask = _hier_mask_host(
            [np.asarray(x) for x in la],
            [np.asarray(x) for x in lb],
            float(np.asarray(tau)),
        )
    if block_n > 1:
        gm, gn, gk = mask.shape
        assert gn % block_n == 0, (gn, block_n)
        grouped = mask.reshape(gm, gn // block_n, block_n, gk)
        mask = grouped.any(2) if isinstance(mask, np.ndarray) else \
            jnp.any(grouped, axis=2)
    return mask


def _flat_triples_host(na: np.ndarray, nb: np.ndarray, tau: float,
                       block_n: int, *, keep_mask: bool):
    """Concrete flat gate on host, in row chunks: the fp32 products are
    exactly `gate_mask`'s, but the (gm, gn, gk) float tensor is never held
    whole — each chunk is reduced to bool (and to super-columns) before the
    next is computed, so peak memory is the 1-byte bitmap at most (and only
    when `keep_mask` asks for it, i.e. a dense-path backend will consume it).

    Returns ((ii, jb, kk) super-column-granularity triples, bitmap or None).
    """
    gm, gk = na.shape
    gn = nb.shape[1]
    assert gn % block_n == 0, (gn, block_n)
    gnb = gn // block_n
    nbt = np.ascontiguousarray(nb.T)  # (gn, gk)
    mask = np.zeros((gm, gnb, gk), bool) if keep_mask else None
    # ~64 MB transient fp32 product per chunk
    step = max(1, (1 << 24) // max(gn * gk, 1))
    parts_i, parts_j, parts_k = [], [], []
    for i0 in range(0, gm, step):
        blk = na[i0:i0 + step, None, :] * nbt[None] >= tau
        if block_n > 1:
            blk = blk.reshape(blk.shape[0], gnb, block_n, gk).any(2)
        if keep_mask:
            mask[i0:i0 + step] = blk
        bi, bj, bk_ = np.nonzero(blk)
        parts_i.append((bi.astype(np.int64) + i0))
        parts_j.append(bj)
        parts_k.append(bk_)
    return (np.concatenate(parts_i), np.concatenate(parts_j),
            np.concatenate(parts_k)), mask


def _maybe_compact(mask, backend: str):
    """map_offset compaction (§3.3) when the backend's kernel consumes it."""
    if kops.get_backend(backend).needs_compaction:
        return kref.spamm_compact_ref(mask)
    return None, None


def _any_traced(vals) -> bool:
    """True if any operand (matrix, normmap, pyramid level, or τ) is a
    tracer — i.e. plan() is being called under jit/vmap."""
    for v in vals:
        if isinstance(v, NormPyramid):
            if any(isinstance(l, jax.core.Tracer) for l in v.levels):
                return True
        elif isinstance(v, jax.core.Tracer):
            return True
    return False


def _side_pyramid(norm, x, levels: int, tile: int, bk, use_mxu: bool,
                  side: str) -> NormPyramid:
    """Resolve one operand side (matrix / normmap / pyramid) to a pyramid
    with at least `levels` coarsening steps."""
    if isinstance(norm, NormPyramid):
        return norm.extended(levels)
    if norm is not None:
        return NormPyramid.from_normmap(norm, levels, tile=tile)
    if x is None:
        raise ValueError(f"need `{side}` or `norm_{side}`")
    return NormPyramid(
        kops.pyramid_norms(x, tile, levels, backend=bk.name, use_mxu=use_mxu),
        tile=tile,
    )


def _frozen_step_flags(fp, active: jax.Array) -> jax.Array:
    """Traced INIT/ACC/FLUSH flags over a FrozenPlan's static step tables.

    `active` is the traced per-step activation gate (already AND step_real).
    Pure static-shape cumsum/gather arithmetic: INIT fires on a segment's
    first active step, FLUSH on its last; a segment with NO active step gets
    one forced INIT|FLUSH (no ACC) at its final step so its visited output
    tile is written with explicit zeros — the frozen twin of
    `compact_from_triples`'s empty-plan handling, and bit-identical to the
    eager work-list (same active steps, same ascending-k f32 accumulation).
    """
    act = active.astype(jnp.int32)
    cum = jnp.cumsum(act)
    excl = cum - act                      # actives strictly before each step
    first_excl = excl[fp.seg_first]
    before = excl - first_excl            # actives before, within segment
    total = cum[fp.seg_last] - first_excl  # actives in the whole segment
    init = (active & (before == 0)).astype(jnp.int32)
    flush = (active & (before + 1 == total)).astype(jnp.int32)
    idx = jnp.arange(act.shape[0], dtype=jnp.int32)
    empty_write = ((total == 0) & (idx == fp.seg_last)).astype(jnp.int32)
    return (init * STEP_INIT + act * STEP_ACC + flush * STEP_FLUSH
            + empty_write * (STEP_INIT | STEP_FLUSH))


def _plan_frozen(a, fp, *, norm_a=None, use_mxu_norm: bool = False
                 ) -> SpammPlan:
    """Traced plan from a FrozenPlan weight side: the compiled graph runs
    the activation-side get-norm plus an O(S) gather-compare over the frozen
    step tables — zero weight-side get-norm, zero dense-bitmap sort, and the
    concrete work-list path is the only executed path."""
    from repro.plans.frozen import FrozenPlan, FrozenWeight  # circular-safe

    if isinstance(fp, FrozenWeight):
        if a is None:
            raise ValueError("a FrozenWeight needs the activation to pick "
                             "the row grid; pass `a` or pre-specialize with "
                             "for_rows(gm)")
        if isinstance(fp.nbmax, jax.core.Tracer):
            raise ValueError(
                "FrozenWeight.for_rows must run eagerly (its step tables "
                "are concrete data); specialize before jit and pass the "
                "FrozenPlan as a jit argument")
        fp = fp.for_rows(a.shape[0] // fp.tile)
    assert isinstance(fp, FrozenPlan), type(fp)
    bk = kops.get_backend(fp.backend)
    if bk.needs_compaction and bk.matmul_worklist is None:
        raise ValueError(
            f"backend {bk.name!r} consumes dense kidx tables but has no "
            "work-list entry point — the frozen path cannot feed it; "
            "register a matmul_worklist or use a mask-gating backend")
    tile = fp.tile
    dtype = getattr(fp, "compute_dtype", "float32")
    a_scale = None
    if norm_a is None:
        if a is None:
            raise ValueError("need `a` or `norm_a`")
        # low-precision plans gate on the quantized activation view (the
        # weight-side tables were frozen from the quantized weight, and
        # fp.tau is already the widened gate threshold)
        if dtype == "int8":
            # fused absmax/scale + get-norm: one read of the activation
            # yields the quantized-view norms AND the per-tile scales, so
            # execute() quantizes from plan-carried scales instead of a
            # separate per-call absmax pass
            norm_a, a_scale = kops.int8_norms_and_scales(
                a, tile, backend=bk.name, use_mxu=use_mxu_norm)
        else:
            a_view = (kquant.quantized_view(a, dtype, tile)
                      if dtype != "float32" else a)
            norm_a = bk.norms(a_view, tile, use_mxu=use_mxu_norm)
    gm, gk = norm_a.shape
    if (gm, gk) != (fp.gm, fp.gk):
        raise ValueError(
            f"frozen plan was specialized for a ({fp.gm}, {fp.gk}) "
            f"activation grid, got ({gm}, {gk}) — rebuild with "
            f"for_rows({gm})")
    tau = jnp.asarray(fp.tau, jnp.float32)
    # the traced activation gate: exact flat τ-test per frozen step (the
    # super-column max commutes with the gate — fp32 multiply is monotone
    # in each non-negative factor), restricted to real (non-padding) steps
    pa = norm_a[fp.step_i, fp.step_k]
    pb = fp.nbmax[fp.step_k, fp.step_j]
    active = fp.step_real & (pa * pb >= tau)
    flags = _frozen_step_flags(fp, active)
    work = SpammWork(rows=None, cols=None, offsets=None, klist=None,
                     step_i=fp.step_i, step_j=fp.step_j, step_k=fp.step_k,
                     step_flags=flags)
    nvalid = jnp.zeros((gm, fp.gnb), jnp.int32).at[fp.step_i, fp.step_j].add(
        active.astype(jnp.int32))
    valid_tiles = jnp.sum(active, dtype=jnp.int32)
    return SpammPlan(tau, norm_a, fp.norm_b, None, None, nvalid, valid_tiles,
                     work, a_scale, getattr(fp, "b_scale", None),
                     tile=tile, block_n=fp.block_n, backend=bk.name,
                     levels=fp.num_levels, compute_dtype=dtype)


def plan(
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    tau=None,
    *,
    valid_ratio=None,
    norm_a=None,
    norm_b=None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    levels: int = 0,
    frozen_weight=None,
    compute_dtype: str = "float32",
    bucket_min: int = 16,
) -> SpammPlan:
    """Build the gating phase for (M, K) @ (K, N), dims divisible by tile
    (and N by tile·block_n) — pad upstream (see `pad_to_tile` /
    `core.spamm.spamm`).

    Either side may be given as the matrix (positional) or as a precomputed
    normmap / NormPyramid (norm_a= / norm_b= keywords; the matrix argument
    may then be omitted). Exactly one of `tau` / `valid_ratio` must be set;
    valid_ratio runs the §3.5.2 τ-search on the normmaps.

    levels > 0 (or a NormPyramid operand) switches to hierarchical gating:
    coarse-to-fine refinement over the norm pyramid. The resulting mask is
    bit-identical to flat gating (levels=0); what changes is the cost of
    building it — sub-linear in the pruned region for concrete operands —
    and a coarse-first τ-search when valid_ratio is given. Under jit
    (traced operands) the plan silently downgrades to flat gating: the mask
    is identical and the sparse descent can't run there, so `levels` is
    free on compiled paths rather than an overhead.

    frozen_weight (a `repro.plans.frozen.FrozenPlan`, or a `FrozenWeight`
    when planning eagerly) replaces the whole weight side with precomputed
    artifacts: τ/tile/block_n/levels/backend/compute_dtype come FROM the
    artifact (the keyword args are ignored), only the activation-side gate
    is computed (pass norm_a= to skip even that), and the resulting plan
    executes via the frozen `SpammWork` step tables — the path compiled
    prefill/decode take with plans as jit inputs.

    compute_dtype ("float32" | "bfloat16" | "int8", aliases accepted) plans
    for low-precision execution: normmaps are computed (in f32) from the
    QUANTIZED operand view — the values the kernel will actually multiply —
    and an explicit τ is widened by the analytic quantization error bound
    (kernels/quantize.py) so the low-precision gate provably keeps every
    tile the f32 gate at the requested τ keeps. With valid_ratio the
    τ-search runs directly on the quantized norms (the target ratio IS the
    spec; no widening on top). Callers who pass precomputed norm_a/norm_b
    at a low dtype are responsible for having computed them from the
    quantized view (`WeightPlanCache.weight_side(dtype=...)` does).

    bucket_min floors the work-list step tables' power-of-two bucket
    (`core.cost.bucket`) — autotuned per weight (`TunedParams.bucket`) so a
    serving stream whose Σnvalid hovers around a bucket boundary stops
    re-jitting; 16 is the historical default.
    """
    if frozen_weight is not None:
        if tau is not None or valid_ratio is not None:
            raise ValueError("frozen_weight carries its own tau; pass "
                             "neither tau nor valid_ratio")
        return _plan_frozen(a, frozen_weight, norm_a=norm_a,
                            use_mxu_norm=use_mxu_norm)
    if (tau is None) == (valid_ratio is None):
        raise ValueError("give exactly one of tau / valid_ratio")
    bk = kops.get_backend(backend)

    compute_dtype = kquant.canonical_dtype(compute_dtype)
    a_scale = b_scale = None
    if compute_dtype != "float32":
        # gate on what the kernel will multiply. int8: the fused
        # absmax/scale + get-norm kernel turns each operand matrix into
        # (quantized-view norms, per-tile scales) in ONE read — the plan
        # keeps the scales so execute() skips its absmax pass; the matrix
        # slot is cleared because the norms below ARE its only use (the
        # hierarchical path pools pyramids from the fine normmap).
        # bf16: the quantize-dequantized f32 view replaces the operand
        # before any norm computation, as before.
        if compute_dtype == "int8":
            if a is not None and norm_a is None:
                norm_a, a_scale = kops.int8_norms_and_scales(
                    a, tile, backend=bk.name, use_mxu=use_mxu_norm)
                a = None
            if b is not None and norm_b is None:
                norm_b, b_scale = kops.int8_norms_and_scales(
                    b, tile, backend=bk.name, use_mxu=use_mxu_norm)
                b = None
        else:
            if a is not None:
                a = kquant.quantized_view(a, compute_dtype, tile)
            if b is not None:
                b = kquant.quantized_view(b, compute_dtype, tile)
        if tau is not None:
            tau = kquant.widen_tau(tau, compute_dtype, tile)

    hier = (levels > 0 or isinstance(norm_a, NormPyramid)
            or isinstance(norm_b, NormPyramid))
    if hier and _any_traced((a, b, norm_a, norm_b, tau)):
        # Under jit the sparse descent can't run and the dense traced
        # refinement produces the SAME mask as flat gating for strictly more
        # work — downgrade to flat so `levels` is free on compiled paths
        # (jitted prefill) while eager callers keep the hierarchical win.
        # hier_gate_mask stays available for traced callers who want the
        # level-by-level refinement explicitly.
        if isinstance(norm_a, NormPyramid):
            norm_a = norm_a.base
        if isinstance(norm_b, NormPyramid):
            norm_b = norm_b.base
        hier = False
    triples = None          # surviving (i, j, k); j granularity per flag
    triples_grouped = False  # True ⇒ j is already a super-column id
    mask = None
    if hier:
        want = max(
            levels,
            norm_a.num_levels if isinstance(norm_a, NormPyramid) else 0,
            norm_b.num_levels if isinstance(norm_b, NormPyramid) else 0,
        )
        pyr_a = _side_pyramid(norm_a, a, want, tile, bk, use_mxu_norm, "a")
        pyr_b = _side_pyramid(norm_b, b, want, tile, bk, use_mxu_norm, "b")
        norm_a, norm_b = pyr_a.base, pyr_b.base
        if valid_ratio is not None:
            from repro.core.tau_search import search_tau_pyramid  # circular-safe

            tau, _ = search_tau_pyramid(pyr_a, pyr_b, valid_ratio)
        tau = jnp.asarray(tau, jnp.float32)
        if _any_traced((pyr_a, pyr_b, tau)):
            # even with concrete OPERANDS, an enclosing jit turns the
            # nested-jit kernels (pyramid_norms, the τ-search) into tracer
            # producers — the host descent can't run there, so gate with the
            # traced coarse-to-fine refinement (bit-identical mask)
            mask = hier_gate_mask(pyr_a, pyr_b, tau, block_n)
        else:
            # fully concrete: the descent hands over its surviving triples —
            # the compacted set — and no dense bitmap is ever materialized
            lv = min(pyr_a.num_levels, pyr_b.num_levels)
            triples = _hier_descend_host(
                [np.asarray(x) for x in pyr_a.levels[: lv + 1]],
                [np.asarray(x) for x in pyr_b.levels[: lv + 1]],
                float(np.asarray(tau)),
            )
    else:
        if norm_a is None:
            if a is None:
                raise ValueError("need `a` or `norm_a`")
            norm_a = bk.norms(a, tile, use_mxu=use_mxu_norm)
        if norm_b is None:
            if b is None:
                raise ValueError("need `b` or `norm_b`")
            norm_b = bk.norms(b, tile, use_mxu=use_mxu_norm)

        if valid_ratio is not None:
            from repro.core.tau_search import search_tau  # circular-safe

            tau, _ = search_tau(norm_a, norm_b, valid_ratio)
        tau = jnp.asarray(tau, jnp.float32)
        if _any_traced((norm_a, norm_b, tau)):
            mask = gate_mask(norm_a, norm_b, tau, block_n)
        else:
            # concrete flat gate on host: same fp32 products as gate_mask,
            # then a nonzero scan — the triples feed compact_from_triples so
            # kidx/nvalid need no sort over the (gm, gn, gk) grid
            triples, mask = _flat_triples_host(
                np.asarray(norm_a), np.asarray(norm_b),
                float(np.asarray(tau)), block_n,
                keep_mask=bk.matmul_worklist is None)
            triples_grouped = True

    gm, gk = norm_a.shape
    gn = norm_b.shape[-1]
    gnb = gn // block_n
    if triples is not None:  # concrete plan: compacted-first
        # per-step tables only for backends that will execute the ragged
        # kernel; bitmap/dense-kidx backends never read them
        steps = bk.matmul_worklist is not None
        if triples_grouped:
            # the chunked nonzero scan emits triples in row-major (sorted
            # fused-key) order with grouping already applied — skip the sort
            work_np, nvalid_np = compact_from_triples(
                *triples, gm=gm, gn=gnb, gk=gk, block_n=1, steps=steps,
                assume_sorted=True, bucket_min=bucket_min)
        else:
            work_np, nvalid_np = compact_from_triples(
                *triples, gm=gm, gn=gn, gk=gk, block_n=block_n, steps=steps,
                bucket_min=bucket_min)
        valid_tiles = jnp.int32(int(work_np.klist.size))
        nvalid = jnp.asarray(nvalid_np)
        # dense kidx only for dense-grid kernels with no ragged entry point
        kidx = (jnp.asarray(kidx_from_work(work_np, gm, gnb, gk))
                if bk.needs_compaction and bk.matmul_worklist is None
                else None)
        if mask is None and not steps:
            # no ragged executor means the executable form IS the bitmap (or
            # the kidx above) — scatter it now from the pair view instead of
            # lazily from step tables that were never built (numpy twin of
            # SpammPlan.mask's traceable step-view scatter; keep in sync)
            m_host = np.zeros((gm, gnb, gk), bool)
            counts = np.diff(work_np.offsets)
            m_host[np.repeat(work_np.rows, counts),
                   np.repeat(work_np.cols, counts), work_np.klist] = True
            mask = m_host
        work = SpammWork(*(jnp.asarray(x) if x is not None else None
                           for x in work_np))
        mask = jnp.asarray(mask) if mask is not None else None
    else:  # traced plan: dense bitmap, legacy compaction
        valid_tiles = jnp.sum(mask, dtype=jnp.int32)
        kidx, nvalid = _maybe_compact(mask, bk.name)
        work = None
    return SpammPlan(tau, norm_a, norm_b, mask, kidx, nvalid, valid_tiles,
                     work, a_scale, b_scale, tile=tile, block_n=block_n,
                     backend=bk.name, levels=(want if hier else 0),
                     compute_dtype=compute_dtype)


def execute(p: SpammPlan, a: jax.Array, b: jax.Array, *, out_dtype=None):
    """Run the multiplication phase of a prebuilt plan on (a, b).

    a/b must have the tile-padded shapes the plan was built for. Executing
    the same plan twice on the same operands is bit-identical to the
    unplanned `kernels.ops.spamm_matmul` — the plan IS that call's first
    half.

    Low-precision plans (`p.compute_dtype`): callers keep passing the
    ORIGINAL operands — execute owns the cast/quantization. bf16 casts both
    operands and takes the normal kernel entry points (f32 accumulate is
    their contract); int8 quantizes per tile (reusing plan-stored scales
    when present — bit-identical either way, quantization is a pure function
    of the operand) and drives `matmul_worklist_int8`. Backends without the
    int8 entry point (jnp/third-party) get the widen-to-f32 fallback: the
    dequantized f32 view runs the normal path, numerically the product the
    int8 kernel approximates to a few ulps.
    """
    gm, gk = p.norm_a.shape
    _, gn = p.norm_b.shape
    t = p.tile
    assert a.shape == (gm * t, gk * t), (a.shape, (gm * t, gk * t))
    assert b.shape == (gk * t, gn * t), (b.shape, (gk * t, gn * t))
    bk = kops.get_backend(p.backend)
    dtype = getattr(p, "compute_dtype", "float32")
    if dtype == "int8":
        a_q, a_s = kquant.quantize_tiles(a, t, scales=p.a_scale)
        b_q, b_s = kquant.quantize_tiles(b, t, scales=p.b_scale)
        if (p.work is not None and p.work.step_i is not None
                and bk.matmul_worklist_int8 is not None):
            return bk.matmul_worklist_int8(
                a_q, b_q, a_s, b_s, p.work, p.tile, p.block_n,
                out_dtype or jnp.float32)
        # widen-to-f32 fallback: dequantize and take the normal path
        a = kquant.dequantize_tiles(a_q, a_s, t)
        b = kquant.dequantize_tiles(b_q, b_s, t)
    elif dtype == "bfloat16":
        if p.work is not None and bk.matmul_worklist is not None:
            # the worklist kernel is dtype-blind: bf16 operands feed the
            # MXU's native bf16×bf16→f32 path, accumulator stays f32
            a = a.astype(jnp.bfloat16)
            b = b.astype(jnp.bfloat16)
        else:
            # widen-to-f32 fallback: f32 math over the bf16-rounded values
            a = a.astype(jnp.bfloat16).astype(jnp.float32)
            b = b.astype(jnp.bfloat16).astype(jnp.float32)
    if p.work is not None and bk.matmul_worklist is not None:
        # ragged path: Σnvalid grid steps, dense mask never materialized
        return bk.matmul_worklist(a, b, p.work, p.tile, p.block_n,
                                  out_dtype or jnp.float32)
    return bk.matmul(a, b, p.mask, p.kidx, p.nvalid, p.tile, p.block_n,
                     out_dtype or jnp.float32)


# ---------------------------------------------------------------------------
# per-weight plan cache (serving hot path)
# ---------------------------------------------------------------------------

class _WeightEntry(NamedTuple):
    weight: Any          # strong ref: anchors the id() key (no stale reuse)
    padded: jax.Array
    norms: Any           # normmap (levels=0) or NormPyramid (levels>0)


class WeightPlanCache:
    """Caches the weight-side gating artifacts (tile padding + normmap or
    full norm pyramid), keyed on weight identity/shape/dtype/tile/backend/
    levels.

    Serving engines and eager model forward passes call the same weight
    matrix against a stream of activations; the activation-side normmap and
    the bitmap depend on the batch, but the weight normmap (the expensive
    O(K·N) half of get-norm) and the padded copy do not — compute them once
    per weight instead of per token batch. With levels > 0 the cache holds
    the weight-side NormPyramid, so hierarchical replans pay zero weight-side
    work beyond the first request.

    Tracers are never cached (inside jit the trace itself is cached, and
    tracer ids are meaningless); the cache is an eager-path optimization.
    LRU-bounded; `hits`/`misses` expose effectiveness for tests/benchmarks.

    Frozen tier: `frozen_weight` memoizes `repro.plans.frozen.FrozenWeight`
    artifacts by content fingerprint, falling through to the attached
    `PlanStore` (`self.store`) and only then to a fresh build — the cache is
    the in-memory tier above the on-disk store, so a warm store makes
    engine start-up a pure load (no get-norm pass).
    """

    def __init__(self, maxsize: int = 256, store=None):
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self.store = store           # optional repro.plans.store.PlanStore
        self._frozen: dict = {}
        self.frozen_hits = 0
        self.frozen_misses = 0

    @staticmethod
    def _cacheable(w) -> bool:
        return isinstance(w, (np.ndarray, jax.Array)) and not isinstance(
            w, jax.core.Tracer
        )

    def weight_side(self, w, *, tile: int, backend: str,
                    use_mxu: bool = False, levels: int = 0,
                    block_n: int = 1, dtype: str = "float32"):
        """(padded_weight, weight_norms) for w, cached on identity.

        w may be 2-D (K, N) → normmap (gk, gn), or 3-D batched (B, K, N) —
        the per-expert MoE shape — → normmap (B, gk, gn) from one reshaped
        get-norm pass (row tiles never cross slices after padding).
        levels > 0 returns a NormPyramid instead of the plain normmap (for
        3-D weights the pyramid levels carry the batch dim). block_n > 1
        pads N to tile·block_n so the super-column grouping always divides
        the column grid (the padding is part of the cache key). dtype (a
        compute dtype) computes the norms from the QUANTIZED weight view —
        what a low-precision execute will multiply — and is part of the
        cache key; the returned padded weight stays the original f32 (the
        executor owns the actual cast/quantization)."""
        bk = kops.get_backend(backend)
        dtype = kquant.canonical_dtype(dtype)

        def compute():
            wp = pad_to_tile(jnp.asarray(w), tile, tile * block_n)
            # 3-D (per-expert MoE) weights norm through one reshaped 2-D
            # pass — row tiles never cross slices after padding
            w2 = (wp.reshape(wp.shape[0] * wp.shape[1], wp.shape[2])
                  if wp.ndim == 3 else wp)
            if dtype == "int8":
                # fused absmax/scale + get-norm: quantized-view norms from
                # one read (the scales are dropped here — execute recomputes
                # them bit-identically; the cache stays dtype-agnostic)
                nw, _ = kops.int8_norms_and_scales(
                    w2, tile, backend=bk.name, use_mxu=use_mxu)
            elif dtype != "float32":
                nw = bk.norms(kquant.quantized_view(w2, dtype, tile), tile,
                              use_mxu=use_mxu)
            else:
                nw = bk.norms(w2, tile, use_mxu=use_mxu)
            if wp.ndim == 3:
                nw = nw.reshape(wp.shape[0], wp.shape[1] // tile, -1)
            if levels > 0:
                # batched pooling (pool_norms_ref pools the trailing 2 dims)
                nw = NormPyramid.from_normmap(nw, levels, tile=tile)
            return wp, nw

        if not self._cacheable(w):
            return compute()
        key = (id(w), w.shape, str(w.dtype), tile, bk.name, use_mxu, levels,
               block_n, dtype)
        ent = self._entries.get(key)
        if ent is not None and ent.weight is w:
            self.hits += 1
            self._entries.move_to_end(key)
            return ent.padded, ent.norms
        self.misses += 1
        wp, nw = compute()
        self._entries[key] = _WeightEntry(w, wp, nw)
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return wp, nw

    def plan_for(self, x_padded, w, tau=None, *, valid_ratio=None,
                 tile: int = 64, block_n: int = 1, backend: str = "auto",
                 use_mxu_norm: bool = False, levels: int = 0,
                 compute_dtype: str = "float32"):
        """Full plan for x @ w with the weight side served from the cache.
        x_padded must already be tile-padded. Returns (plan, padded_weight).
        levels > 0 plans hierarchically with the cached weight pyramid.
        compute_dtype plans for low-precision execution: the cached weight
        norms come from the quantized weight view and plan() handles the
        activation view + τ widening (the weight-side b_scale is recomputed
        by execute — bit-identical, quantization is pure).
        """
        compute_dtype = kquant.canonical_dtype(compute_dtype)
        wp, nw = self.weight_side(w, tile=tile, backend=backend,
                                  use_mxu=use_mxu_norm, levels=levels,
                                  block_n=block_n, dtype=compute_dtype)
        p = plan(x_padded, None, tau, valid_ratio=valid_ratio, norm_b=nw,
                 tile=tile, block_n=block_n, backend=backend,
                 use_mxu_norm=use_mxu_norm, levels=levels,
                 compute_dtype=compute_dtype)
        return p, wp

    def frozen_weight(self, w, *, tau, tile: int = 64, block_n: int = 1,
                      levels: int = 0, backend: str = "auto",
                      use_mxu: bool = False, store=None,
                      dtype: str = "float32", tuned=None):
        """FrozenWeight for `w` at the given gating config, through the
        memory → store → build tiers. Keyed on the weight's CONTENT
        fingerprint (slices of a stacked parameter hash stably, unlike
        id()), so repeated engine warm-ups and the precompute CLI agree.
        dtype is the compute dtype the artifact is frozen for (quantized
        norms + widened gate τ + int8 scale tables) and part of the key.
        tuned (a `core.cost.TunedParams`) rides the built artifact as
        provenance + bucket floor; it is NOT part of the cache/store key —
        callers passing tuned params pass the tuned block_n/levels here too
        (that's what addresses the artifact). A store hit that predates the
        field gets `tuned` re-attached so the bucket floor still applies."""
        from repro.plans import frozen as _frozen  # circular-safe
        from repro.plans import store as _pstore

        store = store if store is not None else self.store
        h = _pstore.fingerprint(w)
        resolved = kops.resolve_backend(backend)
        dtype = kquant.canonical_dtype(dtype)
        key = (h, float(tau), tile, block_n, levels, resolved, use_mxu,
               dtype)
        hit = self._frozen.get(key)
        if hit is not None:
            self.frozen_hits += 1
            return hit
        self.frozen_misses += 1
        fw = None
        if store is not None:
            fw = store.get(h, tau=tau, tile=tile, block_n=block_n,
                           levels=levels, backend=resolved, use_mxu=use_mxu,
                           dtype=dtype)
            if fw is not None and fw.tuned is None and tuned is not None:
                fw.tuned = tuned
        if fw is None:
            fw = _frozen.FrozenWeight.build(
                w, tau, tile=tile, block_n=block_n, levels=levels,
                backend=resolved, use_mxu=use_mxu, weight_hash=h,
                compute_dtype=dtype, tuned=tuned)
            if store is not None:
                store.put(fw)
        self._frozen[key] = fw
        return fw

    def clear(self):
        self._entries.clear()
        self.hits = self.misses = 0
        self._frozen.clear()
        self.frozen_hits = self.frozen_misses = 0

    def __len__(self):
        return len(self._entries)


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

def spamm_bmm(
    x: jax.Array,
    w: jax.Array,
    tau=None,
    *,
    valid_ratio=None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    out_dtype=None,
    cache: Optional[WeightPlanCache] = None,
    levels: int = 0,
):
    """Batched SpAMM: (B, M, K) @ (K, N) or (B, M, K) @ (B, K, N).

    levels > 0 plans the shared-weight case hierarchically (the batch folds
    into the row-tile grid, so it is one big 2-D product); the per-batch-
    weight case keeps flat per-slice gating (its vmapped masks are already
    per-slice small) while still caching the weight-side artifacts.

    Shared-weight case: the batch dim folds into the row-tile grid — the
    whole batch runs as ONE (B·M, K) @ (K, N) product whose row tiles never
    cross slice boundaries, so the gating is exactly the per-slice gating
    while the weight-side plan (normmap + padding, optionally from `cache`)
    is computed once and shared across the batch. Per-batch-weight case:
    normmaps for every slice come from one reshaped get-norm call, gating is
    vmapped, and the multiplication runs per slice under lax.map (jnp
    backend: vmapped masked einsum).

    Arbitrary shapes are zero-padded to tile multiples and un-padded.
    Returns (C (B, M, N), SpammInfo).
    """
    if (tau is None) == (valid_ratio is None):
        raise ValueError("give exactly one of tau / valid_ratio")
    bsz, m, k = x.shape
    bk = kops.get_backend(backend)
    out_dtype = out_dtype or jnp.float32

    if w.ndim == 2:  # (B, M, K) @ (K, N): fold batch into the row-tile grid
        k2, n = w.shape
        assert k == k2, (x.shape, w.shape)
        xp = pad_to_tile(x, tile)
        mp, kp = xp.shape[1:]
        if cache is not None:
            wp, nw = cache.weight_side(w, tile=tile, backend=backend,
                                       use_mxu=use_mxu_norm, levels=levels,
                                       block_n=block_n)
        else:
            wp = pad_to_tile(w, tile, tile * block_n)
            nw = bk.norms(wp, tile, use_mxu=use_mxu_norm)
            if levels > 0:
                nw = NormPyramid.from_normmap(nw, levels, tile=tile)
        x2 = xp.reshape(bsz * mp, kp)
        p = plan(x2, None, tau, valid_ratio=valid_ratio, norm_b=nw,
                 tile=tile, block_n=block_n, backend=backend,
                 use_mxu_norm=use_mxu_norm, levels=levels)
        c = execute(p, x2, wp, out_dtype=out_dtype)
        c = c.reshape(bsz, mp, -1)[:, :m, :n]
        frac = p.valid_fraction
        tau_used = p.tau
    else:  # (B, M, K) @ (B, K, N): per-slice plans, weight norms in one pass
        if valid_ratio is not None:
            raise ValueError("valid_ratio needs a shared weight; pass tau for "
                             "per-batch weights")
        assert w.shape[0] == bsz and w.shape[1] == k, (x.shape, w.shape)
        n = w.shape[2]
        xp = pad_to_tile(x, tile)
        mp, kp = xp.shape[1:]
        gm, gk = mp // tile, kp // tile
        if cache is not None:
            wp, nw = cache.weight_side(w, tile=tile, backend=backend,
                                       use_mxu=use_mxu_norm, block_n=block_n)
        else:
            wp = pad_to_tile(w, tile, tile * block_n)
            np_ = wp.shape[2]
            nw = bk.norms(wp.reshape(bsz * kp, np_), tile,
                          use_mxu=use_mxu_norm).reshape(bsz, gk, -1)
        na = bk.norms(xp.reshape(bsz * mp, kp), tile,
                      use_mxu=use_mxu_norm).reshape(bsz, gm, gk)
        tau_used = jnp.asarray(tau, jnp.float32)
        mask = jax.vmap(lambda a_, b_: gate_mask(a_, b_, tau_used, block_n))(
            na, nw)
        if bk.needs_compaction:
            kidx, nvalid = jax.vmap(kref.spamm_compact_ref)(mask)
            c = jax.lax.map(
                lambda s: bk.matmul(s[0], s[1], s[2], s[3], s[4], tile,
                                    block_n, out_dtype),
                (xp, wp, mask, kidx, nvalid),
            )
        else:
            c = jax.vmap(
                lambda a_, b_, m_: bk.matmul(a_, b_, m_, None, None, tile,
                                             block_n, out_dtype)
            )(xp, wp, mask)
        c = c[:, :m, :n]
        frac = jnp.sum(mask, dtype=jnp.int32) / mask.size

    return c, SpammInfo(
        tau=jnp.asarray(tau_used, jnp.float32),
        valid_fraction=frac,
        effective_flops=frac * (2.0 * bsz * m * k * n),
    )
