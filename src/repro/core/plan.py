"""Plan/execute split for the SpAMM pipeline.

The paper's pipeline has two phases with very different reuse behavior:

  * a cheap **gating** phase — get-norm (§3.2) → bitmap → `map_offset`
    compaction (§3.3) — that depends only on the operands' normmaps and τ;
  * an expensive **multiplication** phase (Alg. 2/3) that consumes the
    gating artifacts and the operand data.

For serving-style workloads the right-hand operand (a weight matrix) is
static across requests, so its half of the gating phase can be planned once
and reused for every token batch — the "preprocess once, multiply many"
structure Acc-SpMM and tSparse use to make sparse tensor-core kernels pay
off. This module is the ONE implementation of the gating phase (mask,
super-column grouping, compaction); every other call site
(`kernels.ops.spamm_matmul`, `core.spamm.spamm`, `core.module.spamm_linear`,
`core.distributed.spamm_rowpart/_2d`) builds a `SpammPlan` here and runs it
through `execute`.

Hierarchical (norm-pyramid) gating: the original SpAMM is a *recursive*
algorithm; the flat one-level gate re-derived here costs O(gm·gn·gk) norm
products regardless of sparsity. Since a coarse tile's Frobenius norm
upper-bounds every sub-tile's norm, a coarse-level τ-test that fails rules
out every fine pair inside it — so a `NormPyramid` (levels of sqrt-sumsq
pooled normmaps) gives *exact* coarse-to-fine pruning: `plan(..., levels=L)`
gates at the coarsest level first and refines only inside surviving coarse
blocks, producing a mask bit-identical to flat gating while plan
construction becomes sub-linear in the pruned region.

API:
  plan(a, b, tau | valid_ratio=...)  → SpammPlan   (or from precomputed
                                       normmaps via norm_a= / norm_b=;
                                       levels=L turns on pyramid gating)
  execute(plan, a, b)                → C
  NormPyramid                        — coarse-to-fine normmap stack
  hier_gate_mask(pyr_a, pyr_b, tau)  — coarse-to-fine mask (≡ gate_mask)
  WeightPlanCache                    — per-weight gating artifacts, keyed on
                                       weight identity/shape/tile/levels
  spamm_bmm(x, w, tau)               — batched (B,M,K)@(K,N) / (B,K,N) with
                                       the weight-side plan shared across
                                       the batch
"""
from __future__ import annotations

import collections
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# padding helper (shared by every caller that accepts arbitrary shapes)
# ---------------------------------------------------------------------------

def pad_to_tile(x: jax.Array, tile: int) -> jax.Array:
    """Zero-pad the trailing two dims of x up to multiples of `tile`."""
    m, n = x.shape[-2:]
    pm, pn = (-m) % tile, (-n) % tile
    if pm == 0 and pn == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# NormPyramid — coarse-to-fine normmap stack
# ---------------------------------------------------------------------------

# Relative slack applied to τ at coarse levels only: coarse norms are computed
# in fp32 (sqrt of pooled sumsq), so a coarse product can round a hair below a
# fine product it mathematically dominates. The slack widens the candidate set
# (never prunes extra), keeping the level-0 test — which is exactly the flat
# gate — the sole decider of the final mask. Bit-identity to flat gating is
# therefore unconditional; 1e-5 covers the fp32 rounding of several pooling
# levels with orders of magnitude to spare.
_COARSE_SLACK = 1e-5


@jax.tree_util.register_pytree_node_class
class NormPyramid:
    """Coarse-to-fine stack of normmaps for one operand side.

    levels[0] is the plain normmap at `tile`; levels[l] ceil-halves each grid
    dim of levels[l-1] by sqrt-of-sumsq pooling, so levels[l][I, J] is the
    exact Frobenius norm of the (tile·2^l)² block (zero-padded at ragged
    edges) and upper-bounds every descendant tile norm. Built from ONE
    get-norm pass over the matrix plus `num_levels` cheap reductions.

    A pytree (children = the level arrays), so pyramids pass through
    jit/vmap and live in caches exactly like plain normmaps.
    """

    def __init__(self, levels, *, tile: int):
        self.levels = tuple(levels)
        self.tile = tile

    def tree_flatten(self):
        return self.levels, (self.tile,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children, tile=aux[0])

    @property
    def base(self) -> jax.Array:
        """The finest normmap — what flat gating / SpammPlan.norm_* store."""
        return self.levels[0]

    @property
    def coarse(self) -> jax.Array:
        return self.levels[-1]

    @property
    def num_levels(self) -> int:
        """Number of coarsening steps (0 ⇒ just the flat normmap)."""
        return len(self.levels) - 1

    @property
    def coarse_tile(self) -> int:
        return self.tile * (2 ** self.num_levels)

    def extended(self, levels: int) -> "NormPyramid":
        """This pyramid deepened to `levels` coarsening steps (no-op if
        already at least that deep) — pools from the current coarsest."""
        if self.num_levels >= levels:
            return self
        lv = list(self.levels)
        for _ in range(levels - self.num_levels):
            lv.append(kref.pool_norms_ref(lv[-1]))
        return NormPyramid(lv, tile=self.tile)

    @classmethod
    def from_normmap(cls, normmap: jax.Array, levels: int, *, tile: int = 64
                     ) -> "NormPyramid":
        """Pyramid from an existing finest normmap (reuses the get-norm pass
        that produced it; each level is one pooling reduction)."""
        lv = [normmap]
        for _ in range(levels):
            lv.append(kref.pool_norms_ref(lv[-1]))
        return cls(lv, tile=tile)

    @classmethod
    def build(cls, x: jax.Array, levels: int, *, tile: int = 64,
              backend: str = "auto", use_mxu: bool = False) -> "NormPyramid":
        """Pyramid from the matrix via the backend's pyramid_norms kernel."""
        return cls(
            kops.pyramid_norms(x, tile, levels, backend=backend,
                               use_mxu=use_mxu),
            tile=tile,
        )


# ---------------------------------------------------------------------------
# SpammPlan
# ---------------------------------------------------------------------------

class SpammInfo(NamedTuple):
    tau: jax.Array              # threshold actually used
    valid_fraction: jax.Array   # executed-tile fraction (== paper valid ratio)
    effective_flops: jax.Array  # 2·M·K·N · valid_fraction


@jax.tree_util.register_pytree_node_class
class SpammPlan:
    """Cached gating phase of one SpAMM product.

    Array fields (pytree children — a plan passes through jit/vmap):
      tau         f32 scalar
      norm_a      (gm, gk)  A-side normmap
      norm_b      (gk, gn)  B-side normmap
      mask        (gm, gn//block_n, gk) bool — validity bitmap at
                  super-column granularity (block_n=1 ⇒ per-tile)
      kidx        (gm, gn//block_n, gk) int32 compacted valid-k lists, or
                  None when the backend gates from `mask` directly
      nvalid      (gm, gn//block_n) int32, or None (as above)
      valid_tiles i32 scalar — Σ mask

    Static metadata (aux): tile, block_n, backend (resolved name), levels
    (pyramid coarsening steps the mask was gated with; 0 = flat — the mask is
    bit-identical either way, `levels` only records how it was built).
    """

    def __init__(self, tau, norm_a, norm_b, mask, kidx, nvalid, valid_tiles,
                 *, tile: int, block_n: int, backend: str, levels: int = 0):
        self.tau = tau
        self.norm_a = norm_a
        self.norm_b = norm_b
        self.mask = mask
        self.kidx = kidx
        self.nvalid = nvalid
        self.valid_tiles = valid_tiles
        self.tile = tile
        self.block_n = block_n
        self.backend = backend
        self.levels = levels

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.tau, self.norm_a, self.norm_b, self.mask,
                    self.kidx, self.nvalid, self.valid_tiles)
        return children, (self.tile, self.block_n, self.backend, self.levels)

    @classmethod
    def tree_unflatten(cls, aux, children):
        tile, block_n, backend, levels = aux
        return cls(*children, tile=tile, block_n=block_n, backend=backend,
                   levels=levels)

    # -- derived quantities -------------------------------------------------
    @property
    def total_tiles(self) -> int:
        gm, gnb, gk = self.mask.shape
        return gm * gnb * gk

    @property
    def valid_fraction(self) -> jax.Array:
        return self.valid_tiles / self.total_tiles

    def info(self) -> dict:
        """The info dict `kernels.ops.spamm_matmul` has always returned.

        `nvalid` is the per-(i, j) valid-k count (the paper's validNum). The
        compacted copy is reused when the backend built one; backends that
        gate straight from the bitmap get the same counts summed from it.
        """
        nvalid = self.nvalid
        if nvalid is None:
            nvalid = jnp.sum(self.mask, axis=-1, dtype=jnp.int32)
        return {
            "norm_a": self.norm_a,
            "norm_b": self.norm_b,
            "nvalid": nvalid,
            "valid_tiles": self.valid_tiles,
            "total_tiles": self.total_tiles,
            "valid_fraction": self.valid_fraction,
        }


# ---------------------------------------------------------------------------
# the gating phase — THE single implementation
# ---------------------------------------------------------------------------

def gate_mask(norm_a: jax.Array, norm_b: jax.Array, tau, block_n: int = 1):
    """Validity bitmap from normmaps (paper Alg. 2 lines 3–8).

    block_n > 1 groups gn into gn//block_n super-columns; a super-column is
    valid for k if ANY of its member columns is (superset mask ⇒ exactness).
    Returns (gm, gn//block_n, gk) bool.
    """
    tau = jnp.asarray(tau, jnp.float32)
    if block_n > 1:
        gk, gn = norm_b.shape
        assert gn % block_n == 0, (gn, block_n)
        nb_g = norm_b.reshape(gk, gn // block_n, block_n)
        fine = norm_a[:, None, :, None] * jnp.swapaxes(nb_g, 0, 1)[None] >= tau
        return jnp.any(fine, axis=-1)
    return kref.spamm_mask_ref(norm_a, norm_b, tau)


# children of one coarse (i, j, k) triple: the 2×2×2 refinement offsets,
# kept as three separate contiguous columns — strided (N, 3) row layout
# costs ~2.5× on the gather-heavy descent below
_OFF_I = np.array([i for i in (0, 1) for _ in (0, 1) for _ in (0, 1)], np.int32)
_OFF_J = np.array([j for _ in (0, 1) for j in (0, 1) for _ in (0, 1)], np.int32)
_OFF_K = np.array([k for _ in (0, 1) for _ in (0, 1) for k in (0, 1)], np.int32)


def _hier_mask_host(la, lb, tau: float) -> np.ndarray:
    """Sparse coarse-to-fine descent on concrete normmaps (numpy).

    la/lb: per-level np normmaps, finest first. Gates the full (tiny)
    coarsest level, then repeatedly expands only the SURVIVING triples into
    their 2×2×2 children — work is O(coarse grid + surviving candidates), not
    O(gm·gn·gk), which is what makes plan construction sub-linear in the
    pruned region. The level-0 test is the exact flat gate, so the scattered
    result is bit-identical to `gate_mask`.
    """
    top = len(la) - 1
    tau_c = tau - _COARSE_SLACK * abs(tau)
    na, nb = la[top], lb[top]
    cand = na[:, None, :] * np.swapaxes(nb, 0, 1)[None] >= (tau_c if top else tau)
    ii, jj, kk = [x.astype(np.int32) for x in np.nonzero(cand)]
    for l in range(top - 1, -1, -1):
        gm_l, gk_l = la[l].shape
        gn_l = lb[l].shape[1]
        if ii.shape[0] == 0:
            break
        i2 = (ii[:, None] * 2 + _OFF_I[None]).ravel()
        j2 = (jj[:, None] * 2 + _OFF_J[None]).ravel()
        k2 = (kk[:, None] * 2 + _OFF_K[None]).ravel()
        # ceil-pooled coarse grids overhang ragged fine edges — drop phantoms
        keep = (i2 < gm_l) & (j2 < gn_l) & (k2 < gk_l)
        if not keep.all():
            i2, j2, k2 = i2[keep], j2[keep], k2[keep]
        vals = la[l][i2, k2] * lb[l][k2, j2]
        s = vals >= (tau if l == 0 else tau_c)
        ii, jj, kk = i2[s], j2[s], k2[s]
    gm, gk = la[0].shape
    gn = lb[0].shape[1]
    mask = np.zeros(gm * gn * gk, bool)
    if ii.shape[0]:
        mask[(ii.astype(np.int64) * gn + jj) * gk + kk] = True
    return mask.reshape(gm, gn, gk)


def _hier_mask_traced(la, lb, tau) -> jax.Array:
    """Dense traceable analogue of `_hier_mask_host` for jit'd callers.

    Upsamples the surviving-candidate set level by level and ANDs it with
    each level's gate. No asymptotic saving inside jit (the arrays stay
    dense), but the same exactness argument applies: the candidate set is a
    superset of the flat mask, and the final level applies the exact flat
    test — so cand ∧ flat ≡ flat, bit-identical.
    """
    top = len(la) - 1
    tau = jnp.asarray(tau, jnp.float32)
    tau_c = tau - _COARSE_SLACK * jnp.abs(tau)
    cand = (la[top][:, None, :] * jnp.swapaxes(lb[top], 0, 1)[None]
            >= (tau_c if top else tau))
    for l in range(top - 1, -1, -1):
        gm_l, gk_l = la[l].shape
        gn_l = lb[l].shape[1]
        cand = jnp.repeat(jnp.repeat(jnp.repeat(cand, 2, 0), 2, 1), 2, 2)
        cand = cand[:gm_l, :gn_l, :gk_l]
        t = tau if l == 0 else tau_c
        cand = cand & (la[l][:, None, :] * jnp.swapaxes(lb[l], 0, 1)[None] >= t)
    return cand


def hier_gate_mask(pyr_a: NormPyramid, pyr_b: NormPyramid, tau,
                   block_n: int = 1):
    """Coarse-to-fine validity bitmap — bit-identical to `gate_mask` on the
    finest normmaps (the exactness invariant: a failing coarse product
    upper-bounds, hence rules out, every fine product inside it).

    Concrete operands take the sparse numpy descent (sub-linear in the
    pruned region — the eager planning hot path) and return a HOST (numpy)
    bitmap, letting the planner count valid tiles without an accelerator
    round-trip; traced operands fall back to a dense but jit-compatible
    refinement returning a traced array.
    """
    levels = min(pyr_a.num_levels, pyr_b.num_levels)
    la = list(pyr_a.levels[: levels + 1])
    lb = list(pyr_b.levels[: levels + 1])
    traced = any(isinstance(x, jax.core.Tracer) for x in la + lb + [tau])
    if traced:
        mask = _hier_mask_traced(la, lb, tau)
    else:
        mask = _hier_mask_host(
            [np.asarray(x) for x in la],
            [np.asarray(x) for x in lb],
            float(np.asarray(tau)),
        )
    if block_n > 1:
        gm, gn, gk = mask.shape
        assert gn % block_n == 0, (gn, block_n)
        grouped = mask.reshape(gm, gn // block_n, block_n, gk)
        mask = grouped.any(2) if isinstance(mask, np.ndarray) else \
            jnp.any(grouped, axis=2)
    return mask


def _maybe_compact(mask, backend: str):
    """map_offset compaction (§3.3) when the backend's kernel consumes it."""
    if kops.get_backend(backend).needs_compaction:
        return kref.spamm_compact_ref(mask)
    return None, None


def _any_traced(vals) -> bool:
    """True if any operand (matrix, normmap, pyramid level, or τ) is a
    tracer — i.e. plan() is being called under jit/vmap."""
    for v in vals:
        if isinstance(v, NormPyramid):
            if any(isinstance(l, jax.core.Tracer) for l in v.levels):
                return True
        elif isinstance(v, jax.core.Tracer):
            return True
    return False


def _side_pyramid(norm, x, levels: int, tile: int, bk, use_mxu: bool,
                  side: str) -> NormPyramid:
    """Resolve one operand side (matrix / normmap / pyramid) to a pyramid
    with at least `levels` coarsening steps."""
    if isinstance(norm, NormPyramid):
        return norm.extended(levels)
    if norm is not None:
        return NormPyramid.from_normmap(norm, levels, tile=tile)
    if x is None:
        raise ValueError(f"need `{side}` or `norm_{side}`")
    return NormPyramid(
        kops.pyramid_norms(x, tile, levels, backend=bk.name, use_mxu=use_mxu),
        tile=tile,
    )


def plan(
    a: Optional[jax.Array] = None,
    b: Optional[jax.Array] = None,
    tau=None,
    *,
    valid_ratio=None,
    norm_a=None,
    norm_b=None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    levels: int = 0,
) -> SpammPlan:
    """Build the gating phase for (M, K) @ (K, N), dims divisible by tile
    (and N by tile·block_n) — pad upstream (see `pad_to_tile` /
    `core.spamm.spamm`).

    Either side may be given as the matrix (positional) or as a precomputed
    normmap / NormPyramid (norm_a= / norm_b= keywords; the matrix argument
    may then be omitted). Exactly one of `tau` / `valid_ratio` must be set;
    valid_ratio runs the §3.5.2 τ-search on the normmaps.

    levels > 0 (or a NormPyramid operand) switches to hierarchical gating:
    coarse-to-fine refinement over the norm pyramid. The resulting mask is
    bit-identical to flat gating (levels=0); what changes is the cost of
    building it — sub-linear in the pruned region for concrete operands —
    and a coarse-first τ-search when valid_ratio is given. Under jit
    (traced operands) the plan silently downgrades to flat gating: the mask
    is identical and the sparse descent can't run there, so `levels` is
    free on compiled paths rather than an overhead.
    """
    if (tau is None) == (valid_ratio is None):
        raise ValueError("give exactly one of tau / valid_ratio")
    bk = kops.get_backend(backend)

    hier = (levels > 0 or isinstance(norm_a, NormPyramid)
            or isinstance(norm_b, NormPyramid))
    if hier and _any_traced((a, b, norm_a, norm_b, tau)):
        # Under jit the sparse descent can't run and the dense traced
        # refinement produces the SAME mask as flat gating for strictly more
        # work — downgrade to flat so `levels` is free on compiled paths
        # (jitted prefill) while eager callers keep the hierarchical win.
        # hier_gate_mask stays available for traced callers who want the
        # level-by-level refinement explicitly.
        if isinstance(norm_a, NormPyramid):
            norm_a = norm_a.base
        if isinstance(norm_b, NormPyramid):
            norm_b = norm_b.base
        hier = False
    if hier:
        want = max(
            levels,
            norm_a.num_levels if isinstance(norm_a, NormPyramid) else 0,
            norm_b.num_levels if isinstance(norm_b, NormPyramid) else 0,
        )
        pyr_a = _side_pyramid(norm_a, a, want, tile, bk, use_mxu_norm, "a")
        pyr_b = _side_pyramid(norm_b, b, want, tile, bk, use_mxu_norm, "b")
        norm_a, norm_b = pyr_a.base, pyr_b.base
        if valid_ratio is not None:
            from repro.core.tau_search import search_tau_pyramid  # circular-safe

            tau, _ = search_tau_pyramid(pyr_a, pyr_b, valid_ratio)
        tau = jnp.asarray(tau, jnp.float32)
        mask = hier_gate_mask(pyr_a, pyr_b, tau, block_n)
    else:
        if norm_a is None:
            if a is None:
                raise ValueError("need `a` or `norm_a`")
            norm_a = bk.norms(a, tile, use_mxu=use_mxu_norm)
        if norm_b is None:
            if b is None:
                raise ValueError("need `b` or `norm_b`")
            norm_b = bk.norms(b, tile, use_mxu=use_mxu_norm)

        if valid_ratio is not None:
            from repro.core.tau_search import search_tau  # circular-safe

            tau, _ = search_tau(norm_a, norm_b, valid_ratio)
        tau = jnp.asarray(tau, jnp.float32)
        mask = gate_mask(norm_a, norm_b, tau, block_n)

    if isinstance(mask, np.ndarray):  # host descent: count before upload
        valid_tiles = jnp.int32(int(np.count_nonzero(mask)))
        mask = jnp.asarray(mask)
    else:
        valid_tiles = jnp.sum(mask, dtype=jnp.int32)
    kidx, nvalid = _maybe_compact(mask, bk.name)
    return SpammPlan(tau, norm_a, norm_b, mask, kidx, nvalid, valid_tiles,
                     tile=tile, block_n=block_n, backend=bk.name,
                     levels=(want if hier else 0))


def execute(p: SpammPlan, a: jax.Array, b: jax.Array, *, out_dtype=None):
    """Run the multiplication phase of a prebuilt plan on (a, b).

    a/b must have the tile-padded shapes the plan was built for. Executing
    the same plan twice on the same operands is bit-identical to the
    unplanned `kernels.ops.spamm_matmul` — the plan IS that call's first
    half.
    """
    gm, gk = p.norm_a.shape
    _, gn = p.norm_b.shape
    t = p.tile
    assert a.shape == (gm * t, gk * t), (a.shape, (gm * t, gk * t))
    assert b.shape == (gk * t, gn * t), (b.shape, (gk * t, gn * t))
    bk = kops.get_backend(p.backend)
    return bk.matmul(a, b, p.mask, p.kidx, p.nvalid, p.tile, p.block_n,
                     out_dtype or jnp.float32)


# ---------------------------------------------------------------------------
# per-weight plan cache (serving hot path)
# ---------------------------------------------------------------------------

class _WeightEntry(NamedTuple):
    weight: Any          # strong ref: anchors the id() key (no stale reuse)
    padded: jax.Array
    norms: Any           # normmap (levels=0) or NormPyramid (levels>0)


class WeightPlanCache:
    """Caches the weight-side gating artifacts (tile padding + normmap or
    full norm pyramid), keyed on weight identity/shape/dtype/tile/backend/
    levels.

    Serving engines and eager model forward passes call the same weight
    matrix against a stream of activations; the activation-side normmap and
    the bitmap depend on the batch, but the weight normmap (the expensive
    O(K·N) half of get-norm) and the padded copy do not — compute them once
    per weight instead of per token batch. With levels > 0 the cache holds
    the weight-side NormPyramid, so hierarchical replans pay zero weight-side
    work beyond the first request.

    Tracers are never cached (inside jit the trace itself is cached, and
    tracer ids are meaningless); the cache is an eager-path optimization.
    LRU-bounded; `hits`/`misses` expose effectiveness for tests/benchmarks.
    """

    def __init__(self, maxsize: int = 256):
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _cacheable(w) -> bool:
        return isinstance(w, (np.ndarray, jax.Array)) and not isinstance(
            w, jax.core.Tracer
        )

    def weight_side(self, w, *, tile: int, backend: str,
                    use_mxu: bool = False, levels: int = 0):
        """(padded_weight, weight_norms) for w, cached on identity.

        w may be 2-D (K, N) → normmap (gk, gn), or 3-D batched (B, K, N) —
        the per-expert MoE shape — → normmap (B, gk, gn) from one reshaped
        get-norm pass (row tiles never cross slices after padding).
        levels > 0 returns a NormPyramid instead of the plain normmap (for
        3-D weights the pyramid levels carry the batch dim)."""
        bk = kops.get_backend(backend)

        def compute():
            wp = pad_to_tile(jnp.asarray(w), tile)
            if wp.ndim == 3:
                bsz, kp, np_ = wp.shape
                nw = bk.norms(wp.reshape(bsz * kp, np_), tile,
                              use_mxu=use_mxu).reshape(bsz, kp // tile, -1)
            else:
                nw = bk.norms(wp, tile, use_mxu=use_mxu)
            if levels > 0:
                # batched pooling (pool_norms_ref pools the trailing 2 dims)
                nw = NormPyramid.from_normmap(nw, levels, tile=tile)
            return wp, nw

        if not self._cacheable(w):
            return compute()
        key = (id(w), w.shape, str(w.dtype), tile, bk.name, use_mxu, levels)
        ent = self._entries.get(key)
        if ent is not None and ent.weight is w:
            self.hits += 1
            self._entries.move_to_end(key)
            return ent.padded, ent.norms
        self.misses += 1
        wp, nw = compute()
        self._entries[key] = _WeightEntry(w, wp, nw)
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return wp, nw

    def plan_for(self, x_padded, w, tau=None, *, valid_ratio=None,
                 tile: int = 64, block_n: int = 1, backend: str = "auto",
                 use_mxu_norm: bool = False, levels: int = 0):
        """Full plan for x @ w with the weight side served from the cache.
        x_padded must already be tile-padded. Returns (plan, padded_weight).
        levels > 0 plans hierarchically with the cached weight pyramid.
        """
        wp, nw = self.weight_side(w, tile=tile, backend=backend,
                                  use_mxu=use_mxu_norm, levels=levels)
        p = plan(x_padded, None, tau, valid_ratio=valid_ratio, norm_b=nw,
                 tile=tile, block_n=block_n, backend=backend,
                 use_mxu_norm=use_mxu_norm, levels=levels)
        return p, wp

    def clear(self):
        self._entries.clear()
        self.hits = self.misses = 0

    def __len__(self):
        return len(self._entries)


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

def spamm_bmm(
    x: jax.Array,
    w: jax.Array,
    tau=None,
    *,
    valid_ratio=None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    out_dtype=None,
    cache: Optional[WeightPlanCache] = None,
    levels: int = 0,
):
    """Batched SpAMM: (B, M, K) @ (K, N) or (B, M, K) @ (B, K, N).

    levels > 0 plans the shared-weight case hierarchically (the batch folds
    into the row-tile grid, so it is one big 2-D product); the per-batch-
    weight case keeps flat per-slice gating (its vmapped masks are already
    per-slice small) while still caching the weight-side artifacts.

    Shared-weight case: the batch dim folds into the row-tile grid — the
    whole batch runs as ONE (B·M, K) @ (K, N) product whose row tiles never
    cross slice boundaries, so the gating is exactly the per-slice gating
    while the weight-side plan (normmap + padding, optionally from `cache`)
    is computed once and shared across the batch. Per-batch-weight case:
    normmaps for every slice come from one reshaped get-norm call, gating is
    vmapped, and the multiplication runs per slice under lax.map (jnp
    backend: vmapped masked einsum).

    Arbitrary shapes are zero-padded to tile multiples and un-padded.
    Returns (C (B, M, N), SpammInfo).
    """
    if (tau is None) == (valid_ratio is None):
        raise ValueError("give exactly one of tau / valid_ratio")
    bsz, m, k = x.shape
    bk = kops.get_backend(backend)
    out_dtype = out_dtype or jnp.float32

    if w.ndim == 2:  # (B, M, K) @ (K, N): fold batch into the row-tile grid
        k2, n = w.shape
        assert k == k2, (x.shape, w.shape)
        xp = pad_to_tile(x, tile)
        mp, kp = xp.shape[1:]
        if cache is not None:
            wp, nw = cache.weight_side(w, tile=tile, backend=backend,
                                       use_mxu=use_mxu_norm, levels=levels)
        else:
            wp = pad_to_tile(w, tile)
            nw = bk.norms(wp, tile, use_mxu=use_mxu_norm)
            if levels > 0:
                nw = NormPyramid.from_normmap(nw, levels, tile=tile)
        x2 = xp.reshape(bsz * mp, kp)
        p = plan(x2, None, tau, valid_ratio=valid_ratio, norm_b=nw,
                 tile=tile, block_n=block_n, backend=backend,
                 use_mxu_norm=use_mxu_norm, levels=levels)
        c = execute(p, x2, wp, out_dtype=out_dtype)
        c = c.reshape(bsz, mp, -1)[:, :m, :n]
        frac = p.valid_fraction
        tau_used = p.tau
    else:  # (B, M, K) @ (B, K, N): per-slice plans, weight norms in one pass
        if valid_ratio is not None:
            raise ValueError("valid_ratio needs a shared weight; pass tau for "
                             "per-batch weights")
        assert w.shape[0] == bsz and w.shape[1] == k, (x.shape, w.shape)
        n = w.shape[2]
        xp = pad_to_tile(x, tile)
        mp, kp = xp.shape[1:]
        gm, gk = mp // tile, kp // tile
        if cache is not None:
            wp, nw = cache.weight_side(w, tile=tile, backend=backend,
                                       use_mxu=use_mxu_norm)
        else:
            wp = pad_to_tile(w, tile)
            np_ = wp.shape[2]
            nw = bk.norms(wp.reshape(bsz * kp, np_), tile,
                          use_mxu=use_mxu_norm).reshape(bsz, gk, -1)
        na = bk.norms(xp.reshape(bsz * mp, kp), tile,
                      use_mxu=use_mxu_norm).reshape(bsz, gm, gk)
        tau_used = jnp.asarray(tau, jnp.float32)
        mask = jax.vmap(lambda a_, b_: gate_mask(a_, b_, tau_used, block_n))(
            na, nw)
        if bk.needs_compaction:
            kidx, nvalid = jax.vmap(kref.spamm_compact_ref)(mask)
            c = jax.lax.map(
                lambda s: bk.matmul(s[0], s[1], s[2], s[3], s[4], tile,
                                    block_n, out_dtype),
                (xp, wp, mask, kidx, nvalid),
            )
        else:
            c = jax.vmap(
                lambda a_, b_, m_: bk.matmul(a_, b_, m_, None, None, tile,
                                             block_n, out_dtype)
            )(xp, wp, mask)
        c = c[:, :m, :n]
        frac = jnp.sum(mask, dtype=jnp.int32) / mask.size

    return c, SpammInfo(
        tau=jnp.asarray(tau_used, jnp.float32),
        valid_fraction=frac,
        effective_flops=frac * (2.0 * bsz * m * k * n),
    )
