"""Distributed SpAMM (paper §3.4 + §3.5.1, extended beyond the paper).

Paper-faithful mode (`spamm_rowpart`): C is row-partitioned across devices on
one mesh axis, B is replicated — the multi-GPU scheme of §3.4 (the paper
streams B/A in batches over PCIe; on a TPU pod the replication is an
all-gather the XLA scheduler overlaps with the local get-norm compute, which
plays the role of the paper's batched-UM transfer overlap). Load balance is
the §3.5.1 strided (cyclic) tile-row assignment.

Beyond-paper mode (`spamm_2d`): C sharded 2-D over (row_axis × col_axis); the
contraction dimension is sharded over col_axis, each device norm-gates its
local k-slice and the partial products are combined with a psum_scatter
(ring reduce-scatter, overlapped by XLA) — the SUMMA-style extension the
paper explicitly leaves as future work ("can be further integrated with
CANNON and SUMMA").
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import plan as _plan
from repro.core import schedule as _schedule


def _resolve_schedule(a, b, tau, num_devices, *, tile, backend,
                      sched_levels: int) -> str:
    """schedule="auto": pick contiguous/cyclic from a coarse work estimate.

    Builds norm pyramids for both operands and evaluates the §3.5.1 V matrix
    at the coarsest level that still gives every device ≥ 1 coarse row — the
    estimate costs one get-norm pass plus an 8^level-reduced gating sweep,
    cheap enough to re-run per step as operands evolve. Device loads are
    attributed through the FINE shard boundaries (`schedule.device_loads`):
    a coarse row straddling a boundary splits its work across its actual
    owners instead of being array_split to one side, which could mis-pick
    cyclic near shard boundaries. Traced operands can't steer a
    Python-level decision, so under jit the paper default ('contiguous') is
    kept.
    """
    if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
        return "contiguous"
    gm = a.shape[0] // tile
    # keep ≥ 2 coarse rows per device: with exactly one, cyclic and
    # contiguous assign identically and the estimate can't tell them apart
    lv = 0
    while lv < sched_levels and (gm >> (lv + 1)) >= 2 * num_devices:
        lv += 1
    pyr_a = _plan.NormPyramid.build(a, lv, tile=tile, backend=backend)
    pyr_b = _plan.NormPyramid.build(b, lv, tile=tile, backend=backend)
    v = _schedule.v_matrix(pyr_a, pyr_b, tau, level=lv)
    return _schedule.auto_schedule(v, num_devices, level=lv, fine_rows=gm)


def _local_spamm(a_loc, b, tau, tile, backend, block_n):
    # gating on the device-local shard: plans are built per shard (each
    # shard's normmap slice is its own) and executed in place — the same
    # single gating implementation (core.plan) as the flat call path.
    p = _plan.plan(a_loc, b, tau, tile=tile, backend=backend, block_n=block_n)
    c = _plan.execute(p, a_loc, b)
    return c, p.valid_fraction.reshape(1)


def spamm_rowpart(
    a: jax.Array,
    b: jax.Array,
    tau,
    mesh: Mesh,
    *,
    axis: str = "data",
    tile: int = 64,
    backend: str = "auto",
    block_n: int = 1,
    schedule: str = "contiguous",
    sched_levels: int = 3,
):
    """Paper §3.4: row-partition C over `axis`, B replicated.

    a: (M, K), b: (K, N); M/tile divisible by mesh.shape[axis].
    schedule: 'contiguous' (paper default), 'cyclic' (§3.5.1 load balance —
    NOTE: permutes tile-rows *inside the step*, which lowers to a large
    collective; production jobs should store A pre-permuted and pass
    'pre_permuted', which is free: identical HLO to contiguous with cyclic
    balance. See EXPERIMENTS.md §Perf c1), 'pre_permuted', or 'auto'
    (coarse norm-pyramid work estimate at ≤ `sched_levels` coarsening steps
    picks contiguous vs cyclic per call).
    Returns (C, mean_valid_fraction).
    """
    m, k = a.shape
    ndev = mesh.shape[axis]
    gm = m // tile
    assert gm % ndev == 0, (gm, ndev)
    if schedule == "auto":
        schedule = _resolve_schedule(a, b, tau, ndev, tile=tile,
                                     backend=backend,
                                     sched_levels=sched_levels)

    in_step_perm = schedule == "cyclic"
    if in_step_perm:
        perm = _schedule.device_permutation(ndev, gm, schedule)
        inv = np.argsort(perm)
        a = a.reshape(gm, tile, k)[perm].reshape(m, k)

    fn = shard_map(
        functools.partial(
            _local_spamm, tau=tau, tile=tile, backend=backend, block_n=block_n
        ),
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=(P(axis, None), P(axis)),
    )
    c, fracs = fn(a, b)
    if in_step_perm:
        c = c.reshape(gm, tile, -1)[inv].reshape(m, -1)
    return c, jnp.mean(fracs)


def _local_spamm_psum(a_loc, b_loc, tau, tile, backend, block_n, col_axis):
    # gate on LOCAL k-slice norms: global bitmap decomposes per k, so the
    # union over shards equals the flat single-device bitmap (exactness).
    p = _plan.plan(a_loc, b_loc, tau, tile=tile, backend=backend,
                   block_n=block_n)
    c_part = _plan.execute(p, a_loc, b_loc)
    # ring reduce-scatter of the partial products over the contraction axis;
    # scatter along N so C ends fully 2-D sharded.
    c = jax.lax.psum_scatter(c_part, col_axis, scatter_dimension=1, tiled=True)
    return c, p.valid_fraction.reshape(1, 1)


def spamm_2d(
    a: jax.Array,
    b: jax.Array,
    tau,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    tile: int = 64,
    backend: str = "auto",
    block_n: int = 1,
    schedule: str = "contiguous",
    sched_levels: int = 3,
):
    """Beyond-paper SUMMA-style 2-D SpAMM.

    A sharded (rows over row_axis, K over col_axis); B sharded (K over
    col_axis); C comes back sharded (rows over row_axis, cols over col_axis)
    via psum_scatter. Norm gating happens on local k-slices — exact.
    schedule='auto' picks contiguous/cyclic from the coarse work estimate
    (see `spamm_rowpart`). Returns (C, mean_valid_fraction).
    """
    m, k = a.shape
    row_axes = row_axis if isinstance(row_axis, tuple) else (row_axis,)
    nrow = 1
    for ax in row_axes:
        nrow *= mesh.shape[ax]
    ncol = mesh.shape[col_axis]
    gm = m // tile
    assert gm % nrow == 0 and (k // tile) % ncol == 0
    if schedule == "auto":
        schedule = _resolve_schedule(a, b, tau, nrow, tile=tile,
                                     backend=backend,
                                     sched_levels=sched_levels)

    in_step_perm = schedule == "cyclic"
    if in_step_perm:
        perm = _schedule.device_permutation(nrow, gm, schedule)
        inv = np.argsort(perm)
        a = a.reshape(gm, tile, k)[perm].reshape(m, k)

    fn = shard_map(
        functools.partial(
            _local_spamm_psum,
            tau=tau,
            tile=tile,
            backend=backend,
            block_n=block_n,
            col_axis=col_axis,
        ),
        mesh=mesh,
        in_specs=(P(row_axes, col_axis), P(col_axis, None)),
        out_specs=(P(row_axes, col_axis), P(row_axes, col_axis)),
    )
    c, fracs = fn(a, b)
    if in_step_perm:
        c = c.reshape(gm, tile, -1)[inv].reshape(m, -1)
    return c, jnp.mean(fracs)
