"""Distributed SpAMM (paper §3.4 + §3.5.1, extended beyond the paper).

Paper-faithful mode (`spamm_rowpart`): C is row-partitioned across devices on
one mesh axis, B is replicated — the multi-GPU scheme of §3.4 (the paper
streams B/A in batches over PCIe; on a TPU pod the replication is an
all-gather the XLA scheduler overlaps with the local get-norm compute, which
plays the role of the paper's batched-UM transfer overlap).

Beyond-paper mode (`spamm_2d`): C sharded 2-D over (row_axis × col_axis); the
contraction dimension is sharded over col_axis, each device norm-gates its
local k-slice and the partial products are combined with a psum_scatter
(ring reduce-scatter, overlapped by XLA) — the SUMMA-style extension the
paper explicitly leaves as future work ("can be further integrated with
CANNON and SUMMA").

Row-strip schedules (both modes):

  'contiguous'  — uniform-width strips in storage order (paper §3.4
                  default). Cheapest HLO: no permutation, no gather.
  'cyclic'      — uniform-width strips of STRIDED tile-rows (paper §3.5.1
                  load balance). Balances smooth work profiles but pays an
                  in-step permutation collective ('pre_permuted' stores A
                  already permuted and is free).
  'equal_work'  — VARIABLE-width contiguous strips cut so each device's
                  predicted work (the coarse norm-pyramid V estimate) is
                  equal — `schedule.equal_work_partition`. No permutation
                  collective, handles skewed/banded/stride-aliased profiles
                  both uniform schedules lose on, and tolerates ragged
                  gm % num_devices != 0. The strip shapes are a per-device
                  row-offset table; pass a frozen table via `offsets=` to
                  skip the estimate (what the re-sharding controller does).

  'auto'        — per-call pick from the coarse work estimate
                  (`schedule.auto_schedule`): contiguous unless its
                  predicted imbalance exceeds the threshold AND cyclic
                  improves it; equal_work only when the uniform pick is
                  still imbalanced and the equal-work cut beats it by a
                  margin. Traced operands can't steer a Python-level
                  decision, so under jit 'auto' keeps the paper default
                  ('contiguous').

Drift/re-shard contract: a partition cut from one step's estimate may decay
as operands evolve. The control plane (`schedule.ReshardController`, driven
by the serving engine / train loop) re-probes the estimate every K steps and
re-cuts only when the live partition's predicted imbalance exceeds a fresh
cut's by the drift threshold; execution here is bit-identical under ANY
partition (gating and per-tile accumulation are row-independent), so
re-sharding never changes results — only where they are computed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import plan as _plan
from repro.core import schedule as _schedule


def _work_estimate(a, b, tau, num_devices, *, tile, backend,
                   sched_levels: int):
    """Coarse work-estimate V for scheduling: (v, level, gm), or
    (None, 0, gm) when the operands are traced (jit) and no estimate can
    steer a Python-level decision.

    Builds norm pyramids for both operands and evaluates the §3.5.1 V matrix
    at the coarsest level that still gives every device ≥ 2 coarse rows (with
    exactly one, cyclic and contiguous assign identically and the estimate
    can't tell them apart) — the estimate costs one get-norm pass plus an
    8^level-reduced gating sweep, cheap enough to re-run per step as the
    operands evolve.
    """
    gm = a.shape[0] // tile
    if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
        return None, 0, gm
    lv = 0
    while lv < sched_levels and (gm >> (lv + 1)) >= 2 * num_devices:
        lv += 1
    pyr_a = _plan.NormPyramid.build(a, lv, tile=tile, backend=backend)
    pyr_b = _plan.NormPyramid.build(b, lv, tile=tile, backend=backend)
    return _schedule.v_matrix(pyr_a, pyr_b, tau, level=lv), lv, gm


def _pick_schedule(a, b, tau, num_devices, *, tile, backend,
                   sched_levels: int, offsets=None):
    """THE scheduling decision, shared by spamm_rowpart and spamm_2d:
    (schedule, offsets) given the operands and an optional frozen table.

    A supplied `offsets` table IS the decision (equal_work, no estimate).
    Otherwise "auto" picks contiguous/cyclic/equal_work from the coarse
    work estimate — device loads attributed through the FINE shard
    boundaries (`schedule.device_loads`), so a coarse row straddling a
    boundary splits its work across its actual owners instead of being
    array_split to one side — escalating to equal_work on ragged grids
    (uniform strips can't cover gm % ndev != 0), and cutting the offsets
    from the estimate already in hand (no second get-norm pass). Under jit
    the paper default ('contiguous') is kept.
    """
    gm = a.shape[0] // tile
    if offsets is not None:
        return "equal_work", offsets
    v, lv, _ = _work_estimate(a, b, tau, num_devices, tile=tile,
                              backend=backend, sched_levels=sched_levels)
    if v is None:
        return "contiguous", None  # traced: paper default
    schedule = _schedule.auto_schedule(v, num_devices, level=lv,
                                       fine_rows=gm)
    if schedule != "equal_work" and gm % num_devices != 0:
        schedule = "equal_work"
    if schedule == "equal_work":
        offsets = _schedule.equal_work_partition(v, num_devices, level=lv,
                                                 fine_rows=gm)
    return schedule, offsets


def _resolve_schedule(a, b, tau, num_devices, *, tile, backend,
                      sched_levels: int, allow_equal_work: bool = True) -> str:
    """The "auto" pick as a bare name (diagnostics/tests; the execution
    paths use `_pick_schedule`, which also cuts the offsets)."""
    v, lv, gm = _work_estimate(a, b, tau, num_devices, tile=tile,
                               backend=backend, sched_levels=sched_levels)
    if v is None:
        return "contiguous"
    return _schedule.auto_schedule(v, num_devices, level=lv, fine_rows=gm,
                                   allow_equal_work=allow_equal_work)


def _strip_tables(offsets, gm: int, num_devices: int):
    """Clamp-pad gather tables of a variable-width row partition — now the
    shared `schedule.strip_tables` (the serving engine shards its compiled
    steps from the SAME construction, so a pod's `spamm_rowpart` cut and the
    engine's can never disagree). Kept as an alias at the historical name."""
    return _schedule.strip_tables(offsets, gm, num_devices)


def _equal_work_offsets(a, b, tau, num_devices, *, tile, backend,
                        sched_levels, gm):
    """Cut equal-work strips from a fresh coarse estimate (eager-only)."""
    v, lv, _ = _work_estimate(a, b, tau, num_devices, tile=tile,
                              backend=backend, sched_levels=sched_levels)
    if v is None:
        raise ValueError(
            "schedule='equal_work' under jit needs a precomputed partition: "
            "pass offsets= (e.g. from schedule.equal_work_partition or a "
            "ReshardController) — traced operands cannot be estimated")
    return _schedule.equal_work_partition(v, num_devices, level=lv,
                                          fine_rows=gm)


def _local_spamm(a_loc, b, tau, tile, backend, block_n,
                 compute_dtype="float32"):
    # gating on the device-local shard: plans are built per shard (each
    # shard's normmap slice is its own) and executed in place — the same
    # single gating implementation (core.plan) as the flat call path.
    # compute_dtype != f32 reproduces the numerics of a LOW-PRECISION
    # REPLICATED B: quantization is a pure per-tile function of b, so every
    # shard quantizing its replica equals quantize-once-then-broadcast — the
    # wire payload of that broadcast is what distributed.compression's
    # compress_tiles/halo_wire_bytes account for.
    p = _plan.plan(a_loc, b, tau, tile=tile, backend=backend, block_n=block_n,
                   compute_dtype=compute_dtype)
    c = _plan.execute(p, a_loc, b)
    return c, p.valid_fraction.reshape(1)


def spamm_rowpart(
    a: jax.Array,
    b: jax.Array,
    tau,
    mesh: Mesh,
    *,
    axis: str = "data",
    tile: int = 64,
    backend: str = "auto",
    block_n: int = 1,
    schedule: str = "contiguous",
    sched_levels: int = 3,
    offsets=None,
    compute_dtype: str = "float32",
):
    """Paper §3.4: row-partition C over `axis`, B replicated.

    a: (M, K), b: (K, N); M divisible by tile. The uniform schedules need
    M/tile divisible by mesh.shape[axis]; 'equal_work' handles ragged grids
    (gm % ndev != 0) through its padded variable-width strips. A non-None
    `offsets` table always routes through the equal_work path, whatever
    `schedule` says — a frozen partition IS the scheduling decision.
    schedule: 'contiguous' (paper default), 'cyclic' (§3.5.1 load balance —
    NOTE: permutes tile-rows *inside the step*, which lowers to a large
    collective; production jobs should store A pre-permuted and pass
    'pre_permuted', which is free: identical HLO to contiguous with cyclic
    balance. See EXPERIMENTS.md §Perf c1), 'pre_permuted', 'equal_work'
    (variable-width contiguous strips cut to equalize the coarse work
    estimate; `offsets=` supplies a frozen row-offset table, e.g. from a
    `schedule.ReshardController`), or 'auto' (coarse norm-pyramid work
    estimate at ≤ `sched_levels` coarsening steps picks the schedule per
    call — see the module docstring for the decision rule).
    Returns (C, mean_valid_fraction). Under equal_work the mean weights
    each device's fraction by its REAL strip width (uniform strips reduce
    to the plain mean); clamp-pad rows can still nudge a device's own
    fraction toward its last row's density — telemetry-grade, the product
    itself is exact.
    compute_dtype (float32 | bfloat16 | int8) runs each shard's gated GEMM
    in low precision with the conservative widened-τ gate; the replicated B
    then only needs to cross the wire in the quantized format (see
    `repro.distributed.compression.compress_tiles` / `halo_wire_bytes`).
    """
    m, k = a.shape
    ndev = mesh.shape[axis]
    gm = m // tile
    if offsets is not None or schedule == "auto":
        schedule, offsets = _pick_schedule(a, b, tau, ndev, tile=tile,
                                           backend=backend,
                                           sched_levels=sched_levels,
                                           offsets=offsets)
    fn = shard_map(
        functools.partial(
            _local_spamm, tau=tau, tile=tile, backend=backend,
            block_n=block_n, compute_dtype=compute_dtype,
        ),
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=(P(axis, None), P(axis)),
    )

    if schedule == "equal_work":
        if offsets is None:
            offsets = _equal_work_offsets(a, b, tau, ndev, tile=tile,
                                          backend=backend,
                                          sched_levels=sched_levels, gm=gm)
        perm, keep = _strip_tables(offsets, gm, ndev)
        a_x = a.reshape(gm, tile, k)[perm].reshape(-1, k)
        c_x, fracs = fn(a_x, b)
        c = c_x.reshape(len(perm), tile, -1)[np.flatnonzero(keep)]
        # weight each device's fraction by its real (unpadded) strip width
        w = np.diff(np.asarray(offsets, np.float64))
        w = jnp.asarray(w / w.sum(), jnp.float32)
        return c.reshape(m, -1), jnp.sum(fracs.reshape(-1) * w)

    assert gm % ndev == 0, (gm, ndev, "ragged grids need schedule='equal_work'")
    in_step_perm = schedule == "cyclic"
    if in_step_perm:
        perm = _schedule.device_permutation(ndev, gm, schedule)
        inv = np.argsort(perm)
        a = a.reshape(gm, tile, k)[perm].reshape(m, k)
    c, fracs = fn(a, b)
    if in_step_perm:
        c = c.reshape(gm, tile, -1)[inv].reshape(m, -1)
    return c, jnp.mean(fracs)


def _local_spamm_psum(a_loc, b_loc, tau, tile, backend, block_n, col_axis):
    # gate on LOCAL k-slice norms: global bitmap decomposes per k, so the
    # union over shards equals the flat single-device bitmap (exactness).
    p = _plan.plan(a_loc, b_loc, tau, tile=tile, backend=backend,
                   block_n=block_n)
    c_part = _plan.execute(p, a_loc, b_loc)
    # ring reduce-scatter of the partial products over the contraction axis;
    # scatter along N so C ends fully 2-D sharded.
    c = jax.lax.psum_scatter(c_part, col_axis, scatter_dimension=1, tiled=True)
    return c, p.valid_fraction.reshape(1, 1)


def spamm_2d(
    a: jax.Array,
    b: jax.Array,
    tau,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    tile: int = 64,
    backend: str = "auto",
    block_n: int = 1,
    schedule: str = "contiguous",
    sched_levels: int = 3,
    offsets=None,
):
    """Beyond-paper SUMMA-style 2-D SpAMM.

    A sharded (rows over row_axis, K over col_axis); B sharded (K over
    col_axis); C comes back sharded (rows over row_axis, cols over col_axis)
    via psum_scatter. Norm gating happens on local k-slices — exact.
    schedule='auto'/'equal_work'/`offsets=` behave as in `spamm_rowpart`
    (the row partition is what varies; the k/N sharding over col_axis is
    untouched, so only the row grid may be ragged).
    Returns (C, mean_valid_fraction).
    """
    m, k = a.shape
    row_axes = row_axis if isinstance(row_axis, tuple) else (row_axis,)
    nrow = 1
    for ax in row_axes:
        nrow *= mesh.shape[ax]
    ncol = mesh.shape[col_axis]
    gm = m // tile
    assert (k // tile) % ncol == 0, (k, tile, ncol)
    if offsets is not None or schedule == "auto":
        schedule, offsets = _pick_schedule(a, b, tau, nrow, tile=tile,
                                           backend=backend,
                                           sched_levels=sched_levels,
                                           offsets=offsets)
    fn = shard_map(
        functools.partial(
            _local_spamm_psum,
            tau=tau,
            tile=tile,
            backend=backend,
            block_n=block_n,
            col_axis=col_axis,
        ),
        mesh=mesh,
        in_specs=(P(row_axes, col_axis), P(col_axis, None)),
        out_specs=(P(row_axes, col_axis), P(row_axes, col_axis)),
    )

    if schedule == "equal_work":
        if offsets is None:
            offsets = _equal_work_offsets(a, b, tau, nrow, tile=tile,
                                          backend=backend,
                                          sched_levels=sched_levels, gm=gm)
        perm, keep = _strip_tables(offsets, gm, nrow)
        a_x = a.reshape(gm, tile, k)[perm].reshape(-1, k)
        c_x, fracs = fn(a_x, b)
        c = c_x.reshape(len(perm), tile, -1)[np.flatnonzero(keep)]
        # weight each row-group's fraction by its real strip width (fracs
        # is (nrow, ncol): average the k-shards, then width-weight rows)
        w = np.diff(np.asarray(offsets, np.float64))
        w = jnp.asarray(w / w.sum(), jnp.float32)
        return c.reshape(m, -1), jnp.sum(
            jnp.mean(fracs.reshape(len(w), -1), axis=1) * w)

    assert gm % nrow == 0, (gm, nrow, "ragged grids need schedule='equal_work'")
    in_step_perm = schedule == "cyclic"
    if in_step_perm:
        perm = _schedule.device_permutation(nrow, gm, schedule)
        inv = np.argsort(perm)
        a = a.reshape(gm, tile, k)[perm].reshape(m, k)
    c, fracs = fn(a, b)
    if in_step_perm:
        c = c.reshape(gm, tile, -1)[inv].reshape(m, -1)
    return c, jnp.mean(fracs)
