"""Load-balance scheduling (paper §3.5.1).

For decay matrices the per-output-tile work v[i,j] = Σ_k bitmap[i,j,k]
concentrates near the diagonal (paper Fig. 4). On TPU a single chip executes
its Pallas grid sequentially, so *intra-chip* balance is moot; what survives
the hardware translation is balance *across chips* in the distributed
row-partition (§3.4): contiguous row-strips give diagonal-heavy strips more
work. The paper's fix — each worker takes `s` tiles at stride BDIM/s — maps
to a cyclic (strided) assignment of C tile-rows to devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def v_matrix(norm_a: jax.Array, norm_b: jax.Array, tau) -> jax.Array:
    """V[i,j] = Σ_k bitmap[i,j,k] — the paper's per-tile valid-multiplication
    count, summed from the planner's bitmap (core.plan owns the gating)."""
    from repro.core.plan import gate_mask  # circular-safe

    return jnp.sum(gate_mask(norm_a, norm_b, tau), axis=-1, dtype=jnp.int32)


def rows_for_device(d: int, num_devices: int, gm: int, schedule: str) -> np.ndarray:
    """Tile-row indices device d owns. 'contiguous' = paper §3.4 default;
    'cyclic' = §3.5.1 strided load balance."""
    if schedule == "contiguous":
        per = gm // num_devices
        return np.arange(d * per, (d + 1) * per)
    if schedule == "cyclic":
        return np.arange(d, gm, num_devices)
    raise ValueError(schedule)


def device_permutation(num_devices: int, gm: int, schedule: str) -> np.ndarray:
    """Row-tile permutation s.t. contiguous shards of the permuted matrix
    realize `schedule`. perm[new_pos] = old_row_tile."""
    return np.concatenate(
        [rows_for_device(d, num_devices, gm, schedule) for d in range(num_devices)]
    )


def imbalance(v: jax.Array, num_devices: int, schedule: str) -> jax.Array:
    """max-device-work / mean-device-work under a row-strip assignment of V
    (the §3.4 row partition; banded matrices are naturally balanced here)."""
    gm = v.shape[0]
    work_rows = jnp.sum(v, axis=1)  # work per tile-row
    loads = []
    for d in range(num_devices):
        rows = rows_for_device(d, num_devices, gm, schedule)
        loads.append(jnp.sum(work_rows[np.asarray(rows)]))
    loads = jnp.stack(loads)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)


def tile_imbalance(v: jax.Array, num_workers: int, schedule: str) -> jax.Array:
    """Paper Fig. 4 setting: workers own individual C *tiles* (row-major
    flattened). 'contiguous' gives diagonal-adjacent chunks to one worker
    (v is diagonal-heavy ⇒ imbalance); 'cyclic' is the §3.5.1 stride-s fix."""
    flat = v.reshape(-1)
    n = flat.shape[0] - (flat.shape[0] % num_workers)
    flat = flat[:n]
    if schedule == "contiguous":
        loads = jnp.sum(flat.reshape(num_workers, -1), axis=1)
    elif schedule == "cyclic":
        loads = jnp.sum(flat.reshape(-1, num_workers), axis=0)
    else:
        raise ValueError(schedule)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)
