"""Load-balance scheduling (paper §3.5.1).

For decay matrices the per-output-tile work v[i,j] = Σ_k bitmap[i,j,k]
concentrates near the diagonal (paper Fig. 4). On TPU a single chip executes
its Pallas grid sequentially, so *intra-chip* balance is moot; what survives
the hardware translation is balance *across chips* in the distributed
row-partition (§3.4): contiguous row-strips give diagonal-heavy strips more
work. The paper's fix — each worker takes `s` tiles at stride BDIM/s — maps
to a cyclic (strided) assignment of C tile-rows to devices.

Work estimates may be computed at a coarse norm-pyramid level (`v_matrix`
accepts NormPyramid operands + a `level`): each coarse V entry aggregates a
2^level × 2^level block of C tiles and costs 8^level fewer gate products —
cheap enough for the distributed paths to re-estimate per step and pick the
schedule automatically (`auto_schedule`).

Equal-work partitioning (`equal_work_partition`): instead of fixing the
strip SHAPES and permuting rows (cyclic), cut variable-width CONTIGUOUS
strips whose predicted work is equal — a prefix-sum split of the per-row
work estimate, the same move SpMM row-partitioners make when they split by
nonzero count rather than row count (Yang/Buluç/Owens; Merrill/Garland).
Contiguous strips keep the cheap HLO of the paper's default (no in-step
permutation collective) while absorbing banded/skewed/stride-aliased norm
structure that defeats both uniform schedules. The partition is a plain
row-offset table, so it can be FROZEN and re-cut between steps when the
estimate drifts (`ReshardController`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def v_matrix(norm_a, norm_b, tau, *, level: int = 0) -> jax.Array:
    """V[i,j] = Σ_k bitmap[i,j,k] — the paper's per-tile valid-multiplication
    count, summed from the planner's bitmap (core.plan owns the gating).

    Operands may be plain normmaps or NormPyramids; `level` selects the
    pyramid level the estimate is computed at (plain normmaps ignore it).
    At level l > 0 each entry counts valid COARSE products, a cheap upper
    estimate of the fine work inside that 2^l × 2^l block of C tiles.
    """
    from repro.core.plan import NormPyramid, gate_mask  # circular-safe

    # both sides must be read at the SAME coarsening or their k-grids
    # disagree: clamp jointly to the shallower pyramid, and to 0 when only
    # one side has levels at all
    a_pyr = isinstance(norm_a, NormPyramid)
    b_pyr = isinstance(norm_b, NormPyramid)
    if a_pyr and b_pyr:
        level = min(level, norm_a.num_levels, norm_b.num_levels)
    else:
        level = 0
    if a_pyr:
        norm_a = norm_a.levels[level]
    if b_pyr:
        norm_b = norm_b.levels[level]
    return jnp.sum(gate_mask(norm_a, norm_b, tau), axis=-1, dtype=jnp.int32)


def rows_for_device(d: int, num_devices: int, gm: int, schedule: str) -> np.ndarray:
    """Tile-row indices device d owns under a UNIFORM-shape schedule.
    'contiguous' = paper §3.4 default; 'cyclic' = §3.5.1 strided load
    balance. Non-divisible gm spreads the remainder over the leading devices
    (matters only for coarse estimates — the uniform distributed paths
    themselves require divisibility). 'equal_work' strips have data-dependent
    shapes and are described by an explicit offset table instead — see
    `equal_work_partition` / `rows_for_partition`."""
    if schedule == "contiguous":
        return np.array_split(np.arange(gm), num_devices)[d]
    if schedule == "cyclic":
        return np.arange(d, gm, num_devices)
    if schedule == "equal_work":
        raise ValueError(
            "equal_work strips are variable-width: build an offset table "
            "with equal_work_partition(v, ...) and index it with "
            "rows_for_partition(d, offsets)")
    raise ValueError(schedule)


def rows_for_partition(d: int, offsets: np.ndarray) -> np.ndarray:
    """Tile-row indices device d owns under an explicit variable-width
    partition (`offsets` as returned by `equal_work_partition`)."""
    offsets = np.asarray(offsets, np.int64)
    return np.arange(offsets[d], offsets[d + 1])


def device_permutation(num_devices: int, gm: int, schedule: str) -> np.ndarray:
    """Row-tile permutation s.t. contiguous shards of the permuted matrix
    realize `schedule`. perm[new_pos] = old_row_tile."""
    return np.concatenate(
        [rows_for_device(d, num_devices, gm, schedule) for d in range(num_devices)]
    )


def _fine_work(v, *, level: int = 0, fine_rows: Optional[int] = None
               ) -> np.ndarray:
    """Per-FINE-tile-row work estimate from a (possibly coarse) V.

    V's rows may be coarse (each ceil-pooling 2^level fine tile-rows, the
    norm-pyramid work estimate): each coarse row's work is spread uniformly
    over its member fine rows (clipped at the ragged edge), so any fine
    row-range — uniform shard, cyclic stride, or variable-width strip —
    can sum exactly the work it owns, including coarse rows that STRADDLE
    a strip boundary. Eager-only (host numpy)."""
    work_rows = np.asarray(jnp.sum(v, axis=1), np.float64)
    f = 1 << level
    gm = fine_rows if fine_rows is not None else work_rows.shape[0] * f
    assert work_rows.shape[0] == -(-gm // f), (np.shape(v), level, gm)
    # last coarse row may pool fewer than 2^level fine rows (ceil pooling)
    counts = np.clip(gm - np.arange(work_rows.shape[0]) * f, 0, f)
    return np.repeat(work_rows / np.maximum(counts, 1), f)[:gm]


def _uniform_offsets(n: int, parts: int) -> np.ndarray:
    """Offset table of the uniform contiguous split (== np.array_split's
    strip boundaries, i.e. rows_for_device's 'contiguous' shapes)."""
    sizes = np.full(parts, n // parts, np.int64)
    sizes[: n % parts] += 1
    return np.concatenate(([0], np.cumsum(sizes)))


def _equal_cuts(work: np.ndarray, parts: int) -> np.ndarray:
    """Greedy prefix-sum cut of a 1-D work profile into `parts` contiguous
    non-empty segments targeting total/parts each, then clamped so no
    segment is empty. Returns the better of the cut and the uniform split
    (by max/mean), so quantization at segment granularity can never make
    the result WORSE than uniform-width strips."""
    n = work.shape[0]
    if n < parts:
        raise ValueError(f"cannot cut {n} rows into {parts} non-empty strips")
    uniform = _uniform_offsets(n, parts)
    total = float(work.sum())
    if not np.isfinite(total) or total <= 0:
        return uniform  # degenerate (all-zero) estimate: uniform fallback
    cum = np.cumsum(work, dtype=np.float64)
    targets = total * np.arange(1, parts, dtype=np.float64) / parts
    # first prefix ≥ target, then check if stopping one row earlier is closer
    cuts = np.searchsorted(cum, targets, side="left") + 1
    for i in range(parts - 1):
        c = int(cuts[i])
        if c > 1 and abs(cum[c - 2] - targets[i]) < abs(cum[c - 1] - targets[i]):
            cuts[i] = c - 1
    offsets = np.concatenate(([0], cuts, [n])).astype(np.int64)
    for d in range(1, parts):                    # ≥ 1 row per strip, forward
        offsets[d] = max(offsets[d], offsets[d - 1] + 1)
    for d in range(parts - 1, 0, -1):            # … and backward
        offsets[d] = min(offsets[d], offsets[d + 1] - 1)

    def _imb(offs):
        cs = np.concatenate(([0.0], cum))
        loads = cs[offs[1:]] - cs[offs[:-1]]
        return loads.max() / max(loads.mean(), 1e-9)

    return offsets if _imb(offsets) <= _imb(uniform) else uniform


def equal_work_partition(v, num_devices: int, *, level: int = 0,
                         fine_rows: Optional[int] = None) -> np.ndarray:
    """Variable-width equal-work row strips from a (possibly coarse) work
    estimate V: offsets[d] .. offsets[d+1] are the FINE tile-rows device d
    owns (offsets has num_devices + 1 entries, offsets[0] = 0, offsets[-1]
    = gm). Strips are contiguous, cover [0, gm) exactly once, and every
    strip is non-empty (requires gm ≥ num_devices). Boundaries live on the
    fine TILE grid, so each strip pads to whole tiles by construction.

    The cut is a prefix-sum split of the per-fine-row work (coarse V rows
    are spread over their member fine rows first — see `_fine_work`), with
    a uniform-split guard: an all-zero V, or a profile where row-granularity
    quantization would beat the greedy cut, falls back to the uniform strips
    (never empty ones, never worse than 'contiguous'). Eager-only.
    """
    per_fine = _fine_work(v, level=level, fine_rows=fine_rows)
    return _equal_cuts(per_fine, num_devices)


def partition_loads(v, offsets, *, level: int = 0,
                    fine_rows: Optional[int] = None) -> np.ndarray:
    """Per-device predicted work under an explicit variable-width partition
    (fine-granularity attribution: coarse rows straddling a strip boundary
    split their work across the strips that own their fine rows).

    The table must cover THIS grid exactly — a stale one cut for another
    grid raises instead of silently reading as phantom zero-load strips
    (the same guard the execution path's `_strip_tables` applies)."""
    per_fine = _fine_work(v, level=level, fine_rows=fine_rows)
    gm = per_fine.shape[0]
    offs = np.asarray(offsets, np.int64)
    if offs[0] != 0 or offs[-1] != gm or np.any(np.diff(offs) < 0):
        raise ValueError(
            f"offset table {offs} does not cover row grid {gm}: re-cut the "
            f"partition for this grid (equal_work_partition)")
    cs = np.concatenate(([0.0], np.cumsum(per_fine, dtype=np.float64)))
    return cs[offs[1:]] - cs[offs[:-1]]


def partition_imbalance(v, offsets, *, level: int = 0,
                        fine_rows: Optional[int] = None) -> float:
    """max-device-work / mean-device-work under an explicit partition — the
    drift signal the re-sharding controller compares against a fresh cut."""
    loads = partition_loads(v, offsets, level=level, fine_rows=fine_rows)
    return float(loads.max() / max(loads.mean(), 1e-9))


def strip_tables(offsets, gm: int, num_devices: int, *,
                 width: Optional[int] = None):
    """Gather/scatter tables realizing a variable-width row partition on a
    uniform shard grid: every device's strip is right-padded to a common
    width by CLAMPING to its own last row (pad slots recompute a row already
    owned — gating is row-independent, so real rows are untouched and pads
    are simply dropped on the way back).

    Returns (perm, keep): perm[(d * w + s)] = fine row device d computes in
    slot s; keep marks the non-pad slots. Because strips are contiguous and
    ascending, keep-masked slots in (device, slot) order enumerate rows
    0..gm-1 exactly once, in order.

    `width` fixes the padded strip width (≥ the widest strip): the serving
    engine pins it per wave so every re-cut of the SAME grid produces
    identically-shaped tables — the static-shape half of recompile-free
    re-sharding. None uses the widest strip (what `spamm_rowpart` pads to).

    This is THE strip construction: `distributed.spamm_rowpart` and the
    sharded engine both build their shards from it, so a pod's row partition
    and the engine's cut can never disagree. Validates the table explicitly
    (frozen offsets may come from a stale controller cut for a different
    grid or device count; a malformed table would otherwise shard strips
    across the wrong devices silently).
    """
    offs = np.asarray(offsets, np.int64)
    if offs.shape != (num_devices + 1,):
        raise ValueError(
            f"offset table has {offs.shape[0] - 1} strips for "
            f"{num_devices} devices — re-cut it for this mesh")
    if offs[0] != 0 or offs[-1] != gm or np.any(np.diff(offs) < 1):
        raise ValueError(
            f"malformed offset table {offs} for row grid {gm}: must rise "
            f"monotonically from 0 to gm with non-empty strips")
    widths = np.diff(offs)
    wmax = int(widths.max())
    if width is not None:
        if width < wmax:
            raise ValueError(
                f"fixed strip width {width} < widest strip {wmax}: clamp "
                f"the cut (rescale_offsets max_width=) before building "
                f"tables")
        wmax = int(width)
    slots = np.arange(wmax)[None, :]
    idx = np.minimum(offs[:-1, None] + slots, offs[1:, None] - 1)
    keep = (slots < widths[:, None]).reshape(-1)
    return idx.reshape(-1), keep


def rescale_offsets(offsets, fine_rows: int, *,
                    max_width: Optional[int] = None) -> np.ndarray:
    """Re-express an offset table cut on one row grid as a cut of another:
    each boundary keeps its FRACTIONAL position (rounded to the new grid),
    then is clamped monotone with non-empty strips, and optionally so no
    strip exceeds `max_width` rows.

    The serving engine cuts at coarser granularity than the probe (request
    groups vs probe token rows) and pins a static strip width per wave; this
    is the one mapping between the controller's grid and an executor's.
    Requires num_strips ≤ fine_rows ≤ num_strips · max_width."""
    offs = np.asarray(offsets, np.int64)
    parts = offs.shape[0] - 1
    src = int(offs[-1])
    if parts < 1 or src < 1 or offs[0] != 0 or np.any(np.diff(offs) < 1):
        raise ValueError(f"malformed offset table {offs}")
    if fine_rows < parts:
        raise ValueError(
            f"cannot cut {fine_rows} rows into {parts} non-empty strips")
    if max_width is not None and fine_rows > parts * max_width:
        raise ValueError(
            f"{fine_rows} rows cannot fit {parts} strips of ≤ {max_width}")
    out = np.rint(offs.astype(np.float64) * (fine_rows / src)).astype(np.int64)
    out[0], out[-1] = 0, fine_rows
    for d in range(1, parts):                    # ≥ 1 row per strip, forward
        out[d] = max(out[d], out[d - 1] + 1)
    for d in range(parts - 1, 0, -1):            # … and backward
        out[d] = min(out[d], out[d + 1] - 1)
    if max_width is not None:
        for d in range(parts - 1, 0, -1):        # strip d ≤ max_width
            out[d] = max(out[d], out[d + 1] - max_width)
        for d in range(1, parts):
            out[d] = min(out[d], out[d - 1] + max_width)
    assert out[0] == 0 and out[-1] == fine_rows and np.all(np.diff(out) >= 1)
    return out


def device_loads(v: jax.Array, num_devices: int, schedule: str, *,
                 level: int = 0, fine_rows: int = None,
                 offsets=None) -> np.ndarray:
    """Per-device work under a row-strip assignment, attributed at FINE
    tile-row granularity (see `_fine_work` for the coarse-row spreading).

    schedule = 'contiguous' / 'cyclic' take rows_for_device's uniform
    shapes; 'equal_work' (or an explicit `offsets` table from
    `equal_work_partition`) sums the variable-width strips — including
    coarse rows that straddle a strip boundary, which split their work
    across their actual owners. Ownership comes from the SAME functions the
    execution sharding is built from (`rows_for_device` /
    `equal_work_partition`), so estimate and execution cannot drift apart.
    """
    if schedule == "equal_work" or offsets is not None:
        if offsets is None:
            offsets = equal_work_partition(v, num_devices, level=level,
                                           fine_rows=fine_rows)
        offsets = np.asarray(offsets, np.int64)
        assert offsets.shape == (num_devices + 1,), (offsets.shape, num_devices)
        return partition_loads(v, offsets, level=level, fine_rows=fine_rows)
    per_fine = _fine_work(v, level=level, fine_rows=fine_rows)
    gm = per_fine.shape[0]
    return np.array([
        per_fine[rows_for_device(d, num_devices, gm, schedule)].sum()
        for d in range(num_devices)
    ])


def imbalance(v: jax.Array, num_devices: int, schedule: str,
              offsets=None) -> jax.Array:
    """max-device-work / mean-device-work under a row-strip assignment of V
    (the §3.4 row partition; banded matrices are naturally balanced here).
    'equal_work' / explicit `offsets` evaluate the variable-width strips
    (eager-only, like the partition itself)."""
    if schedule == "equal_work" or offsets is not None:
        loads = device_loads(v, num_devices, schedule, offsets=offsets)
        return jnp.asarray(loads.max() / max(loads.mean(), 1e-9), jnp.float32)
    gm = v.shape[0]
    work_rows = jnp.sum(v, axis=1)  # work per tile-row
    loads = []
    for d in range(num_devices):
        rows = rows_for_device(d, num_devices, gm, schedule)
        loads.append(jnp.sum(work_rows[np.asarray(rows)]))
    loads = jnp.stack(loads)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)


def tile_imbalance(v: jax.Array, num_workers: int, schedule: str) -> jax.Array:
    """Paper Fig. 4 setting: workers own individual C *tiles* (row-major
    flattened). 'contiguous' gives diagonal-adjacent chunks to one worker
    (v is diagonal-heavy ⇒ imbalance); 'cyclic' is the §3.5.1 stride-s fix;
    'equal_work' cuts variable-length contiguous tile runs by prefix sum
    (eager-only) — no truncation to a worker multiple, because the strips
    need not share a shape."""
    flat = v.reshape(-1)
    if schedule == "equal_work":
        work = np.asarray(flat, np.float64)
        offs = _equal_cuts(work, num_workers)
        cs = np.concatenate(([0.0], np.cumsum(work)))
        loads = jnp.asarray(cs[offs[1:]] - cs[offs[:-1]])
        return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)
    n = flat.shape[0] - (flat.shape[0] % num_workers)
    flat = flat[:n]
    if schedule == "contiguous":
        loads = jnp.sum(flat.reshape(num_workers, -1), axis=1)
    elif schedule == "cyclic":
        loads = jnp.sum(flat.reshape(-1, num_workers), axis=0)
    else:
        raise ValueError(schedule)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)


def auto_schedule(v: jax.Array, num_devices: int, *,
                  threshold: float = 1.25, level: int = 0,
                  fine_rows: int = None,
                  equal_work_margin: float = 1.1,
                  allow_equal_work: bool = True) -> str:
    """Pick the row-strip schedule from a (possibly coarse) work estimate V:

      1. 'cyclic' when the contiguous assignment is measurably imbalanced
         (> `threshold`) AND cyclic actually improves it, else 'contiguous'
         (the cheapest HLO — no in-step permutation);
      2. 'equal_work' when the pick from step 1 is STILL imbalanced beyond
         `threshold` and the equal-work cut beats it by `equal_work_margin`
         — variable-width contiguous strips fix the profiles both uniform
         schedules lose on (stride-aliased hot rows defeat cyclic, skewed
         mass defeats contiguous) at zero permutation cost.

    The thresholds are deliberately conservative: the in-step cyclic
    permutation costs a collective and an equal-work re-cut invalidates a
    frozen partition, so mild imbalance (e.g. banded matrices' lighter edge
    rows) should trigger neither.

    level/fine_rows: set when V is a coarse pyramid-level estimate of a
    product whose FINE row grid is what actually shards — the loads are then
    attributed through `device_loads`' fine-boundary split instead of
    treating coarse rows as indivisible (at level 0 this reduces exactly to
    the flat per-row attribution, so there is ONE decision rule).
    Eager-only: the decision is a Python string."""
    gm = fine_rows if fine_rows is not None else v.shape[0] << level
    if gm < num_devices:
        return "contiguous"  # fewer rows than devices: nothing to fix
    imbs = {}
    scheds = ("contiguous", "cyclic") + (
        ("equal_work",) if allow_equal_work else ())
    for sched in scheds:
        loads = device_loads(v, num_devices, sched, level=level,
                             fine_rows=gm)
        imbs[sched] = float(loads.max() / max(loads.mean(), 1e-9))
    pick = ("cyclic" if imbs["contiguous"] > threshold
            and imbs["cyclic"] < imbs["contiguous"] else "contiguous")
    if (allow_equal_work and imbs[pick] > threshold
            and imbs[pick] >= equal_work_margin * imbs["equal_work"]):
        pick = "equal_work"
    return pick


# ---------------------------------------------------------------------------
# drift-triggered re-sharding (control plane)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReshardConfig:
    """Knobs of the drift-triggered re-sharding loop.

    num_devices: strips to cut (a pod passes its data-axis size; 0 lets the
      OWNER — engine/train loop — default it from its mesh before building
      the controller, which itself requires a positive count).
    every: probe cadence in engine/train steps (0 disables the controller).
    drift_threshold: re-cut when the LIVE partition's predicted imbalance
      exceeds the fresh equal-work cut's by this factor (1.0 = re-cut
      whenever a different cut is better at all; higher = stickier
      partitions, fewer re-shards).
    level: norm-pyramid level of the probe estimate (coarse = cheaper).
    probe_window: serving probes estimate from at most this many of each
      request's most recent tokens, keeping per-probe cost constant as
      generation grows (0 = unbounded).
    """
    num_devices: int = 0
    every: int = 16
    drift_threshold: float = 1.2
    level: int = 0
    probe_window: int = 2048


class ReshardController:
    """Owns the live equal-work partition and re-cuts it when the work
    estimate drifts (the between-steps half of the load-balance story:
    `equal_work_partition` cuts strips from a snapshot; activations evolve,
    so a frozen cut decays — the controller re-probes every `cfg.every`
    steps and replaces the partition only when the drift exceeds
    `cfg.drift_threshold`, keeping re-shards rare enough to amortize).

    Pure control plane: probing/re-cutting never touches the computed
    values — consumers hand `offsets` to `distributed.spamm_rowpart`/
    `spamm_2d`, whose outputs are bit-identical under ANY partition.
    """

    def __init__(self, cfg: ReshardConfig):
        if cfg.num_devices <= 0:
            raise ValueError(
                "ReshardController needs a positive num_devices — resolve "
                "the 0-means-mesh-default before constructing it (the "
                "engine and train loop do this from ctx.batch_axes)")
        self.cfg = cfg
        self.offsets: Optional[np.ndarray] = None  # live partition
        self.resharded = 0        # partition replacements (drift events)
        self.probes = 0           # estimate recomputations
        self.history: list = []   # one dict per probe (telemetry series)
        self._published = 0       # history index consumed by publish()

    @property
    def live_imbalance(self) -> Optional[float]:
        """Predicted imbalance of the live partition at the last probe."""
        return self.history[-1]["live_imbalance"] if self.history else None

    @property
    def live_loads(self) -> Optional[np.ndarray]:
        """Per-strip predicted work of the live partition at the last probe
        (what the serve readout and the train loop's telemetry print as the
        per-shard load profile)."""
        if not self.history:
            return None
        return np.asarray(self.history[-1]["loads"], np.float64)

    def due(self, step: int) -> bool:
        return self.cfg.every > 0 and step % self.cfg.every == 0

    def probe(self, v, step: int, *, level: Optional[int] = None,
              fine_rows: Optional[int] = None) -> np.ndarray:
        """Feed a fresh work estimate; returns the (possibly re-cut) live
        offsets. The first probe cuts the initial partition (not counted as
        a re-shard). Later probes compare the live partition's predicted
        imbalance under the FRESH estimate against a fresh cut's and replace
        the partition only beyond the drift threshold.

        A probe whose row grid differs from the live partition's (serving
        waves grow/shrink the token count) resets like a first probe:
        partitions for different grids are incomparable — evaluating stale
        offsets against the new grid would clip them into phantom zero-load
        strips and fire spurious drift events."""
        lv = self.cfg.level if level is None else level
        ndev = self.cfg.num_devices
        self.probes += 1
        fresh = equal_work_partition(v, ndev, level=lv, fine_rows=fine_rows)
        fresh_imb = partition_imbalance(v, fresh, level=lv,
                                        fine_rows=fine_rows)
        event = False
        stale = (self.offsets is None or self.offsets.shape != fresh.shape
                 or self.offsets[-1] != fresh[-1])
        if stale:
            self.offsets = fresh
            live_imb = fresh_imb
        else:
            live_imb = partition_imbalance(v, self.offsets, level=lv,
                                           fine_rows=fine_rows)
            event = (live_imb > self.cfg.drift_threshold * fresh_imb
                     and not np.array_equal(fresh, self.offsets))
            if event:
                self.offsets = fresh
                self.resharded += 1
        loads = partition_loads(v, self.offsets, level=lv,
                                fine_rows=fine_rows)
        self.history.append({
            "step": step,
            "grid": int(fresh[-1]),
            "live_imbalance": live_imb,
            "fresh_imbalance": fresh_imb,
            "resharded": event,
            "loads": [float(x) for x in loads],
        })
        return self.offsets

    def publish(self, registry):
        """Feed history entries recorded since the last call into an
        `obs.MetricsRegistry`: probe/re-shard counters, the predicted
        imbalance histogram, and a live-imbalance gauge. Incremental (the
        controller keeps a cursor), so callers can publish per wave/step
        without double counting; idempotent when no new probes landed."""
        from repro.obs import IMBALANCE_BUCKETS

        new = self.history[self._published:]
        if not new:
            return
        self._published = len(self.history)
        probes = registry.counter(
            "spamm_reshard_probes_total", "Work-estimate recomputations")
        events = registry.counter(
            "spamm_reshard_events_total",
            "Partition replacements (drift beyond threshold)")
        imb = registry.histogram(
            "spamm_partition_imbalance",
            "Predicted imbalance of the live partition at each probe",
            buckets=IMBALANCE_BUCKETS)
        gauge = registry.gauge(
            "spamm_partition_imbalance_live",
            "Live partition's predicted imbalance at the latest probe")
        probes.inc(len(new))
        events.inc(sum(1 for h in new if h["resharded"]))
        for h in new:
            if h["live_imbalance"] is not None:
                imb.observe(float(h["live_imbalance"]))
        last = new[-1]["live_imbalance"]
        if last is not None:
            gauge.set(float(last))


def resolve_reshard_devices(cfg: ReshardConfig, mesh,
                            batch_axes) -> ReshardConfig:
    """Resolve ReshardConfig's num_devices=0 convention to the mesh's
    batch-axis extent (the strips a pod's row partition would shard over) —
    the one place the engine and train loop share for it."""
    if cfg.num_devices > 0:
        return cfg
    ndev = 1
    for ax in batch_axes:
        ndev *= mesh.shape[ax]
    return dataclasses.replace(cfg, num_devices=ndev)


def probe_v_estimate(x, weight_norms, tau, *, tile: int = 64,
                     backend: str = "auto", level: int = 0):
    """Work-estimate V for activation rows `x` against a CACHED weight-side
    normmap/pyramid — the cheap re-sharding probe: only the activation-side
    get-norm (plus `level` poolings) is fresh; the weight side piggybacks on
    `WeightPlanCache.weight_side`. Returns (v, fine_rows) where fine_rows is
    x's tile-row count (the grid the partition shards)."""
    from repro.core import plan as _plan     # circular-safe
    from repro.kernels import ops as kops

    bk = kops.get_backend(backend)
    xp = _plan.pad_to_tile(jnp.asarray(x, jnp.float32), tile)
    nx = bk.norms(xp, tile)
    if level > 0:
        nx = _plan.NormPyramid.from_normmap(nx, level, tile=tile)
    return v_matrix(nx, weight_norms, tau, level=level), xp.shape[0] // tile
