"""Load-balance scheduling (paper §3.5.1).

For decay matrices the per-output-tile work v[i,j] = Σ_k bitmap[i,j,k]
concentrates near the diagonal (paper Fig. 4). On TPU a single chip executes
its Pallas grid sequentially, so *intra-chip* balance is moot; what survives
the hardware translation is balance *across chips* in the distributed
row-partition (§3.4): contiguous row-strips give diagonal-heavy strips more
work. The paper's fix — each worker takes `s` tiles at stride BDIM/s — maps
to a cyclic (strided) assignment of C tile-rows to devices.

Work estimates may be computed at a coarse norm-pyramid level (`v_matrix`
accepts NormPyramid operands + a `level`): each coarse V entry aggregates a
2^level × 2^level block of C tiles and costs 8^level fewer gate products —
cheap enough for the distributed paths to re-estimate per step and pick the
schedule automatically (`auto_schedule`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def v_matrix(norm_a, norm_b, tau, *, level: int = 0) -> jax.Array:
    """V[i,j] = Σ_k bitmap[i,j,k] — the paper's per-tile valid-multiplication
    count, summed from the planner's bitmap (core.plan owns the gating).

    Operands may be plain normmaps or NormPyramids; `level` selects the
    pyramid level the estimate is computed at (plain normmaps ignore it).
    At level l > 0 each entry counts valid COARSE products, a cheap upper
    estimate of the fine work inside that 2^l × 2^l block of C tiles.
    """
    from repro.core.plan import NormPyramid, gate_mask  # circular-safe

    # both sides must be read at the SAME coarsening or their k-grids
    # disagree: clamp jointly to the shallower pyramid, and to 0 when only
    # one side has levels at all
    a_pyr = isinstance(norm_a, NormPyramid)
    b_pyr = isinstance(norm_b, NormPyramid)
    if a_pyr and b_pyr:
        level = min(level, norm_a.num_levels, norm_b.num_levels)
    else:
        level = 0
    if a_pyr:
        norm_a = norm_a.levels[level]
    if b_pyr:
        norm_b = norm_b.levels[level]
    return jnp.sum(gate_mask(norm_a, norm_b, tau), axis=-1, dtype=jnp.int32)


def rows_for_device(d: int, num_devices: int, gm: int, schedule: str) -> np.ndarray:
    """Tile-row indices device d owns. 'contiguous' = paper §3.4 default;
    'cyclic' = §3.5.1 strided load balance. Non-divisible gm spreads the
    remainder over the leading devices (matters only for coarse estimates —
    the distributed paths themselves require divisibility)."""
    if schedule == "contiguous":
        return np.array_split(np.arange(gm), num_devices)[d]
    if schedule == "cyclic":
        return np.arange(d, gm, num_devices)
    raise ValueError(schedule)


def device_permutation(num_devices: int, gm: int, schedule: str) -> np.ndarray:
    """Row-tile permutation s.t. contiguous shards of the permuted matrix
    realize `schedule`. perm[new_pos] = old_row_tile."""
    return np.concatenate(
        [rows_for_device(d, num_devices, gm, schedule) for d in range(num_devices)]
    )


def device_loads(v: jax.Array, num_devices: int, schedule: str, *,
                 level: int = 0, fine_rows: int = None) -> np.ndarray:
    """Per-device work under a row-strip assignment, attributed at FINE
    tile-row granularity.

    V's rows may be coarse (each ceil-pooling 2^level fine tile-rows, the
    norm-pyramid work estimate): a coarse row that straddles a fine shard
    boundary must split its work across the devices that actually own its
    fine rows — `rows_for_device`'s array_split over COARSE rows does not
    match that ownership (its remainder spreading differs from how fine
    contiguous shards map onto ceil-pooled coarse rows, and cyclic strides
    walk fine rows, not coarse ones). Each coarse row's work is spread
    uniformly over its member fine rows (clipped at the ragged edge), then
    summed per device with the exact fine assignment.
    """
    work_rows = np.asarray(jnp.sum(v, axis=1), np.float64)
    f = 1 << level
    gm = fine_rows if fine_rows is not None else work_rows.shape[0] * f
    assert work_rows.shape[0] == -(-gm // f), (v.shape, level, gm)
    # last coarse row may pool fewer than 2^level fine rows (ceil pooling)
    counts = np.clip(gm - np.arange(work_rows.shape[0]) * f, 0, f)
    per_fine = np.repeat(work_rows / np.maximum(counts, 1), f)[:gm]
    # ownership comes from rows_for_device — the SAME function the execution
    # sharding (device_permutation) is built from, so estimate and execution
    # cannot drift apart again
    return np.array([
        per_fine[rows_for_device(d, num_devices, gm, schedule)].sum()
        for d in range(num_devices)
    ])


def imbalance(v: jax.Array, num_devices: int, schedule: str) -> jax.Array:
    """max-device-work / mean-device-work under a row-strip assignment of V
    (the §3.4 row partition; banded matrices are naturally balanced here)."""
    gm = v.shape[0]
    work_rows = jnp.sum(v, axis=1)  # work per tile-row
    loads = []
    for d in range(num_devices):
        rows = rows_for_device(d, num_devices, gm, schedule)
        loads.append(jnp.sum(work_rows[np.asarray(rows)]))
    loads = jnp.stack(loads)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)


def tile_imbalance(v: jax.Array, num_workers: int, schedule: str) -> jax.Array:
    """Paper Fig. 4 setting: workers own individual C *tiles* (row-major
    flattened). 'contiguous' gives diagonal-adjacent chunks to one worker
    (v is diagonal-heavy ⇒ imbalance); 'cyclic' is the §3.5.1 stride-s fix."""
    flat = v.reshape(-1)
    n = flat.shape[0] - (flat.shape[0] % num_workers)
    flat = flat[:n]
    if schedule == "contiguous":
        loads = jnp.sum(flat.reshape(num_workers, -1), axis=1)
    elif schedule == "cyclic":
        loads = jnp.sum(flat.reshape(-1, num_workers), axis=0)
    else:
        raise ValueError(schedule)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)


def auto_schedule(v: jax.Array, num_devices: int, *,
                  threshold: float = 1.25, level: int = 0,
                  fine_rows: int = None) -> str:
    """Pick the row-strip schedule from a (possibly coarse) work estimate V:
    'cyclic' when the contiguous assignment is measurably imbalanced AND
    cyclic actually improves it, else 'contiguous' (the cheapest HLO — no
    in-step permutation). The threshold is deliberately conservative: the
    in-step cyclic permutation costs a collective, so mild imbalance (e.g.
    banded matrices' lighter edge rows) should not trigger it.

    level/fine_rows: set when V is a coarse pyramid-level estimate of a
    product whose FINE row grid is what actually shards — the loads are then
    attributed through `device_loads`' fine-boundary split instead of
    treating coarse rows as indivisible (at level 0 this reduces exactly to
    the flat per-row attribution, so there is ONE decision rule).
    Eager-only: the decision is a Python string."""
    gm = fine_rows if fine_rows is not None else v.shape[0] << level
    if gm < num_devices:
        return "contiguous"  # fewer rows than devices: nothing to fix
    imbs = {}
    for sched in ("contiguous", "cyclic"):
        loads = device_loads(v, num_devices, sched, level=level,
                             fine_rows=gm)
        imbs[sched] = float(loads.max() / max(loads.mean(), 1e-9))
    return ("cyclic" if imbs["contiguous"] > threshold
            and imbs["cyclic"] < imbs["contiguous"] else "contiguous")
