"""SpAMM as a drop-in layer for the model zoo (paper §4.3: ergo + VGG13 show
SpAMM embedded in larger applications; here it replaces x @ W GEMMs).

`spamm_linear(x, w, ...)` flattens leading dims, zero-pads to tile multiples,
builds a `SpammPlan` (weight side optionally served from a `WeightPlanCache`)
and executes it. Differentiable via custom_vjp:

  * bwd="dense" (default): exact dense gradients — the paper accelerates
    inference only, so training keeps unbiased grads while the forward enjoys
    tile skipping.
  * bwd="spamm": gradients gated with plans DERIVED from the forward plan's
    normmaps (dx gates g @ Wᵀ with norms(g)·norms(W)ᵀ, dw gates xᵀ @ g with
    norms(x)ᵀ·norms(g)) — a beyond-paper mode trading gradient exactness for
    symmetric FLOP savings. The weight/activation normmaps are computed once
    in the forward and reused, not recomputed per gradient.

The model zoo threads a single `SpammContext` (config + shared
WeightPlanCache) instead of raw (tau, tile, backend, block_n) tuples — see
`maybe_spamm_matmul`.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import plan as _plan
from repro.core.plan import WeightPlanCache, pad_to_tile


class SpammContext:
    """Static SpAMM execution context for the model zoo: the `SpammConfig`
    plus a `WeightPlanCache` shared across every gated GEMM of a model.

    Hashed by identity (usable as a jit static / custom_vjp nondiff arg);
    create one per model/engine, not per call.

    Gating telemetry: between `begin_stats()` and `end_stats()` every gated
    GEMM taps its plan's valid_fraction through `jax.experimental.io_callback`
    — an effectful host callback, so it survives jit AND lax.scan-over-layers
    (the values materialize at *execution* time, per compiled call, not at
    trace time). The serving engine brackets each request wave with
    begin/end and attaches the drained stats to the request metadata.
    """

    __slots__ = ("cfg", "cache", "_taps", "_collect")

    def __init__(self, cfg: Any, cache: Optional[WeightPlanCache] = None):
        self.cfg = cfg
        self.cache = cache if cache is not None else WeightPlanCache()
        self._taps: list = []
        self._collect = False

    def __repr__(self):
        return f"SpammContext({self.cfg!r}, cache={len(self.cache)} entries)"

    @property
    def enable(self) -> bool:
        return bool(getattr(self.cfg, "enable", False))

    # -- gating telemetry ---------------------------------------------------
    def begin_stats(self):
        """Start collecting per-GEMM valid fractions (must be called before
        the first trace of the step that should report them)."""
        self._taps = []
        self._collect = True

    def _record(self, f):
        # host side of the tap; re-check _collect at RUN time — once a
        # callback is embedded in a compiled function it fires on every
        # execution, including ones outside a begin/end window
        if self._collect:
            self._taps.append(float(f))

    def tap(self, valid_fraction):
        """Record one gated GEMM's valid fraction (no-op unless collecting).

        The callback embeds into whatever computation is being traced, so a
        jitted prefill reports fractions on every execution."""
        if not self._collect:
            return
        from jax.experimental import io_callback  # deferred: cheap import

        io_callback(
            self._record, None,
            jnp.asarray(valid_fraction, jnp.float32), ordered=False,
        )

    def end_stats(self):
        """Stop collecting and drain: list of per-GEMM valid fractions tapped
        since `begin_stats` (empty when no gated GEMM executed)."""
        taps, self._taps = self._taps, []
        self._collect = False
        return taps


def as_context(spamm_cfg) -> Optional[SpammContext]:
    """Normalize what the model zoo threads: None / SpammConfig /
    SpammContext all become an Optional[SpammContext]."""
    if spamm_cfg is None or isinstance(spamm_cfg, SpammContext):
        return spamm_cfg
    return SpammContext(spamm_cfg)


def _flatten_pad(x, tile):
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)
    return pad_to_tile(x2, tile), (lead, m, k)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def spamm_linear(
    x: jax.Array,
    w: jax.Array,
    tau: jax.Array,
    tile: int = 64,
    backend: str = "auto",
    bwd: str = "dense",
    block_n: int = 1,
    ctx: Optional[SpammContext] = None,
    levels: int = 0,
) -> jax.Array:
    """y[..., n] = SpAMM(x[..., k] @ w[k, n], tau). Output dtype follows x.

    `ctx` (optional, static) supplies the WeightPlanCache so eager callers
    (serving) pay the weight-side gating once per weight. `levels` > 0 plans
    hierarchically over the norm pyramid (mask unchanged, planning cheaper;
    the weight-side pyramid is what the cache then holds).
    """
    y, _ = _fwd_impl(x, w, tau, tile, backend, block_n, ctx, levels)
    return y


def _fwd_impl(x, w, tau, tile, backend, block_n, ctx, levels=0):
    """Plan + execute one gated GEMM; returns (y, plan)."""
    xp, (lead, m, k) = _flatten_pad(x, tile)
    n = w.shape[-1]
    if ctx is not None:
        p, wp = ctx.cache.plan_for(
            xp, w, tau, tile=tile, block_n=block_n, backend=backend,
            levels=levels,
        )
        ctx.tap(p.valid_fraction)
    else:
        # N pads to tile·block_n (not just tile) so odd-N weights survive
        # super-column gating; the cache path does the same in weight_side
        wp = pad_to_tile(w, tile, tile * block_n)
        p = _plan.plan(xp, wp, tau, tile=tile, block_n=block_n,
                       backend=backend, levels=levels)
    c = _plan.execute(p, xp, wp)
    y = c[:m, :n].reshape(*lead, n).astype(x.dtype)
    return y, p


def _spamm_linear_fwd(x, w, tau, tile, backend, bwd, block_n, ctx, levels):
    y, p = _fwd_impl(x, w, tau, tile, backend, block_n, ctx, levels)
    # residuals carry the forward normmaps so bwd="spamm" replans without
    # re-running get-norm on x or w
    return y, (x, w, tau, p.norm_a, p.norm_b)


def _spamm_linear_bwd(tile, backend, bwd, block_n, ctx, levels, res, g):
    x, w, tau, norm_x, norm_w = res
    lead = x.shape[:-1]
    k, n = w.shape
    m = 1
    for s in lead:
        m *= s
    g2 = g.reshape(m, n)
    x2 = x.reshape(m, k)
    if bwd == "dense":
        dx = (g2 @ w.T).reshape(x.shape).astype(x.dtype)
        dw = (x2.T @ g2).astype(w.dtype)
    elif bwd == "spamm":
        # g/w pad N to tile·block_n to match the forward normmaps' column
        # grid (norm_w came from the block_n-padded weight)
        gp = pad_to_tile(g2, tile, tile * block_n)
        xp = pad_to_tile(x2, tile)
        wp = pad_to_tile(w, tile, tile * block_n)
        # dx = (g @ Wᵀ) gated by norms(g)·norms(W)ᵀ — the forward bitmap
        # with its (k, j) axes transposed, built from the cached weight norms
        p_dx = _plan.plan(gp, None, tau, norm_b=norm_w.T, tile=tile,
                          backend=backend)
        norm_g = p_dx.norm_a
        dxp = _plan.execute(p_dx, gp, wp.T)
        # dw = (xᵀ @ g) gated by norms(x)ᵀ·norms(g)
        p_dw = _plan.plan(None, None, tau, norm_a=norm_x.T, norm_b=norm_g,
                          tile=tile, backend=backend)
        dwp = _plan.execute(p_dw, xp.T, gp)
        dx = dxp[:m, :k].reshape(x.shape).astype(x.dtype)
        dw = dwp[:k, :n].astype(w.dtype)
    else:
        raise ValueError(f"bwd={bwd!r}")
    dtau = jnp.zeros_like(jnp.asarray(tau, jnp.float32))
    return dx, dw, dtau


spamm_linear.defvjp(_spamm_linear_fwd, _spamm_linear_bwd)


def spamm_bmm_linear(x: jax.Array, w: jax.Array, spamm_ctx) -> jax.Array:
    """Batched gated GEMM for per-slice weights (B, K, N) — the MoE grouped
    FFN shape — via `core.plan.spamm_bmm` with a shared τ. Forward-gated
    only (used on inference/eval paths; training MoE keeps dense grads)."""
    cfg = spamm_ctx.cfg
    c, info = _plan.spamm_bmm(
        x, w, jnp.asarray(cfg.tau, jnp.float32),
        tile=cfg.tile, block_n=cfg.block_n, backend=cfg.backend,
        cache=spamm_ctx.cache, levels=getattr(cfg, "levels", 0),
    )
    spamm_ctx.tap(info.valid_fraction)
    return c.astype(x.dtype)


def maybe_spamm_matmul(x: jax.Array, w: jax.Array, spamm_cfg: Any) -> jax.Array:
    """The hook the model zoo calls for every eligible GEMM: dense when
    spamm_cfg is disabled, plan-routed spamm_linear when enabled.
    `spamm_cfg` may be a SpammConfig or a SpammContext (cfg + plan cache)."""
    ctx = as_context(spamm_cfg)
    if ctx is None or not ctx.enable:
        return x @ w
    cfg = ctx.cfg
    return spamm_linear(
        x,
        w,
        jnp.asarray(cfg.tau, jnp.float32),
        cfg.tile,
        cfg.backend,
        cfg.bwd,
        cfg.block_n,
        ctx,
        getattr(cfg, "levels", 0),
    )
