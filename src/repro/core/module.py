"""SpAMM as a drop-in layer for the model zoo (paper §4.3: ergo + VGG13 show
SpAMM embedded in larger applications; here it replaces x @ W GEMMs).

`spamm_linear(x, w, ...)` flattens leading dims, zero-pads to tile multiples,
builds a `SpammPlan` (weight side optionally served from a `WeightPlanCache`)
and executes it. Differentiable via custom_vjp:

  * bwd="dense" (default): exact dense gradients — the paper accelerates
    inference only, so training keeps unbiased grads while the forward enjoys
    tile skipping.
  * bwd="spamm": gradients gated with plans DERIVED from the forward plan's
    normmaps (dx gates g @ Wᵀ with norms(g)·norms(W)ᵀ, dw gates xᵀ @ g with
    norms(x)ᵀ·norms(g)) — a beyond-paper mode trading gradient exactness for
    symmetric FLOP savings. The weight/activation normmaps are computed once
    in the forward and reused, not recomputed per gradient.

The model zoo threads a single `SpammContext` (config + shared
WeightPlanCache) instead of raw (tau, tile, backend, block_n) tuples — see
`maybe_spamm_matmul`.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import cost as _cost
from repro.core import plan as _plan
from repro.core.plan import WeightPlanCache, pad_to_tile


class Tap(NamedTuple):
    """One labeled telemetry event from a gated GEMM.

    `phase` and `site` are captured at TRACE time (static strings baked into
    the callback partial); `layer` rides as a traced int32 operand so it
    survives `lax.scan`-over-layers — the scan body feeds the per-iteration
    layer index in, and the host sees the concrete value per execution.
    `layer` is -1 when no layer label was in scope (eager callers, MoE
    shard_map interiors)."""
    phase: str
    site: Optional[str]
    layer: int
    value: float


class SpammContext:
    """Static SpAMM execution context for the model zoo: the `SpammConfig`
    plus a `WeightPlanCache` shared across every gated GEMM of a model.

    Hashed by identity (usable as a jit static / custom_vjp nondiff arg);
    create one per model/engine, not per call.

    Gating telemetry: between `begin_stats()` and `end_stats()` every gated
    GEMM taps its plan's valid_fraction through `jax.experimental.io_callback`
    — an effectful host callback, so it survives jit AND lax.scan-over-layers
    (the values materialize at *execution* time, per compiled call, not at
    trace time). Events are LABELED `Tap(phase, site, layer, value)` records:
    phase and site are static strings captured at trace time, the layer index
    is a traced operand fed by the layer stack (`set_layer`). The serving
    engine brackets each request wave with begin/end and attaches the drained
    stats — per-wave aggregates plus a per-layer/per-site breakdown — to the
    request metadata.

    Cost telemetry (optional): with `enable_cost_taps(coeffs)`, the frozen
    path additionally records a per-executed-GEMM time prediction on the
    SAME callback as the fraction/bytes, feeding the predicted-vs-measured
    residual channel. The prediction's static terms are evaluated at trace
    time (`cost.predict_plan_static`, host floats baked into the callback
    partial) and finished host-side from the concrete fraction/bytes
    operands (`cost.finish_plan_time_s`) — armed and unarmed contexts trace
    IDENTICAL graphs, so arming costs nothing on the device timeline.
    """

    __slots__ = ("cfg", "cache", "_taps", "_byte_taps", "_cost_taps",
                 "_collect", "_phase", "_layer", "_trace_buffer",
                 "cost_coeffs")

    def __init__(self, cfg: Any, cache: Optional[WeightPlanCache] = None):
        self.cfg = cfg
        self.cache = cache if cache is not None else WeightPlanCache()
        self._taps: list = []
        self._byte_taps: list = []
        self._cost_taps: list = []
        self._collect = False
        self._phase = "prefill"
        self._layer = None
        self._trace_buffer: Optional[list] = None
        self.cost_coeffs = None

    def __repr__(self):
        return f"SpammContext({self.cfg!r}, cache={len(self.cache)} entries)"

    @property
    def enable(self) -> bool:
        return bool(getattr(self.cfg, "enable", False))

    # -- gating telemetry ---------------------------------------------------
    def begin_stats(self):
        """Start collecting per-GEMM valid fractions (must be called before
        the first trace of the step that should report them)."""
        self._taps = []
        self._byte_taps = []
        self._cost_taps = []
        self._collect = True

    def set_phase(self, phase: str):
        """Tag subsequent taps with a phase label ("prefill" | "decode" |
        "train"). The label is captured at TRACE time, so set it before the
        first call of each jitted step function — every execution of that
        compiled step then reports under its phase, which is what lets the
        engine tell prefill from decode gating fractions apart."""
        self._phase = phase

    def set_layer(self, layer):
        """Tag subsequent taps with a layer index. Unlike the phase, the
        layer may be a TRACED int32 (the `lax.scan` body feeds each
        iteration's index in via the scan xs) — it rides the callback as an
        operand, so every execution reports the concrete per-layer value.
        Reset to None after the stack to avoid leaking a scan tracer into
        unrelated taps."""
        self._layer = layer

    def swap_layer(self, layer):
        """Set the layer label and return the previous one — bracketing for
        regions whose taps must NOT close over an outer-trace layer tracer
        (MoE blocks tap inside shard_map; an outer scan's index tracer must
        not be captured there)."""
        prev, self._layer = self._layer, layer
        return prev

    def _layer_arg(self):
        layer = self._layer if self._layer is not None else -1
        return jnp.asarray(layer, jnp.int32)

    def enable_cost_taps(self, coeffs):
        """Arm the cost-prediction channel: `coeffs` is a `cost.CostCoeffs`
        (host floats, resolved once per engine from the tune profile). Must
        be set BEFORE the first trace of the instrumented step — the
        prediction arithmetic embeds into the compiled graph."""
        self.cost_coeffs = coeffs

    def _record(self, phase, site, f, layer):
        # host side of the tap; re-check _collect at RUN time — once a
        # callback is embedded in a compiled function it fires on every
        # execution, including ones outside a begin/end window
        if self._collect:
            self._taps.append(Tap(phase, site, int(layer), float(f)))

    def _record_bytes(self, phase, site, nb, layer):
        if self._collect:
            self._byte_taps.append(Tap(phase, site, int(layer), float(nb)))

    def _record_gemm(self, phase, site, f, nb, layer):
        if self._collect:
            layer = int(layer)
            self._taps.append(Tap(phase, site, layer, float(f)))
            self._byte_taps.append(Tap(phase, site, layer, float(nb)))

    def _record_gemm_cost(self, phase, site, cost_static, f, nb, layer):
        if self._collect:
            layer, f, nb = int(layer), float(f), float(nb)
            self._taps.append(Tap(phase, site, layer, f))
            self._byte_taps.append(Tap(phase, site, layer, nb))
            if self.cost_coeffs is not None:
                # finish the prediction host-side from the concrete operands
                # (the static terms were baked into this partial at trace
                # time) — the armed graph carries zero extra ops
                pred = _cost.finish_plan_time_s(cost_static, f, nb,
                                                self.cost_coeffs)
                self._cost_taps.append(Tap(phase, site, layer, pred))

    # -- trace-time buffering (the grad-safe path) --------------------------
    # io_callback effects are DROPPED inside a custom_vjp fwd rule under
    # value_and_grad (and inside grad-of-scan), so the train step cannot
    # report through callbacks. Instead the stack collects taps as traced
    # VALUES: while a trace buffer is open, tap() appends the traced
    # fraction to it and the caller threads the sum through the scan carry
    # into the step metrics — pure dataflow, survives grad and remat.
    def begin_trace_buffer(self):
        self._trace_buffer = []

    def drain_trace_buffer(self) -> list:
        buf, self._trace_buffer = (self._trace_buffer or []), None
        return buf

    def suspend_trace_buffer(self):
        """Temporarily disable buffering (MoE blocks trace their gated GEMMs
        inside shard_map — their tracers must not leak into an outer-trace
        carry; those taps fall back to the callback path)."""
        buf, self._trace_buffer = self._trace_buffer, None
        return buf

    def resume_trace_buffer(self, buf):
        self._trace_buffer = buf

    def tap(self, valid_fraction, site: Optional[str] = None):
        """Record one gated GEMM's valid fraction, tagged with the current
        phase/site/layer labels (no-op unless collecting or a trace buffer
        is open).

        The callback embeds into whatever computation is being traced, so a
        jitted prefill reports fractions on every execution."""
        if self._trace_buffer is not None:
            self._trace_buffer.append(jnp.asarray(valid_fraction, jnp.float32))
            return
        if not self._collect:
            return
        from jax.experimental import io_callback  # deferred: cheap import

        io_callback(
            functools.partial(self._record, self._phase, site), None,
            jnp.asarray(valid_fraction, jnp.float32), self._layer_arg(),
            ordered=False,
        )

    def tap_bytes(self, nbytes, site: Optional[str] = None):
        """Record one gated GEMM's bytes-moved estimate (plan.bytes_moved()),
        tagged with the current phase. Separate channel from tap(): the
        fraction taps feed the gating-quality stats, the byte taps feed the
        mixed-precision bandwidth telemetry — draining one must not consume
        the other. Callback-only (no trace-buffer tier: bytes are a serving
        metric, the grad path never reports them)."""
        if not self._collect:
            return
        from jax.experimental import io_callback  # deferred: cheap import

        io_callback(
            functools.partial(self._record_bytes, self._phase, site), None,
            jnp.asarray(nbytes, jnp.float32), self._layer_arg(),
            ordered=False,
        )

    def tap_gemm(self, valid_fraction, nbytes, cost_static=None,
                 site: Optional[str] = None):
        """Record one gated GEMM's fraction + bytes (+ optionally a cost
        prediction) through a SINGLE io_callback — the frozen serving
        path's tap. One host roundtrip instead of two keeps the labeled
        telemetry CHEAPER than the anonymous two-callback scheme it
        replaced; the host side fans the operands back out into the
        separate channels.

        `cost_static` is `cost.predict_plan_static(...)` output (host
        floats). It is baked into the callback partial, NOT traced: the
        host recorder finishes the prediction from the fraction/bytes
        operands already on the wire, so the cost channel adds no operands
        and no graph ops — armed and unarmed steps compile identically."""
        if not self._collect:
            return
        from jax.experimental import io_callback  # deferred: cheap import

        frac = jnp.asarray(valid_fraction, jnp.float32)
        nb = jnp.asarray(nbytes, jnp.float32)
        if cost_static is not None:
            io_callback(
                functools.partial(self._record_gemm_cost, self._phase, site,
                                  cost_static),
                None, frac, nb, self._layer_arg(), ordered=False,
            )
        else:
            io_callback(
                functools.partial(self._record_gemm, self._phase, site),
                None, frac, nb, self._layer_arg(), ordered=False,
            )

    def end_stats(self):
        """Stop collecting and drain: list of `Tap(phase, site, layer,
        valid_fraction)` events recorded since `begin_stats` (empty when no
        gated GEMM executed)."""
        taps, self._taps = self._taps, []
        self._collect = False
        return taps

    def drain_byte_stats(self):
        """Drain the bytes-moved taps: `Tap` events (value = bytes) recorded
        since `begin_stats`. Call before `end_stats` flips _collect off if
        callbacks may still be landing; the engine drains both together."""
        taps, self._byte_taps = self._byte_taps, []
        return taps

    def drain_cost_stats(self):
        """Drain the cost-prediction taps: `Tap` events (value = predicted
        seconds) — empty unless `enable_cost_taps` armed the channel before
        the instrumented steps were traced."""
        taps, self._cost_taps = self._cost_taps, []
        return taps


def as_context(spamm_cfg) -> Optional[SpammContext]:
    """Normalize what the model zoo threads: None / SpammConfig /
    SpammContext all become an Optional[SpammContext]."""
    if spamm_cfg is None or isinstance(spamm_cfg, SpammContext):
        return spamm_cfg
    return SpammContext(spamm_cfg)


def _flatten_pad(x, tile):
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)
    return pad_to_tile(x2, tile), (lead, m, k)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9)
)
def _spamm_linear_stats(
    x: jax.Array,
    w: jax.Array,
    tau: jax.Array,
    tile: int = 64,
    backend: str = "auto",
    bwd: str = "dense",
    block_n: int = 1,
    ctx: Optional[SpammContext] = None,
    levels: int = 0,
    compute_dtype: str = "float32",
):
    """(y, valid_fraction) — the gated GEMM plus its gating stat as a REAL
    OUTPUT. The fraction must flow out of the custom_vjp rather than be
    tapped inside it: the fwd rule is traced in its own subsidiary trace
    under autodiff, so a tap fired there either gets dropped (callbacks) or
    leaks an inner tracer (trace buffers). Callers tap the returned value."""
    y, p = _fwd_impl(x, w, tau, tile, backend, block_n, ctx, levels,
                     compute_dtype)
    return y, p.valid_fraction


def spamm_linear(
    x: jax.Array,
    w: jax.Array,
    tau: jax.Array,
    tile: int = 64,
    backend: str = "auto",
    bwd: str = "dense",
    block_n: int = 1,
    ctx: Optional[SpammContext] = None,
    levels: int = 0,
    compute_dtype: str = "float32",
) -> jax.Array:
    """y[..., n] = SpAMM(x[..., k] @ w[k, n], tau). Output dtype follows x.

    `ctx` (optional, static) supplies the WeightPlanCache so eager callers
    (serving) pay the weight-side gating once per weight. `levels` > 0 plans
    hierarchically over the norm pyramid (mask unchanged, planning cheaper;
    the weight-side pyramid is what the cache then holds). `compute_dtype`
    selects the forward GEMM operand precision (float32 | bfloat16 | int8 —
    f32 accumulate, conservative widened-τ gate); gradients always run f32.
    """
    return _spamm_linear_stats(x, w, tau, tile, backend, bwd, block_n, ctx,
                               levels, compute_dtype)[0]


def _fwd_impl(x, w, tau, tile, backend, block_n, ctx, levels=0,
              compute_dtype="float32"):
    """Plan + execute one gated GEMM; returns (y, plan)."""
    xp, (lead, m, k) = _flatten_pad(x, tile)
    n = w.shape[-1]
    if ctx is not None:
        p, wp = ctx.cache.plan_for(
            xp, w, tau, tile=tile, block_n=block_n, backend=backend,
            levels=levels, compute_dtype=compute_dtype,
        )
    else:
        # N pads to tile·block_n (not just tile) so odd-N weights survive
        # super-column gating; the cache path does the same in weight_side
        wp = pad_to_tile(w, tile, tile * block_n)
        p = _plan.plan(xp, wp, tau, tile=tile, block_n=block_n,
                       backend=backend, levels=levels,
                       compute_dtype=compute_dtype)
    c = _plan.execute(p, xp, wp)
    y = c[:m, :n].reshape(*lead, n).astype(x.dtype)
    return y, p


def _spamm_linear_fwd(x, w, tau, tile, backend, bwd, block_n, ctx, levels,
                      compute_dtype):
    y, p = _fwd_impl(x, w, tau, tile, backend, block_n, ctx, levels,
                     compute_dtype)
    # residuals carry the forward normmaps so bwd="spamm" replans without
    # re-running get-norm on x or w
    return (y, p.valid_fraction), (x, w, tau, p.norm_a, p.norm_b)


def _spamm_linear_bwd(tile, backend, bwd, block_n, ctx, levels, compute_dtype,
                      res, g):
    # gradients deliberately ignore compute_dtype: bwd="dense" is exact f32
    # by contract, and bwd="spamm" regates from the forward normmaps (already
    # quantization-aware via the widened forward τ) but multiplies in f32 —
    # low-precision grads would bias training for no serving win
    del compute_dtype
    x, w, tau, norm_x, norm_w = res
    g, _ = g  # cotangent of the valid-fraction stat output is discarded
    lead = x.shape[:-1]
    k, n = w.shape
    m = 1
    for s in lead:
        m *= s
    g2 = g.reshape(m, n)
    x2 = x.reshape(m, k)
    if bwd == "dense":
        dx = (g2 @ w.T).reshape(x.shape).astype(x.dtype)
        dw = (x2.T @ g2).astype(w.dtype)
    elif bwd == "spamm":
        # g/w pad N to tile·block_n to match the forward normmaps' column
        # grid (norm_w came from the block_n-padded weight)
        gp = pad_to_tile(g2, tile, tile * block_n)
        xp = pad_to_tile(x2, tile)
        wp = pad_to_tile(w, tile, tile * block_n)
        # dx = (g @ Wᵀ) gated by norms(g)·norms(W)ᵀ — the forward bitmap
        # with its (k, j) axes transposed, built from the cached weight norms
        p_dx = _plan.plan(gp, None, tau, norm_b=norm_w.T, tile=tile,
                          backend=backend)
        norm_g = p_dx.norm_a
        dxp = _plan.execute(p_dx, gp, wp.T)
        # dw = (xᵀ @ g) gated by norms(x)ᵀ·norms(g)
        p_dw = _plan.plan(None, None, tau, norm_a=norm_x.T, norm_b=norm_g,
                          tile=tile, backend=backend)
        dwp = _plan.execute(p_dw, xp.T, gp)
        dx = dxp[:m, :k].reshape(x.shape).astype(x.dtype)
        dw = dwp[:k, :n].astype(w.dtype)
    else:
        raise ValueError(f"bwd={bwd!r}")
    dtau = jnp.zeros_like(jnp.asarray(tau, jnp.float32))
    return dx, dw, dtau


_spamm_linear_stats.defvjp(_spamm_linear_fwd, _spamm_linear_bwd)


def spamm_bmm_linear(x: jax.Array, w: jax.Array, spamm_ctx) -> jax.Array:
    """Batched gated GEMM for per-slice weights (B, K, N) — the MoE grouped
    FFN shape — via `core.plan.spamm_bmm` with a shared τ. Forward-gated
    only (used on inference/eval paths; training MoE keeps dense grads)."""
    cfg = spamm_ctx.cfg
    c, info = _plan.spamm_bmm(
        x, w, jnp.asarray(cfg.tau, jnp.float32),
        tile=cfg.tile, block_n=cfg.block_n, backend=cfg.backend,
        cache=spamm_ctx.cache, levels=getattr(cfg, "levels", 0),
    )
    spamm_ctx.tap(info.valid_fraction, site="moe_bmm")
    return c.astype(x.dtype)


def spamm_linear_frozen(x: jax.Array, w: jax.Array, fp,
                        ctx: Optional[SpammContext] = None,
                        site: Optional[str] = None) -> jax.Array:
    """Gated GEMM with a frozen weight side (forward-only serving path).

    `fp` is a `repro.plans.frozen.FrozenPlan` specialized to x's flattened
    row grid, passed INTO the enclosing jit as an argument: the traced graph
    computes only the activation-side gate and runs the frozen `SpammWork`
    step tables — no weight get-norm, no dense-bitmap sort. Bit-identical to
    `spamm_linear` with the same config (the frozen tables are a superset
    re-gated by the exact flat τ-test). Inference path: no custom_vjp.

    `site` labels the tap ("wq", "w1", ...); when the context has cost taps
    armed the predicted call time rides the same callback — the static part
    of the prediction is computed HERE at trace time (host floats baked
    into the callback), the executed-work part on the host from the tap's
    own operands, so arming costs zero extra graph ops."""
    tile = fp.tile
    xp, (lead, m, k) = _flatten_pad(x, tile)
    n = w.shape[-1]
    p = _plan.plan(xp, frozen_weight=fp)
    if ctx is not None:
        cost = (_cost.predict_plan_static(p, ctx.cost_coeffs)
                if ctx.cost_coeffs is not None else None)
        ctx.tap_gemm(p.valid_fraction, p.bytes_moved(), cost, site=site)
    wp = pad_to_tile(w, tile, tile * fp.block_n)
    c = _plan.execute(p, xp, wp)
    return c[:m, :n].reshape(*lead, n).astype(x.dtype)


def maybe_spamm_matmul(x: jax.Array, w: jax.Array, spamm_cfg: Any,
                       frozen=None, require_frozen: bool = False,
                       site: Optional[str] = None) -> jax.Array:
    """The hook the model zoo calls for every eligible GEMM: dense when
    spamm_cfg is disabled, plan-routed spamm_linear when enabled.
    `spamm_cfg` may be a SpammConfig or a SpammContext (cfg + plan cache).

    `frozen` (a FrozenPlan jit input) routes the GEMM through the frozen
    work-list path instead of tracing the gate from scratch.
    `require_frozen=True` (the decode path) falls back to DENSE when no
    frozen plan is available for this site — decode-step gating is only
    worth its trace when the weight side comes precomputed.
    `site` is a static per-GEMM label ("wq", "w2", ...) for the telemetry."""
    ctx = as_context(spamm_cfg)
    if ctx is None or not ctx.enable or (require_frozen and frozen is None):
        return x @ w
    if frozen is not None:
        return spamm_linear_frozen(x, w, frozen, ctx, site=site)
    cfg = ctx.cfg
    y, frac = _spamm_linear_stats(
        x,
        w,
        jnp.asarray(cfg.tau, jnp.float32),
        cfg.tile,
        cfg.backend,
        cfg.bwd,
        cfg.block_n,
        ctx,
        getattr(cfg, "levels", 0),
        getattr(cfg, "dtype", "float32"),
    )
    ctx.tap(frac, site=site)
    return y
