"""SpAMM as a drop-in layer for the model zoo (paper §4.3: ergo + VGG13 show
SpAMM embedded in larger applications; here it replaces x @ W GEMMs).

`spamm_linear(x, w, ...)` flattens leading dims, zero-pads to tile multiples,
runs the SpAMM pipeline, and un-pads. Differentiable via custom_vjp:

  * bwd="dense" (default): exact dense gradients — the paper accelerates
    inference only, so training keeps unbiased grads while the forward enjoys
    tile skipping.
  * bwd="spamm": gradients computed with the SAME forward bitmap transposed
    (dx uses mask[i,j,k]→[i,k,j]-gated g @ Wᵀ, dw uses xᵀ @ g gated) — a
    beyond-paper mode trading gradient exactness for symmetric FLOP savings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import spamm as _spamm
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _flatten_pad(x, tile):
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)
    return _spamm.pad_to_tile(x2, tile), (lead, m, k)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def spamm_linear(
    x: jax.Array,
    w: jax.Array,
    tau: jax.Array,
    tile: int = 64,
    backend: str = "auto",
    bwd: str = "dense",
    block_n: int = 1,
) -> jax.Array:
    """y[..., n] = SpAMM(x[..., k] @ w[k, n], tau). Output dtype follows x."""
    y, _ = _fwd_impl(x, w, tau, tile, backend, block_n)
    return y


def _fwd_impl(x, w, tau, tile, backend, block_n):
    xp, (lead, m, k) = _flatten_pad(x, tile)
    wp = _spamm.pad_to_tile(w, tile)
    n = w.shape[-1]
    c, info = kops.spamm_matmul(
        xp, wp, tau, tile=tile, block_n=block_n, backend=backend
    )
    y = c[:m, :n].reshape(*lead, n).astype(x.dtype)
    return y, info


def _spamm_linear_fwd(x, w, tau, tile, backend, bwd, block_n):
    y, _ = _fwd_impl(x, w, tau, tile, backend, block_n)
    return y, (x, w, tau)


def _spamm_linear_bwd(tile, backend, bwd, block_n, res, g):
    x, w, tau = res
    lead = x.shape[:-1]
    k, n = w.shape
    m = 1
    for s in lead:
        m *= s
    g2 = g.reshape(m, n)
    x2 = x.reshape(m, k)
    if bwd == "dense":
        dx = (g2 @ w.T).reshape(x.shape).astype(x.dtype)
        dw = (x2.T @ g2).astype(w.dtype)
    elif bwd == "spamm":
        gp = _spamm.pad_to_tile(g2, tile)
        xp = _spamm.pad_to_tile(x2, tile)
        wp = _spamm.pad_to_tile(w, tile)
        dxp, _ = kops.spamm_matmul(gp, wp.T, tau, tile=tile, backend=backend)
        dwp, _ = kops.spamm_matmul(xp.T, gp, tau, tile=tile, backend=backend)
        dx = dxp[:m, :k].reshape(x.shape).astype(x.dtype)
        dw = dwp[:k, :n].astype(w.dtype)
    else:
        raise ValueError(f"bwd={bwd!r}")
    dtau = jnp.zeros_like(jnp.asarray(tau, jnp.float32))
    return dx, dw, dtau


spamm_linear.defvjp(_spamm_linear_fwd, _spamm_linear_bwd)


def maybe_spamm_matmul(x: jax.Array, w: jax.Array, spamm_cfg: Any) -> jax.Array:
    """The hook the model zoo calls for every eligible GEMM: dense when
    spamm_cfg is disabled, spamm_linear when enabled."""
    if spamm_cfg is None or not getattr(spamm_cfg, "enable", False):
        return x @ w
    return spamm_linear(
        x,
        w,
        jnp.asarray(spamm_cfg.tau, jnp.float32),
        spamm_cfg.tile,
        spamm_cfg.backend,
        spamm_cfg.bwd,
        spamm_cfg.block_n,
    )
