"""SpAMM core — the paper's contribution as a composable JAX module.

Functional API over the plan/execute pipeline (repro.core.plan) with:
  * arbitrary (M, K) @ (K, N) shapes (auto zero-padding to tile multiples,
    paper §3 "the matrices are padded with zeros"),
  * tau- or valid-ratio-driven gating (ratio → tau via core.tau_search),
  * the original *recursive* Algorithm 1 as an oracle for the equivalence
    property test (paper §3.1 claims re-design ≡ recursion),
  * scalable valid-ratio counting that never materializes the O(BDIM³)
    product tensor (sorted normmap + searchsorted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as _plan
from repro.core.plan import SpammInfo, pad_to_tile  # re-exported API


# ---------------------------------------------------------------------------
# scalable valid-ratio counting (no O(gm·gn·gk) tensor)
# ---------------------------------------------------------------------------

def count_valid(norm_a: jax.Array, norm_b: jax.Array, tau) -> jax.Array:
    """#{(i,j,k): na[i,k]·nb[k,j] >= tau} in O(gm·gk·log gn) memory-light form.

    The count can exceed int32 for production grids — gm·gk·gn overflows 2³¹
    already at gm = gk = gn = 1290, i.e. an N ≈ 82k matrix at tile 64. When
    the grid makes overflow possible the sum falls back to i64 (f32 without
    jax_enable_x64 — approximate above 2²⁴ but monotone, which is all the
    τ-bisection needs); smaller grids keep the exact int32 sum.
    """
    gm, gk = norm_a.shape
    gk2, gn = norm_b.shape
    assert gk == gk2
    tau = jnp.asarray(tau, jnp.float32)
    sorted_nb = jnp.sort(norm_b, axis=1)  # (gk, gn)
    # threshold per (i, k): nb >= tau / na  (na==0 ⇒ nothing passes unless tau<=0)
    thr = tau / jnp.maximum(norm_a, 1e-38)  # (gm, gk)
    counts = jax.vmap(
        lambda row, t: gn - jnp.searchsorted(row, t, side="left"),
        in_axes=(0, 1),
        out_axes=1,
    )(sorted_nb, thr)  # (gm, gk), each entry <= gn (int32-safe)
    # na == 0: products are 0; valid iff tau <= 0
    zero_a = norm_a <= 0.0
    counts = jnp.where(zero_a, jnp.where(tau <= 0.0, gn, 0), counts)
    if gm * gk * gn < 2 ** 31:
        return jnp.sum(counts, dtype=jnp.int32)  # exact
    acc = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.float32
    return jnp.sum(counts.astype(acc))


def valid_ratio_of(norm_a: jax.Array, norm_b: jax.Array, tau) -> jax.Array:
    """paper §3.5.2: valid ratio = Σ V[i,j] / BDIM³ (generalized to gm·gn·gk).

    The denominator is formed as a python float: gm·gk·gn overflows int32
    for large grids long before the arrays themselves are a problem.
    """
    gm, gk = norm_a.shape
    _, gn = norm_b.shape
    return count_valid(norm_a, norm_b, tau) / (float(gm) * float(gk) * float(gn))


# ---------------------------------------------------------------------------
# top-level SpAMM
# ---------------------------------------------------------------------------

def spamm(
    a: jax.Array,
    b: jax.Array,
    tau=None,
    *,
    valid_ratio=None,
    tile: int = 64,
    block_n: int = 1,
    backend: str = "auto",
    use_mxu_norm: bool = False,
    out_dtype=None,
    compute_dtype: str = "float32",
):
    """C ≈ A @ B with norm-gated tile skipping. Returns (C, SpammInfo).

    Exactly one of `tau` / `valid_ratio` must be given. Arbitrary shapes are
    zero-padded to tile multiples (paper §3) and the result is un-padded.
    One-shot plan+execute; to amortize the gating phase across repeated
    products, build the plan once with `repro.core.plan.plan` and call
    `repro.core.plan.execute` per product.

    `compute_dtype` selects the GEMM operand precision (float32 | bfloat16 |
    int8); accumulation is always f32 and the gate stays a superset of the
    f32 gate (norms from the quantized view, τ widened by the analytic
    quantization bound — repro.kernels.quantize).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    # the weight side pads N to tile·block_n, not just tile: super-column
    # grouping needs gn % block_n == 0 for ANY N (padded columns have zero
    # norms, so they never flip a super-column's gate on their own)
    ap, bp = pad_to_tile(a, tile), pad_to_tile(b, tile, tile * block_n)

    p = _plan.plan(
        ap, bp, tau,
        valid_ratio=valid_ratio,
        tile=tile, block_n=block_n, backend=backend,
        use_mxu_norm=use_mxu_norm,
        compute_dtype=compute_dtype,
    )
    c = _plan.execute(p, ap, bp, out_dtype=out_dtype)[:m, :n]
    frac = p.valid_fraction
    return c, SpammInfo(
        tau=p.tau,
        valid_fraction=frac,
        effective_flops=frac * (2.0 * m * k * n),
    )


# ---------------------------------------------------------------------------
# original recursive Algorithm 1 (oracle for the equivalence test)
# ---------------------------------------------------------------------------

def recursive_spamm(a: np.ndarray, b: np.ndarray, tau: float, leaf: int) -> np.ndarray:
    """Paper Algorithm 1, verbatim quad-tree recursion (numpy, test oracle).

    Square matrices with N a power-of-two multiple of `leaf`.
    """
    n = a.shape[0]
    assert a.shape == b.shape == (n, n)

    def fnorm(x):
        return float(np.sqrt(np.sum(np.asarray(x, np.float64) ** 2)))

    def rec(ab, bb):
        nn = ab.shape[0]
        if nn == leaf:
            return np.asarray(ab, np.float64) @ np.asarray(bb, np.float64)
        h = nn // 2
        c = np.zeros((nn, nn), np.float64)
        for i in (0, 1):
            for j in (0, 1):
                acc = np.zeros((h, h), np.float64)
                for k in (0, 1):
                    asub = ab[i * h:(i + 1) * h, k * h:(k + 1) * h]
                    bsub = bb[k * h:(k + 1) * h, j * h:(j + 1) * h]
                    if fnorm(asub) * fnorm(bsub) >= tau:
                        acc += rec(asub, bsub)
                c[i * h:(i + 1) * h, j * h:(j + 1) * h] = acc
        return c

    return rec(a, b)


# ---------------------------------------------------------------------------
# decay-matrix generators (paper §2.1 / §4.1)
# ---------------------------------------------------------------------------

def algebraic_decay(n: int, c: float = 0.1, lam: float = 0.1, seed=None) -> np.ndarray:
    """a_ij = c / (|i-j|^lam + 1); with seed, sign-randomized (keeps |a_ij|)."""
    d = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :]).astype(np.float64)
    m = (c / (d ** lam + 1.0)).astype(np.float32)
    if seed is not None:
        rng = np.random.default_rng(seed)
        m = m * rng.choice(np.float32([-1.0, 1.0]), size=m.shape)
    return m


def exponential_decay(n: int, c: float = 1.0, lam: float = 0.9, seed=None) -> np.ndarray:
    """|a_ij| <= c·lam^|i-j| (ergo-style matrices in §4.3.1 decay this way)."""
    d = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :]).astype(np.float64)
    m = (c * np.power(lam, d)).astype(np.float32)
    if seed is not None:
        rng = np.random.default_rng(seed)
        m = m * rng.uniform(0.5, 1.0, size=m.shape).astype(np.float32)
        m = m * rng.choice(np.float32([-1.0, 1.0]), size=m.shape)
    return m
