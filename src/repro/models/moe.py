"""Mixture-of-Experts with two production sharding strategies (DESIGN.md §5).

Both run inside shard_map (token dispatch must stay local to a data shard —
a pjit-level sort would become a global collective):

* impl="tp"  (mixtral-8x22b): every chip holds ALL experts, ff-dim sharded
  over `model`; local sort-based dispatch → grouped GEMM → psum(model) for
  the down-projection. No token movement at all.
* impl="ep"  (qwen2-moe): experts sharded over `model` (padded to a multiple
  of the axis size); tokens replicated over `model`, each chip computes only
  its expert subset and the disjoint contributions psum(model)-combine.

Dispatch is sort-based (linear), not one-hot einsum (quadratic in tokens):
top-k assignments are sorted by expert id, positions within an expert come
from a searchsorted over the sorted ids, capacity overflow drops (standard).
Router aux loss (switch-style load balance) is returned as a metric.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import MoEConfig
from repro.core.module import as_context, maybe_spamm_matmul, spamm_bmm_linear


def moe_params(key, cfg: MoEConfig, d_model: int, dtype, model_axis_size: int = 1):
    e = cfg.num_experts
    if cfg.impl == "ep":
        e = math.ceil(e / model_axis_size) * model_axis_size  # pad for EP
    ks = jax.random.split(key, 8)
    s_in, s_ff = 1.0 / math.sqrt(d_model), 1.0 / math.sqrt(cfg.expert_ff)
    p = {
        "router": jax.random.normal(ks[0], (d_model, cfg.num_experts), jnp.float32) * s_in,
        "w1": jax.random.normal(ks[1], (e, d_model, cfg.expert_ff), dtype) * s_in,
        "w3": jax.random.normal(ks[2], (e, d_model, cfg.expert_ff), dtype) * s_in,
        "w2": jax.random.normal(ks[3], (e, cfg.expert_ff, d_model), dtype) * s_ff,
    }
    if cfg.num_shared:
        p["shared"] = {
            "w1": jax.random.normal(ks[4], (d_model, cfg.shared_ff), dtype) * s_in,
            "w3": jax.random.normal(ks[5], (d_model, cfg.shared_ff), dtype) * s_in,
            "w2": jax.random.normal(ks[6], (cfg.shared_ff, d_model), dtype)
            * (1.0 / math.sqrt(cfg.shared_ff)),
            "gate": jax.random.normal(ks[7], (d_model, 1), jnp.float32) * s_in,
        }
    return p


def _dispatch(x, router_w, cfg: MoEConfig, capacity: int):
    """Local sort-based dispatch. x: (T, d). Returns routing tensors + aux."""
    t, d = x.shape
    k = cfg.top_k
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                            # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    flat_e = eidx.reshape(-1).astype(jnp.int32)                      # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(cfg.num_experts, dtype=jnp.int32),
                              side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = pos < capacity

    # switch aux loss: E * sum_e f_e * P_e
    f = jnp.mean(jax.nn.one_hot(eidx[..., 0], cfg.num_experts, dtype=jnp.float32), 0)
    pbar = jnp.mean(probs, axis=0)
    aux = cfg.num_experts * jnp.sum(f * pbar)
    return se, st, sg, pos, keep, aux


def _grouped_ffn(buf, w1, w3, w2, act, spamm_cfg):
    """buf: (E_loc, C, d) → (E_loc, C, d) via per-expert SwiGLU.

    With SpAMM enabled and `moe_bmm` set, the three grouped GEMMs run as
    batched (E, C, d) @ (E, d, ff) products through `core.plan.spamm_bmm`:
    one get-norm pass per operand, per-expert gating, weight-side plans
    shared with the context's cache. Otherwise (default / training) each
    expert goes through the vmapped `spamm_linear` custom-vjp path."""
    cdt = buf.dtype
    ctx = as_context(spamm_cfg)

    if ctx is not None and ctx.enable and getattr(ctx.cfg, "moe_bmm", False):
        g = spamm_bmm_linear(buf, w1.astype(cdt), ctx)
        u = spamm_bmm_linear(buf, w3.astype(cdt), ctx)
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
        return spamm_bmm_linear(h, w2.astype(cdt), ctx)

    def one(b, w1e, w3e, w2e):
        g = maybe_spamm_matmul(b, w1e.astype(cdt), ctx)
        u = maybe_spamm_matmul(b, w3e.astype(cdt), ctx)
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
        return maybe_spamm_matmul(h, w2e.astype(cdt), ctx)

    return jax.vmap(one)(buf, w1, w3, w2)


def _shared_ffn(params, x, act, spamm_cfg):
    cdt = x.dtype
    g = maybe_spamm_matmul(x, params["w1"].astype(cdt), spamm_cfg)
    u = maybe_spamm_matmul(x, params["w3"].astype(cdt), spamm_cfg)
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    out = maybe_spamm_matmul(h, params["w2"].astype(cdt), spamm_cfg)
    gate = jax.nn.sigmoid((x.astype(jnp.float32) @ params["gate"]))
    return out * gate.astype(cdt)


def moe_block(
    params: dict,
    x: jax.Array,             # (B, S, d), replicated over `model_axis`
    cfg: MoEConfig,
    act: str,
    *,
    mesh,
    batch_axes=("data",),
    model_axis: str = "model",
    spamm_cfg=None,
):
    """Returns (y, aux_loss). Runs as a shard_map over the full mesh."""
    b, s, d = x.shape
    nmodel = mesh.shape[model_axis]
    e_pad = params["w1"].shape[0]

    t_global = b * s
    ndata = 1
    for ax in (batch_axes or ()):
        ndata *= mesh.shape[ax]
    t_loc = t_global // ndata
    capacity = int(math.ceil(t_loc * cfg.top_k / cfg.num_experts * cfg.capacity_factor))
    capacity = max(4, -(-capacity // 4) * 4)

    if cfg.impl == "tp":
        w_specs = {
            "router": P(None, None),
            "w1": P(None, None, model_axis),
            "w3": P(None, None, model_axis),
            "w2": P(None, model_axis, None),
        }
    else:  # ep
        w_specs = {
            "router": P(None, None),
            "w1": P(model_axis, None, None),
            "w3": P(model_axis, None, None),
            "w2": P(model_axis, None, None),
        }
    if "shared" in params:
        w_specs["shared"] = {
            "w1": P(None, model_axis),
            "w3": P(None, model_axis),
            "w2": P(model_axis, None),
            "gate": P(None, None),
        }

    def local(p, xc):
        bl, sl, _ = xc.shape
        xt = xc.reshape(bl * sl, d)
        se, st, sg, pos, keep, aux = _dispatch(xt, p["router"], cfg, capacity)
        cdt = xc.dtype

        # NOTE on scatter indexing: over-capacity (and, in EP, foreign-expert)
        # tokens must be routed to OUT-OF-BOUNDS indices and dropped by
        # mode="drop". Clamping them onto a valid slot and writing zeros
        # would CLOBBER the legitimate token living in that slot (scatter
        # `set` order is unspecified) — a real bug this replaced.
        if cfg.impl == "tp":
            buf = jnp.zeros((e_pad, capacity, d), cdt)
            buf = buf.at[se, pos].set(xt[st], mode="drop")  # OOB pos dropped
            out = _grouped_ffn(buf, p["w1"], p["w3"], p["w2"], act, spamm_cfg)
            y = jnp.zeros((bl * sl, d), jnp.float32)
            y = y.at[st].add(
                out[se, jnp.minimum(pos, capacity - 1)].astype(jnp.float32)
                * (sg * keep)[:, None],   # dropped tokens contribute 0
                mode="drop",
            )
            y = jax.lax.psum(y, model_axis)  # combine ff-dim partials
        else:  # ep: each chip owns e_loc experts
            e_loc = e_pad // nmodel
            eoff = jax.lax.axis_index(model_axis) * e_loc
            le = se - eoff
            owned = (le >= 0) & (le < e_loc)
            mine = owned & keep
            buf = jnp.zeros((e_loc, capacity, d), cdt)
            buf = buf.at[jnp.where(owned, le, e_loc), pos].set(
                xt[st], mode="drop"   # foreign experts + OOB pos dropped
            )
            out = _grouped_ffn(buf, p["w1"], p["w3"], p["w2"], act, spamm_cfg)
            lec = jnp.clip(le, 0, e_loc - 1)
            y = jnp.zeros((bl * sl, d), jnp.float32)
            y = y.at[st].add(
                out[lec, jnp.minimum(pos, capacity - 1)].astype(jnp.float32)
                * (sg * mine)[:, None],   # foreign/dropped reads masked to 0
                mode="drop",
            )
            y = jax.lax.psum(y, model_axis)  # disjoint expert contributions

        if "shared" in p:
            ysh = _shared_ffn(p["shared"], xt, act, spamm_cfg)
            if cfg.impl == "tp":
                # shared ffn is ff-sharded too → its partial went into... no:
                # computed fully here with sharded w → psum needed
                ysh = jax.lax.psum(ysh.astype(jnp.float32), model_axis)
            else:
                ysh = jax.lax.psum(ysh.astype(jnp.float32), model_axis)
            y = y + ysh
        return y.reshape(bl, sl, d).astype(cdt), aux.reshape(1)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(w_specs, P(batch_axes, None, None)),
        out_specs=(P(batch_axes, None, None), P(batch_axes)),
    )
    # expert GEMMs tap inside the shard_map trace: suspend any open trace
    # buffer so their tracers can't leak into an outer-trace carry (these
    # taps report through the callback path instead)
    sctx = as_context(spamm_cfg)
    saved = sctx.suspend_trace_buffer() if sctx is not None else None
    try:
        y, aux = fn(params, x)
    finally:
        if sctx is not None:
            sctx.resume_trace_buffer(saved)
    return y, jnp.mean(aux)
