"""Decoder stack assembly: layer bodies, scan-over-layers, caches.

One generic stack covers all 10 archs:
  dense / vlm / audio : attention + MLP
  moe                 : attention + MoE block (shard_map inside the layer)
  ssm                 : Mamba2 block only
  hybrid              : 12 × (rec, rec, local-attn) groups + 2 rec tail,
                        each sub-layer followed by an MLP (Griffin residual
                        pattern: temporal-mix block and MLP block alternate)

Scan-over-layers keeps the HLO small (mandatory for the 512-chip dry-run);
per-layer FSDP all-gathers overlap with compute via the XLA scheduler.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.module import SpammContext, maybe_spamm_matmul
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_rope, embed, mlp, mlp_params, rms_norm


class NetCtx(NamedTuple):
    mesh: Mesh
    batch_axes: tuple = ("data",)
    model_axis: str = "model"

    def shard(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )


# ---------------------------------------------------------------------------
# attention layer
# ---------------------------------------------------------------------------

def attn_params(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hk * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hk * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), dtype) / math.sqrt(hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hk * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hk * hd,), jnp.float32)
    return p


def _qkv(p, x, cfg: ModelConfig, ctx: NetCtx, positions, spamm_cfg=None,
         frozen=None, require_frozen: bool = False):
    b, s, d = x.shape
    hq, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    cdt = x.dtype
    fz = frozen or {}
    q = maybe_spamm_matmul(x, p["wq"].astype(cdt), spamm_cfg,
                           frozen=fz.get("wq"), require_frozen=require_frozen,
                           site="wq")
    k = maybe_spamm_matmul(x, p["wk"].astype(cdt), spamm_cfg,
                           frozen=fz.get("wk"), require_frozen=require_frozen,
                           site="wk")
    v = maybe_spamm_matmul(x, p["wv"].astype(cdt), spamm_cfg,
                           frozen=fz.get("wv"), require_frozen=require_frozen,
                           site="wv")
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hk, hd)
    v = v.reshape(b, s, hk, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = ctx.shard(q, ctx.batch_axes, None, ctx.model_axis, None)
    return q, k, v


def attention_layer(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    positions: jax.Array,
    *,
    window: Optional[int] = None,
    spamm_cfg=None,
    return_kv: bool = False,
    frozen=None,
):
    q, k, v = _qkv(p, x, cfg, ctx, positions, spamm_cfg, frozen)
    o = attn_mod.flash_attention(
        q, k, v,
        causal=True,
        window=window,
        q_chunk=pcfg.attn_q_chunk,
        kv_chunk=pcfg.attn_kv_chunk,
    )
    o = o.reshape(*x.shape[:2], -1)
    out = maybe_spamm_matmul(o, p["wo"].astype(x.dtype), spamm_cfg,
                             frozen=(frozen or {}).get("wo"), site="wo")
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(
    p: dict,
    x: jax.Array,            # (B, 1, d)
    cache_k: jax.Array,      # (B, S, Hk, hd) — full or ring buffer
    cache_v: jax.Array,
    pos: jax.Array,          # scalar int32 (lockstep) or (B,) per-row index
                             # of the incoming token; per-row entries ≥ S are
                             # idle-slot sentinels — their cache writes DROP
                             # and their outputs are garbage the caller
                             # discards (chunked-engine slot scheduling)
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    *,
    window: Optional[int] = None,
    ring: bool = False,
    spamm_cfg=None,
    frozen=None,
):
    b = x.shape[0]
    hq, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = jnp.asarray(pos, jnp.int32)
    vector_pos = pos.ndim > 0
    posb = pos.reshape(b, 1) if vector_pos else jnp.full((b, 1), pos, jnp.int32)
    # decode gates only through frozen plans (require_frozen): re-tracing the
    # gate per decode step is never worth it, but a frozen weight side is
    q, k, v = _qkv(p, x, cfg, ctx, posb, spamm_cfg, frozen,
                   require_frozen=True)
    q1 = q[:, 0]  # (B, Hq, hd)
    if pcfg.decode_seq_shard and ctx.mesh is not None and ctx.mesh.shape[ctx.model_axis] > 1:
        if vector_pos:
            raise NotImplementedError(
                "decode_seq_shard expects a lockstep scalar position; "
                "per-row decode positions (chunked serving) need the "
                "unsharded decode path")
        o, cache_k, cache_v = attn_mod.decode_attention_seqsharded(
            q1, k, v, cache_k, cache_v, pos + 1,
            mesh=ctx.mesh, batch_axes=ctx.batch_axes, axis=ctx.model_axis,
            window=window, ring=ring,
        )
    elif vector_pos:
        # per-row scatter; mode="drop" discards rows whose position is out
        # of range, which is exactly the idle-slot sentinel contract. Per-row
        # positions REQUIRE a linear full-length cache (chunked serving pads
        # to max_len), so never apply the ring modulo here: for valid lanes
        # (pos < S) it is a no-op, while a sentinel (pos == max_len == S,
        # when sliding_window >= max_len keeps `ring` True) would wrap to
        # slot 0 and clobber a mid-prefill lane's K/V instead of dropping.
        slot = pos
        bi = jnp.arange(b)
        cache_k = cache_k.at[bi, slot].set(k[:, 0].astype(cache_k.dtype),
                                           mode="drop")
        cache_v = cache_v.at[bi, slot].set(v[:, 0].astype(cache_v.dtype),
                                           mode="drop")
        o = attn_mod.decode_attention(
            q1, cache_k, cache_v, pos + 1, window=window, ring=ring,
        )
    else:
        slot = (pos % cache_k.shape[1]) if ring else pos
        cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
        o = attn_mod.decode_attention(
            q1, cache_k, cache_v, pos + 1, window=window, ring=ring,
        )
    out = maybe_spamm_matmul(
        o.reshape(b, 1, hq * hd), p["wo"].astype(x.dtype), spamm_cfg,
        frozen=(frozen or {}).get("wo"), require_frozen=True, site="wo")
    return out, (cache_k, cache_v)


def attention_prefill_chunk(
    p: dict,
    x: jax.Array,            # (B, C, d) — one tile-aligned prompt chunk
    cache_k: jax.Array,      # (B, S, Hk, hd) — LINEAR cache (no ring)
    cache_v: jax.Array,
    positions: jax.Array,    # (B, C) int32 absolute positions; entries ≥ S
                             # are sentinels: the K/V write DROPS and the
                             # row's output is garbage the caller discards
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    *,
    window: Optional[int] = None,
    spamm_cfg=None,
    frozen=None,
):
    """One chunk of position-offset prefill: project/rope the chunk at its
    absolute positions, scatter K/V into the linear cache, then flash-attend
    the chunk's queries against the WHOLE cache with a per-row causal bias.

    Bit-parity contract with one-shot prefill (tile-aligned equal lengths):
    cache slots at/beyond each row's position are fully masked, and a fully
    masked KV block is bitwise neutral in the online softmax (NEG_INF
    absorbs finite f32 scores exactly; exp underflows to exact 0 and the
    rescale factor is exp(0)=1), so attending over max_len cache slots
    chunk by chunk reproduces the one-shot scores block for block."""
    b, c, _ = x.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _qkv(p, x, cfg, ctx, positions, spamm_cfg, frozen)
    bi = jnp.arange(b)[:, None]
    cache_k = cache_k.at[bi, positions].set(k.astype(cache_k.dtype),
                                            mode="drop")
    cache_v = cache_v.at[bi, positions].set(v.astype(cache_v.dtype),
                                            mode="drop")
    o = attn_mod.flash_attention(
        q, cache_k, cache_v,
        causal=True,
        window=window,
        q_chunk=pcfg.attn_q_chunk,
        kv_chunk=pcfg.attn_kv_chunk,
        q_offset=positions[:, 0],
    )
    out = maybe_spamm_matmul(
        o.reshape(b, c, hq * hd), p["wo"].astype(x.dtype), spamm_cfg,
        frozen=(frozen or {}).get("wo"), site="wo")
    return out, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def layer_params(key, cfg: ModelConfig, dtype, kind: str, model_axis_size: int):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {
            "ln": jnp.zeros((cfg.d_model,), jnp.float32),
            "ssm": ssm_mod.ssm_params(ks[0], cfg.ssm, cfg.d_model, dtype),
        }
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if kind == "rec":
        p["mix"] = rglru_mod.rglru_params(ks[0], cfg.rglru, cfg.d_model, dtype)
    else:
        p["mix"] = attn_params(ks[0], cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_params(ks[1], cfg.moe, cfg.d_model, dtype,
                                      model_axis_size)
    else:
        p["mlp"] = mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _tap_ctx(spamm_cfg) -> Optional[SpammContext]:
    """The SpammContext behind what the stack threads, for label bracketing
    (set_layer/swap_layer). None when taps can't be labeled — a raw
    SpammConfig means maybe_spamm_matmul builds throwaway contexts, so
    there is no shared object to label through."""
    return spamm_cfg if isinstance(spamm_cfg, SpammContext) else None


def _ffn(p, h, cfg: ModelConfig, ctx: NetCtx, spamm_cfg, frozen=None,
         require_frozen: bool = False):
    """MLP or MoE sub-layer on normalized input h. Returns (out, aux).

    MoE blocks keep the traced gating path (their expert buffers live
    inside shard_map; frozen plans cover the dense attention/MLP GEMMs)."""
    if cfg.moe is not None:
        # MoE taps fire inside shard_map: an enclosing scan's layer-index
        # tracer must not be closed over there, so the label is cleared for
        # the block (those taps report layer=-1, like every shard_map tap).
        tctx = _tap_ctx(spamm_cfg)
        prev = tctx.swap_layer(None) if tctx is not None else None
        try:
            return moe_mod.moe_block(
                p["moe"], h, cfg.moe, cfg.act,
                mesh=ctx.mesh, batch_axes=ctx.batch_axes,
                model_axis=ctx.model_axis,
                spamm_cfg=None if require_frozen else spamm_cfg,
            )
        finally:
            if tctx is not None:
                tctx.swap_layer(prev)
    return mlp(p["mlp"], h, cfg.act, spamm_cfg, frozen,
               require_frozen), jnp.float32(0.0)


def layer_fwd(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    positions: jax.Array,
    kind: str,                  # "attn" | "rec" | "ssm"
    *,
    spamm_cfg=None,
    collect_cache: bool = False,
    frozen=None,
):
    """One residual layer. Returns (x, aux, cache). `frozen` is this
    layer's {"mix": {...}, "mlp": {...}} dict of FrozenPlan jit inputs."""
    fz = frozen or {}
    if pcfg.seq_shard_acts and x.shape[1] > 1:
        # Megatron-SP: residual stream seq-sharded over the model axis; GSPMD
        # turns the TP psum into reduce-scatter + all-gather (half the wire
        # bytes) and shards norms/elementwise over seq.
        x = ctx.shard(x, ctx.batch_axes, ctx.model_axis, None)
    else:
        x = ctx.shard(x, ctx.batch_axes, None, None)
    if kind == "ssm":
        h, cache = ssm_mod.ssm_block(p["ssm"], rms_norm(x, p["ln"], cfg.norm_eps),
                                     cfg.ssm, norm_eps=cfg.norm_eps)
        return x + h, jnp.float32(0.0), (cache if collect_cache else None)

    window = cfg.sliding_window if kind == "attn" else None
    if kind == "attn":
        if collect_cache:
            h, (k, v) = attention_layer(
                p["mix"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, pcfg, ctx,
                positions, window=window, spamm_cfg=spamm_cfg, return_kv=True,
                frozen=fz.get("mix"),
            )
            cache = {"k": k, "v": v}
        else:
            h = attention_layer(
                p["mix"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, pcfg, ctx,
                positions, window=window, spamm_cfg=spamm_cfg,
                frozen=fz.get("mix"),
            )
            cache = None
    else:  # rec
        h, cache = rglru_mod.rglru_block(
            p["mix"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg.rglru
        )
        cache = cache if collect_cache else None
    x = x + h
    f, aux = _ffn(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg, ctx, spamm_cfg,
                  fz.get("mlp"))
    return x + f, aux, cache


def layer_prefill_chunk(
    p: dict,
    x: jax.Array,               # (B, C, d)
    cache: dict,
    positions: jax.Array,       # (B, C) absolute positions (sentinels ≥ S)
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    *,
    spamm_cfg=None,
    frozen=None,
):
    """One residual layer of chunked prefill: attention writes the chunk's
    K/V into the linear cache at its absolute positions; the FFN is
    stateless per position, so it is the plain prefill body. Only "attn"
    stacks chunk — recurrent state (ssm/rec) would have to thread through
    every chunk carry, which is the decode path's job."""
    fz = frozen or {}
    x = ctx.shard(x, ctx.batch_axes, None, None)
    h, (ck, cv) = attention_prefill_chunk(
        p["mix"], rms_norm(x, p["ln1"], cfg.norm_eps), cache["k"], cache["v"],
        positions, cfg, pcfg, ctx, window=cfg.sliding_window,
        spamm_cfg=spamm_cfg, frozen=fz.get("mix"),
    )
    new = dict(cache, k=ck, v=cv)
    x = x + h
    f, _ = _ffn(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg, ctx, spamm_cfg,
                fz.get("mlp"))
    return x + f, new


def layer_decode(
    p: dict,
    x: jax.Array,               # (B, 1, d)
    cache: dict,
    pos: jax.Array,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    kind: str,
    *,
    spamm_cfg=None,
    frozen=None,
):
    fz = frozen or {}
    if kind == "ssm":
        h, new = ssm_mod.ssm_decode_step(
            p["ssm"], rms_norm(x[:, 0], p["ln"], cfg.norm_eps), cache, cfg.ssm,
            norm_eps=cfg.norm_eps,
        )
        return x + h[:, None], new

    if kind == "attn":
        # ring buffer iff the cache is exactly the sliding window (static)
        ring = (
            cfg.sliding_window is not None
            and cache["k"].shape[1] <= cfg.sliding_window
        )
        h, (ck, cv) = attention_decode(
            p["mix"], rms_norm(x, p["ln1"], cfg.norm_eps),
            cache["k"], cache["v"], pos, cfg, pcfg, ctx,
            window=cfg.sliding_window, ring=ring,
            spamm_cfg=spamm_cfg, frozen=fz.get("mix"),
        )
        new = dict(cache, k=ck, v=cv)
    else:
        h1, new = rglru_mod.rglru_decode_step(
            p["mix"], rms_norm(x[:, 0], p["ln1"], cfg.norm_eps), cache, cfg.rglru
        )
        h = h1[:, None]
    x = x + h
    f, _ = _ffn(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg, ctx, spamm_cfg,
                fz.get("mlp"), require_frozen=True)
    return x + f, new


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def hybrid_pattern(cfg: ModelConfig):
    """(n_groups, group_kinds, tail_kinds) for the hybrid arch."""
    pat = cfg.rglru.block_pattern  # ("rec", "rec", "attn")
    kinds = {"rec": "rec", "attn": "attn"}
    glen = len(pat)
    n_groups = cfg.num_layers // glen
    tail = cfg.num_layers - n_groups * glen
    return n_groups, tuple(kinds[k] for k in pat), ("rec",) * tail


def stack_kinds(cfg: ModelConfig):
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "hybrid":
        return "hybrid"
    return "attn"


def _remat(fn, pcfg: ParallelConfig):
    if pcfg.remat == "none":
        return fn
    if pcfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def stack_fwd(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    positions: jax.Array,
    *,
    spamm_cfg=None,
    collect_spamm_stats: bool = False,
):
    """Run all layers (train/loss path, no caches). Returns (x, aux), or
    (x, aux, (frac_sum, gemm_count, layer_frac_sums, layer_gemm_counts))
    with `collect_spamm_stats` — the last two are (num_layers,) f32 arrays
    of per-layer fraction sums / gated-GEMM counts (the per-layer
    attribution the grad path cannot get from callbacks).

    The stats ride the scan carry/ys as traced values (SpammContext's trace
    buffer), NOT io_callbacks — callbacks are dropped under
    grad-of-custom_vjp, dataflow is not, so the train step can export the
    same per-GEMM fractions the serving engine taps. MoE expert GEMMs trace
    inside shard_map and are excluded (see moe_block)."""
    kind = stack_kinds(cfg)
    collect = (collect_spamm_stats and spamm_cfg is not None
               and spamm_cfg.enable)

    def tapped_layer(p, h, k):
        """layer_fwd with its gated-GEMM taps captured as traced values."""
        if not collect:
            h, a, _ = layer_fwd(p, h, cfg, pcfg, ctx, positions, k,
                                spamm_cfg=spamm_cfg)
            return h, a, jnp.float32(0.0), jnp.float32(0.0)
        spamm_cfg.begin_trace_buffer()
        try:
            h, a, _ = layer_fwd(p, h, cfg, pcfg, ctx, positions, k,
                                spamm_cfg=spamm_cfg)
        finally:
            fracs = spamm_cfg.drain_trace_buffer()
        vs = jnp.float32(0.0)
        for f in fracs:
            vs = vs + f
        return h, a, vs, jnp.float32(len(fracs))

    zero = jnp.float32(0.0)

    if kind == "hybrid":
        n_groups, gkinds, tail = hybrid_pattern(cfg)

        def gbody(carry, p):
            h, aux, vs, vc = carry
            ss, cs = [], []
            for i, k in enumerate(gkinds):
                h, a, s, c = tapped_layer(p[f"l{i}"], h, k)
                aux, vs, vc = aux + a, vs + s, vc + c
                ss.append(s)
                cs.append(c)
            return (h, aux, vs, vc), (jnp.stack(ss), jnp.stack(cs))

        (x, aux, vs, vc), (gss, gcs) = jax.lax.scan(
            _remat(gbody, pcfg), (x, zero, zero, zero), params["groups"]
        )
        # per-layer ys come out (n_groups, glen); flatten to stack order and
        # append the unrolled tail
        lvs = [gss.reshape(-1)]
        lvc = [gcs.reshape(-1)]
        for i, k in enumerate(tail):
            x, a, s, c = tapped_layer(params["tail"][f"l{i}"], x, k)
            aux, vs, vc = aux + a, vs + s, vc + c
            lvs.append(s[None])
            lvc.append(c[None])
        if collect:
            return x, aux, (vs, vc, jnp.concatenate(lvs),
                            jnp.concatenate(lvc))
        return x, aux

    def body(carry, p):
        h, aux, vs, vc = carry
        h, a, s, c = tapped_layer(p, h, kind)
        return (h, aux + a, vs + s, vc + c), (s, c)

    if pcfg.scan_layers:
        (x, aux, vs, vc), (lvs, lvc) = jax.lax.scan(
            _remat(body, pcfg), (x, zero, zero, zero), params["layers"]
        )
    else:
        aux = vs = vc = zero
        ls, lc = [], []
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda t: t[i], params["layers"])
            (x, aux, vs, vc), (s, c) = _remat(body, pcfg)((x, aux, vs, vc), p)
            ls.append(s)
            lc.append(c)
        lvs, lvc = jnp.stack(ls), jnp.stack(lc)
    return (x, aux, (vs, vc, lvs, lvc)) if collect else (x, aux)


def stack_prefill(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    positions: jax.Array,
    cache_len: int,
    *,
    spamm_cfg=None,
    frozen=None,
):
    """Forward + collect caches. Returns (x, cache_pytree).

    `spamm_cfg` is the SpammContext the serving engine threads so prefill
    GEMMs run through the plan/execute pipeline like the train forward.
    `frozen` mirrors the params structure at the gated-weight subtrees with
    FrozenPlan jit inputs (stacked per layer under "layers"/"groups" — they
    ride the layer scan as a second xs); {} / missing keys fall back to the
    traced gate.

    When `spamm_cfg` is a SpammContext, each layer's index rides the scan
    as an extra xs and is fed to `set_layer` — the taps inside the scanned
    body then report per-layer labels at execution time."""
    kind = stack_kinds(cfg)
    s = x.shape[1]
    fz = frozen or {}
    tctx = _tap_ctx(spamm_cfg)

    def trim(c):
        """Ring-ify sliding-window KV caches: token t lives at slot t % W."""
        if c is None:
            return None
        if "k" in c and c["k"].shape[1] > cache_len:
            w = cache_len
            tail_k, tail_v = c["k"][:, -w:], c["v"][:, -w:]
            shift = s % w  # tail index i holds token (s - w + i) → slot (s+i)%w
            if shift:
                tail_k = jnp.roll(tail_k, shift, axis=1)
                tail_v = jnp.roll(tail_v, shift, axis=1)
            c = dict(c, k=tail_k, v=tail_v)
        return c

    if kind == "hybrid":
        n_groups, gkinds, tail = hybrid_pattern(cfg)
        glen = len(gkinds)

        def gbody(h, pfg):
            p, f, g = pfg
            caches = {}
            for i, k in enumerate(gkinds):
                if tctx is not None:
                    tctx.set_layer(g * glen + i)
                h, _, c = layer_fwd(p[f"l{i}"], h, cfg, pcfg, ctx, positions, k,
                                    spamm_cfg=spamm_cfg, collect_cache=True,
                                    frozen=f.get(f"l{i}"))
                caches[f"l{i}"] = trim(c)
            return h, caches

        try:
            x, gcaches = jax.lax.scan(
                gbody, x, (params["groups"], fz.get("groups", {}),
                           jnp.arange(n_groups)))
            tcaches = {}
            for i, k in enumerate(tail):
                if tctx is not None:
                    tctx.set_layer(n_groups * glen + i)
                x, _, c = layer_fwd(params["tail"][f"l{i}"], x, cfg, pcfg, ctx,
                                    positions, k, spamm_cfg=spamm_cfg,
                                    collect_cache=True,
                                    frozen=fz.get("tail", {}).get(f"l{i}"))
                tcaches[f"l{i}"] = trim(c)
        finally:
            if tctx is not None:
                tctx.set_layer(None)
        return x, {"groups": gcaches, "tail": tcaches}

    def body(h, pfl):
        p, f, li = pfl
        if tctx is not None:
            tctx.set_layer(li)
        h, _, c = layer_fwd(p, h, cfg, pcfg, ctx, positions, kind,
                            spamm_cfg=spamm_cfg, collect_cache=True,
                            frozen=f)
        return h, trim(c)

    try:
        x, caches = jax.lax.scan(body, x, (params["layers"],
                                           fz.get("layers", {}),
                                           jnp.arange(cfg.num_layers)))
    finally:
        if tctx is not None:
            tctx.set_layer(None)
    return x, {"layers": caches}


def stack_prefill_chunk(
    params: dict,
    x: jax.Array,          # (B, C, d) — one chunk of embedded prompt tokens
    cache: dict,
    positions: jax.Array,  # (B, C) absolute positions (sentinels ≥ max_len)
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    *,
    spamm_cfg=None,
    frozen=None,
):
    """Chunked prefill over the layer stack: like `stack_decode`, the decode
    caches ride the scan as xs and come back as ys, so each chunk runs at
    ONE static (B, C) shape regardless of where in the prompt it lands.
    Attention ("attn") stacks only — ssm/hybrid recurrent state cannot
    resume from a position offset without threading the whole state chain.

    Layer labels ride the scan like `stack_prefill`'s."""
    kind = stack_kinds(cfg)
    if kind != "attn":
        raise NotImplementedError(
            f"chunked prefill covers stateless-FFN attention stacks only "
            f"(got stack kind {kind!r}: recurrent prefill state does not "
            f"checkpoint at a chunk boundary)")
    fz = frozen or {}
    tctx = _tap_ctx(spamm_cfg)

    def body(h, pcf):
        p, c, f, li = pcf
        if tctx is not None:
            tctx.set_layer(li)
        h, nc = layer_prefill_chunk(p, h, c, positions, cfg, pcfg, ctx,
                                    spamm_cfg=spamm_cfg, frozen=f)
        return h, nc

    try:
        x, caches = jax.lax.scan(body, x, (params["layers"], cache["layers"],
                                           fz.get("layers", {}),
                                           jnp.arange(cfg.num_layers)))
    finally:
        if tctx is not None:
            tctx.set_layer(None)
    return x, {"layers": caches}


def stack_decode(
    params: dict,
    x: jax.Array,          # (B, 1, d)
    cache: dict,
    pos: jax.Array,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    ctx: NetCtx,
    *,
    spamm_cfg=None,
    frozen=None,
):
    """Decode gating is frozen-plan-only: sites with a FrozenPlan run the
    compiled work-list, sites without fall back to dense (require_frozen in
    `layer_decode`) — per-step re-tracing of the gate is never paid.

    Layer labels ride the scan like `stack_prefill`'s."""
    kind = stack_kinds(cfg)
    fz = frozen or {}
    tctx = _tap_ctx(spamm_cfg)

    if kind == "hybrid":
        n_groups, gkinds, tail = hybrid_pattern(cfg)
        glen = len(gkinds)

        def gbody(h, pcfg_):
            p, c, f, g = pcfg_
            newc = {}
            for i, k in enumerate(gkinds):
                if tctx is not None:
                    tctx.set_layer(g * glen + i)
                h, nc = layer_decode(p[f"l{i}"], h, c[f"l{i}"], pos, cfg, pcfg,
                                     ctx, k, spamm_cfg=spamm_cfg,
                                     frozen=f.get(f"l{i}"))
                newc[f"l{i}"] = nc
            return h, newc

        try:
            x, gcaches = jax.lax.scan(
                gbody, x, (params["groups"], cache["groups"],
                           fz.get("groups", {}), jnp.arange(n_groups)))
            tcaches = {}
            for i, k in enumerate(tail):
                if tctx is not None:
                    tctx.set_layer(n_groups * glen + i)
                x, nc = layer_decode(params["tail"][f"l{i}"], x,
                                     cache["tail"][f"l{i}"],
                                     pos, cfg, pcfg, ctx, k,
                                     spamm_cfg=spamm_cfg,
                                     frozen=fz.get("tail", {}).get(f"l{i}"))
                tcaches[f"l{i}"] = nc
        finally:
            if tctx is not None:
                tctx.set_layer(None)
        return x, {"groups": gcaches, "tail": tcaches}

    def body(h, pcf):
        p, c, f, li = pcf
        if tctx is not None:
            tctx.set_layer(li)
        h, nc = layer_decode(p, h, c, pos, cfg, pcfg, ctx, kind,
                             spamm_cfg=spamm_cfg, frozen=f)
        return h, nc

    try:
        x, caches = jax.lax.scan(body, x, (params["layers"], cache["layers"],
                                           fz.get("layers", {}),
                                           jnp.arange(cfg.num_layers)))
    finally:
        if tctx is not None:
            tctx.set_layer(None)
    return x, {"layers": caches}
