"""Attention for the zoo: chunked-flash (training/prefill) and decode paths.

* `flash_attention` — online-softmax over KV chunks inside a q-chunk scan;
  never materializes an (Sq, Skv) score tensor (required for 32k prefill).
  Supports GQA (q heads grouped onto kv heads), causal masking, and sliding
  windows. For windowed attention the KV range per q chunk is statically
  bounded (dynamic_slice of width window+q_chunk) → linear-time SWA/local
  attention for mixtral/recurrentgemma.
* `decode_attention` — single-token attention against a (B, S, Hk, D) cache.
* `decode_attention_seqsharded` — flash-decoding style shard_map: the KV
  cache is sharded along SEQUENCE over the `model` mesh axis (works for any
  kv-head count incl. MQA kv=1), each chip computes a partial softmax over
  its slice, partials merge with an LSE psum (DESIGN.md §5).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

NEG_INF = -1e30


def _mask_bias(qpos, kpos, window: Optional[int], kv_limit: Optional[int] = None):
    """(..., q, k) additive bias: causal + optional sliding window.

    `qpos` may carry leading batch dims — chunked prefill hands per-row
    absolute positions (B, q) and gets a (B, q, k) bias back."""
    qp = qpos[..., :, None]
    ok = kpos <= qp
    if window is not None:
        ok &= kpos > (qp - window)
    if kv_limit is not None:
        ok &= kpos < kv_limit
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_chunk(q, k, v, bias, scale):
    """q: (B,qc,Hk,G,D) k/v: (B,kc,Hk,D) bias: (qc,kc) or batched
    (B,qc,kc) → partial (o,m,l)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    if getattr(bias, "ndim", 0) == 3:      # per-row bias → (B,1,1,qc,kc)
        bias = bias[:, None, None]
    s = s * scale + bias
    m = jnp.max(s, axis=-1)                       # (B,Hk,G,q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                       # (B,Hk,G,q)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def _causal_flash_packed(q5, k4, v4, scale, chunk):
    """Causal flash over ONLY the lower-triangular (iq, ik≤iq) chunk pairs.

    The masked-full scan computes nq·nk block products and masks half away —
    2× wasted FLOPs *and* probs traffic. Here a flat scan walks the
    nq(nq+1)/2 valid pairs (statically enumerated, so the HLO while has a
    known trip count); only diagonal blocks apply the causal mask. Running
    (o, m, l) carry resets at each row start; normalized row outputs are
    emitted at row ends and gathered afterwards.
    """
    b, nq, qc, hk, g, d = q5.shape
    nk = k4.shape[1]
    assert nq == nk and k4.shape[2] == qc

    pairs = [(iq, ik) for iq in range(nq) for ik in range(iq + 1)]
    t_iq = jnp.asarray([p[0] for p in pairs], jnp.int32)
    t_ik = jnp.asarray([p[1] for p in pairs], jnp.int32)
    row_start = jnp.asarray([p[0] == p[1] == 0 or p[1] == 0 for p in pairs])
    row_end = jnp.asarray([p[0] == p[1] for p in pairs])
    end_idx = jnp.asarray([i for i, p in enumerate(pairs) if p[0] == p[1]],
                          jnp.int32)

    pos = jnp.arange(qc)
    diag_bias = jnp.where(pos[:, None] >= pos[None, :], 0.0, NEG_INF).astype(
        jnp.float32)

    def body(carry, xs):
        o_acc, m_acc, l_acc = carry
        iq, ik, start, end = xs
        # fresh row → reset the running softmax state
        o_acc = jnp.where(start, 0.0, o_acc)
        m_acc = jnp.where(start, NEG_INF, m_acc)
        l_acc = jnp.where(start, 0.0, l_acc)
        qcb = jax.lax.dynamic_index_in_dim(q5, iq, axis=1, keepdims=False)
        kcb = jax.lax.dynamic_index_in_dim(k4, ik, axis=1, keepdims=False)
        vcb = jax.lax.dynamic_index_in_dim(v4, ik, axis=1, keepdims=False)
        bias = jnp.where(iq == ik, diag_bias, 0.0)  # off-diag fully valid
        o, m, l = _attend_chunk(qcb, kcb, vcb, bias, scale)
        m_new = jnp.maximum(m_acc, m)
        r_old = jnp.exp(m_acc - m_new)
        r_new = jnp.exp(m - m_new)
        o_acc = o_acc * r_old[..., None] + o * r_new[..., None]
        l_acc = l_acc * r_old + l * r_new
        out = jnp.where(end, o_acc / jnp.maximum(l_acc, 1e-30)[..., None], 0.0)
        return (o_acc, m_acc * 0 + m_new, l_acc), out.astype(q5.dtype)

    o0 = jnp.zeros((b, hk, g, qc, d), jnp.float32)
    m0 = jnp.full((b, hk, g, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, qc), jnp.float32)
    _, outs = jax.lax.scan(body, (o0, m0, l0), (t_iq, t_ik, row_start, row_end))
    o = outs[end_idx]  # (nq, B, hk, g, qc, D)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qc, hk * g, d)
    return o


def flash_attention(
    q: jax.Array,            # (B, Sq, Hq, D)
    k: jax.Array,            # (B, Skv, Hk, D)
    v: jax.Array,            # (B, Skv, Hk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset=0,              # absolute position of q[0] (prefill continuation):
                             # static int, traced scalar, or (B,) per-row array
    packed: bool = True,     # pair-packed causal scan (skips masked blocks)
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hk, _ = k.shape
    g = hq // hk
    scale = 1.0 / math.sqrt(d)
    # only a STATIC offset can drive the banded dynamic-slice window path or
    # the packed lower-triangular scan; traced/per-row offsets take the
    # general kv-scan with the window folded into the additive bias
    off_static = isinstance(q_offset, int)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad to chunk multiples (padded q rows discarded; padded kv masked out)
    sq_real, skv_real = sq, skv
    pq, pk = (-sq) % q_chunk, (-skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        sq += pq
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        skv += pk
    kv_limit = skv_real if pk else None
    nq = sq // q_chunk
    q5 = q.reshape(b, nq, q_chunk, hk, g, d)

    if window is not None and off_static:
        # static KV band per q chunk: [q_start - window + 1, q_start + q_chunk)
        band = window + q_chunk

        def per_q(iq, qc):
            q_start = iq * q_chunk + q_offset
            lo = jnp.clip(q_start - window + 1, 0, skv - band) if skv >= band else 0
            kc = jax.lax.dynamic_slice_in_dim(k, lo, min(band, skv), axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, lo, min(band, skv), axis=1)
            qpos = q_start + jnp.arange(q_chunk)
            kpos = lo + jnp.arange(min(band, skv))
            bias = _mask_bias(qpos, kpos, window, kv_limit)
            o, m, l = _attend_chunk(qc, kc, vc, bias, scale)
            return o / jnp.maximum(l, 1e-30)[..., None]

        def scan_body(_, xs):
            iq, qc = xs
            return None, per_q(iq, qc)

        _, o = jax.lax.scan(scan_body, None, (jnp.arange(nq), q5.swapaxes(0, 1)))
        o = o.swapaxes(0, 1)  # (B, nq, Hk, G, qc, D)
        o = o.transpose(0, 1, 4, 2, 3, 5).reshape(b, sq, hq, d)
        return o[:, :sq_real].astype(q.dtype)

    nk = skv // kv_chunk
    k4 = k.reshape(b, nk, kv_chunk, hk, d)
    v4 = v.reshape(b, nk, kv_chunk, hk, d)

    if (
        causal
        and packed
        and off_static
        and q_offset == 0
        and sq == skv
        and q_chunk == kv_chunk
        and pq == 0
        and pk == 0
    ):
        return _causal_flash_packed(q5, k4, v4, scale, q_chunk)

    def per_q(iq, qc):
        # (qc,) for scalar offsets, (B, qc) for per-row offsets — _mask_bias
        # and _attend_chunk broadcast either shape
        qpos = jnp.asarray(q_offset)[..., None] + iq * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, xs):
            ik, kc, vc = xs
            o_acc, m_acc, l_acc = carry
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            if causal or kv_limit is not None:
                bias = _mask_bias(qpos, kpos, window, kv_limit)
                if not causal:
                    bias = _mask_bias(jnp.full((q_chunk,), skv), kpos, None, kv_limit)
            else:
                bias = jnp.float32(0.0)
            o, m, l = _attend_chunk(qc, kc, vc, bias, scale)
            m_new = jnp.maximum(m_acc, m)
            r_old = jnp.exp(m_acc - m_new)
            r_new = jnp.exp(m - m_new)
            o_acc = o_acc * r_old[..., None] + o * r_new[..., None]
            l_acc = l_acc * r_old + l * r_new
            return (o_acc, m_new, l_acc), None

        o0 = jnp.zeros((b, hk, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, hk, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_body, (o0, m0, l0), (jnp.arange(nk), k4.swapaxes(0, 1), v4.swapaxes(0, 1))
        )
        return o / jnp.maximum(l, 1e-30)[..., None]

    def scan_body(_, xs):
        iq, qc = xs
        return None, per_q(iq, qc)

    _, o = jax.lax.scan(scan_body, None, (jnp.arange(nq), q5.swapaxes(0, 1)))
    o = o.swapaxes(0, 1)
    o = o.transpose(0, 1, 4, 2, 3, 5).reshape(b, sq, hq, d)
    return o[:, :sq_real].astype(q.dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _slot_positions(slots, lb, ring, cache_len):
    """Token position held by each cache slot, per batch row → (B, S_loc).

    Linear cache: slot s holds token s. Ring cache (sliding window W): the
    newest token is p = lb-1; slot s holds t = p - ((p - s) mod W); negative
    → slot never written.
    """
    if not ring:
        return jnp.broadcast_to(slots[None, :], (lb.shape[0], slots.shape[0]))
    p = (lb - 1)[:, None]
    return p - jnp.mod(p - slots[None, :], cache_len)


def _decode_partial(q4, k_loc, v_loc, lb, window, slots, scale, ring, cache_len):
    kpos = _slot_positions(slots, lb, ring, cache_len)   # (B, S_loc)
    scores = jnp.einsum("bhgd,bshd->bhgs", q4, k_loc,
                        preferred_element_type=jnp.float32) * scale
    valid = (kpos < lb[:, None]) & (kpos >= 0)
    if window is not None:
        valid &= kpos >= (lb[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_loc.dtype), v_loc,
                   preferred_element_type=jnp.float32)
    return o, m, l


def decode_attention(
    q: jax.Array,        # (B, Hq, D) — one new token per sequence
    k_cache: jax.Array,  # (B, S, Hk, D)
    v_cache: jax.Array,  # (B, S, Hk, D)
    length,              # scalar or (B,): number of valid token positions
    *,
    window: Optional[int] = None,
    ring: bool = False,  # cache is a sliding-window ring buffer
) -> jax.Array:
    b, hq, d = q.shape
    _, s, hk, _ = k_cache.shape
    g = hq // hk
    scale = 1.0 / math.sqrt(d)
    q4 = q.reshape(b, hk, g, d)
    length = jnp.asarray(length)
    lb = length if length.ndim else jnp.broadcast_to(length, (b,))
    o, m, l = _decode_partial(
        q4, k_cache, v_cache, lb, window, jnp.arange(s), scale, ring, s
    )
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(b, hq, d).astype(q.dtype)


def _local_cache_update(cache_loc, new_val, slot, offset, s_loc):
    """Write (B,1,Hk,D) new_val at global slot `slot` iff it lands in this
    shard's [offset, offset+s_loc) slice — local slice/select/update only
    (a pjit-level DUS at a traced index makes GSPMD rewrite the whole cache
    per layer; this keeps it O(token) instead of O(cache))."""
    loc = slot - offset
    in_range = (loc >= 0) & (loc < s_loc)
    locc = jnp.clip(loc, 0, s_loc - 1)
    old = jax.lax.dynamic_slice_in_dim(cache_loc, locc, 1, axis=1)
    val = jnp.where(in_range, new_val.astype(cache_loc.dtype), old)
    return jax.lax.dynamic_update_slice_in_dim(cache_loc, val, locc, axis=1)


def decode_attention_seqsharded(
    q: jax.Array,        # (B, Hq, D) replicated over `axis`
    k_new: jax.Array,    # (B, 1, Hk, D) — this step's key (pre-roped)
    v_new: jax.Array,
    k_cache: jax.Array,  # (B, S, Hk, D) sharded on S over `axis`
    v_cache: jax.Array,
    length,              # scalar/(B,): tokens valid AFTER this update
    *,
    mesh,
    batch_axes=("data",),
    axis: str = "model",
    window: Optional[int] = None,
    ring: bool = False,
):
    """Flash-decoding: local cache update + partial softmax per KV slice,
    merged with an LSE psum. Returns (out, k_cache, v_cache)."""
    b, hq, d = q.shape
    _, s, hk, _ = k_cache.shape
    g = hq // hk
    scale = 1.0 / math.sqrt(d)
    nshard = mesh.shape[axis]
    s_loc = s // nshard

    def local(qc, knc, vnc, kc, vc, lb):
        idx = jax.lax.axis_index(axis)
        off = idx * s_loc
        pos = lb[0] - 1                      # uniform decode position
        slot = jnp.mod(pos, s) if ring else pos
        kc = _local_cache_update(kc, knc, slot, off, s_loc)
        vc = _local_cache_update(vc, vnc, slot, off, s_loc)
        slots = off + jnp.arange(s_loc)
        q4 = qc.reshape(qc.shape[0], hk, g, d)
        o, m, l = _decode_partial(q4, kc, vc, lb, window, slots, scale,
                                  ring, s)
        m_g = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis)
        o_g = jax.lax.psum(o * corr[..., None], axis)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(qc.shape[0], hq, d).astype(qc.dtype), kc, vc

    cspec = P(batch_axes, axis, None, None)
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(batch_axes, None, None, None),
            P(batch_axes, None, None, None),
            cspec,
            cspec,
            P(batch_axes),
        ),
        out_specs=(P(batch_axes, None, None), cspec, cspec),
    )
    length = jnp.asarray(length)
    lb = length if length.ndim else jnp.broadcast_to(length, (b,))
    return fn(q, k_new, v_new, k_cache, v_cache, lb)
