"""Mamba2 — SSD (state-space duality) sequence mixing, chunked (arXiv 2405.21060).

Training/prefill run the chunked SSD algorithm as a lax.scan over sequence
chunks (intra-chunk quadratic term + carried inter-chunk state) — O(S·Q)
compute, O(Q²) transient memory per chunk, sub-quadratic end to end.
Decode is the O(1)-per-token recurrent update on the carried (h, p, n) state.

Sharding: d_inner (heads) shards over `model`; the scan is over time. The
conv + gates are elementwise in channels so GSPMD propagates cleanly.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import rms_norm


class SSMDims(NamedTuple):
    d_inner: int
    heads: int
    conv_ch: int     # channels through the causal conv (d_inner + 2*g*state)
    proj_out: int    # in_proj output width


def ssm_dims(cfg: SSMConfig, d_model: int) -> SSMDims:
    d_inner = cfg.expand * d_model
    heads = d_inner // cfg.head_dim
    conv_ch = d_inner + 2 * cfg.n_groups * cfg.state
    proj_out = d_inner + conv_ch + heads  # z, (x,B,C) through conv, dt
    return SSMDims(d_inner, heads, conv_ch, proj_out)


def ssm_params(key, cfg: SSMConfig, d_model: int, dtype) -> dict:
    dims = ssm_dims(cfg, d_model)
    ks = jax.random.split(key, 5)
    dt = jnp.exp(
        jax.random.uniform(ks[0], (dims.heads,), jnp.float32)
        * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    return {
        "in_proj": jax.random.normal(ks[1], (d_model, dims.proj_out), dtype)
        / math.sqrt(d_model),
        "conv": jax.random.normal(ks[2], (cfg.conv_dim, dims.conv_ch), dtype) * 0.1,
        "conv_bias": jnp.zeros((dims.conv_ch,), jnp.float32),
        "A_log": jnp.log(jax.random.uniform(ks[3], (dims.heads,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((dims.heads,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse softplus
        "norm": jnp.zeros((dims.d_inner,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (dims.d_inner, d_model), dtype)
        / math.sqrt(dims.d_inner),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :].astype(out.dtype)


def _split_proj(cfg: SSMConfig, dims: SSMDims, proj: jax.Array):
    z, xbc, dt = jnp.split(
        proj, [dims.d_inner, dims.d_inner + dims.conv_ch], axis=-1
    )
    return z, xbc, dt


def _split_xbc(cfg: SSMConfig, dims: SSMDims, xbc: jax.Array):
    gn = cfg.n_groups * cfg.state
    x, bb, cc = jnp.split(xbc, [dims.d_inner, dims.d_inner + gn], axis=-1)
    return x, bb, cc


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P) inputs (already conv'd + silu'd)
    dt: jax.Array,     # (B, S, H) softplus'd step sizes
    a: jax.Array,      # (H,) negative decay rates (-exp(A_log))
    bmat: jax.Array,   # (B, S, N) input projections (n_groups=1 squeezed)
    cmat: jax.Array,   # (B, S, N) output projections
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
):
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xd = (x * dt[..., None]).astype(jnp.float32)          # discretized input
    da = dt * a[None, None, :]                            # (B, S, H) ≤ 0

    def to_chunks(t):
        return t.reshape(b, nc, q, *t.shape[2:]).swapaxes(0, 1)  # (nc, B, q, ...)

    xs = (to_chunks(xd), to_chunks(da), to_chunks(bmat.astype(jnp.float32)),
          to_chunks(cmat.astype(jnp.float32)))

    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(state, xs_c):
        xc, dac, bc, cc = xs_c                    # (B,q,H,P) (B,q,H) (B,q,N) (B,q,N)
        acs = jnp.cumsum(dac, axis=1)             # (B,q,H) cumulative decay
        asum = acs[:, -1]                         # (B,H)
        # intra-chunk: L[b,h,i,j] = exp(acs_i - acs_j) for j<=i else 0
        seg = acs[:, :, None, :] - acs[:, None, :, :]          # (B,q,q,H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        l_mat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cc, bc)            # (B,q,q)
        y_diag = jnp.einsum("bij,bijh,bjhp->bihp", scores, l_mat, xc)
        # inter-chunk: contribution of carried state
        y_off = jnp.einsum("bin,bhpn,bih->bihp", cc, state, jnp.exp(acs))
        # state update
        decay_out = jnp.exp(asum[:, None, :] - acs)            # (B,q,H)
        new_state = state * jnp.exp(asum)[:, :, None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhpn", bc, decay_out, xc
        )
        return new_state, y_diag + y_off

    final, ys = jax.lax.scan(body, s0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssm_block(
    params: dict,
    x: jax.Array,       # (B, S, d)
    cfg: SSMConfig,
    *,
    norm_eps: float = 1e-5,
):
    """Full Mamba2 block (train/prefill). Returns (y, final_cache)."""
    bsz, s, d = x.shape
    dims = ssm_dims(cfg, d)
    cdt = x.dtype
    proj = x @ params["in_proj"].astype(cdt)
    z, xbc, dt = _split_proj(cfg, dims, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv"].astype(cdt),
                                   params["conv_bias"]))
    xin, bmat, cmat = _split_xbc(cfg, dims, xbc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xh = xin.reshape(bsz, s, dims.heads, cfg.head_dim)
    # chunked main run + remainder (arbitrary sequence lengths)
    q = min(cfg.chunk, s)
    s_main = (s // q) * q
    y, state = ssd_chunked(
        xh[:, :s_main], dt[:, :s_main], a, bmat[:, :s_main], cmat[:, :s_main], q
    )
    if s_main < s:
        y2, state = ssd_chunked(
            xh[:, s_main:], dt[:, s_main:], a, bmat[:, s_main:], cmat[:, s_main:],
            s - s_main, init_state=state,
        )
        y = jnp.concatenate([y, y2], axis=1)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, dims.d_inner).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    out = y @ params["out_proj"].astype(cdt)
    conv_cache = xbc_tail(x, params, cfg, dims)  # last (K-1) pre-conv inputs
    return out, {"state": state, "conv": conv_cache}


def xbc_tail(x, params, cfg: SSMConfig, dims: SSMDims):
    """Pre-conv xbc values for the last (conv_dim-1) positions → decode cache."""
    cdt = x.dtype
    tail = x[:, -(cfg.conv_dim - 1):, :]
    proj = tail @ params["in_proj"].astype(cdt)
    _, xbc, _ = _split_proj(cfg, dims, proj)
    return xbc


def ssm_decode_step(
    params: dict,
    x: jax.Array,       # (B, d) one token
    cache: dict,        # {"state": (B,H,P,N), "conv": (B, K-1, conv_ch)}
    cfg: SSMConfig,
    *,
    norm_eps: float = 1e-5,
):
    bsz, d = x.shape
    dims = ssm_dims(cfg, d)
    cdt = x.dtype
    proj = x @ params["in_proj"].astype(cdt)
    z, xbc, dt = _split_proj(cfg, dims, proj)
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,K,ch)
    w = params["conv"].astype(cdt)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + params["conv_bias"].astype(cdt)
    xbc_t = jax.nn.silu(conv_out)
    xin, bmat, cmat = _split_xbc(cfg, dims, xbc_t)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    xh = xin.reshape(bsz, dims.heads, cfg.head_dim).astype(jnp.float32)
    state = cache["state"]
    decay = jnp.exp(dt * a[None, :])                                   # (B,H)
    xd = xh * dt[..., None]
    state = state * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", bmat.astype(jnp.float32), xd
    )
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(bsz, dims.d_inner).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    out = y @ params["out_proj"].astype(cdt)
    return out, {"state": state, "conv": hist[:, 1:, :]}
