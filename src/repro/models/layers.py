"""Shared layer primitives for the model zoo (pure JAX, no flax).

Parameters are plain dict pytrees; every GEMM routes through
`core.module.maybe_spamm_matmul` so the paper's technique is a config switch
on any architecture (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.module import maybe_spamm_matmul


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(params: dict, x: jax.Array, act: str, spamm_cfg=None, frozen=None,
        require_frozen: bool = False) -> jax.Array:
    """SwiGLU ('silu'), GeGLU ('gelu'), or classic 4x MLP ('gelu_mlp').

    `frozen` is this layer's dict of per-weight FrozenPlans (jit inputs;
    missing keys fall back to the traced gate, or to dense when
    `require_frozen` — the decode contract)."""
    cdt = x.dtype
    fz = frozen or {}
    if act in ("silu", "gelu"):
        g = maybe_spamm_matmul(x, params["w1"].astype(cdt), spamm_cfg,
                               frozen=fz.get("w1"),
                               require_frozen=require_frozen, site="w1")
        u = maybe_spamm_matmul(x, params["w3"].astype(cdt), spamm_cfg,
                               frozen=fz.get("w3"),
                               require_frozen=require_frozen, site="w3")
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        return maybe_spamm_matmul(g * u, params["w2"].astype(cdt), spamm_cfg,
                                  frozen=fz.get("w2"),
                                  require_frozen=require_frozen, site="w2")
    if act == "gelu_mlp":
        h = jax.nn.gelu(maybe_spamm_matmul(x, params["w1"].astype(cdt),
                                           spamm_cfg, frozen=fz.get("w1"),
                                           require_frozen=require_frozen,
                                           site="w1"))
        return maybe_spamm_matmul(h, params["w2"].astype(cdt), spamm_cfg,
                                  frozen=fz.get("w2"),
                                  require_frozen=require_frozen, site="w2")
    raise ValueError(act)


def mlp_params(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_ff = 1.0 / math.sqrt(d_ff)
    p = {
        "w1": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w2": jax.random.normal(k2, (d_ff, d_model), dtype) * s_ff,
    }
    if act in ("silu", "gelu"):
        p["w3"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed(params: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["embedding"].astype(compute_dtype)[tokens]


def chunked_ce_loss(
    h: jax.Array,            # (B, S, d) final hidden states (already normed)
    unembed: jax.Array,      # (d, V)
    labels: jax.Array,       # (B, S) int32, -1 = masked
    chunk: int,
) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits: scan over seq
    chunks; the chunk body is rematerialized in backward."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    @jax.checkpoint
    def chunk_loss(hc, lc):
        logits = (hc @ unembed).astype(jnp.float32)  # (B, c, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        hc, lc = xs
        l, m = chunk_loss(hc, lc)
        return (carry[0] + l, carry[1] + m), None

    hs = h[:, : n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    ls = labels[:, : n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ls))
    if rem:
        l, m = chunk_loss(h[:, n * chunk :], labels[:, n * chunk :])
        tot, cnt = tot + l, cnt + m
    return tot / jnp.maximum(cnt, 1.0)
