"""Top-level model API: init, sharding specs, train/prefill/decode steps.

* `init_params(cfg, pcfg, key)` — full parameter pytree (use under
  jax.eval_shape for the dry-run: no allocation).
* `param_pspecs(cfg, pcfg, params)` — PartitionSpec pytree implementing the
  DP(+pod)/FSDP/TP/EP rules of DESIGN.md §5.
* `loss_fn / make_train_step / make_prefill_step / make_decode_step` — the
  jit-able step functions the launcher lowers.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.core import module as spmod
from repro.models import transformer as tr
from repro.models.layers import chunked_ce_loss, rms_norm
from repro.models import ssm as ssm_mod
from repro.models.transformer import NetCtx


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, pcfg: ParallelConfig, key,
                model_axis_size: int = 1) -> dict:
    pdt = _dtype(pcfg.param_dtype)
    k_emb, k_layers, k_un = jax.random.split(key, 3)
    params: dict = {
        "embed": {
            "embedding": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), pdt)
            * (1.0 / math.sqrt(cfg.d_model))
        },
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "unembed": {
            "kernel": jax.random.normal(k_un, (cfg.d_model, cfg.vocab), pdt)
            * (1.0 / math.sqrt(cfg.d_model))
        },
    }
    kind = tr.stack_kinds(cfg)
    if kind == "hybrid":
        n_groups, gkinds, tail = tr.hybrid_pattern(cfg)
        gkeys = jax.random.split(k_layers, n_groups + len(tail))

        def one_group(k):
            ks = jax.random.split(k, len(gkinds))
            return {
                f"l{i}": tr.layer_params(ks[i], cfg, pdt, gk, model_axis_size)
                for i, gk in enumerate(gkinds)
            }

        params["groups"] = jax.vmap(one_group)(gkeys[:n_groups])
        params["tail"] = {
            f"l{i}": tr.layer_params(gkeys[n_groups + i], cfg, pdt, tk,
                                     model_axis_size)
            for i, tk in enumerate(tail)
        }
    else:
        lkeys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: tr.layer_params(k, cfg, pdt, kind, model_axis_size)
        )(lkeys)
    return params


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _leaf_spec(path: str, shape, cfg: ModelConfig, pcfg: ParallelConfig,
               stacked: bool) -> P:
    """PartitionSpec for one parameter leaf; `stacked` = has leading L dim."""
    fsdp = "data" if pcfg.fsdp else None
    m = "model"
    rules = []
    if "embedding" in path:
        rules = [m, fsdp]
    elif "unembed" in path:
        rules = [fsdp, m]
    elif "moe" in path:
        ep = cfg.moe is not None and cfg.moe.impl == "ep"
        if "router" in path or "gate" in path:
            rules = [None] * len(shape)
        elif "shared" in path:
            rules = [fsdp, m] if path.endswith("w1") or path.endswith("w3") else [m, fsdp]
        elif ep:
            rules = [m, None, None]           # experts over model, replicated DP
        elif path.endswith("w2"):
            rules = [None, m, fsdp]           # (E, ff, d)
        else:
            rules = [None, fsdp, m]           # (E, d, ff)
    elif any(k in path for k in ("wq", "wk", "wv", "in_proj", "in_gelu",
                                 "in_rec", "w1", "w3")):
        rules = [fsdp, m]
    elif any(k in path for k in ("wo", "out_proj", "w2")) or path.endswith("out"):
        rules = [m, fsdp]
    elif path.endswith("conv") or "conv" in path.split("/")[-1]:
        rules = [None, m] if len(shape) >= 2 else [None]
    else:
        rules = [None] * len(shape)
    base = len(shape) - len(rules)
    if base < 0:  # rank-1 leaf (biases) matched a 2-D rule
        rules = [None] * len(shape)
        base = 0
    return P(*([None] * base + rules))


def param_pspecs(cfg: ModelConfig, pcfg: ParallelConfig, params) -> Any:
    def walk(tree, prefix, stacked):
        if isinstance(tree, dict):
            return {
                k: walk(v, f"{prefix}/{k}",
                        stacked or k in ("layers", "groups"))
                for k, v in tree.items()
            }
        return _leaf_spec(prefix, tree.shape, cfg, pcfg, stacked)

    return walk(params, "", False)


def shardings_for(mesh: Mesh, tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward_hidden(cfg, pcfg, ctx: NetCtx, params, batch, *, spamm_cfg=None,
                   collect_spamm_stats: bool = False):
    """tokens or embeds → final-normed hidden states (B, S, d).

    `spamm_cfg` may be a SpammConfig or a prebuilt `SpammContext` (config +
    shared WeightPlanCache); the stack threads the context object, not raw
    (tau, tile, backend, block_n) tuples. With `collect_spamm_stats` the
    return gains a third element (frac_sum, gemm_count, layer_frac_sums,
    layer_gemm_counts) of traced gating-stat values (see `stack_fwd`)."""
    spamm_cfg = spmod.as_context(spamm_cfg)
    cdt = _dtype(pcfg.compute_dtype)
    if "embeds" in batch:
        x = batch["embeds"].astype(cdt)
    else:
        x = params["embed"]["embedding"].astype(cdt)[batch["tokens"]]
    x = ctx.shard(x, ctx.batch_axes, None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    out = tr.stack_fwd(params, x, cfg, pcfg, ctx, positions,
                       spamm_cfg=spamm_cfg,
                       collect_spamm_stats=collect_spamm_stats)
    if len(out) == 3:
        x, aux, spamm_stats = out
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux, spamm_stats
    x, aux = out
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(cfg, pcfg, ctx, params, batch, *, spamm_cfg=None):
    spamm_cfg = spmod.as_context(spamm_cfg)
    collect = spamm_cfg is not None and spamm_cfg.enable
    if collect:
        h, aux, (vs, vc, lvs, lvc) = forward_hidden(
            cfg, pcfg, ctx, params, batch, spamm_cfg=spamm_cfg,
            collect_spamm_stats=True)
    else:
        h, aux = forward_hidden(cfg, pcfg, ctx, params, batch,
                                spamm_cfg=spamm_cfg)
    unembed = params["unembed"]["kernel"].astype(h.dtype)
    ce = chunked_ce_loss(h, unembed, batch["labels"], pcfg.loss_chunk)
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    met = {"ce": ce, "aux": aux}
    if collect:
        # same per-GEMM gating stats the serving engine taps, exported as
        # step metrics (mean valid fraction over the step's gated GEMMs),
        # plus the per-layer breakdown (stack order, (num_layers,) arrays)
        met["spamm_valid_fraction"] = vs / jnp.maximum(vc, 1.0)
        met["spamm_gated_gemms"] = vc
        met["spamm_layer_valid_fraction"] = lvs / jnp.maximum(lvc, 1.0)
        met["spamm_layer_gated_gemms"] = lvc
    return ce + aux_w * aux, met


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, pcfg: ParallelConfig, batch: int,
               max_len: int) -> dict:
    """Zeroed decode caches (use under eval_shape for specs)."""
    cdt = _dtype(pcfg.compute_dtype)
    kind = tr.stack_kinds(cfg)
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def attn_cache():
        s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        return {
            "k": jnp.zeros((batch, s, hk, hd), cdt),
            "v": jnp.zeros((batch, s, hk, hd), cdt),
        }

    def ssm_cache():
        dims = ssm_mod.ssm_dims(cfg.ssm, cfg.d_model)
        return {
            "state": jnp.zeros((batch, dims.heads, cfg.ssm.head_dim,
                                cfg.ssm.state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_dim - 1, dims.conv_ch), cdt),
        }

    def rec_cache():
        w = cfg.rglru.lru_width or cfg.d_model
        return {
            "h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.rglru.conv_dim - 1, w), cdt),
        }

    def stack_cache(mk, n):
        one = mk()
        return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (n, *t.shape)), one)

    if kind == "ssm":
        return {"layers": stack_cache(ssm_cache, cfg.num_layers)}
    if kind == "hybrid":
        n_groups, gkinds, tail = tr.hybrid_pattern(cfg)
        group = {
            f"l{i}": (rec_cache() if k == "rec" else attn_cache())
            for i, k in enumerate(gkinds)
        }
        groups = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_groups, *t.shape)), group
        )
        tailc = {f"l{i}": rec_cache() for i, _ in enumerate(tail)}
        return {"groups": groups, "tail": tailc}
    return {"layers": stack_cache(attn_cache, cfg.num_layers)}


def cache_pspecs(cfg: ModelConfig, pcfg: ParallelConfig, cache,
                 batch_axes=("data",), model_axis="model",
                 batch_replicated: bool = False) -> Any:
    """Sequence-sharded attention caches; states sharded over model width."""
    ba = None if batch_replicated else batch_axes

    def leaf(path, t):
        if path.endswith("/k") or path.endswith("/v"):
            # (L, B, S, Hk, hd) or (B, S, Hk, hd)
            lead = [None] * (t.ndim - 4)
            return P(*lead, ba, model_axis, None, None)
        if path.endswith("state"):        # (L, B, H, P, N)
            lead = [None] * (t.ndim - 4)
            return P(*lead, ba, model_axis, None, None)
        if path.endswith("/h"):           # (L, B, W)
            lead = [None] * (t.ndim - 2)
            return P(*lead, ba, model_axis)
        if path.endswith("conv"):         # (L, B, K-1, ch)
            lead = [None] * (t.ndim - 3)
            return P(*lead, ba, None, model_axis)
        return P(*([None] * t.ndim))

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        return leaf(prefix, tree)

    return walk(cache, "")


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig, ctx: NetCtx,
                    optimizer, *, spamm_cfg=None):
    """Returns fn(params, opt_state, batch, step) → (params, opt_state, metrics)."""
    spamm_cfg = spmod.as_context(spamm_cfg)  # one context for every call

    def step(params, opt_state, batch, step_no):
        (loss, met), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, pcfg, ctx, p, batch, spamm_cfg=spamm_cfg),
            has_aux=True,
        )(params)
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state,
                                                    step_no)
        metrics = {"loss": loss, "grad_norm": gnorm, **met}
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig, ctx: NetCtx,
                      *, spamm_cfg=None):
    """fn(params, batch, frozen=None) → (cache, last_logits). Logits only
    for the final position (materializing (B, S, V) at 32k is not a
    production thing).

    `frozen` is the optional pytree of precomputed weight-side SpAMM plans
    (see `repro.plans`): a jit ARGUMENT, so the compiled graph consumes the
    step tables as data instead of re-deriving weight normmaps per trace."""
    spamm_cfg = spmod.as_context(spamm_cfg)  # one context for every call

    def step(params, batch, frozen=None):
        cdt = _dtype(pcfg.compute_dtype)
        if "embeds" in batch:
            x = batch["embeds"].astype(cdt)
        else:
            x = params["embed"]["embedding"].astype(cdt)[batch["tokens"]]
        x = ctx.shard(x, ctx.batch_axes, None, None)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        cache_len = (min(cfg.sliding_window, s) if cfg.sliding_window else s)
        x, cache = tr.stack_prefill(params, x, cfg, pcfg, ctx, positions,
                                    cache_len, spamm_cfg=spamm_cfg,
                                    frozen=frozen)
        h_last = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
        logits = (h_last @ params["unembed"]["kernel"].astype(cdt)).astype(jnp.float32)
        return cache, logits

    return step


def make_prefill_chunk_step(cfg: ModelConfig, pcfg: ParallelConfig,
                            ctx: NetCtx, *, spamm_cfg=None):
    """fn(params, batch, cache, positions, last_idx, frozen=None) →
    (cache, logits). One tile-aligned chunk of position-offset prefill: the
    chunk's tokens run the layer stack at ONE static (B, C) shape, writing
    K/V into the LINEAR decode cache at `positions` (B, C) — absolute
    per-row token indices; entries ≥ cache length are idle/pad sentinels
    whose writes drop (`.at[].set(mode="drop")`). `logits` (B, V) is read
    at `last_idx` (B,), the in-chunk index of each row's final prompt token
    (clamped, so rows whose prompt does not end in this chunk return values
    the caller ignores). Attention stacks only — see `stack_prefill_chunk`.

    `frozen` is the chunk-shape FrozenPlan pytree (rows = B·C), a jit
    argument exactly like the one-shot prefill's."""
    spamm_cfg = spmod.as_context(spamm_cfg)  # one context for every call

    def step(params, batch, cache, positions, last_idx, frozen=None):
        cdt = _dtype(pcfg.compute_dtype)
        if "embeds" in batch:
            x = batch["embeds"].astype(cdt)
        else:
            x = params["embed"]["embedding"].astype(cdt)[batch["tokens"]]
        x = ctx.shard(x, ctx.batch_axes, None, None)
        b, c, _ = x.shape
        x, cache = tr.stack_prefill_chunk(
            params, x, cache, positions, cfg, pcfg, ctx,
            spamm_cfg=spamm_cfg, frozen=frozen)
        idx = jnp.clip(last_idx, 0, c - 1)
        h_last = rms_norm(x[jnp.arange(b), idx], params["final_norm"],
                          cfg.norm_eps)
        logits = (h_last @ params["unembed"]["kernel"].astype(cdt)).astype(jnp.float32)
        return cache, logits

    return step


def make_decode_step(cfg: ModelConfig, pcfg: ParallelConfig, ctx: NetCtx,
                     *, spamm_cfg=None):
    """fn(params, tokens_or_embeds (B,1[,d]), cache, pos, frozen=None) →
    (logits, cache). Decode GEMMs gate only through `frozen` plans (sites
    without one stay dense — see `stack_decode`)."""
    spamm_cfg = spmod.as_context(spamm_cfg)  # one context for every call

    def step(params, inp, cache, pos, frozen=None):
        cdt = _dtype(pcfg.compute_dtype)
        if inp.ndim == 3:
            x = inp.astype(cdt)
        else:
            x = params["embed"]["embedding"].astype(cdt)[inp]
        x = ctx.shard(x, ctx.batch_axes, None, None)
        x, cache = tr.stack_decode(params, x, cache, pos, cfg, pcfg, ctx,
                                   spamm_cfg=spamm_cfg, frozen=frozen)
        h = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
        logits = (h @ params["unembed"]["kernel"].astype(cdt)).astype(jnp.float32)
        return logits, cache

    return step


def reshard_probe(controller, spamm_ctx, params, step: int, *,
                  tokens=None, x=None) -> None:
    """Shared body of the drift-triggered re-sharding probe (serving engine
    and train loop both call this — one implementation, one drift behavior).

    Activation rows come from `x` directly (frontend archs feed embeds) or
    from embedding `tokens` through the model's table (ids clamped into the
    vocab). Their norms are computed FRESH; the weight side piggybacks on
    the cached `WeightPlanCache.weight_side` norms of the unembed kernel —
    present for every arch and shaped like every gated GEMM's weight side —
    so a probe costs one activation get-norm, nothing else. Feeds the
    controller only when the row grid has at least one row per device."""
    scfg = spamm_ctx.cfg
    lv = controller.cfg.level
    if x is None:
        emb = params["embed"]["embedding"]
        ids = jnp.asarray(np.asarray(tokens, np.int64) % emb.shape[0])
        x = jnp.take(jnp.asarray(emb), ids, axis=0)
    from repro.core import schedule as _schedule  # circular-safe

    _, nw = spamm_ctx.cache.weight_side(
        params["unembed"]["kernel"], tile=scfg.tile, backend=scfg.backend,
        levels=lv)
    v, fine_rows = _schedule.probe_v_estimate(
        x, nw, scfg.tau, tile=scfg.tile, backend=scfg.backend, level=lv)
    if fine_rows >= controller.cfg.num_devices:
        controller.probe(v, step, level=lv, fine_rows=fine_rows)
