"""RecurrentGemma / Griffin recurrent block: RG-LRU + causal conv (2402.19427).

Block: x → (linear → GELU) ⊙ (linear → conv1d(4) → RG-LRU) → linear.
RG-LRU:  r_t = σ(blockdiag(W_a) x_t + b_a)      (recurrence gate)
         i_t = σ(blockdiag(W_x) x_t + b_x)      (input gate)
         a_t = exp(-c · softplus(Λ) · r_t)
         h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan over time (the recurrence is linear);
decode is the O(1) per-token update. Gate matrices are block-diagonal
(num_blocks heads) as in Griffin.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig

_NUM_BLOCKS = 16


def rglru_params(key, cfg: RGLRUConfig, d_model: int, dtype) -> dict:
    w = cfg.lru_width or d_model
    nb = _NUM_BLOCKS
    ks = jax.random.split(key, 7)
    s_d = 1.0 / math.sqrt(d_model)
    s_b = 1.0 / math.sqrt(w // nb)
    # Λ init so that a^c = exp(-c·softplus(Λ)) ∈ [0.9, 0.999] at r=1
    lo, hi = 0.9, 0.999
    u = jax.random.uniform(ks[0], (w,), jnp.float32, lo**2, hi**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * cfg.c_exponent)))
    return {
        "in_gelu": jax.random.normal(ks[1], (d_model, w), dtype) * s_d,
        "in_rec": jax.random.normal(ks[2], (d_model, w), dtype) * s_d,
        "conv": jax.random.normal(ks[3], (cfg.conv_dim, w), dtype) * 0.1,
        "conv_bias": jnp.zeros((w,), jnp.float32),
        "wa": jax.random.normal(ks[4], (nb, w // nb, w // nb), jnp.float32) * s_b,
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": jax.random.normal(ks[5], (nb, w // nb, w // nb), jnp.float32) * s_b,
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": jax.random.normal(ks[6], (w, d_model), dtype) / math.sqrt(w),
    }


def _block_diag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (..., W); w: (nb, W/nb, W/nb)."""
    nb, bw, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bw)
    out = jnp.einsum("...nh,nhk->...nk", xs.astype(jnp.float32), w)
    return out.reshape(*x.shape) + b


def _gates(params, x):
    r = jax.nn.sigmoid(_block_diag(x, params["wa"], params["ba"]))
    i = jax.nn.sigmoid(_block_diag(x, params["wx"], params["bx"]))
    return r, i


def _log_a(params, r, c):
    return -c * jax.nn.softplus(params["lam"]) * r  # (..., W) ≤ 0


def _causal_conv(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :].astype(out.dtype)


def rglru_scan(params, x, cfg: RGLRUConfig, init_h=None):
    """x: (B, S, W) post-conv inputs. Returns (y, final_h)."""
    r, i = _gates(params, x)
    log_a = _log_a(params, r, cfg.c_exponent)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    if init_h is not None:
        # fold the carried state in as a virtual step 0 input
        gated = gated.at[:, 0, :].add(a[:, 0, :] * init_h)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_block(params, x, cfg: RGLRUConfig):
    """Full recurrent block (train/prefill). x: (B, S, d) → (y, cache)."""
    cdt = x.dtype
    gate = jax.nn.gelu(x @ params["in_gelu"].astype(cdt))
    rec = x @ params["in_rec"].astype(cdt)
    conv_cache = rec[:, -(cfg.conv_dim - 1):, :]
    rec = _causal_conv(rec, params["conv"].astype(cdt), params["conv_bias"])
    y, h = rglru_scan(params, rec, cfg)
    out = (gate * y) @ params["out"].astype(cdt)
    return out, {"h": h, "conv": conv_cache}


def rglru_decode_step(params, x, cache, cfg: RGLRUConfig):
    """x: (B, d); cache {"h": (B,W), "conv": (B, K-1, W)}."""
    cdt = x.dtype
    gate = jax.nn.gelu(x @ params["in_gelu"].astype(cdt))
    rec = x @ params["in_rec"].astype(cdt)
    hist = jnp.concatenate([cache["conv"], rec[:, None, :]], axis=1)  # (B,K,W)
    w = params["conv"].astype(cdt)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + params["conv_bias"].astype(cdt)
    r, i = _gates(params, conv_out)
    log_a = _log_a(params, r, cfg.c_exponent)
    a = jnp.exp(log_a)
    h = a * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * conv_out.astype(jnp.float32)
    )
    out = (gate * h.astype(cdt)) @ params["out"].astype(cdt)
    return out, {"h": h, "conv": hist[:, 1:, :]}
