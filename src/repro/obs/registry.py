"""Metrics registry: counters, gauges, and explicit-bucket histograms with
label sets, Prometheus-text rendering, and JSON-able snapshots.

This is the host-side sink the SpAMM telemetry feeds: `SpammContext` taps
(labeled per phase/layer/site), engine latency (TTFT, per-decode-step),
`ReshardController` history, and train-loop step durations all land here.
Deliberately dependency-free and tiny — a handful of dicts behind one lock —
because it sits on the serving hot path: `observe()`/`inc()` must cost less
than the `io_callback` that delivered the sample.

Metric naming follows the Prometheus conventions the dump targets: counters
end in `_total`, histograms expose `<name>_bucket{le=...}` (cumulative),
`<name>_sum`, `<name>_count`. `parse_prometheus` round-trips the rendered
text — CI uses it to validate `--metrics-out` dumps without needing a real
Prometheus install.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, Iterable, Optional, Sequence, Tuple

# Default bucket ladders, chosen to straddle what this repo actually measures.
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05,
    0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0,
)
FRACTION_BUCKETS: Tuple[float, ...] = (
    0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0,
)
# log2(measured / predicted): 0 = perfectly calibrated cost model, +1 = the
# kernel ran 2x slower than predicted, -1 = 2x faster.
RESIDUAL_LOG2_BUCKETS: Tuple[float, ...] = (
    -4.0, -3.0, -2.0, -1.0, -0.5, -0.25, 0.0, 0.25, 0.5, 1.0, 2.0, 3.0, 4.0,
)
IMBALANCE_BUCKETS: Tuple[float, ...] = (
    1.0, 1.02, 1.05, 1.1, 1.2, 1.5, 2.0, 3.0, 5.0, 10.0,
)


def _check_name(name: str) -> str:
    if not name or not all(c.isalnum() or c in "_:" for c in name) \
            or name[0].isdigit():
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labelnames: Sequence[str], labelvalues: Sequence[str],
                extra: Sequence[Tuple[str, str]] = ()) -> str:
    pairs = [f'{k}="{_escape_label(v)}"'
             for k, v in zip(labelnames, labelvalues)]
    pairs += [f'{k}="{_escape_label(v)}"' for k, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


class _Metric:
    """Shared label-series plumbing; subclasses define the per-series state."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = _check_name(name)
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        # hot path (one call per telemetry sample): length check + keyed
        # lookup raises on any mismatch without building comparison sets
        if len(labels) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        try:
            return tuple(str(labels[k]) for k in self.labelnames)
        except KeyError:
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}") from None

    def series(self) -> Dict[Tuple[str, ...], object]:
        with self._lock:
            return dict(self._series)


class Counter(_Metric):
    """Monotonically increasing per-series float."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels):
        if amount < 0:
            raise ValueError(f"{self.name}: counter increment must be >= 0")
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Gauge(_Metric):
    """Last-write-wins per-series float."""

    kind = "gauge"

    def set(self, value: float, **labels):
        with self._lock:
            self._series[self._key(labels)] = float(value)

    def value(self, **labels) -> Optional[float]:
        v = self._series.get(self._key(labels))
        return None if v is None else float(v)


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "recent")

    def __init__(self, nbuckets: int, keep_recent: int):
        self.counts = [0] * (nbuckets + 1)  # +1 for the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.recent = deque(maxlen=keep_recent) if keep_recent else None


class Histogram(_Metric):
    """Explicit-bucket histogram. `buckets` are ascending upper bounds; a
    +Inf bucket is implicit. `keep_recent=N` additionally retains the last N
    raw samples per series (the train loop's straggler median reads them) —
    bounded, so the registry never grows with run length."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS_S,
                 keep_recent: int = 0):
        super().__init__(name, help, labelnames)
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"{name}: buckets must be ascending: {b}")
        self.buckets = b
        self.keep_recent = int(keep_recent)

    def _get(self, key) -> _HistSeries:
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.buckets),
                                               self.keep_recent)
        return s

    def observe(self, value: float, **labels):
        v = float(value)
        key = self._key(labels)
        with self._lock:
            s = self._get(key)
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            s.counts[i] += 1
            s.sum += v
            s.count += 1
            if s.recent is not None:
                s.recent.append(v)

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return 0 if s is None else s.count

    def sum(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return 0.0 if s is None else s.sum

    def recent(self, **labels) -> list:
        s = self._series.get(self._key(labels))
        return [] if s is None or s.recent is None else list(s.recent)

    def quantile(self, q: float, **labels) -> Optional[float]:
        """Bucket-interpolated quantile estimate (Prometheus
        histogram_quantile semantics: linear within the winning bucket,
        clamped to the highest finite bound for the +Inf bucket)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        s = self._series.get(self._key(labels))
        if s is None or s.count == 0:
            return None
        rank = q * s.count
        cum = 0
        for i, c in enumerate(s.counts):
            cum += c
            if cum >= rank and c > 0:
                if i >= len(self.buckets):      # +Inf bucket
                    return self.buckets[-1]
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i]
                frac = (rank - (cum - c)) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.buckets[-1]


class MetricsRegistry:
    """Get-or-create factory for named metrics plus the export surface.

    One registry per `Observability` bundle; metric objects are cached by
    name so hot paths can hold a direct reference instead of re-resolving.
    """

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help=help,
                                              labelnames=labelnames, **kw)
                return m
        if type(m) is not cls:
            raise ValueError(f"{name}: registered as {m.kind}, "
                             f"requested {cls.kind}")
        if tuple(labelnames) != m.labelnames:
            raise ValueError(f"{name}: labelnames {tuple(labelnames)} != "
                             f"registered {m.labelnames}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_S,
                  keep_recent: int = 0) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets, keep_recent=keep_recent)

    def metrics(self) -> Iterable[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    # -- export -------------------------------------------------------------
    def render_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        out = []
        for m in self.metrics():
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            for key, s in sorted(m.series().items()):
                if isinstance(m, Histogram):
                    cum = 0
                    for i, ub in enumerate(m.buckets + (math.inf,)):
                        cum += s.counts[i]
                        lab = _fmt_labels(m.labelnames, key,
                                          extra=(("le", _fmt_value(ub)),))
                        out.append(f"{m.name}_bucket{lab} {cum}")
                    lab = _fmt_labels(m.labelnames, key)
                    out.append(f"{m.name}_sum{lab} {_fmt_value(s.sum)}")
                    out.append(f"{m.name}_count{lab} {s.count}")
                else:
                    lab = _fmt_labels(m.labelnames, key)
                    out.append(f"{m.name}{lab} {_fmt_value(s)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """JSON-able dump (rides `write_bench_json(metrics=...)`). Label
        series keys are rendered `k=v,k=v` strings so the result nests as
        plain dicts."""
        snap = {}
        for m in self.metrics():
            series = {}
            for key, s in sorted(m.series().items()):
                skey = ",".join(f"{k}={v}"
                                for k, v in zip(m.labelnames, key)) or ""
                if isinstance(m, Histogram):
                    series[skey] = {
                        "buckets": list(m.buckets),
                        "counts": list(s.counts),
                        "sum": s.sum,
                        "count": s.count,
                    }
                else:
                    series[skey] = s
            snap[m.name] = {"type": m.kind, "help": m.help,
                            "labelnames": list(m.labelnames),
                            "series": series}
        return snap

    def summary_table(self) -> str:
        """Human-oriented end-of-run table: one line per series; histograms
        show count/mean/p50/p95."""
        lines = ["metric                                   value"]
        lines.append("-" * 72)
        for m in self.metrics():
            for key, s in sorted(m.series().items()):
                lab = _fmt_labels(m.labelnames, key)
                if isinstance(m, Histogram):
                    if s.count == 0:
                        continue
                    mean = s.sum / s.count
                    kw = dict(zip(m.labelnames, key))
                    p50 = m.quantile(0.5, **kw)
                    p95 = m.quantile(0.95, **kw)
                    lines.append(
                        f"{m.name}{lab:<30} n={s.count} mean={mean:.6g} "
                        f"p50={p50:.6g} p95={p95:.6g}")
                else:
                    lines.append(f"{m.name}{lab:<30} {_fmt_value(s)}")
        return "\n".join(lines)


def parse_prometheus(text: str) -> dict:
    """Parse a Prometheus text dump back into {metric_name: {type, samples}}
    where samples maps the full label string to a float. Enough fidelity for
    CI to validate a `--metrics-out` dump; not a general client."""
    metrics: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            metrics.setdefault(name, {"type": kind, "samples": {}})
            continue
        if line.startswith("#"):
            continue
        # sample line: name{labels} value  |  name value
        if "}" in line:
            head, _, val = line.rpartition(" ")
            name = head.split("{", 1)[0]
            labels = head[len(name):]
        else:
            name, _, val = line.rpartition(" ")
            labels = ""
        base = name
        for suf in ("_bucket", "_sum", "_count"):
            if name.endswith(suf) and name[: -len(suf)] in metrics:
                base = name[: -len(suf)]
                break
        if base not in metrics:
            metrics.setdefault(name, {"type": "untyped", "samples": {}})
            base = name
        v = float("inf") if val == "+Inf" else float(val)
        metrics[base]["samples"][name + labels] = v
    return metrics
