"""Host-side span tracer with Chrome-trace/Perfetto JSON export.

Spans are wall-clock (`time.perf_counter_ns`) intervals around HOST-side
phases of a run: plan freeze, plan-store I/O, compiled prefill, each decode
step, reshard probe / re-cut, cache permute. They deliberately measure the
dispatch+block window (the engine blocks on the step output anyway for its
lockstep loop), not device kernel time — per-kernel attribution comes from
the labeled taps, spans answer "where did the wall-clock of this wave go".

Export is the Chrome trace-event JSON format ("traceEvents", `ph: "X"`
complete events, microsecond timestamps), loadable in Perfetto / chrome
about://tracing.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Optional


class SpanTracer:
    """Append-only list of completed spans. Thread-safe; nested spans are
    reconstructed by the viewer from begin/duration overlap on the same
    (pid, tid) track, so `span()` needs no explicit parent bookkeeping."""

    def __init__(self, enabled: bool = True, process_name: str = "repro",
                 max_events: int = 200_000):
        self.enabled = enabled
        self.process_name = process_name
        self.max_events = max_events
        self.events: list = []
        self._lock = threading.Lock()
        self._epoch_ns = time.perf_counter_ns()

    def _emit(self, ev: dict):
        with self._lock:
            if len(self.events) < self.max_events:
                self.events.append(ev)

    @contextmanager
    def span(self, name: str, **args):
        """Time a host-side phase; extra kwargs become viewer-visible args."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            t1 = time.perf_counter_ns()
            ev = {
                "name": name,
                "ph": "X",
                "ts": (t0 - self._epoch_ns) / 1e3,   # µs
                "dur": (t1 - t0) / 1e3,
                "pid": os.getpid(),
                "tid": threading.get_ident(),
            }
            if args:
                ev["args"] = {k: _jsonable(v) for k, v in args.items()}
            self._emit(ev)

    def add_complete(self, name: str, t0_ns: int, t1_ns: int, **args):
        """Record a span from explicit perf_counter_ns endpoints — for code
        whose natural end-of-interval is a later blocking point (the
        engine's decode loop blocks on step t's output at the top of
        iteration t+1, so the span closes there)."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - self._epoch_ns) / 1e3,
            "dur": (t1_ns - t0_ns) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self._emit(ev)

    def instant(self, name: str, **args):
        """Zero-duration marker (e.g. 'reshard committed')."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self._emit(ev)

    def span_names(self) -> set:
        with self._lock:
            return {e["name"] for e in self.events}

    def chrome_trace(self) -> dict:
        """The trace document; `export(path)` writes it."""
        with self._lock:
            events = list(self.events)
        meta = [{
            "name": "process_name", "ph": "M", "pid": os.getpid(), "tid": 0,
            "args": {"name": self.process_name},
        }]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return str(v)


@contextmanager
def _null_span():
    yield


def maybe_span(tracer: Optional[SpanTracer], name: str, **args):
    """Span when a tracer is attached and enabled, no-op otherwise — lets
    instrumented code read as one line without None-checks at call sites."""
    if tracer is None or not tracer.enabled:
        return _null_span()
    return tracer.span(name, **args)
