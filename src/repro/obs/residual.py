"""Cost-residual channel: predicted vs measured time for the gated GEMMs.

The PR 7 cost model (`core.cost.predict_time_s`) drives autotuning and
re-shard probes, but nothing told us when its calibrated coefficients drift
from reality (new machine, stale `CostProfile`, changed XLA version). This
channel closes the loop: each executed frozen-path GEMM taps its in-trace
predicted call time (`cost.predict_plan_time_s` — same roofline arithmetic,
embedded next to the gate so it sees the EXECUTED work-list, not a planning
estimate), the engine pairs the per-phase prediction sums with the measured
host wall-clock of that phase, and the log2(measured/predicted) ratio lands
in a histogram.

Interpretation: a calibrated profile on its own machine should concentrate
mass near 0 (within ±0.5 ≈ 1.4x); a persistent shift means re-run
`benchmarks/autotune.py --calibrate`. Granularity is per phase per wave
(prefill total, decode-step total), NOT per kernel: the taps are unordered
io_callbacks, so individual GEMMs cannot be paired with sub-step wall-clock
without serializing the step. The per-phase sum is exactly the quantity the
autotuner's argmin integrates, so it is also the right one to validate.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.obs.registry import (MetricsRegistry, RESIDUAL_LOG2_BUCKETS,
                                Histogram)


class CostResidualTracker:
    """Pairs predicted-vs-measured phase times into registry metrics."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.hist: Histogram = registry.histogram(
            "spamm_cost_time_residual_log2",
            help="log2(measured / predicted) wall-clock of gated-GEMM work "
                 "per phase per wave; 0 = calibrated cost model",
            labelnames=("phase",), buckets=RESIDUAL_LOG2_BUCKETS)
        self.predicted_s = registry.counter(
            "spamm_cost_predicted_seconds_total",
            help="cost-model predicted gated-GEMM seconds",
            labelnames=("phase",))
        self.measured_s = registry.counter(
            "spamm_cost_measured_seconds_total",
            help="measured wall-clock seconds of the paired phase",
            labelnames=("phase",))

    def record(self, phase: str, predicted_s: float,
               measured_s: float) -> Optional[float]:
        """Record one pairing; returns the log2 residual (None if either
        side is non-positive — e.g. no gated GEMM executed in the phase)."""
        if predicted_s <= 0.0 or measured_s <= 0.0:
            return None
        r = math.log2(measured_s / predicted_s)
        self.hist.observe(r, phase=phase)
        self.predicted_s.inc(predicted_s, phase=phase)
        self.measured_s.inc(measured_s, phase=phase)
        return r
