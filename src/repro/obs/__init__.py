"""Observability subsystem: labeled metrics, host spans, cost residuals.

`Observability` bundles the three channels one runtime (engine, train loop,
benchmark) shares:

  * `registry` — `MetricsRegistry` of counters/gauges/histograms with label
    sets (`layer`, `phase`, `site`, `dtype`); the `SpammContext` taps and
    the engine's latency measurements feed it. Export with
    `write_metrics(path)` (Prometheus text) or `registry.snapshot()` (JSON,
    rides `benchmarks.report.write_bench_json(metrics=...)`).
  * `tracer` — `SpanTracer` host-side spans (freeze, plan-store I/O,
    prefill, decode steps, reshard probe/re-cut, cache permute); export
    with `write_trace(path)` (Chrome-trace/Perfetto JSON).
  * `residual` — `CostResidualTracker` pairing cost-model predictions with
    measured wall-clock per phase.

Pass `obs=False` to an instrumented component for a hard-off bundle: spans
and blocking latency measurements are skipped and the cost-prediction taps
never embed in the traced graphs, so the uninstrumented path is the exact
pre-PR computation (`benchmarks/obs_overhead.py` holds the <2% line).
"""
from __future__ import annotations

from typing import Optional, Union

from repro.obs.registry import (  # noqa: F401  (re-exported surface)
    Counter, FRACTION_BUCKETS, Gauge, Histogram, IMBALANCE_BUCKETS,
    LATENCY_BUCKETS_S, MetricsRegistry, RESIDUAL_LOG2_BUCKETS,
    parse_prometheus,
)
from repro.obs.residual import CostResidualTracker  # noqa: F401
from repro.obs.tracer import SpanTracer, maybe_span  # noqa: F401


class Observability:
    """One bundle per runtime; share it across components of a run (engine +
    CLI, or train loop + CLI) so the exported dump is the whole story."""

    def __init__(self, enabled: bool = True, process_name: str = "repro"):
        self.enabled = enabled
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer(enabled=enabled, process_name=process_name)
        self.residual = CostResidualTracker(self.registry)

    def span(self, name: str, **args):
        return maybe_span(self.tracer if self.enabled else None, name, **args)

    def write_metrics(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.registry.render_prometheus())
        return path

    def write_trace(self, path: str) -> str:
        return self.tracer.export(path)

    def summary_table(self) -> str:
        return self.registry.summary_table()

    @classmethod
    def ensure(cls, obs: Union["Observability", bool, None],
               process_name: str = "repro") -> "Observability":
        """Normalize the `obs=` argument instrumented components accept:
        None -> fresh enabled bundle, False -> fresh disabled bundle,
        an existing bundle -> itself."""
        if isinstance(obs, cls):
            return obs
        return cls(enabled=(obs is not False), process_name=process_name)
