"""Batched serving engine: prefill + greedy decode with slot-based batching.

A fixed pool of `batch` slots; requests (prompts) fill free slots, a slot
frees when its sequence emits EOS or hits max_new_tokens (continuous-
batching-lite: admission happens between decode steps; prefill per admission
wave). The decode step is the same jitted fn the dry-run lowers — decode
caches come back from prefill and are padded to the engine's max length.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import module as spmod
from repro.models import model as M
from repro.models.transformer import NetCtx


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: Optional[dict] = None   # populated by Engine.generate: per-request
                                 # metadata — {"tokens": np.ndarray,
                                 # "spamm": gating stats dict or None}


class Engine:
    """`spamm_cfg` (SpammConfig or SpammContext) turns on norm-gated GEMMs in
    prefill. The engine owns ONE SpammContext threaded through every request.

    Note on amortization: the prefill step is jitted, so inside the compiled
    graph the weight normmaps are recomputed per call (tracers are never
    cached — see WeightPlanCache) and plans stay dense-bitmap; what jit
    amortizes is the Python-side gating/trace. The cache pays off on the
    EAGER plan/execute serving path (see benchmarks/plan_cache.py), where
    plans now carry the §3.3 compacted work-list straight from the gating
    descent and execution runs the ragged Σnvalid-step kernel
    (`spamm_mm_worklist`) — cost proportional to valid work, see
    benchmarks/sparse_exec.py. Moving weight plans to jit inputs so the
    compiled prefill skips get-norm too is the natural next step.
    """

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, ctx: NetCtx,
                 params, *, max_len: int = 512, spamm_cfg=None):
        self.cfg, self.pcfg, self.ctx = cfg, pcfg, ctx
        self.params = params
        self.max_len = max_len
        self.spamm_ctx = spmod.as_context(spamm_cfg)
        self._prefill = jax.jit(
            M.make_prefill_step(cfg, pcfg, ctx, spamm_cfg=self.spamm_ctx))
        self._decode = jax.jit(M.make_decode_step(cfg, pcfg, ctx))

    def _pad_cache(self, cache, cur_len: int):
        """Grow linear KV caches from cur_len to max_len slots."""
        target = (
            min(self.max_len, self.cfg.sliding_window)
            if self.cfg.sliding_window else self.max_len
        )

        def grow(path, t):
            keys = [getattr(k, "key", None) for k in path]
            if keys and keys[-1] in ("k", "v") and t.shape[-3] < target:
                pad = [(0, 0)] * t.ndim
                pad[-3] = (0, target - t.shape[-3])
                return jnp.pad(t, pad)
            return t

        return jax.tree_util.tree_map_with_path(grow, cache)

    def _spamm_stats(self, fracs, hits0: int, misses0: int):
        """Per-wave gating stats dict from the drained valid fractions and
        the plan-cache counter deltas across this wave."""
        cache = self.spamm_ctx.cache
        return {
            "valid_fraction": float(np.mean(fracs)) if fracs else None,
            "gated_gemms": len(fracs),
            "plan_cache_hits": cache.hits - hits0,
            "plan_cache_misses": cache.misses - misses0,
        }

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Greedy-decode a batch of same-length prompts (engine pads to the
        longest prompt internally with left-trim to uniform length).

        When SpAMM is enabled, each request's `out` metadata carries the
        prefill gating stats of its wave (mean valid_fraction over the gated
        GEMMs, plan-cache hit/miss deltas) instead of dropping them.
        """
        assert requests, "empty batch"
        b = len(requests)
        plen = min(min(len(r.prompt) for r in requests), self.max_len - 1)
        toks = np.stack([r.prompt[-plen:] for r in requests]).astype(np.int32)
        collect = self.spamm_ctx is not None and self.spamm_ctx.enable
        spamm_meta = None
        if collect:
            hits0 = self.spamm_ctx.cache.hits
            misses0 = self.spamm_ctx.cache.misses
            self.spamm_ctx.begin_stats()
            try:
                cache, logits = self._prefill(
                    self.params, {"tokens": jnp.asarray(toks)})
            finally:
                # unordered io_callbacks are NOT flushed by output readiness
                # — effects_barrier is the documented flush; the finally
                # closes the collect window even on a failed prefill so the
                # context's telemetry can't be left collecting forever
                jax.effects_barrier()
                fracs = self.spamm_ctx.end_stats()
            spamm_meta = self._spamm_stats(fracs, hits0, misses0)
        else:
            cache, logits = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)})
        cache = self._pad_cache(cache, plen)
        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = plen
        budget = max(r.max_new_tokens for r in requests)
        for t in range(budget):
            for i, r in enumerate(requests):
                if not done[i]:
                    outs[i].append(int(cur[i]))
                    if (r.eos_id is not None and int(cur[i]) == r.eos_id) or \
                       len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if done.all() or pos >= self.max_len - 1:
                break
            logits, cache = self._decode(
                self.params, cur[:, None], cache, jnp.int32(pos)
            )
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            pos += 1
        results = [np.asarray(o, np.int32) for o in outs]
        for r, toks_out in zip(requests, results):
            r.out = {"tokens": toks_out, "spamm": spamm_meta}
        return results
