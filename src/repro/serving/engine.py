"""Batched serving engine: prefill + greedy decode with slot-based batching.

Two data planes share the jitted decode step:

* WAVE mode (default for equal-length prompts): the batch prefills in one
  shot, then decodes in lockstep until every sequence finishes — a slot
  that emits EOS stays in the batch as dead weight until the wave drains.
* CHUNKED mode (`prefill_chunk`, and the automatic path for mixed-length
  prompts on attention stacks): a power-of-two-bucketed pool of slots;
  prompts prefill in tile-aligned chunks at ONE static chunk shape,
  interleaved with decode steps, writing into the KV cache at per-slot
  position offsets. Here the continuous-batching story is real: a slot
  frees when its sequence emits EOS or hits max_new_tokens, and queued
  requests are admitted into freed slots between decode steps via chunked
  prefill — no prompt is ever trimmed and per-step latency is bounded by
  the chunk size.

The decode step is the same jitted fn the dry-run lowers — decode caches
come back from prefill (wave mode pads them to the engine's max length;
chunked mode allocates full-length linear caches up front).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import module as spmod
from repro.core import schedule as _schedule
from repro.core.plan import _bucket
from repro.models import model as M
from repro.models.transformer import NetCtx, stack_kinds
from repro.obs import (FRACTION_BUCKETS, Histogram, LATENCY_BUCKETS_S,
                       Observability)

# queue-depth / occupancy histograms bucket on a request-count ladder
COUNT_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


def _floor_pow2(n: int) -> int:
    """Largest power of two <= n (n >= 1). The slot pool floors a
    non-power-of-two `max_slots` so the documented cap on concurrent slots
    (and their KV-cache memory) is never exceeded while the pool stays on
    the power-of-two bucket ladder."""
    return 1 << (int(n).bit_length() - 1)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: Optional[dict] = None   # populated by Engine.generate: per-request
                                 # metadata — {"tokens": np.ndarray,
                                 # "spamm": gating stats dict or None}


class Engine:
    """`spamm_cfg` (SpammConfig or SpammContext) turns on norm-gated GEMMs in
    prefill AND decode. The engine owns ONE SpammContext threaded through
    every request.

    Chunked prefill + slot admission (`prefill_chunk`, `max_slots`): with
    `prefill_chunk=C` (or automatically for mixed-length prompts on
    attention stacks when `prefill_chunk is None`), `generate` runs the
    slot scheduler instead of the one-shot wave. The slot pool is bucketed
    to a power of two (`cost.bucket`, capped by `max_slots`), so the
    chunked-prefill and decode jit caches are keyed by the BUCKET ladder,
    not by every distinct (batch, prompt_len) — a mixed-shape sweep
    compiles O(log slots) traces (`cost.bucket_ladder` names the bound and
    `trace_counts` proves it). Each scheduler iteration admits queued
    requests into idle slots, advances every prefilling slot by one
    tile-aligned chunk of C tokens (ONE static (slots, C) shape, written
    into full-length linear KV caches at per-slot position offsets via
    drop-mode scatters — idle/pad slots carry position sentinels ≥ max_len
    so their writes vanish), then runs one decode step over the decoding
    slots (per-row positions). Finished slots free between decode steps.
    Bit-parity contract: chunk cuts fall on row-tile boundaries
    (C % tile == 0), so on tile-aligned equal-length prompts the chunked
    tokens are bit-identical to the one-shot wave's — fully masked KV
    blocks are bitwise neutral in the online softmax, and tile membership
    (hence the gate) is unchanged. Recurrent stacks (ssm/hybrid) cannot
    chunk (state does not checkpoint at a chunk boundary): they reject
    mixed-length batches loudly instead of silently trimming. In
    pod-sharded mode `prefill_chunk` swaps the wave's one-shot prefill for
    a chunk loop at the same static shard shapes (equal lengths still
    required; admission stays wave-based).

    Frozen-plan contract (the amortization story): the weight-side gating
    artifacts are a pure function of the static weights, so the engine
    freezes them ONCE (`repro.plans.freeze_tree`, optionally warm-started
    from an on-disk `PlanStore` populated by `repro.launch.precompute_plans`
    — then engine start-up is a pure load, no planning pass) and passes the
    per-shape `FrozenPlan` pytrees into the jitted `_prefill`/`_decode` as
    ARGUMENTS. Inside the compiled graphs only the activation-side gate is
    traced; the weight get-norm and the dense-bitmap + `spamm_compact_ref`
    sort never appear — the concrete `SpammWork` work-list path (PR 3) is
    the only executed path, bit-identical to the eager plan/execute
    pipeline. `WeightPlanCache` is the in-memory tier above the store (it
    memoizes the frozen artifacts by weight fingerprint) and still serves
    the eager plan/execute path (benchmarks/plan_cache.py).

    `freeze_plans=False` opts back into the legacy in-trace gating for A/B
    comparisons (benchmarks/frozen_prefill.py measures the gap).

    Pod-sharded execution (`mesh_devices=N > 1`): the compiled steps run
    under `shard_map` over a 1-D "rows" mesh of the first N devices —
    params REPLICATED (`P()`), activation rows, decode caches, and frozen
    plans SHARDED on the leading dim (`P("rows")`). The live equal-work
    offsets drive placement: the wave's requests are cut into contiguous
    per-device groups (`schedule.rescale_offsets` maps the controller's
    probe-grid cut onto the request-group grid; `schedule.strip_tables` —
    the same construction `distributed.spamm_rowpart` shards with — builds
    the clamp-padded slot tables), and each shard's step tables come from
    `FrozenWeight.slice_rows`/`shard_by_offsets`, sliced ON HOST at
    (re-)shard time and passed as per-shard jit inputs, never in-trace.
    Every shard pads to one static width (`shard_max_width` groups, default
    2·ceil(G/N)), and strips beyond a shard's real width carry a clear
    `real` bit — pad rows do zero gated work, which is exactly how unequal
    predicted work becomes equal wall-clock. A `ReshardController` re-cut
    between decode steps swaps the live sharding WITHOUT recompiling: the
    engine keeps a per-offsets-table cache of sharded `FrozenPlan` pytrees
    (same static shapes, new table contents), re-gathers the stacked decode
    cache host-side along the slot permutation, and the jit cache hits
    (`Engine.trace_counts` proves it). Bit-parity contract: shard cuts fall
    on request-group boundaries of `tile` requests (gating is per row tile,
    so a cut inside a tile would change tile membership and the gate), and
    prompts must satisfy plen % tile == 0 — under those alignment rules the
    sharded engine's tokens are bit-identical to the single-device engine's.
    The body runs with a mesh-free `NetCtx` (ctx.shard no-ops inside the
    shard), so MoE archs — whose expert FFNs open their OWN shard_map over
    the outer mesh — are rejected at construction; per-expert frozen plans
    are the ROADMAP item that lifts this. Multi-host serving rides the same
    contract (the mesh becomes multi-host; the host-side slicing is
    device-count-agnostic) and is the remaining slice.

    Drift-triggered re-sharding (`reshard_cfg`, a `schedule.ReshardConfig`):
    the engine owns a `schedule.ReshardController` holding the equal-work
    row partition a pod deployment would feed to
    `distributed.spamm_rowpart(offsets=...)`. Every `reshard_cfg.every`
    engine steps (prefill counts one, each decode step one, cumulative
    across waves) it re-probes the coarse V estimate — activation-side
    norms of the live token embeddings, weight side piggybacking on the
    cached `WeightPlanCache.weight_side` pyramid of the probe weight (the
    unembed kernel: present for every arch, shaped like every gated GEMM's
    weight side) — and re-cuts the strips only when the live partition's
    predicted imbalance drifts beyond the fresh cut's by the configured
    threshold. Pure control plane: outputs are bit-identical with
    re-sharding on, off, or at any cadence; `Request.out["spamm"]` reports
    the wave's `resharded` event count, probe count, and the live
    partition's predicted imbalance.

    Telemetry (`obs`, a `repro.obs.Observability` bundle): the engine feeds
    three sinks. (1) The METRICS REGISTRY gets labeled per-execution samples
    from the context's `Tap` events — valid-fraction histograms and
    GEMM/byte counters keyed (phase, layer, site[, dtype]) — plus TTFT and
    per-decode-step latency histograms, wave/token counters, plan-cache and
    plan-store hit/miss counters, and the `ReshardController`'s probe
    history; `Observability.write_metrics` dumps it in Prometheus text
    form. (2) The SPAN TRACER records host wall-clock spans (freeze,
    plan_assembly, prefill, decode_step, reshard_probe, cache_permute,
    wave) exportable as Chrome-trace JSON for Perfetto. (3) The
    COST-RESIDUAL channel pairs each phase's roofline-predicted seconds
    (summed over the wave's executed gated GEMMs via in-graph
    `cost.predict_plan_time_s` taps) with measured wall-clock into a
    log2-ratio histogram — the live calibration check on the cost model
    the autotuner and the re-sharder both lean on. `obs=False` is the
    hard-off A/B baseline: no spans, no latency reads, and the cost taps
    never embed, so the traced graphs are exactly the pre-telemetry ones
    (benchmarks/obs_overhead.py holds the instrumented engine to <2%
    overhead and bit-identical tokens against it). Labels ride the existing
    callbacks as static partial args or traced operands — jit cache keys
    and `trace_counts` are unchanged by instrumentation.
    """

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, ctx: NetCtx,
                 params, *, max_len: int = 512, spamm_cfg=None,
                 plan_store=None, freeze_plans: Optional[bool] = None,
                 reshard_cfg: Optional[_schedule.ReshardConfig] = None,
                 mesh_devices: int = 0,
                 shard_max_width: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 max_slots: Optional[int] = None,
                 obs=None):
        self.cfg, self.pcfg, self.ctx = cfg, pcfg, ctx
        self.params = params
        self.max_len = max_len
        self.spamm_ctx = spmod.as_context(spamm_cfg)
        enabled = self.spamm_ctx is not None and self.spamm_ctx.enable
        # `prefill_chunk`: None = auto (chunked scheduler only for
        # mixed-length attention-stack batches), int C = always chunk at C
        # tokens, 0/False = never chunk (mixed lengths are rejected).
        # `max_slots` caps the chunked scheduler's concurrent slot pool —
        # below the batch size it exercises queue-driven admission.
        self._prefill_chunk = prefill_chunk
        self._max_slots = int(max_slots) if max_slots else None
        if self._max_slots is not None and self._max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if prefill_chunk:
            c = int(prefill_chunk)
            if c < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1 (or 0/None), got "
                    f"{prefill_chunk}")
            if stack_kinds(cfg) != "attn":
                raise ValueError(
                    f"chunked prefill needs a stateless-FFN attention stack "
                    f"(got {stack_kinds(cfg)!r}: recurrent prefill state "
                    f"does not checkpoint at a chunk boundary)")
            if enabled and c % self.spamm_ctx.cfg.tile:
                raise ValueError(
                    f"prefill_chunk={c} must be a multiple of the SpAMM "
                    f"tile ({self.spamm_ctx.cfg.tile}): gating is per row "
                    f"tile, so a chunk cut inside a tile would change tile "
                    f"membership and the gate")
        # `obs`: an Observability bundle to share (CLI passes one so the
        # exported dump covers the whole run), None for a private enabled
        # bundle, False for hard-off (no spans, no latency blocks, no cost
        # taps in the traced graphs — the uninstrumented A/B baseline)
        self.obs = Observability.ensure(obs, process_name="repro-engine")
        if isinstance(plan_store, str):
            from repro.plans.store import PlanStore  # deferred: optional dep

            plan_store = PlanStore(plan_store)
        self.plan_store = plan_store
        self._freeze = enabled if freeze_plans is None else (
            bool(freeze_plans) and enabled)
        if enabled and plan_store is not None:
            self.spamm_ctx.cache.store = plan_store
        self._fw_tree = None     # path-tree of FrozenWeight (lists per layer)
        self._fp_cache: dict = {}  # row-tile grid gm → FrozenPlan pytree
        self._sfp_cache: dict = {}  # (tpg, width, offsets) → sharded pytree
        self._gm_hist: dict = {}   # observed row-tile grid gm → step count
        self._resharder = None
        self._steps = 0          # engine steps (prefill + decode), all waves
        self._shard = None       # live wave's sharding tables (sharded mode)
        self.trace_counts = {"prefill": 0, "decode": 0}  # (re)compile guard
        self._ndev = int(mesh_devices) if mesh_devices else 0
        self._sharded = self._ndev > 1
        self._shard_width = shard_max_width
        if self._sharded:
            if not self._freeze:
                raise ValueError(
                    "mesh_devices > 1 needs frozen plans (per-shard step "
                    "tables ARE the sharding mechanism) — enable spamm_cfg "
                    "and keep freeze_plans on")
            if cfg.moe is not None:
                raise ValueError(
                    "pod-sharded serving cannot take MoE archs yet: expert "
                    "FFNs open their own shard_map over the outer mesh "
                    "(per-expert frozen plans are the ROADMAP item)")
            devs = jax.devices()
            if len(devs) < self._ndev:
                raise ValueError(
                    f"mesh_devices={self._ndev} but only {len(devs)} "
                    f"devices visible")
            from repro.launch.mesh import mesh_from_devices

            self._spamm_mesh = mesh_from_devices(
                np.array(devs[:self._ndev]), ("rows",))
        if reshard_cfg is not None and enabled and reshard_cfg.every > 0:
            if self._sharded and reshard_cfg.num_devices == 0:
                reshard_cfg = dataclasses.replace(
                    reshard_cfg, num_devices=self._ndev)
            else:
                reshard_cfg = _schedule.resolve_reshard_devices(
                    reshard_cfg, ctx.mesh, ctx.batch_axes)
            if self._sharded and reshard_cfg.num_devices != self._ndev:
                raise ValueError(
                    f"reshard_cfg cuts {reshard_cfg.num_devices} strips but "
                    f"the engine shards over {self._ndev} devices — they "
                    f"must match (the cut IS the placement)")
            self._resharder = _schedule.ReshardController(reshard_cfg)
        if enabled and self._freeze and self.obs.enabled:
            # arm the cost-prediction tap channel BEFORE the first trace:
            # coefficients resolve once, host-side, from the tune profile
            # (or the nominal table) at the config's resolved backend
            from repro.core import cost as _cost
            from repro.kernels.ops import resolve_backend

            scfg = self.spamm_ctx.cfg
            prof = _cost.CostProfile.load_or_default(
                getattr(scfg, "tune_profile", None))
            self.spamm_ctx.enable_cost_taps(
                prof.coeffs(resolve_backend(scfg.backend)))
        if self.obs.enabled:
            reg = self.obs.registry
            self._m_ttft = reg.histogram(
                "serve_ttft_seconds", labelnames=(),
                help="wave start to first-token available (includes reshard "
                     "probe + prefill dispatch + execution)",
                buckets=LATENCY_BUCKETS_S)
            self._m_decode_s = reg.histogram(
                "serve_decode_step_seconds", labelnames=(),
                help="inter-token latency per decode step (reshard stalls "
                     "included)", buckets=LATENCY_BUCKETS_S)
            self._m_vf = reg.histogram(
                "spamm_valid_fraction", labelnames=("phase", "layer", "site"),
                help="per-execution gated-GEMM valid fraction",
                buckets=FRACTION_BUCKETS)
            self._m_gemms = reg.counter(
                "spamm_gated_gemms_total",
                labelnames=("phase", "layer", "site"),
                help="gated GEMM executions (per shard in sharded mode)")
            self._m_bytes = reg.counter(
                "spamm_gemm_bytes_total",
                labelnames=("phase", "layer", "site", "dtype"),
                help="analytic GEMM bytes moved by the executed work-lists")
            self._m_waves = reg.counter(
                "serve_waves_total", help="request waves served")
            self._m_tokens = reg.counter(
                "serve_tokens_total", help="tokens emitted")
            self._m_cache = reg.counter(
                "spamm_plan_cache_total", labelnames=("result",),
                help="WeightPlanCache hits/misses")
            self._m_store = reg.counter(
                "spamm_plan_store_total", labelnames=("result",),
                help="on-disk PlanStore hits/misses")
            self._m_admit = reg.counter(
                "serve_admissions_total",
                help="requests admitted into a slot (chunked scheduler)")
            self._m_chunks = reg.counter(
                "serve_prefill_chunks_total",
                help="chunked-prefill steps executed (each advances every "
                     "prefilling slot by prefill_chunk tokens)")
            self._m_queue = reg.histogram(
                "serve_queue_depth", labelnames=(),
                help="requests waiting for a slot, sampled per scheduler "
                     "iteration (chunked mode)", buckets=COUNT_BUCKETS)
            self._m_occupancy = reg.histogram(
                "serve_slot_occupancy", labelnames=(),
                help="live slots per scheduler iteration (chunked mode)",
                buckets=COUNT_BUCKETS)
        self._build_steps()

    def _counted(self, fn, key: str):
        """Wrap a step body so Python re-execution (= a fresh jit trace)
        bumps `trace_counts[key]` — the recompile-free re-shard guard."""
        def wrapped(*args):
            self.trace_counts[key] += 1
            return fn(*args)

        return wrapped

    def _build_steps(self):
        cfg, pcfg = self.cfg, self.pcfg
        chunkable = stack_kinds(cfg) == "attn"
        if not self._sharded:
            self._prefill = jax.jit(self._counted(
                M.make_prefill_step(cfg, pcfg, self.ctx,
                                    spamm_cfg=self.spamm_ctx), "prefill"))
            self._decode = jax.jit(self._counted(M.make_decode_step(
                cfg, pcfg, self.ctx,
                spamm_cfg=self.spamm_ctx if self._freeze else None),
                "decode"))
            # chunked prefill shares the "prefill" trace counter: the
            # jit-cache-bound guard counts every prefill-side trace
            self._chunk = None if not chunkable else jax.jit(self._counted(
                M.make_prefill_chunk_step(cfg, pcfg, self.ctx,
                                          spamm_cfg=self.spamm_ctx),
                "prefill"))
            return
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        # the body computes one shard locally: a mesh-free ctx makes every
        # ctx.shard a no-op (no nested sharding constraints), and the frozen
        # plans / caches arrive with a leading shard dim that the body peels
        body_ctx = NetCtx(mesh=None, batch_axes=(),
                          model_axis=self.ctx.model_axis)
        inner_pre = M.make_prefill_step(cfg, pcfg, body_ctx,
                                        spamm_cfg=self.spamm_ctx)
        inner_dec = M.make_decode_step(cfg, pcfg, body_ctx,
                                       spamm_cfg=self.spamm_ctx)

        def unstack(tree):
            return jax.tree.map(lambda t: t[0], tree)

        def restack(tree):
            return jax.tree.map(lambda t: t[None], tree)

        def pre_body(params, batch, frozen):
            cache, logits = inner_pre(params, batch, unstack(frozen))
            return restack(cache), logits

        def dec_body(params, inp, cache, pos, frozen):
            logits, cache = inner_dec(params, inp, unstack(cache), pos,
                                      unstack(frozen))
            return logits, restack(cache)

        mesh = self._spamm_mesh
        self._prefill = jax.jit(shard_map(
            self._counted(pre_body, "prefill"), mesh=mesh,
            in_specs=(P(), P("rows"), P("rows")),
            out_specs=(P("rows"), P("rows"))))
        self._decode = jax.jit(shard_map(
            self._counted(dec_body, "decode"), mesh=mesh,
            in_specs=(P(), P("rows"), P("rows"), P(), P("rows")),
            out_specs=(P("rows"), P("rows"))))
        self._chunk = None
        if chunkable:
            inner_chunk = M.make_prefill_chunk_step(
                cfg, pcfg, body_ctx, spamm_cfg=self.spamm_ctx)

            def chunk_body(params, batch, cache, positions, last_idx,
                           frozen):
                cache, logits = inner_chunk(params, batch, unstack(cache),
                                            positions, last_idx,
                                            unstack(frozen))
                return restack(cache), logits

            self._chunk = jax.jit(shard_map(
                self._counted(chunk_body, "prefill"), mesh=mesh,
                in_specs=(P(), P("rows"), P("rows"), P("rows"), P("rows"),
                          P("rows")),
                out_specs=(P("rows"), P("rows"))))

    # -- drift-triggered re-sharding (control plane) -------------------------
    @property
    def partition_offsets(self):
        """Live equal-work row-offset table (None until the first probe) —
        what a pod deployment passes to `distributed.spamm_rowpart`."""
        return self._resharder.offsets if self._resharder else None

    @property
    def shard_layout(self):
        """Live wave layout in REQUEST units — None when unsharded or
        before the first wave. `offsets` cuts the batch into per-shard
        request ranges; `slot_width` is the padded per-shard slot count
        every shard allocates; `real` the per-shard live request counts."""
        if not self._sharded or self._shard is None:
            return None
        tile = self.spamm_ctx.cfg.tile
        offs = self._shard["offs_g"] * tile
        return {"offsets": offs,
                "slot_width": int(self._shard["wmax_g"]) * tile,
                "real": [int(r) for r in np.diff(offs)]}

    def _maybe_reshard(self, requests, outs, cache=None, cur=None):
        """Advance the engine step counter; at the configured cadence,
        re-probe the coarse work estimate from the live tokens (prompts +
        generated so far) and let the controller re-cut on drift
        (`model.reshard_probe` is the shared probe body). Never touches the
        computed values. In pod-sharded mode a re-cut additionally swaps
        the live wave's tables and re-gathers `cache`/`cur` host-side along
        the slot permutation — same static shapes and shardings, so the
        jitted steps' cache entries survive (`trace_counts` proves it).
        Returns the (possibly re-gathered) `(cache, cur)`."""
        step, self._steps = self._steps, self._steps + 1
        rs = self._resharder
        if rs is None or not rs.due(step):
            return cache, cur
        win = rs.cfg.probe_window
        # per-request most-recent window keeps probe cost constant as
        # generation grows (the estimate tracks the live distribution; the
        # distant past doesn't shard the next step's rows anyway)

        def recent(r, o):
            t = np.concatenate([np.asarray(r.prompt, np.int64),
                                np.asarray(o, np.int64)])
            return t[-win:] if win else t

        toks = np.concatenate([recent(r, o)
                               for r, o in zip(requests, outs)])
        with self.obs.span("reshard_probe", step=step):
            M.reshard_probe(rs, self.spamm_ctx, self.params, step,
                            tokens=toks)
        if self._sharded and self._shard is not None:
            src = self._refresh_shard()
            if src is not None:
                with self.obs.span("cache_permute", step=step):
                    if cache is not None:
                        cache = self._permute_cache(cache, src)
                    if cur is not None:
                        from jax.sharding import NamedSharding
                        from jax.sharding import PartitionSpec as P

                        cur = jax.device_put(
                            jnp.take(cur, jnp.asarray(src), axis=0),
                            NamedSharding(self._spamm_mesh, P("rows")))
        if self.obs.enabled and rs is not None:
            rs.publish(self.obs.registry)
        return cache, cur

    # -- frozen-plan assembly ------------------------------------------------
    def _frozen_for(self, rows: int) -> dict:
        """The FrozenPlan pytree for a step whose gated GEMMs see `rows`
        flattened activation rows — built once per row-tile grid and reused
        (the jitted steps recompile per shape anyway, so this adds no
        compiles). Stacked layers get stacked plans (scan xs)."""
        if not self._freeze:
            return {}
        scfg = self.spamm_ctx.cfg
        tile = scfg.tile
        gm = (rows + tile - 1) // tile
        hit = self._fp_cache.get(gm)
        if hit is not None:
            return hit
        self._ensure_fw_tree()
        with self.obs.span("plan_assembly", gm=gm):
            return self._assemble_frozen(gm)

    def _assemble_frozen(self, gm: int) -> dict:
        from repro.plans.frozen import stack_plans

        def specialize(node):
            if isinstance(node, dict):
                return {k: specialize(v) for k, v in node.items()}
            if isinstance(node, list):
                # per-layer plans must share one step bucket to stack into a
                # scan input; padding steps carry a clear `real` bit. Each
                # weight's autotuned bucket floor participates in the max, so
                # the common bucket honors every layer's tuned floor (the
                # result is a power of two ≥ each floor, hence stable under
                # every layer's own for_rows flooring).
                bucket = max(_bucket(gm * fw.num_kj, fw.bucket_floor)
                             for fw in node)
                return stack_plans(
                    [fw.for_rows(gm, min_steps=bucket) for fw in node])
            return node.for_rows(gm)

        tree = specialize(self._fw_tree)
        self._fp_cache[gm] = tree
        return tree

    def _ensure_fw_tree(self):
        """Freeze the weight-side gating artifacts once (warm-started from
        the plan store when present) — shared by the single-device and
        pod-sharded assembly paths."""
        if self._fw_tree is None:
            from repro.plans.precompute import freeze_tree

            with self.obs.span("freeze",
                               store=self.plan_store is not None):
                self._fw_tree, _ = freeze_tree(
                    self.params, self.spamm_ctx.cfg,
                    cache=self.spamm_ctx.cache, store=self.plan_store)

    def _note_gm(self, gm: int, n: int = 1):
        self._gm_hist[int(gm)] = self._gm_hist.get(int(gm), 0) + int(n)

    @property
    def gm_histogram(self) -> dict:
        """Observed serving row-grid histogram {gm row tiles: executed gated
        step count}. Feed it to `core.cost.tune_weight(gm_hist=...)` so the
        tuner prices the grids this engine actually runs instead of the
        synthetic `DEFAULT_TUNE_GM`."""
        return dict(self._gm_hist)

    # -- pod-sharded wave layout ---------------------------------------------
    def _group_offsets(self, G: int, wmax_g: int) -> np.ndarray:
        """The live cut re-expressed on the wave's request-group grid and
        clamped to the static shard width (uniform until the first probe)."""
        rs = self._resharder
        src = (np.asarray(rs.offsets, np.int64)
               if rs is not None and rs.offsets is not None
               else np.arange(self._ndev + 1, dtype=np.int64))
        return _schedule.rescale_offsets(src, G, max_width=wmax_g)

    def _shard_tables(self, offs_g: np.ndarray, wmax_g: int, G: int) -> dict:
        """Request-level gather tables for one cut: `perm` lists, per padded
        slot in (device, slot) order, the request that fills it (pad slots
        clamp-replicate their strip's last group, so every slot carries live
        data and no garbage feeds the tile gates); `keep` marks real slots;
        `real_slots[r]` is the unique kept slot holding request r."""
        tile = self.spamm_ctx.cfg.tile
        perm_g, keep_g = _schedule.strip_tables(
            offs_g, G, self._ndev, width=wmax_g)
        perm = (perm_g[:, None] * tile + np.arange(tile)).reshape(-1)
        keep = np.repeat(keep_g, tile)
        slots = np.nonzero(keep)[0]
        real = np.empty(G * tile, np.int64)
        real[perm[slots]] = slots
        return {"G": int(G), "wmax_g": int(wmax_g),
                "offs_g": np.asarray(offs_g, np.int64),
                "perm": perm, "keep": keep, "real_slots": real}

    def _begin_wave(self, b: int, plen: int):
        """Lay a wave out on the mesh: cut the request groups by the live
        offsets and pin the static per-shard width for the whole wave, so a
        mid-wave re-cut can never change a shape."""
        tile = self.spamm_ctx.cfg.tile
        ndev = self._ndev
        if b % tile:
            raise ValueError(
                f"pod-sharded serving needs batch % tile == 0 (got b={b}, "
                f"tile={tile}): gating is per row tile, and a shard cut "
                f"inside a tile would change tile membership and the gate")
        if plen % tile:
            raise ValueError(
                f"pod-sharded serving needs prompt length % tile == 0 (got "
                f"plen={plen}, tile={tile}) so prefill row tiles never "
                f"straddle a request boundary")
        G = b // tile
        if G < ndev:
            raise ValueError(
                f"{G} request group(s) of tile={tile} requests cannot fill "
                f"{ndev} shards — grow the batch to at least tile*ndev="
                f"{tile * ndev}")
        ceil_g = -(-G // ndev)
        cap = int(self._shard_width) if self._shard_width else 2 * ceil_g
        wmax_g = max(ceil_g, min(G, cap))
        self._shard = self._shard_tables(
            self._group_offsets(G, wmax_g), wmax_g, G)

    def _refresh_shard(self):
        """Re-cut the live wave from the controller's current offsets.
        Returns the old→new global-slot gather, or None when the cut (at
        request-group granularity) did not move."""
        sh = self._shard
        offs_g = self._group_offsets(sh["G"], sh["wmax_g"])
        if np.array_equal(offs_g, sh["offs_g"]):
            return None
        new = self._shard_tables(offs_g, sh["wmax_g"], sh["G"])
        src = sh["real_slots"][new["perm"]]
        self._shard = new
        return src

    def _sharded_frozen_for(self, tpg: int) -> dict:
        """Per-shard FrozenPlan pytree for the live cut, stacked on a
        leading mesh dim — `tpg` is row tiles per request group (plen for
        prefill, 1 for decode). Sliced ON HOST from the frozen weight-side
        tables and cached per (tpg, width, offsets): a re-cut back to a
        seen cut is a dict hit, a fresh cut costs only numpy slicing, and
        either way the jitted steps never see a new shape."""
        sh = self._shard
        key = (tpg, sh["wmax_g"], tuple(int(x) for x in sh["offs_g"]))
        hit = self._sfp_cache.get(key)
        if hit is not None:
            return hit
        self._ensure_fw_tree()
        with self.obs.span("plan_assembly", tpg=tpg, sharded=True):
            return self._assemble_sharded(tpg, key)

    def _assemble_sharded(self, tpg: int, key) -> dict:
        sh = self._shard

        from repro.plans.frozen import stack_plans

        offs = sh["offs_g"] * tpg      # the cut, on this step's row-tile grid
        W = sh["wmax_g"] * tpg         # padded per-shard row-tile width
        ndev = self._ndev

        def specialize(node):
            if isinstance(node, dict):
                return {k: specialize(v) for k, v in node.items()}
            if isinstance(node, list):
                # same cross-layer common-bucket rule as `_frozen_for`, but
                # computed at the PADDED width so every shard — and every
                # future cut at this width — lands on one step count
                bucket = max(_bucket(W * fw.num_kj, fw.bucket_floor)
                             for fw in node)
                shards = [stack_plans([fw.slice_rows(
                    int(offs[d]), int(offs[d + 1]), gm=W, min_steps=bucket)
                    for fw in node]) for d in range(ndev)]
                return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
            return node.shard_by_offsets(offs, width=W)

        tree = specialize(self._fw_tree)
        self._sfp_cache[key] = tree
        return tree

    def _permute_cache(self, cache, src):
        """Host-side re-gather of the stacked decode cache along the
        old→new slot map `src` (a re-cut is rare; the jitted steps never
        see this op). Leaves come back committed to the mesh with the same
        P("rows") layout the steps emit, so the swap cannot perturb the jit
        cache key."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        ndev = self._ndev
        rows = NamedSharding(self._spamm_mesh, P("rows"))
        idx = jnp.asarray(src)

        def fix(path, t):
            keys = [getattr(k, "key", None) for k in path]
            name = keys[-1] if keys else None
            # batch-axis-from-the-end suffix rule (model.cache_pspecs):
            # counting from the end survives the leading mesh-stack dim
            if name in ("k", "v", "state"):
                ba = t.ndim - 4
            elif name == "h":
                ba = t.ndim - 2
            elif name == "conv":
                ba = t.ndim - 3
            else:
                return t
            u = jnp.moveaxis(t, ba, 1)
            per = u.shape[1]
            u = u.reshape((ndev * per,) + u.shape[2:])
            u = jnp.take(u, idx, axis=0)
            u = u.reshape((ndev, per) + u.shape[1:])
            return jax.device_put(jnp.moveaxis(u, 1, ba), rows)

        return jax.tree_util.tree_map_with_path(fix, cache)

    def _pad_cache(self, cache, *, full: bool = False):
        """Grow linear KV caches to the engine's slot budget: max_len, or
        the sliding window when one is smaller (the decode ring). With
        `full=True` always grow to max_len — chunked prefill scatters at
        absolute positions, so windowed archs keep a LINEAR full-length
        cache (the window applies as a mask; `layer_decode`'s ring
        condition turns itself off on a cache longer than the window, and
        when window >= max_len keeps it True the per-row decode path still
        writes linearly — it never applies the ring modulo, so position
        sentinels drop instead of wrapping)."""
        target = (
            min(self.max_len, self.cfg.sliding_window)
            if self.cfg.sliding_window and not full else self.max_len
        )

        def grow(path, t):
            keys = [getattr(k, "key", None) for k in path]
            if keys and keys[-1] in ("k", "v") and t.shape[-3] < target:
                pad = [(0, 0)] * t.ndim
                pad[-3] = (0, target - t.shape[-3])
                return jnp.pad(t, pad)
            return t

        return jax.tree_util.tree_map_with_path(grow, cache)

    def _spamm_stats(self, taps, hits0: int, misses0: int,
                     store0: Optional[tuple], reshard0: Optional[tuple],
                     byte_taps=(), cost_taps=(), ttft_s=None,
                     decode_lat=()):
        """Per-wave gating stats dict from the drained `module.Tap` events
        and the plan-cache/plan-store counter DELTAS across this wave
        (every counter in the dict is per-wave: after first population a
        warm wave reports 0/0 store traffic, never stale lifetime totals).
        With re-sharding on, `resharded`/`reshard_probes` are the wave's
        event deltas and `partition_imbalance` the live partition's
        predicted imbalance at the last probe. `byte_taps` (the context's
        bytes-moved channel, frozen-path GEMMs only) reports SUMS per phase:
        bandwidth adds up across GEMMs where fractions average. In
        pod-sharded mode the taps fire PER SHARD (io_callback runs on every
        mesh device), so `gated_gemms` counts scale by mesh size and the
        fractions average over shards — pad tiles included, which is the
        honest number: pad steps are part of each shard's bucket.

        Labeled channels (new in the telemetry subsystem):

        - `per_layer`: {layer: {site: {...}}} breakdown of the same taps —
          fractions average and counts/bytes sum within each (layer, site)
          cell, so summing `gated_gemms` over cells reproduces the wave
          aggregate exactly. Taps without a layer label (layer < 0: eager
          callers, MoE shard_map interiors) stay out of the breakdown but
          remain in the aggregates.
        - `latency`: host wall-clock — `ttft_s` (wave start to first token
          materialized) and decode-step stats (mean/p50/p95 over the wave's
          measured inter-token gaps; p50/p95 are bucket-interpolated from
          a wave-local histogram with the registry's latency ladder).
        - `cost_residual`: per phase, the roofline-predicted seconds summed
          over this wave's executed gated GEMMs (÷ mesh size when sharded:
          taps fire per shard, shards run concurrently) paired with the
          measured wall-clock, plus log2(measured/predicted). Only present
          when the cost channel is armed (engine obs enabled) and both
          sides are positive.
        """
        cache = self.spamm_ctx.cache
        pre = [t.value for t in taps if t.phase != "decode"]
        dec = [t.value for t in taps if t.phase == "decode"]
        pre_b = [t.value for t in byte_taps if t.phase != "decode"]
        dec_b = [t.value for t in byte_taps if t.phase == "decode"]
        stats = {
            "valid_fraction": float(np.mean(pre)) if pre else None,
            "gated_gemms": len(pre),
            "decode_valid_fraction": float(np.mean(dec)) if dec else None,
            "decode_gated_gemms": len(dec),
            "compute_dtype": getattr(self.spamm_ctx.cfg, "dtype", "float32"),
            "gemm_bytes_moved": float(np.sum(pre_b)) if pre_b else None,
            "decode_gemm_bytes_moved": float(np.sum(dec_b)) if dec_b else None,
            "plan_cache_hits": cache.hits - hits0,
            "plan_cache_misses": cache.misses - misses0,
        }
        if store0 is not None:
            stats["plan_store_hits"] = self.plan_store.hits - store0[0]
            stats["plan_store_misses"] = self.plan_store.misses - store0[1]
        if reshard0 is not None:
            rs = self._resharder
            stats["resharded"] = rs.resharded - reshard0[0]
            stats["reshard_probes"] = rs.probes - reshard0[1]
            stats["partition_imbalance"] = rs.live_imbalance
        # -- per-(layer, site) breakdown ------------------------------------
        acc: dict = {}
        for t in taps:
            if t.layer < 0:
                continue
            a = acc.setdefault((t.layer, t.site or ""),
                               [0.0, 0, 0.0, 0, 0.0])
            if t.phase == "decode":
                a[2] += t.value
                a[3] += 1
            else:
                a[0] += t.value
                a[1] += 1
        for t in byte_taps:
            if t.layer < 0:
                continue
            a = acc.setdefault((t.layer, t.site or ""),
                               [0.0, 0, 0.0, 0, 0.0])
            a[4] += t.value
        per_layer: dict = {}
        for (layer, site), a in sorted(acc.items()):
            per_layer.setdefault(layer, {})[site] = {
                "valid_fraction": a[0] / a[1] if a[1] else None,
                "gated_gemms": a[1],
                "decode_valid_fraction": a[2] / a[3] if a[3] else None,
                "decode_gated_gemms": a[3],
                "gemm_bytes_moved": a[4] if a[4] else None,
            }
        stats["per_layer"] = per_layer
        # -- latency ---------------------------------------------------------
        decode_lat = list(decode_lat)
        if ttft_s is not None or decode_lat:
            lat = {"ttft_s": ttft_s, "decode_steps": len(decode_lat)}
            if decode_lat:
                h = Histogram("wave_decode_step_seconds",
                              buckets=LATENCY_BUCKETS_S)
                for v in decode_lat:
                    h.observe(v)
                lat["decode_mean_s"] = float(np.mean(decode_lat))
                lat["decode_p50_s"] = h.quantile(0.5)
                lat["decode_p95_s"] = h.quantile(0.95)
            stats["latency"] = lat
        # -- cost residual ---------------------------------------------------
        if cost_taps:
            ndev = self._ndev if self._sharded else 1
            pred_pre = sum(t.value for t in cost_taps
                           if t.phase != "decode") / ndev
            pred_dec = sum(t.value for t in cost_taps
                           if t.phase == "decode") / ndev
            meas_dec = float(np.sum(decode_lat)) if decode_lat else 0.0
            cres = {}
            for phase, pred, meas in (("prefill", pred_pre, ttft_s or 0.0),
                                      ("decode", pred_dec, meas_dec)):
                if pred > 0.0 and meas > 0.0:
                    r = self.obs.residual.record(phase, pred, meas)
                    cres[phase] = {"predicted_s": pred, "measured_s": meas,
                                   "log2_ratio": r}
            if cres:
                stats["cost_residual"] = cres
        # -- registry feed ---------------------------------------------------
        if self.obs.enabled:
            for t in taps:
                lab = dict(phase=t.phase, layer=t.layer, site=t.site or "")
                self._m_vf.observe(t.value, **lab)
                self._m_gemms.inc(**lab)
            dtype = stats["compute_dtype"]
            for t in byte_taps:
                self._m_bytes.inc(t.value, phase=t.phase, layer=t.layer,
                                  site=t.site or "", dtype=dtype)
            self._m_cache.inc(stats["plan_cache_hits"], result="hit")
            self._m_cache.inc(stats["plan_cache_misses"], result="miss")
            if store0 is not None:
                self._m_store.inc(stats["plan_store_hits"], result="hit")
                self._m_store.inc(stats["plan_store_misses"], result="miss")
        return stats

    # -- wave layout / dispatch ----------------------------------------------
    def _default_chunk(self) -> int:
        """Tile-aligned default chunk size for the auto mixed-length path."""
        tile = (self.spamm_ctx.cfg.tile
                if self.spamm_ctx is not None and self.spamm_ctx.enable
                else 1)
        return -(-16 // tile) * tile

    def _resolve_chunk(self, mixed: bool) -> Optional[int]:
        """The chunk size this batch prefills at, or None for one-shot."""
        pc = self._prefill_chunk
        if pc is not None and not pc:      # 0/False: chunking disabled
            return None
        if pc is None:                     # auto: chunk only when needed
            if not mixed or self._sharded or self._chunk is None:
                return None
            return self._default_chunk()
        return int(pc)

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Greedy-decode a batch of prompts. Equal-length batches run the
        lockstep wave (one-shot prefill unless `prefill_chunk` asks for
        chunking); mixed-length batches run the chunked slot scheduler on
        attention stacks — every prompt's tokens are used in full. Batches
        the engine cannot serve faithfully raise ValueError instead of
        silently truncating: prompts longer than max_len - 1, and mixed
        lengths where chunking is unavailable (recurrent stacks,
        pod-sharded mode, or an explicit `prefill_chunk=0`).

        When SpAMM is enabled, each request's `out` metadata carries the
        gating stats of its wave, split by phase: prefill (valid_fraction /
        gated_gemms over the gated prefill GEMMs) and decode
        (decode_valid_fraction / decode_gated_gemms summed over the wave's
        decode steps), plus plan-cache hit/miss deltas, a `per_layer`
        breakdown keyed by layer index and GEMM site, `latency` (TTFT and
        decode-step wall-clock stats), and — when the cost channel is armed
        — a `cost_residual` predicted-vs-measured pairing per phase (see
        `_spamm_stats`).

        Host timing uses the lockstep loop's OWN blocking points: the loop
        top's `np.asarray(cur)` blocks on the previous step's output, so the
        engine records `perf_counter_ns` at dispatch and closes the span
        retroactively at the next block (`SpanTracer.add_complete`) — zero
        added device syncs, which is how the instrumented engine stays
        within the obs_overhead benchmark's budget.
        """
        assert requests, "empty batch"
        plens = [len(r.prompt) for r in requests]
        if min(plens) < 1:
            raise ValueError("empty prompt")
        if max(plens) > self.max_len - 1:
            raise ValueError(
                f"prompt of {max(plens)} tokens does not fit "
                f"max_len={self.max_len} (a sequence needs at least one "
                f"decode slot) — raise max_len instead of losing prompt "
                f"tokens")
        mixed = len(set(plens)) > 1
        chunk = self._resolve_chunk(mixed)
        if not self._sharded and chunk:
            return self._generate_chunked(requests, chunk)
        if mixed:
            # loud rejection instead of the old silent left-trim to the
            # shortest prompt: every alternative here loses prompt tokens
            if self._sharded:
                raise ValueError(
                    "pod-sharded serving needs equal-length prompts (the "
                    "chunked mixed-length scheduler is unsharded-only); "
                    "pad client-side or serve unsharded")
            if self._chunk is None:
                raise ValueError(
                    f"{stack_kinds(self.cfg)!r} stacks cannot chunk "
                    f"mixed-length prompts (recurrent prefill state does "
                    f"not checkpoint at a chunk boundary); pad client-side "
                    f"to one length")
            raise ValueError(
                "mixed-length prompts need chunked prefill, but "
                "prefill_chunk=0 disabled it; drop the override or pad "
                "client-side")
        return self._generate_wave(requests, chunk)

    def _generate_wave(self, requests: List[Request],
                       chunk: Optional[int] = None) -> List[np.ndarray]:
        """Lockstep wave: prefill the whole (equal-length) batch, decode
        until every sequence finishes. `chunk` (pod-sharded mode only —
        unsharded chunked batches take `_generate_chunked`) swaps the
        one-shot prefill for a chunk loop at one static shard shape."""
        b = len(requests)
        plen = len(requests[0].prompt)
        toks = np.stack([r.prompt for r in requests]).astype(np.int32)
        collect = self.spamm_ctx is not None and self.spamm_ctx.enable
        obs_on = self.obs.enabled
        t_wave0 = time.perf_counter_ns() if obs_on else 0
        pend = None          # (name, t0_ns) of a dispatched, un-blocked span
        ttft_s = None
        decode_lat: list = []
        spamm_meta = None
        store0 = None
        reshard0 = None
        if collect:
            hits0 = self.spamm_ctx.cache.hits
            misses0 = self.spamm_ctx.cache.misses
            if self.plan_store is not None:
                store0 = (self.plan_store.hits, self.plan_store.misses)
            if self._resharder is not None:
                reshard0 = (self._resharder.resharded, self._resharder.probes)
        # frozen-plan assembly counts into this wave's store deltas (it is
        # where first population / warm-start loading happens)
        if self._sharded:
            self._begin_wave(b, plen)
            frozen_pre = self._sharded_frozen_for(plen)
            frozen_dec = self._sharded_frozen_for(1)
        else:
            frozen_pre = self._frozen_for(b * plen)
            frozen_dec = self._frozen_for(b) if self._freeze else {}
        tile = self.spamm_ctx.cfg.tile if collect else 0
        if collect:
            self.spamm_ctx.begin_stats()
        try:
            if collect:
                self.spamm_ctx.set_phase("prefill")
            outs = [[] for _ in range(b)]
            self._maybe_reshard(requests, outs)
            if self._sharded:
                # the step-0 probe above may have laid down the first cut;
                # re-read the wave tables (dict hits unless the cut moved)
                # and put the batch in padded (device, slot) order
                frozen_pre = self._sharded_frozen_for(plen)
                frozen_dec = self._sharded_frozen_for(1)
                toks_in = toks[self._shard["perm"]]
            else:
                toks_in = toks
            if obs_on:
                pend = ("prefill", time.perf_counter_ns())
            if chunk:
                cache, logits = self._sharded_chunk_prefill(
                    toks_in, plen, chunk)
            else:
                cache, logits = self._prefill(
                    self.params, {"tokens": jnp.asarray(toks_in)},
                    frozen_pre)
                if collect:
                    if self._sharded:
                        self._note_gm(self._shard["wmax_g"] * plen,
                                      self._ndev)
                    else:
                        self._note_gm(-(-(b * plen) // tile))
                cache = self._pad_cache(cache)
            done = np.zeros(b, bool)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            pos = plen
            budget = max(r.max_new_tokens for r in requests)
            if collect:
                self.spamm_ctx.set_phase("decode")
            for t in range(budget):
                vis = np.asarray(cur)   # blocks on the previous step
                if pend is not None:
                    t1 = time.perf_counter_ns()
                    name, t0_ns = pend
                    pend = None
                    self.obs.tracer.add_complete(name, t0_ns, t1, step=t)
                    if name == "prefill":
                        ttft_s = (t1 - t_wave0) / 1e9
                        self._m_ttft.observe(ttft_s)
                    else:
                        dt = (t1 - t0_ns) / 1e9
                        decode_lat.append(dt)
                        self._m_decode_s.observe(dt)
                if self._sharded:
                    # pad slots mirror their strip's last real group; the
                    # kept-slot table reads each request exactly once
                    vis = vis[self._shard["real_slots"]]
                for i, r in enumerate(requests):
                    if not done[i]:
                        outs[i].append(int(vis[i]))
                        if (r.eos_id is not None and int(vis[i]) == r.eos_id) or \
                           len(outs[i]) >= r.max_new_tokens:
                            done[i] = True
                if done.all() or pos >= self.max_len - 1:
                    break
                if obs_on:
                    # the decode-step interval opens HERE so reshard stalls
                    # (probe + cache permute) land inside the step's latency
                    pend = ("decode_step", time.perf_counter_ns())
                cache, cur = self._maybe_reshard(requests, outs, cache, cur)
                if self._sharded:
                    frozen_dec = self._sharded_frozen_for(1)
                logits, cache = self._decode(
                    self.params, cur[:, None], cache, jnp.int32(pos),
                    frozen_dec
                )
                if collect:
                    if self._sharded:
                        self._note_gm(self._shard["wmax_g"], self._ndev)
                    else:
                        self._note_gm(-(-b // tile))
                cur = jnp.argmax(logits, -1).astype(jnp.int32)
                pos += 1
        finally:
            if collect:
                # unordered io_callbacks are NOT flushed by output readiness
                # — effects_barrier is the documented flush; the finally
                # closes the collect window even on a failed step so the
                # context's telemetry can't be left collecting forever
                jax.effects_barrier()
                byte_taps = self.spamm_ctx.drain_byte_stats()
                cost_taps = self.spamm_ctx.drain_cost_stats()
                taps = self.spamm_ctx.end_stats()
                self.spamm_ctx.set_phase("prefill")
            if pend is not None:
                # loop left by budget exhaustion with a step still in
                # flight: close its span at wall-clock now (no forced
                # block), but keep it out of the latency histogram —
                # only fully-blocked intervals are measurements
                self.obs.tracer.add_complete(pend[0], pend[1],
                                             time.perf_counter_ns())
                pend = None
        if collect:
            spamm_meta = self._spamm_stats(taps, hits0, misses0, store0,
                                           reshard0, byte_taps, cost_taps,
                                           ttft_s, decode_lat)
        results = [np.asarray(o, np.int32) for o in outs]
        if obs_on:
            self.obs.tracer.add_complete("wave", t_wave0,
                                         time.perf_counter_ns(),
                                         batch=b, prompt_len=plen)
            self._m_waves.inc()
            self._m_tokens.inc(sum(len(o) for o in results))
        for r, toks_out in zip(requests, results):
            r.out = {"tokens": toks_out, "spamm": spamm_meta}
        return results

    def _sharded_chunk_prefill(self, toks_in: np.ndarray, plen: int,
                               chunk: int):
        """Prefill the padded sharded wave in `chunk`-token chunks at ONE
        static shard shape. Pad slots replicate live rows (the clamp-pad
        idiom), so every chunk runs the identical program; a partial final
        chunk clamp-pads its token tail and carries sentinel positions
        (>= max_len) there, whose drop-mode cache writes vanish. Returns
        (stacked full-length linear cache, final-chunk logits)."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        collect = self.spamm_ctx is not None and self.spamm_ctx.enable
        btot = toks_in.shape[0]
        per = btot // self._ndev
        one = self._pad_cache(
            M.init_cache(self.cfg, self.pcfg, per, self.max_len), full=True)
        stacked = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (self._ndev, *t.shape)), one)
        rows = NamedSharding(self._spamm_mesh, P("rows"))
        cache = jax.tree.map(lambda t: jax.device_put(t, rows), stacked)
        frozen_ck = self._sharded_frozen_for(chunk)
        logits = None
        for lo in range(0, plen, chunk):
            n = min(chunk, plen - lo)
            tk = np.empty((btot, chunk), np.int32)
            tk[:, :n] = toks_in[:, lo:lo + n]
            if n < chunk:
                tk[:, n:] = tk[:, n - 1:n]
            posr = np.full(chunk, self.max_len, np.int32)
            posr[:n] = lo + np.arange(n)
            pos = np.broadcast_to(posr, (btot, chunk)).copy()
            last = np.full(btot, n - 1 if lo + n >= plen else -1, np.int32)
            cache, logits = self._chunk(
                self.params, {"tokens": jnp.asarray(tk)}, cache,
                jnp.asarray(pos), jnp.asarray(last), frozen_ck)
            if collect:
                self._note_gm(self._shard["wmax_g"] * chunk, self._ndev)
            if self.obs.enabled:
                self._m_chunks.inc()
        return cache, logits

    def _generate_chunked(self, requests: List[Request],
                          chunk: int) -> List[np.ndarray]:
        """Slot scheduler: chunked prefill interleaved with decode over a
        power-of-two-bucketed slot pool. Per iteration: (1) queued requests
        are admitted into idle slots, (2) every prefilling slot advances by
        one `chunk`-token chunk at ONE static (slots, chunk) shape — a slot
        whose prompt ends inside the chunk captures its first generated
        token from that chunk's logits, (3) pending tokens are emitted and
        finished slots freed, (4) one decode step runs over the decoding
        slots at per-slot positions. Idle/pad lanes carry position
        sentinels (>= max_len): their cache writes drop and their outputs
        are never read. Termination per slot matches the lockstep wave
        exactly (EOS / max_new_tokens / pos >= max_len - 1 at emit time)."""
        b = len(requests)
        collect = self.spamm_ctx is not None and self.spamm_ctx.enable
        obs_on = self.obs.enabled
        cap = min(b, self._max_slots) if self._max_slots else b
        nslots = _bucket(cap, 1)
        if self._max_slots and nslots > self._max_slots:
            # the bucket ladder rounds UP — past a non-power-of-two
            # max_slots that would run up to 2x the capped slot pool, so
            # floor to the largest power of two that honors the cap
            nslots = _floor_pow2(self._max_slots)
        tile = self.spamm_ctx.cfg.tile if collect else 0
        t_wave0 = time.perf_counter_ns() if obs_on else 0
        ttft_s = None
        decode_lat: list = []
        spamm_meta = None
        store0 = None
        reshard0 = None
        if collect:
            hits0 = self.spamm_ctx.cache.hits
            misses0 = self.spamm_ctx.cache.misses
            if self.plan_store is not None:
                store0 = (self.plan_store.hits, self.plan_store.misses)
            if self._resharder is not None:
                reshard0 = (self._resharder.resharded,
                            self._resharder.probes)
        frozen_ck = self._frozen_for(nslots * chunk)
        frozen_dec = self._frozen_for(nslots) if self._freeze else {}
        cache = self._pad_cache(
            M.init_cache(self.cfg, self.pcfg, nslots, self.max_len),
            full=True)
        outs: List[list] = [[] for _ in range(b)]
        queue = list(range(b))
        slot_req = [-1] * nslots       # request index per slot, -1 when idle
        mode = ["idle"] * nslots       # idle | prefill | decode
        cursor = [0] * nslots          # prompt tokens already fed
        pos = [0] * nslots             # tokens materialized in the cache
        pending: List[Optional[int]] = [None] * nslots
        cur = np.zeros(nslots, np.int32)
        if collect:
            self.spamm_ctx.begin_stats()
        try:
            while queue or any(m != "idle" for m in mode):
                if obs_on:
                    self._m_queue.observe(len(queue))
                # -- admission: queued requests claim idle slots ----------
                for s in range(nslots):
                    if mode[s] == "idle" and queue:
                        slot_req[s] = queue.pop(0)
                        mode[s] = "prefill"
                        cursor[s] = pos[s] = 0
                        pending[s] = None
                        if obs_on:
                            self._m_admit.inc()
                if obs_on:
                    self._m_occupancy.observe(
                        sum(m != "idle" for m in mode))
                # -- one chunk of prefill over the prefilling slots -------
                if any(m == "prefill" for m in mode):
                    tk = np.zeros((nslots, chunk), np.int32)
                    posc = np.full((nslots, chunk), self.max_len, np.int32)
                    last = np.full(nslots, -1, np.int32)
                    fin = []
                    for s in range(nslots):
                        if mode[s] != "prefill":
                            continue
                        pr = np.asarray(requests[slot_req[s]].prompt,
                                        np.int32)
                        n = min(len(pr) - cursor[s], chunk)
                        tk[s, :n] = pr[cursor[s]:cursor[s] + n]
                        if n < chunk:
                            tk[s, n:] = tk[s, n - 1]
                        posc[s, :n] = cursor[s] + np.arange(n)
                        cursor[s] += n
                        if cursor[s] >= len(pr):
                            last[s] = n - 1
                            fin.append(s)
                    if collect:
                        self.spamm_ctx.set_phase("prefill")
                    t0 = time.perf_counter_ns() if obs_on else 0
                    cache, logits = self._chunk(
                        self.params, {"tokens": jnp.asarray(tk)}, cache,
                        jnp.asarray(posc), jnp.asarray(last), frozen_ck)
                    step_tok = np.asarray(
                        jnp.argmax(logits, -1).astype(jnp.int32))
                    if obs_on:
                        t1 = time.perf_counter_ns()
                        self.obs.tracer.add_complete("prefill_chunk", t0, t1)
                        self._m_chunks.inc()
                    if collect:
                        self._note_gm(-(-(nslots * chunk) // tile))
                    self._maybe_reshard(requests, outs)
                    for s in fin:
                        mode[s] = "decode"
                        pos[s] = len(requests[slot_req[s]].prompt)
                        pending[s] = int(step_tok[s])
                    if fin and ttft_s is None and obs_on:
                        ttft_s = (time.perf_counter_ns() - t_wave0) / 1e9
                        self._m_ttft.observe(ttft_s)
                # -- emit pending tokens; finished slots free -------------
                for s in range(nslots):
                    if mode[s] != "decode" or pending[s] is None:
                        continue
                    r = requests[slot_req[s]]
                    tok = pending[s]
                    pending[s] = None
                    outs[slot_req[s]].append(tok)
                    if ((r.eos_id is not None and tok == r.eos_id)
                            or len(outs[slot_req[s]]) >= r.max_new_tokens
                            or pos[s] >= self.max_len - 1):
                        mode[s] = "idle"
                        slot_req[s] = -1
                # -- one decode step over the decoding slots --------------
                dec = [s for s in range(nslots) if mode[s] == "decode"]
                if dec:
                    posv = np.full(nslots, self.max_len, np.int32)
                    for s in dec:
                        cur[s] = outs[slot_req[s]][-1]
                        posv[s] = pos[s]
                    if collect:
                        self.spamm_ctx.set_phase("decode")
                    t0 = time.perf_counter_ns() if obs_on else 0
                    logits, cache = self._decode(
                        self.params, jnp.asarray(cur)[:, None], cache,
                        jnp.asarray(posv), frozen_dec)
                    step_tok = np.asarray(
                        jnp.argmax(logits, -1).astype(jnp.int32))
                    if obs_on:
                        t1 = time.perf_counter_ns()
                        dt = (t1 - t0) / 1e9
                        self.obs.tracer.add_complete("decode_step", t0, t1)
                        decode_lat.append(dt)
                        self._m_decode_s.observe(dt)
                    if collect:
                        self._note_gm(-(-nslots // tile))
                    self._maybe_reshard(requests, outs)
                    for s in dec:
                        pending[s] = int(step_tok[s])
                        pos[s] += 1
        finally:
            if collect:
                jax.effects_barrier()
                byte_taps = self.spamm_ctx.drain_byte_stats()
                cost_taps = self.spamm_ctx.drain_cost_stats()
                taps = self.spamm_ctx.end_stats()
                self.spamm_ctx.set_phase("prefill")
        if collect:
            spamm_meta = self._spamm_stats(taps, hits0, misses0, store0,
                                           reshard0, byte_taps, cost_taps,
                                           ttft_s, decode_lat)
        results = [np.asarray(o, np.int32) for o in outs]
        if obs_on:
            self.obs.tracer.add_complete(
                "wave", t_wave0, time.perf_counter_ns(), batch=b,
                slots=nslots, chunk=chunk)
            self._m_waves.inc()
            self._m_tokens.inc(sum(len(o) for o in results))
        for r, toks_out in zip(requests, results):
            r.out = {"tokens": toks_out, "spamm": spamm_meta}
        return results
