"""Batched serving engine: prefill + greedy decode with slot-based batching.

A fixed pool of `batch` slots; requests (prompts) fill free slots, a slot
frees when its sequence emits EOS or hits max_new_tokens (continuous-
batching-lite: admission happens between decode steps; prefill per admission
wave). The decode step is the same jitted fn the dry-run lowers — decode
caches come back from prefill and are padded to the engine's max length.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import module as spmod
from repro.core import schedule as _schedule
from repro.core.plan import _bucket
from repro.models import model as M
from repro.models.transformer import NetCtx


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: Optional[dict] = None   # populated by Engine.generate: per-request
                                 # metadata — {"tokens": np.ndarray,
                                 # "spamm": gating stats dict or None}


class Engine:
    """`spamm_cfg` (SpammConfig or SpammContext) turns on norm-gated GEMMs in
    prefill AND decode. The engine owns ONE SpammContext threaded through
    every request.

    Frozen-plan contract (the amortization story): the weight-side gating
    artifacts are a pure function of the static weights, so the engine
    freezes them ONCE (`repro.plans.freeze_tree`, optionally warm-started
    from an on-disk `PlanStore` populated by `repro.launch.precompute_plans`
    — then engine start-up is a pure load, no planning pass) and passes the
    per-shape `FrozenPlan` pytrees into the jitted `_prefill`/`_decode` as
    ARGUMENTS. Inside the compiled graphs only the activation-side gate is
    traced; the weight get-norm and the dense-bitmap + `spamm_compact_ref`
    sort never appear — the concrete `SpammWork` work-list path (PR 3) is
    the only executed path, bit-identical to the eager plan/execute
    pipeline. `WeightPlanCache` is the in-memory tier above the store (it
    memoizes the frozen artifacts by weight fingerprint) and still serves
    the eager plan/execute path (benchmarks/plan_cache.py). MoE expert FFNs
    keep the traced prefill gate (their buffers live inside shard_map) and
    stay dense in decode.

    `freeze_plans=False` opts back into the legacy in-trace gating for A/B
    comparisons (benchmarks/frozen_prefill.py measures the gap).

    Drift-triggered re-sharding (`reshard_cfg`, a `schedule.ReshardConfig`):
    the engine owns a `schedule.ReshardController` holding the equal-work
    row partition a pod deployment would feed to
    `distributed.spamm_rowpart(offsets=...)`. Every `reshard_cfg.every`
    engine steps (prefill counts one, each decode step one, cumulative
    across waves) it re-probes the coarse V estimate — activation-side
    norms of the live token embeddings, weight side piggybacking on the
    cached `WeightPlanCache.weight_side` pyramid of the probe weight (the
    unembed kernel: present for every arch, shaped like every gated GEMM's
    weight side) — and re-cuts the strips only when the live partition's
    predicted imbalance drifts beyond the fresh cut's by the configured
    threshold. Pure control plane: outputs are bit-identical with
    re-sharding on, off, or at any cadence; `Request.out["spamm"]` reports
    the wave's `resharded` event count, probe count, and the live
    partition's predicted imbalance.
    """

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, ctx: NetCtx,
                 params, *, max_len: int = 512, spamm_cfg=None,
                 plan_store=None, freeze_plans: Optional[bool] = None,
                 reshard_cfg: Optional[_schedule.ReshardConfig] = None):
        self.cfg, self.pcfg, self.ctx = cfg, pcfg, ctx
        self.params = params
        self.max_len = max_len
        self.spamm_ctx = spmod.as_context(spamm_cfg)
        enabled = self.spamm_ctx is not None and self.spamm_ctx.enable
        if isinstance(plan_store, str):
            from repro.plans.store import PlanStore  # deferred: optional dep

            plan_store = PlanStore(plan_store)
        self.plan_store = plan_store
        self._freeze = enabled if freeze_plans is None else (
            bool(freeze_plans) and enabled)
        if enabled and plan_store is not None:
            self.spamm_ctx.cache.store = plan_store
        self._fw_tree = None     # path-tree of FrozenWeight (lists per layer)
        self._fp_cache: dict = {}  # row-tile grid gm → FrozenPlan pytree
        self._resharder = None
        self._steps = 0          # engine steps (prefill + decode), all waves
        if reshard_cfg is not None and enabled and reshard_cfg.every > 0:
            reshard_cfg = _schedule.resolve_reshard_devices(
                reshard_cfg, ctx.mesh, ctx.batch_axes)
            self._resharder = _schedule.ReshardController(reshard_cfg)
        self._prefill = jax.jit(
            M.make_prefill_step(cfg, pcfg, ctx, spamm_cfg=self.spamm_ctx))
        self._decode = jax.jit(M.make_decode_step(
            cfg, pcfg, ctx,
            spamm_cfg=self.spamm_ctx if self._freeze else None))

    # -- drift-triggered re-sharding (control plane) -------------------------
    @property
    def partition_offsets(self):
        """Live equal-work row-offset table (None until the first probe) —
        what a pod deployment passes to `distributed.spamm_rowpart`."""
        return self._resharder.offsets if self._resharder else None

    def _maybe_reshard(self, requests, outs):
        """Advance the engine step counter; at the configured cadence,
        re-probe the coarse work estimate from the live tokens (prompts +
        generated so far) and let the controller re-cut on drift
        (`model.reshard_probe` is the shared probe body). Never touches the
        computed values."""
        step, self._steps = self._steps, self._steps + 1
        rs = self._resharder
        if rs is None or not rs.due(step):
            return
        win = rs.cfg.probe_window
        # per-request most-recent window keeps probe cost constant as
        # generation grows (the estimate tracks the live distribution; the
        # distant past doesn't shard the next step's rows anyway)

        def recent(r, o):
            t = np.concatenate([np.asarray(r.prompt, np.int64),
                                np.asarray(o, np.int64)])
            return t[-win:] if win else t

        toks = np.concatenate([recent(r, o)
                               for r, o in zip(requests, outs)])
        M.reshard_probe(rs, self.spamm_ctx, self.params, step, tokens=toks)

    # -- frozen-plan assembly ------------------------------------------------
    def _frozen_for(self, rows: int) -> dict:
        """The FrozenPlan pytree for a step whose gated GEMMs see `rows`
        flattened activation rows — built once per row-tile grid and reused
        (the jitted steps recompile per shape anyway, so this adds no
        compiles). Stacked layers get stacked plans (scan xs)."""
        if not self._freeze:
            return {}
        scfg = self.spamm_ctx.cfg
        tile = scfg.tile
        gm = (rows + tile - 1) // tile
        hit = self._fp_cache.get(gm)
        if hit is not None:
            return hit
        if self._fw_tree is None:
            from repro.plans.precompute import freeze_tree

            self._fw_tree, _ = freeze_tree(
                self.params, scfg, cache=self.spamm_ctx.cache,
                store=self.plan_store)

        from repro.plans.frozen import stack_plans

        def specialize(node):
            if isinstance(node, dict):
                return {k: specialize(v) for k, v in node.items()}
            if isinstance(node, list):
                # per-layer plans must share one step bucket to stack into a
                # scan input; padding steps carry a clear `real` bit. Each
                # weight's autotuned bucket floor participates in the max, so
                # the common bucket honors every layer's tuned floor (the
                # result is a power of two ≥ each floor, hence stable under
                # every layer's own for_rows flooring).
                bucket = max(_bucket(gm * fw.num_kj, fw.bucket_floor)
                             for fw in node)
                return stack_plans(
                    [fw.for_rows(gm, min_steps=bucket) for fw in node])
            return node.for_rows(gm)

        tree = specialize(self._fw_tree)
        self._fp_cache[gm] = tree
        return tree

    def _pad_cache(self, cache, cur_len: int):
        """Grow linear KV caches from cur_len to max_len slots."""
        target = (
            min(self.max_len, self.cfg.sliding_window)
            if self.cfg.sliding_window else self.max_len
        )

        def grow(path, t):
            keys = [getattr(k, "key", None) for k in path]
            if keys and keys[-1] in ("k", "v") and t.shape[-3] < target:
                pad = [(0, 0)] * t.ndim
                pad[-3] = (0, target - t.shape[-3])
                return jnp.pad(t, pad)
            return t

        return jax.tree_util.tree_map_with_path(grow, cache)

    def _spamm_stats(self, taps, hits0: int, misses0: int,
                     store0: Optional[tuple], reshard0: Optional[tuple],
                     byte_taps=()):
        """Per-wave gating stats dict from the drained (phase, fraction)
        taps and the plan-cache/plan-store counter DELTAS across this wave
        (every counter in the dict is per-wave: after first population a
        warm wave reports 0/0 store traffic, never stale lifetime totals).
        With re-sharding on, `resharded`/`reshard_probes` are the wave's
        event deltas and `partition_imbalance` the live partition's
        predicted imbalance at the last probe. `byte_taps` (the context's
        bytes-moved channel, frozen-path GEMMs only) reports SUMS per phase:
        bandwidth adds up across GEMMs where fractions average."""
        cache = self.spamm_ctx.cache
        pre = [v for ph, v in taps if ph != "decode"]
        dec = [v for ph, v in taps if ph == "decode"]
        pre_b = [v for ph, v in byte_taps if ph != "decode"]
        dec_b = [v for ph, v in byte_taps if ph == "decode"]
        stats = {
            "valid_fraction": float(np.mean(pre)) if pre else None,
            "gated_gemms": len(pre),
            "decode_valid_fraction": float(np.mean(dec)) if dec else None,
            "decode_gated_gemms": len(dec),
            "compute_dtype": getattr(self.spamm_ctx.cfg, "dtype", "float32"),
            "gemm_bytes_moved": float(np.sum(pre_b)) if pre_b else None,
            "decode_gemm_bytes_moved": float(np.sum(dec_b)) if dec_b else None,
            "plan_cache_hits": cache.hits - hits0,
            "plan_cache_misses": cache.misses - misses0,
        }
        if store0 is not None:
            stats["plan_store_hits"] = self.plan_store.hits - store0[0]
            stats["plan_store_misses"] = self.plan_store.misses - store0[1]
        if reshard0 is not None:
            rs = self._resharder
            stats["resharded"] = rs.resharded - reshard0[0]
            stats["reshard_probes"] = rs.probes - reshard0[1]
            stats["partition_imbalance"] = rs.live_imbalance
        return stats

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Greedy-decode a batch of same-length prompts (engine pads to the
        longest prompt internally with left-trim to uniform length).

        When SpAMM is enabled, each request's `out` metadata carries the
        gating stats of its wave, split by phase: prefill (valid_fraction /
        gated_gemms over the gated prefill GEMMs) and decode
        (decode_valid_fraction / decode_gated_gemms summed over the wave's
        decode steps), plus plan-cache hit/miss deltas.
        """
        assert requests, "empty batch"
        b = len(requests)
        plen = min(min(len(r.prompt) for r in requests), self.max_len - 1)
        toks = np.stack([r.prompt[-plen:] for r in requests]).astype(np.int32)
        collect = self.spamm_ctx is not None and self.spamm_ctx.enable
        spamm_meta = None
        store0 = None
        reshard0 = None
        if collect:
            hits0 = self.spamm_ctx.cache.hits
            misses0 = self.spamm_ctx.cache.misses
            if self.plan_store is not None:
                store0 = (self.plan_store.hits, self.plan_store.misses)
            if self._resharder is not None:
                reshard0 = (self._resharder.resharded, self._resharder.probes)
        # frozen-plan assembly counts into this wave's store deltas (it is
        # where first population / warm-start loading happens)
        frozen_pre = self._frozen_for(b * plen)
        frozen_dec = self._frozen_for(b) if self._freeze else {}
        if collect:
            self.spamm_ctx.begin_stats()
        try:
            if collect:
                self.spamm_ctx.set_phase("prefill")
            outs = [[] for _ in range(b)]
            self._maybe_reshard(requests, outs)
            cache, logits = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, frozen_pre)
            cache = self._pad_cache(cache, plen)
            done = np.zeros(b, bool)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            pos = plen
            budget = max(r.max_new_tokens for r in requests)
            if collect:
                self.spamm_ctx.set_phase("decode")
            for t in range(budget):
                for i, r in enumerate(requests):
                    if not done[i]:
                        outs[i].append(int(cur[i]))
                        if (r.eos_id is not None and int(cur[i]) == r.eos_id) or \
                           len(outs[i]) >= r.max_new_tokens:
                            done[i] = True
                if done.all() or pos >= self.max_len - 1:
                    break
                self._maybe_reshard(requests, outs)
                logits, cache = self._decode(
                    self.params, cur[:, None], cache, jnp.int32(pos),
                    frozen_dec
                )
                cur = jnp.argmax(logits, -1).astype(jnp.int32)
                pos += 1
        finally:
            if collect:
                # unordered io_callbacks are NOT flushed by output readiness
                # — effects_barrier is the documented flush; the finally
                # closes the collect window even on a failed step so the
                # context's telemetry can't be left collecting forever
                jax.effects_barrier()
                byte_taps = self.spamm_ctx.drain_byte_stats()
                taps = self.spamm_ctx.end_stats()
                self.spamm_ctx.set_phase("prefill")
        if collect:
            spamm_meta = self._spamm_stats(taps, hits0, misses0, store0,
                                           reshard0, byte_taps)
        results = [np.asarray(o, np.int32) for o in outs]
        for r, toks_out in zip(requests, results):
            r.out = {"tokens": toks_out, "spamm": spamm_meta}
        return results
