"""Quickstart: SpAMM in five minutes.

  PYTHONPATH=src python examples/quickstart.py

Covers: decay matrices, τ- and valid-ratio-driven gating, error/work
tradeoff, the two Pallas kernels (interpret mode), and the drop-in
SpAMMLinear layer.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spamm as cs
from repro.core.module import spamm_linear
from repro.kernels import ops

# 1. a near-sparse (decay) matrix — paper §2.1
n = 1024
a = jnp.asarray(cs.exponential_decay(n, lam=0.7, seed=0))
b = jnp.asarray(cs.exponential_decay(n, lam=0.7, seed=1))
dense = a @ b

# 2. SpAMM with an explicit norm threshold τ
for tau in (1e-6, 1e-3, 1e-1):
    c, info = cs.spamm(a, b, tau, tile=64, backend="jnp")
    err = float(jnp.linalg.norm(c - dense) / jnp.linalg.norm(dense))
    print(f"tau={tau:8.0e}  executed tiles: {float(info.valid_fraction):6.1%}  "
          f"rel err: {err:.2e}")

# 3. ...or ask for a work budget instead (paper §3.5.2 τ-search)
c, info = cs.spamm(a, b, valid_ratio=0.10, tile=64, backend="jnp")
print(f"\nvalid_ratio=10% → τ={float(info.tau):.4g}, "
      f"achieved {float(info.valid_fraction):.1%}, "
      f"effective GFLOPs {float(info.effective_flops)/1e9:.1f} "
      f"(dense would be {2*n**3/1e9:.1f})")

# 4. the two Pallas TPU kernels, validated in interpret mode on CPU
norms = ops.tile_norms(a, 64, backend="interpret")          # get-norm kernel
c2, _ = ops.spamm_matmul(a, b, 1e-3, tile=64, backend="interpret")
print(f"\nPallas interpret-mode kernels: normmap {norms.shape}, "
      f"mm err vs jnp {float(jnp.max(jnp.abs(c2 - cs.spamm(a, b, 1e-3, tile=64, backend='jnp')[0]))):.2e}")

# 5. drop-in layer for any model GEMM (differentiable, dense backward)
x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 128, 256)),
                jnp.float32)
w = jnp.asarray(0.02 * np.random.default_rng(1).standard_normal((256, 512)),
                jnp.float32)
y = spamm_linear(x, w, jnp.float32(0.05), 64, "jnp")
g = jax.grad(lambda x: jnp.sum(spamm_linear(x, w, jnp.float32(0.05), 64, "jnp") ** 2))(x)
print(f"SpAMMLinear: y{y.shape}, grad ok {g.shape}")

# 6. serving hot path: plan the gating phase once, execute per request
from repro.core import plan as planner

p = planner.plan(a, b, 1e-3, tile=64, backend="jnp")   # get-norm + bitmap (+ compaction)
c3 = planner.execute(p, a, b)                          # multiplication only
print(f"plan/execute: {float(p.valid_fraction):.1%} of tiles executed, "
      f"plan reusable across calls")

# 7. batched execution: (B, M, K) @ (K, N) with the weight plan shared
xb = jnp.asarray(np.random.default_rng(2).standard_normal((4, 256, n)),
                 jnp.float32) * 0.05
cb, binfo = planner.spamm_bmm(xb, b, 1e-3, tile=64, backend="jnp")
print(f"spamm_bmm: {cb.shape} at {float(binfo.valid_fraction):.1%} valid")
