"""End-to-end driver: train a ~100M-param decoder LM for a few hundred steps
with the full substrate (data pipeline → model → AdamW → checkpoints →
fault-tolerant loop), optionally with SpAMM on every GEMM.

Quick CPU profile (default, ~12M params, minutes):
  PYTHONPATH=src python examples/train_lm.py
Full deliverable profile (~100M params, a few hundred steps):
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
With the paper's technique on all eligible GEMMs:
  PYTHONPATH=src python examples/train_lm.py --spamm --tau 1e-3
"""
import argparse
import dataclasses

import jax

from repro.configs import (ModelConfig, ParallelConfig, SpammConfig,
                           TrainConfig)
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.train.loop import train


def small_cfg(full: bool) -> ModelConfig:
    if full:  # ~103M params
        return ModelConfig(
            name="lm-100m", family="dense", num_layers=12, d_model=512,
            num_heads=8, num_kv_heads=8, d_ff=2048, vocab=32_000,
            act="silu", head_dim=64,
        )
    return ModelConfig(  # ~12M params: CPU-minutes profile
        name="lm-12m", family="dense", num_layers=6, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab=8_192,
        act="silu", head_dim=32,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--spamm", action="store_true")
    ap.add_argument("--tau", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    n_params = sum(
        p.size for p in jax.tree.leaves(
            jax.eval_shape(
                lambda k: __import__("repro.models.model", fromlist=["m"])
                .init_params(cfg, ParallelConfig(), k), jax.random.key(0)))
    )
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"steps={args.steps}, batch={args.batch}x{args.seq}")

    pcfg = ParallelConfig(
        compute_dtype="float32", param_dtype="float32", remat="none",
        attn_q_chunk=128, attn_kv_chunk=128, loss_chunk=128,
        decode_seq_shard=False,
    )
    tcfg = TrainConfig(lr=6e-4, total_steps=args.steps,
                       warmup=max(10, args.steps // 20),
                       ckpt_every=max(50, args.steps // 4),
                       ckpt_dir=args.ckpt_dir)
    spamm_cfg = (SpammConfig(enable=True, tau=args.tau, tile=64, backend="jnp")
                 if args.spamm else None)
    res = train(cfg, pcfg, tcfg, make_ctx(make_host_mesh()),
                global_batch=args.batch, seq_len=args.seq,
                spamm_cfg=spamm_cfg, log_every=10)
    print(f"\nloss: {res.losses[0]:.3f} → {res.losses[-1]:.3f} over "
          f"{res.final_step} steps (stragglers flagged: {res.straggler_steps})")


if __name__ == "__main__":
    main()
