"""Paper §4.3.2 scenario: conv layers as im2col GEMMs (VGG13 conv21/conv31
shapes) with ReLU-sparse activations + pruned weights, gated by valid-ratio
(the paper's DNN-facing knob).

  PYTHONPATH=src python examples/vgg_im2col.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import spamm as cs
from repro.data.pipeline import relu_sparse_matrix, vgg_im2col_shapes


def main():
    print(f"{'layer':>8} {'ratio':>7} {'achieved':>9} {'rel err':>9} "
          f"{'work reduction':>15}")
    for name, (m, k, n) in vgg_im2col_shapes().items():
        n = min(n, 6400)
        x = jnp.asarray(relu_sparse_matrix(m, k, sparsity=0.55, seed=1))
        w = np.random.default_rng(2).standard_normal((k, n)).astype(np.float32)
        w *= np.abs(w) > 0.8  # weight pruning (paper §1)
        w = jnp.asarray(w)
        dense = x @ w
        for ratio in (0.97, 0.85, 0.63, 0.43):
            c, info = cs.spamm(x, w, valid_ratio=ratio, tile=64, backend="jnp")
            rel = float(jnp.linalg.norm(c - dense) / jnp.linalg.norm(dense))
            f = float(info.valid_fraction)
            print(f"{name:>8} {ratio:>6.0%} {f:>9.1%} {rel:>9.3f} "
                  f"{1/max(f,1e-9):>14.1f}x")
    print("\n(the paper reports ≤1.1% VGG13 accuracy loss down to ratio 43% —"
          "\n GEMM-level error is absorbed by the network's decision margins)")


if __name__ == "__main__":
    main()
