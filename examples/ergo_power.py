"""Paper §4.3.1 scenario: accelerate matrix powers of electronic-structure
style decay matrices with SpAMM, sweeping τ (the paper's Table 4 / Fig. 6).

  PYTHONPATH=src python examples/ergo_power.py [--n 2048] [--power 4]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import spamm as cs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--power", type=int, default=4)
    ap.add_argument("--lam", type=float, default=0.75)
    args = ap.parse_args()

    a = jnp.asarray(cs.exponential_decay(args.n, lam=args.lam, seed=0))
    exact = np.asarray(a, np.float64)
    for _ in range(args.power - 1):
        exact = exact @ np.asarray(a, np.float64)

    print(f"A^{args.power}, N={args.n}, exponential decay λ={args.lam}")
    print(f"{'tau':>10} {'rel err':>12} {'avg tiles executed':>20}")
    for tau in (1e-10, 1e-8, 1e-6, 1e-4, 1e-2):
        acc = a
        fracs = []
        for _ in range(args.power - 1):
            acc, info = cs.spamm(acc, a, tau, tile=64, backend="jnp")
            fracs.append(float(info.valid_fraction))
        err = np.linalg.norm(np.asarray(acc, np.float64) - exact)
        rel = err / np.linalg.norm(exact)
        print(f"{tau:>10.0e} {rel:>12.2e} {np.mean(fracs):>19.1%}")
    print("\n(cf. paper Table 4: error →0 as τ→1e-10 while work stays skipped;"
          "\n work reduction on TPU = 1/executed-fraction per §Roofline)")


if __name__ == "__main__":
    main()
