"""Perf-trajectory gate: diff fresh BENCH_*.json against committed
reference bounds and FAIL on regression.

  PYTHONPATH=src python -m benchmarks.perf_gate \
      [--ref-dir benchmarks/references] [--fresh-dir .] [--selftest]

The references under benchmarks/references/ are committed (the one
.gitignore exception to the BENCH_*.json rule) and act as the perf
trajectory's ratchet: CI regenerates the fresh files each run and this
gate compares row by row. Comparison rules, by metric key:

  * wall-clock (``*_us``, ``us_per_execute``) — lower-better within a
    generous 2.0 relative tolerance (3× the reference): shared CI runners
    are noisy, so only gross regressions trip;
  * deterministic plan/model outputs (``*bytes_moved``, ``predicted_us``,
    ``valid_fraction``, …) — 1% band BOTH directions: any drift, including
    an improvement, demands a conscious reference update (see
    benchmarks/README.md);
  * tuner decisions and config ints (``block_n``/``levels``/``bucket``) —
    exact;
  * accuracy (``max_err*``) and ratios (``bytes_ratio_vs_f32``) — may only
    improve, within 50% / 5% bands.

Rows are matched on their identity keys (family/n/tile/tau/lam/dtype/
backend). A row pair whose measuring ENVIRONMENT differs (backend or
device kind — the v2 env stamp from `benchmarks.report`) is REFUSED, not
silently compared: wall-clock from a different machine class is not a
trajectory point. Hostname differences are provenance only (CI runners
are a fleet). ``--selftest`` builds synthetic pairs in a temp dir and
asserts the gate passes clean data, fails an injected slowdown, and
refuses an environment mismatch.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import tempfile

from benchmarks.report import BENCH_SCHEMA_VERSION

# cell fields that name a row rather than measure it
IDENTITY_KEYS = ("family", "n", "tile", "tau", "lam", "dtype", "backend",
                 "seed")
# integer decisions/configs compared exactly (a tuner flip IS a trajectory
# event — update the reference deliberately)
EXACT_KEYS = ("block_n", "levels", "bucket", "gated_gemms")
# analytic model/plan outputs: deterministic given the code, so ANY drift
# (either direction) means the model changed — 1% band absorbs fp noise
DETERMINISTIC_KEYS = ("predicted_us", "default_predicted_us",
                      "predicted_speedup_vs_default", "valid_fraction")

WALL_CLOCK_REL_TOL = 2.0     # fresh ≤ ref × (1 + 2.0)
DETERMINISTIC_REL_TOL = 0.01
RATIO_REL_TOL = 0.05         # higher-better: fresh ≥ ref × (1 − 0.05)
ERR_REL_TOL = 0.5            # lower-better accuracy floor

_MISSING = object()


class GateResult:
    def __init__(self):
        self.problems: list = []    # regressions / structural failures
        self.refusals: list = []    # environment mismatches
        self.checked = 0

    @property
    def ok(self) -> bool:
        return not self.problems and not self.refusals


def _identity(cell: dict) -> str:
    return json.dumps({k: cell[k] for k in IDENTITY_KEYS if k in cell},
                      sort_keys=True)


def _check_metric(key: str, ref, fresh, path: str, res: GateResult):
    res.checked += 1
    if key in EXACT_KEYS:
        if fresh != ref:
            res.problems.append(
                f"{path}.{key}: decision changed {ref!r} -> {fresh!r} "
                f"(exact-match key; update the reference deliberately)")
        return
    ref = float(ref)
    fresh = float(fresh)
    scale = max(abs(ref), 1e-12)
    if key in DETERMINISTIC_KEYS or key.endswith("bytes_moved"):
        if abs(fresh - ref) > DETERMINISTIC_REL_TOL * scale:
            res.problems.append(
                f"{path}.{key}: deterministic output drifted "
                f"{ref:g} -> {fresh:g} (>{DETERMINISTIC_REL_TOL:.0%}; "
                f"model/plan changed — regenerate references if intended)")
    elif key.endswith("ratio_vs_f32") or key.endswith("speedup"):
        if fresh < ref * (1.0 - RATIO_REL_TOL):
            res.problems.append(
                f"{path}.{key}: ratio regressed {ref:g} -> {fresh:g} "
                f"(>{RATIO_REL_TOL:.0%} below reference)")
    elif key.startswith("max_err") or key.endswith("_err"):
        if fresh > ref * (1.0 + ERR_REL_TOL) + 1e-12:
            res.problems.append(
                f"{path}.{key}: accuracy regressed {ref:g} -> {fresh:g}")
    elif key.endswith("_us") or key.startswith("us_per"):
        if fresh > ref * (1.0 + WALL_CLOCK_REL_TOL):
            res.problems.append(
                f"{path}.{key}: wall-clock regressed {ref:.1f}us -> "
                f"{fresh:.1f}us (tolerance {WALL_CLOCK_REL_TOL:.0%} over "
                f"reference)")
    # other numerics (lam/tau echoes, counts we have no rule for): no gate


def _walk(ref: dict, fresh: dict, path: str, res: GateResult):
    for key, rv in sorted(ref.items()):
        if key == "env" or key in IDENTITY_KEYS:
            continue
        fv = fresh.get(key, _MISSING)
        if fv is _MISSING:
            res.problems.append(f"{path}.{key}: present in reference, "
                                f"missing in fresh run")
        elif isinstance(rv, dict) and isinstance(fv, dict):
            _walk(rv, fv, f"{path}.{key}", res)
        elif isinstance(rv, bool):
            continue
        elif isinstance(rv, (int, float)) and isinstance(fv, (int, float)):
            _check_metric(key, rv, fv, path, res)
        elif key == "profile_key" and rv != fv:
            res.problems.append(f"{path}.profile_key: coefficients source "
                                f"changed {rv!r} -> {fv!r}")


def _env_mismatch(ref_env: dict, fresh_env: dict):
    """The non-comparable axes: backend + device kind. Hostname is
    provenance, not a gate."""
    bad = [ax for ax in ("backend", "device_kind")
           if ref_env.get(ax) != fresh_env.get(ax)]
    return bad


def compare_docs(ref_doc: dict, fresh_doc: dict, name: str) -> GateResult:
    res = GateResult()
    for doc, which in ((ref_doc, "reference"), (fresh_doc, "fresh")):
        if doc.get("bench_schema_version") != BENCH_SCHEMA_VERSION:
            res.problems.append(
                f"{name} [{which}]: bench_schema_version "
                f"{doc.get('bench_schema_version')!r} != "
                f"{BENCH_SCHEMA_VERSION} (pre-env-stamp file; regenerate)")
    if res.problems:
        return res
    ref_cells = ref_doc.get("data", {}).get("cells", [])
    fresh_by_id = {_identity(c): c
                   for c in fresh_doc.get("data", {}).get("cells", [])}
    for rc in ref_cells:
        ident = _identity(rc)
        path = f"{name}{ident}"
        fc = fresh_by_id.get(ident)
        if fc is None:
            res.problems.append(f"{path}: reference row has no fresh "
                                f"counterpart (coverage shrank)")
            continue
        bad = _env_mismatch(rc.get("env", {}), fc.get("env", {}))
        if bad:
            res.refusals.append(
                f"{path}: REFUSING to compare — environment differs on "
                + ", ".join(f"{ax} ({rc['env'].get(ax)!r} vs "
                            f"{fc['env'].get(ax)!r})" for ax in bad)
                + "; regenerate benchmarks/references/ on the new "
                  "environment")
            continue
        _walk(rc, fc, path, res)
    return res


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def gate(ref_dir: str, fresh_dir: str) -> int:
    refs = sorted(glob.glob(os.path.join(ref_dir, "BENCH_*.json")))
    if not refs:
        print(f"perf_gate: no references under {ref_dir} — nothing gated")
        return 1
    failures = 0
    for ref_path in refs:
        name = os.path.basename(ref_path)
        fresh_path = os.path.join(fresh_dir, name)
        if not os.path.exists(fresh_path):
            print(f"FAIL {name}: fresh file missing (run the benchmark "
                  f"first: python -m benchmarks.run --smoke)")
            failures += 1
            continue
        res = compare_docs(_load(ref_path), _load(fresh_path), name)
        for msg in res.refusals:
            print(f"REFUSED {msg}")
        for msg in res.problems:
            print(f"FAIL {msg}")
        if res.ok:
            print(f"OK   {name}: {res.checked} metrics within bounds "
                  f"(env {_load(fresh_path)['env']['backend']}/"
                  f"{_load(fresh_path)['env']['device_kind']})")
        else:
            failures += 1
    return 1 if failures else 0


# ---------------------------------------------------------------------------
# selftest: the gate must demonstrably fail on an injected slowdown
# ---------------------------------------------------------------------------

def _synthetic_doc(us: float = 100.0, bytes_moved: float = 1.0e6,
                   device_kind: str = "cpu") -> dict:
    env = {"backend": "interpret", "device_kind": device_kind,
           "hostname": "selftest-host", "jax": "0"}
    cell = {"family": "banded", "n": 256, "tile": 32, "tau": 0.05,
            "dtype": "int8", "backend": "interpret", "env": dict(env),
            "us_per_execute": us, "gemm_bytes_moved": bytes_moved,
            "bytes_ratio_vs_f32": 2.0, "block_n": 1}
    return {"bench_schema_version": BENCH_SCHEMA_VERSION,
            "name": "selftest", "env": env, "data": {"cells": [cell]}}


def selftest() -> int:
    ref = _synthetic_doc()

    clean = compare_docs(ref, _synthetic_doc(), "selftest")
    assert clean.ok and clean.checked >= 3, clean.problems

    improved = compare_docs(ref, _synthetic_doc(us=40.0), "selftest")
    assert improved.ok, ("faster wall-clock must pass", improved.problems)

    slow = compare_docs(
        ref, _synthetic_doc(us=100.0 * (1 + WALL_CLOCK_REL_TOL) * 1.05),
        "selftest")
    assert not slow.ok and any("wall-clock regressed" in p
                               for p in slow.problems), slow.problems

    drift = compare_docs(ref, _synthetic_doc(bytes_moved=1.05e6), "selftest")
    assert not drift.ok and any("deterministic" in p
                                for p in drift.problems), drift.problems

    moved = compare_docs(ref, _synthetic_doc(device_kind="TPU v5e"),
                         "selftest")
    assert not moved.ok and moved.refusals and not moved.problems, (
        moved.problems, moved.refusals)

    v1 = dict(_synthetic_doc())
    v1.pop("bench_schema_version")
    legacy = compare_docs(v1, _synthetic_doc(), "selftest")
    assert not legacy.ok and any("bench_schema_version" in p
                                 for p in legacy.problems), legacy.problems

    # end-to-end through the file-level driver, in a temp tree
    with tempfile.TemporaryDirectory() as td:
        rd, fd = os.path.join(td, "ref"), os.path.join(td, "fresh")
        os.makedirs(rd)
        os.makedirs(fd)
        with open(os.path.join(rd, "BENCH_selftest.json"), "w") as f:
            json.dump(ref, f)
        with open(os.path.join(fd, "BENCH_selftest.json"), "w") as f:
            json.dump(_synthetic_doc(us=1e6), f)
        assert gate(rd, fd) == 1, "driver must exit nonzero on regression"
        with open(os.path.join(fd, "BENCH_selftest.json"), "w") as f:
            json.dump(_synthetic_doc(), f)
        assert gate(rd, fd) == 0, "driver must exit zero on clean data"
    print("perf_gate selftest: PASS (clean passes, slowdown + drift + "
          "schema fail, env mismatch refused)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref-dir", default="benchmarks/references",
                    help="committed reference BENCH_*.json directory")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory of freshly generated BENCH_*.json")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gate itself fails on an injected "
                         "slowdown and refuses environment mismatches")
    args = ap.parse_args()
    sys.exit(selftest() if args.selftest
             else gate(args.ref_dir, args.fresh_dir))


if __name__ == "__main__":
    main()
