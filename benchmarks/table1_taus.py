"""Paper Table 1: the τ values the §3.5.2 search selects for each
(valid_ratio × N) on the synthesized algebraic-decay ensemble. The paper's
τ decreases with N and increases as the ratio drops; we verify both trends
(absolute values differ: sign-randomization changes norm magnitudes)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row
from repro.core import spamm as cs
from repro.core.tau_search import search_tau
from repro.kernels import ref

RATIOS = (0.30, 0.20, 0.10, 0.05)
SIZES = (1024, 2048, 4096)
TILE = 64


def run(quick: bool = False):
    sizes = SIZES[:2] if quick else SIZES
    taus = {}
    for n in sizes:
        a = jnp.asarray(cs.algebraic_decay(n, seed=0))
        b = jnp.asarray(cs.algebraic_decay(n, seed=1))
        na = ref.tile_norms_ref(a, TILE)
        nb = ref.tile_norms_ref(b, TILE)
        for ratio in RATIOS:
            tau, res = search_tau(na, nb, ratio)
            taus[(n, ratio)] = float(tau)
            row(
                f"table1/N={n}/ratio={int(ratio*100)}%",
                0.0,
                f"tau={float(tau):.4f};achieved={float(res.achieved_ratio):.3f};"
                f"iters={int(res.iterations)}",
            )
    # paper trend: for fixed N, smaller ratio ⇒ larger τ
    for n in sizes:
        ts = [taus[(n, r)] for r in RATIOS]
        trend = all(ts[i] <= ts[i + 1] + 1e-6 for i in range(len(ts) - 1))
        row(f"table1/trend/N={n}", 0.0, f"tau_monotone_in_1/ratio={trend}")


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
