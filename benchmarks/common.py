"""Shared timing + reporting helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import numpy as np

ROWS: List[tuple] = []


def timeit(fn: Callable, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall-clock microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def header():
    print("name,us_per_call,derived", flush=True)
