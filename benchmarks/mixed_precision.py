"""Mixed-precision SpAMM: f32 vs bf16 vs int8 on the same work-list.

One decay matrix pair per cell, one τ, three compute dtypes through the
SAME plan/execute pipeline (`core.plan` with `compute_dtype=`). Reports
per-dtype execute time, the plan's GEMM bytes-moved estimate
(`SpammPlan.bytes_moved()`), and the accuracy cost vs the f32 SpAMM
result, then asserts:

  * parity — each low-precision result matches the f32 kernel run on the
    quantize-dequantized operands with the same plan (bf16: bit-identical,
    the bf16×bf16 products are exact in the f32 accumulator; int8: a few
    ulps, the int8 kernel's int32 tile dots are EXACT where the f32 oracle
    rounds inside the tile);
  * gate superset — every (i, k, j) triple the f32 gate keeps is kept by
    the quantized gate (the widened-τ guarantee from kernels.quantize);
  * bandwidth — the work-list moves ≥ 1.5× fewer GEMM bytes at int8 than
    f32 (the acceptance floor; the analytic ratio is higher).

The machine-readable report lands in BENCH_mixed_precision.json
(`benchmarks.report.write_bench_json`; .gitignore'd, uploaded by CI).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from benchmarks.report import write_bench_json
from repro.core import plan as cplan
from repro.core.spamm import exponential_decay
from repro.kernels import quantize as kquant

DTYPES = ("float32", "bfloat16", "int8")


def _quantized_oracle(p, a, b, dtype, tile, backend):
    """f32 execution over the quantize-dequantized operands with the SAME
    plan — what each low-precision kernel must reproduce."""
    av = kquant.quantized_view(a, dtype, tile)
    bv = kquant.quantized_view(b, dtype, tile)
    p32 = cplan.SpammPlan(
        p.tau, p.norm_a, p.norm_b, p.mask, p.kidx, p.nvalid, p.valid_tiles,
        p.work, tile=p.tile, block_n=p.block_n, backend=p.backend,
        levels=p.levels,
    )
    return cplan.execute(p32, av, bv)


def _cell(n: int, tile: int, tau: float, lam: float, backend: str):
    a = jnp.asarray(exponential_decay(n, lam=lam, seed=0))
    b = jnp.asarray(exponential_decay(n, lam=lam, seed=1))
    dense = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

    results = {}
    for dtype in DTYPES:
        p = cplan.plan(a, b, tau, tile=tile, backend=backend,
                       compute_dtype=dtype)
        c = cplan.execute(p, a, b)
        t = timeit(lambda: cplan.execute(p, a, b))
        if dtype != "float32":
            oracle = _quantized_oracle(p, a, b, dtype, tile, backend)
            kdiff = float(jnp.max(jnp.abs(c - oracle)))
            scale = float(jnp.max(jnp.abs(oracle))) or 1.0
            assert kdiff <= 1e-5 * scale, (
                f"{dtype} kernel drifted from its dequantized-f32 oracle: "
                f"max|Δ|={kdiff:.3e} vs {1e-5 * scale:.3e}")
        results[dtype] = {
            "plan": p,
            "bytes": float(p.bytes_moved()),
            "us": t,
            "err_vs_dense": float(np.max(np.abs(np.asarray(c) - dense))),
        }

    # gate superset: the widened-τ quantized gates keep every f32-kept pair
    m32 = np.asarray(results["float32"]["plan"].mask)
    for dtype in ("bfloat16", "int8"):
        mq = np.asarray(results[dtype]["plan"].mask)
        assert bool(np.all(~m32 | mq)), (
            f"{dtype} gate dropped a tile the f32 gate keeps (n={n} τ={tau})")

    cell = {"n": n, "tile": tile, "tau": tau, "lam": lam, "backend": backend}
    b32 = results["float32"]["bytes"]
    for dtype in DTYPES:
        r = results[dtype]
        ratio = b32 / max(r["bytes"], 1.0)
        cell[dtype] = {
            "gemm_bytes_moved": r["bytes"],
            "bytes_ratio_vs_f32": ratio,
            "us_per_execute": r["us"],
            "max_err_vs_dense": r["err_vs_dense"],
            "valid_fraction": float(results[dtype]["plan"].valid_fraction),
        }
        row(f"mixed_precision/{backend}/n{n}t{tile}/tau{tau}/{dtype}",
            r["us"],
            f"bytes={r['bytes']:.0f};ratio={ratio:.2f}x;"
            f"err={r['err_vs_dense']:.2e}")
    assert cell["int8"]["bytes_ratio_vs_f32"] >= 1.5, (
        "int8 must move >=1.5x fewer GEMM bytes than f32 on the same "
        f"work-list, got {cell['int8']['bytes_ratio_vs_f32']:.2f}x")
    return cell


def run(quick: bool = False):
    cells = ([(256, 32, 0.05, 0.8)] if quick
             else [(512, 32, 0.05, 0.8), (1024, 64, 0.02, 0.9)])
    # interpret exercises the real Pallas kernel bodies (worklist + the int8
    # variant) on CPU; the jnp fallback is covered by the unit tests
    out = [_cell(n, tile, tau, lam, backend="interpret")
           for n, tile, tau, lam in cells]
    path = write_bench_json("mixed_precision", {"cells": out},
                            backend="interpret")
    print(f"# wrote {path}", flush=True)
