"""Paper Table 2 / Fig. 5: cuSpAMM vs dense GEMM (cuBLAS stand-in = XLA's
dense matmul) on the §4.1 synthesized algebraic-decay ensemble.

This container has no GPU/TPU, so two numbers are reported per cell:
  * measured CPU wall-clock ratio (dense / spamm) for the jnp pipeline —
    a sanity proxy, and
  * the work-reduction `1/valid_ratio` with the measured valid fraction —
    the hardware-independent mechanism behind the paper's speedups (on a
    compute-bound accelerator, speedup → 1/valid_ratio as N grows; paper
    Table 2 shows 5%→up to 13.4×/16.1×, consistent with ~1/0.05 minus
    norm/mask overheads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import spamm as cs
from repro.core.tau_search import search_tau
from repro.kernels import ref

SIZES = (1024, 2048, 4096)
RATIOS = (0.30, 0.20, 0.10, 0.05)
TILE = 64


def run(quick: bool = False):
    sizes = SIZES[:2] if quick else SIZES
    for n in sizes:
        a = jnp.asarray(cs.algebraic_decay(n, seed=0))
        b = jnp.asarray(cs.algebraic_decay(n, seed=1))
        dense = jax.jit(lambda x, y: x @ y)
        t_dense = timeit(dense, a, b)
        na = ref.tile_norms_ref(a, TILE)
        nb = ref.tile_norms_ref(b, TILE)
        for ratio in RATIOS:
            tau, res = search_tau(na, nb, ratio)

            def spamm_fn(x, y, tau=tau):
                c, _ = cs.spamm(x, y, tau, tile=TILE, backend="jnp")
                return c

            t_spamm = timeit(jax.jit(spamm_fn), a, b)
            frac = float(res.achieved_ratio)
            row(
                f"table2/N={n}/ratio={int(ratio*100)}%",
                t_spamm,
                f"cpu_speedup_vs_dense={t_dense/t_spamm:.2f}x;"
                f"achieved_ratio={frac:.3f};work_reduction={1/max(frac,1e-9):.1f}x",
            )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
