"""Roofline autotuner benchmark + calibration driver (core.cost).

Tunes block_n × pyramid levels × worklist bucket floor per weight on two
matrix families at the mixed_precision.py shapes and asserts the tuned
pick is never predicted slower than the hardcoded defaults
(block_n=1, levels=0, bucket=16):

  * banded  — exponential_decay (the paper's locality structure; the gate
    prunes, so blocking/bucketing choices genuinely trade off);
  * random  — dense iid Gaussian (nothing prunes; the tuner should spend
    its budget on wider block_n, not pyramid levels).

Modes:

  PYTHONPATH=src python -m benchmarks.autotune --quick
      predicted-time tuning only (deterministic, host-side — what CI runs)
  PYTHONPATH=src python -m benchmarks.autotune --calibrate cost_profile.json
      measure this machine's coefficients (bytes/s, flops/s, per-step
      overhead) from real kernel wall-clock and persist the profile JSON
  PYTHONPATH=src python -m benchmarks.autotune --quick --measure \
      [--profile cost_profile.json]
      additionally wall-clock the tuned vs default configs through the real
      plan/execute pipeline and assert tuned ≤ default × slack

The machine-readable report lands in BENCH_autotune.json
(`benchmarks.report.write_bench_json`; schema v2, environment-stamped;
diffed against benchmarks/references/ by `benchmarks.perf_gate`).
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from benchmarks.common import header, row, timeit
from benchmarks.report import write_bench_json
from repro.core import cost
from repro.core import plan as cplan
from repro.core.spamm import exponential_decay

# interpret exercises the real Pallas kernel bodies on CPU (same choice as
# mixed_precision.py); wall-clock numbers are interpret-backend numbers and
# the report's env stamp says so
BACKEND = "interpret"
DEFAULTS = (1, 0, 16)          # block_n, levels, bucket — the hardcoded pipeline
MEASURE_SLACK = 1.35           # measured tuned ≤ measured default × this

FAMILIES = ("banded", "random")
DTYPES = ("float32", "int8")


def _family(kind: str, n: int, lam: float, seed: int) -> np.ndarray:
    if kind == "banded":
        return np.asarray(exponential_decay(n, lam=lam, seed=seed),
                          np.float32)
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, n)) / np.sqrt(n)).astype(np.float32)


def _measure_us(a, b, tau, *, tile, dtype, block_n, levels, bucket) -> float:
    """Median wall-clock of one plan's execute at a concrete config,
    through the SAME plan/execute pipeline serving uses."""
    p = cplan.plan(a, b, tau, tile=tile, block_n=block_n, levels=levels,
                   backend=BACKEND, compute_dtype=dtype, bucket_min=bucket)
    return timeit(lambda: cplan.execute(p, a, b))


def _cell(family: str, n: int, tile: int, tau: float, lam: float,
          dtype: str, profile: cost.CostProfile, measure: bool) -> dict:
    b = _family(family, n, lam, seed=1)
    tp = cost.tune_weight(b, tau, tile=tile, dtype=dtype, backend=BACKEND,
                          profile=profile, defaults=DEFAULTS)
    # by construction (defaults always in the search space, strict-< to
    # replace) — but it is the acceptance criterion, so assert it
    assert tp.predicted_us <= tp.default_predicted_us, (
        f"tuned config predicted SLOWER than defaults: {tp}")
    speedup = tp.default_predicted_us / max(tp.predicted_us, 1e-9)
    cell = {
        "family": family, "n": n, "tile": tile, "tau": tau, "lam": lam,
        "dtype": dtype, "backend": BACKEND,
        "tuned": tp.as_manifest(),
        "predicted_us": tp.predicted_us,
        "default_predicted_us": tp.default_predicted_us,
        "predicted_speedup_vs_default": speedup,
    }
    row(f"autotune/{family}/n{n}t{tile}/tau{tau}/{dtype}", tp.predicted_us,
        f"block_n={tp.block_n};levels={tp.levels};bucket={tp.bucket};"
        f"default_us={tp.default_predicted_us:.1f};pred={speedup:.2f}x")
    if measure:
        a = jnp.asarray(_family(family, n, lam, seed=0))
        bj = jnp.asarray(b)
        t_def = _measure_us(a, bj, tau, tile=tile, dtype=dtype,
                            block_n=DEFAULTS[0], levels=DEFAULTS[1],
                            bucket=DEFAULTS[2])
        t_tun = _measure_us(a, bj, tau, tile=tile, dtype=dtype,
                            block_n=tp.block_n, levels=tp.levels,
                            bucket=tp.bucket)
        assert t_tun <= t_def * MEASURE_SLACK, (
            f"tuned config measured slower than defaults beyond slack: "
            f"{t_tun:.1f}us vs {t_def:.1f}us × {MEASURE_SLACK} "
            f"({family} n={n} tile={tile} τ={tau} {dtype})")
        cell["measured_default_us"] = t_def
        cell["measured_tuned_us"] = t_tun
        row(f"autotune/{family}/n{n}t{tile}/tau{tau}/{dtype}/measured",
            t_tun, f"default_us={t_def:.1f};"
                   f"measured={t_def / max(t_tun, 1e-9):.2f}x")
    return cell


def run(quick: bool = False, *, measure: bool = False,
        profile_path: str | None = None):
    profile = cost.CostProfile.load_or_default(profile_path)
    shapes = ([(256, 32, 0.05, 0.8)] if quick
              else [(512, 32, 0.05, 0.8), (1024, 64, 0.02, 0.9)])
    cells = [
        _cell(family, n, tile, tau, lam, dtype, profile, measure)
        for n, tile, tau, lam in shapes
        for family in FAMILIES
        for dtype in DTYPES
    ]
    payload = {
        "cells": cells,
        "profile_key_used": cells[0]["tuned"]["profile_key"],
        "measured": measure,
    }
    path = write_bench_json("autotune", payload, backend=BACKEND)
    print(f"# wrote {path}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="alias of --quick (the CI fast lane's spelling)")
    ap.add_argument("--measure", action="store_true",
                    help="also wall-clock tuned vs default configs through "
                         "the real plan/execute pipeline and gate at "
                         f"{MEASURE_SLACK}× slack")
    ap.add_argument("--profile", default=None,
                    help="calibrated cost-profile JSON (from --calibrate); "
                         "default: nominal per-backend coefficients")
    ap.add_argument("--calibrate", default=None, metavar="PATH",
                    help="measure this machine's coefficients and write the "
                         "profile JSON to PATH, then exit (pass it back via "
                         "--profile / --tune-profile)")
    args = ap.parse_args()
    if args.calibrate:
        coeffs = cost.calibrate(BACKEND, tile=32)
        prof = cost.CostProfile()
        prof.put(BACKEND, coeffs)
        path = prof.save(args.calibrate)
        print(f"calibrated {cost.profile_key(BACKEND)}: "
              f"bw={coeffs.bytes_per_s:.3e}B/s "
              f"flops={coeffs.flops_per_s:.3e}/s "
              f"step={coeffs.step_overhead_s:.3e}s "
              f"base={coeffs.base_overhead_s:.3e}s "
              f"gate={coeffs.gate_ops_per_s:.3e}/s -> {path}")
        return
    header()
    run(quick=args.quick or args.smoke, measure=args.measure,
        profile_path=args.profile)


if __name__ == "__main__":
    main()
