"""Paper Table 4 / Fig. 6: matrix powers of exponential-decay (ergo-style)
matrices across τ ∈ {1e-10 … 1e-2}: error ‖E‖_F and work reduction.

The real ergo matrices come from ErgoSCF water-cluster SCF runs (13656²);
this container generates matrices with the same exponential decay law at
CPU-feasible sizes and varied magnitude (the paper's four matrices differ by
‖C‖_F over 5 orders of magnitude — emulated via the `scale` column).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import spamm as cs

TAUS = (1e-10, 1e-8, 1e-6, 1e-4, 1e-2)
MATS = [  # (lam, scale) — scale stands in for the paper's ‖C‖_F spread
    (0.60, 1.0),
    (0.70, 10.0),
    (0.80, 300.0),
    (0.85, 3000.0),
]
N = 1024
TILE = 64


def run(quick: bool = False):
    mats = MATS[:2] if quick else MATS
    for i, (lam, scale) in enumerate(mats, 1):
        a = jnp.asarray(cs.exponential_decay(N, lam=lam, seed=i)) * scale
        dense = a @ a
        norm_c = float(jnp.linalg.norm(dense))
        t_dense = timeit(jax.jit(lambda x: x @ x), a)
        for tau in TAUS:
            c, info = cs.spamm(a, a, tau, tile=TILE, backend="jnp")
            err = float(jnp.linalg.norm(c - dense))

            def fn(x, tau=tau):
                return cs.spamm(x, x, tau, tile=TILE, backend="jnp")[0]

            t = timeit(jax.jit(fn), a)
            row(
                f"table4/mat{i}(lam={lam})/tau={tau:g}",
                t,
                f"normC={norm_c:.3g};errF={err:.3g};rel={err/max(norm_c,1e-30):.2e};"
                f"valid_ratio={float(info.valid_fraction):.3f};"
                f"cpu_speedup={t_dense/t:.2f}x",
            )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
