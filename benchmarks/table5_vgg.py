"""Paper Table 5: VGG13 conv21/conv31 im2col GEMMs under SpAMM at the
paper's valid-ratio operating points; quality proxy = relative product error
(the paper measures end-task accuracy; a GEMM error ≪ activation scale is
the mechanism behind its ≤1.1% accuracy loss)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import spamm as cs
from repro.data.pipeline import relu_sparse_matrix, vgg_im2col_shapes

RATIOS = (0.97, 0.85, 0.63, 0.43)
TILE = 64


def run(quick: bool = False):
    shapes = vgg_im2col_shapes()
    for name, (m, k, n) in shapes.items():
        n_eff = min(n, 2048 if quick else 6400)
        x = jnp.asarray(relu_sparse_matrix(m, k, sparsity=0.55, seed=1))
        rng = np.random.default_rng(2)
        w = rng.standard_normal((k, n_eff)).astype(np.float32)
        w *= np.abs(w) > 0.8  # pruning-style weight sparsity (paper §1)
        w = jnp.asarray(w)
        dense = x @ w
        t_dense = timeit(jax.jit(lambda a, b: a @ b), x, w)
        ratios = RATIOS[:2] if quick else RATIOS
        for ratio in ratios:
            c, info = cs.spamm(x, w, valid_ratio=ratio, tile=TILE,
                               backend="jnp")
            rel = float(jnp.linalg.norm(c - dense) / jnp.linalg.norm(dense))

            def fn(a, b, tau=float(info.tau)):
                return cs.spamm(a, b, tau, tile=TILE, backend="jnp")[0]

            t = timeit(jax.jit(fn), x, w)
            row(
                f"table5/{name}/ratio={int(ratio*100)}%",
                t,
                f"rel_err={rel:.3f};achieved={float(info.valid_fraction):.3f};"
                f"work_reduction={1/max(float(info.valid_fraction),1e-9):.1f}x;"
                f"cpu_speedup={t_dense/t:.2f}x",
            )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
