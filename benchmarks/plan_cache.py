"""Planned vs. unplanned serving-style repeated SpAMM matmuls.

The serving hot path multiplies a stream of activation batches against the
SAME weight matrix. Unplanned `spamm_matmul` re-runs the full gating phase
(both normmaps + mask + compaction) per call; the plan/execute split
(`repro.core.plan`) computes the weight-side normmap/padding once
(WeightPlanCache) and — when the activation statistics are stable enough to
freeze the whole plan, as for the paper's decay matrices — reuses the entire
gating phase, leaving only the multiplication kernel per call.

Three serving strategies over the same request stream:
  unplanned    — ops.spamm_matmul per request (gating phase every call)
  weight-cache — per-request plan, weight side from WeightPlanCache
  frozen-plan  — plan built once on the first request, execute-only after
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import plan as planner
from repro.core import spamm as cs
from repro.kernels import ops


def run(quick: bool = False):
    n, tile, tau = (512, 64, 1e-2) if quick else (1024, 64, 1e-2)
    nreq = 8
    w = jnp.asarray(cs.exponential_decay(n, lam=0.7, seed=0))
    rng = np.random.default_rng(1)
    xs = [
        jnp.asarray(cs.exponential_decay(n, lam=0.7, seed=2 + i))
        for i in range(nreq)
    ]

    _, info = ops.spamm_matmul(xs[0], w, tau, tile=tile, backend="jnp")
    derived = f"N={n};reqs={nreq};valid={float(info['valid_fraction']):.3f}"

    def unplanned():
        for x in xs:
            c, _ = ops.spamm_matmul(x, w, tau, tile=tile, backend="jnp")
        return c

    t_unplanned = timeit(unplanned)
    row("plan_cache/unplanned", t_unplanned, derived)

    cache = planner.WeightPlanCache()

    def weight_cached():
        for x in xs:
            p, wp = cache.plan_for(x, w, tau, tile=tile, backend="jnp")
            c = planner.execute(p, x, wp)
        return c

    t_cached = timeit(weight_cached)
    row("plan_cache/weight-cache", t_cached,
        f"{derived};hits={cache.hits};speedup={t_unplanned / t_cached:.2f}x")

    frozen = planner.plan(xs[0], w, tau, tile=tile, backend="jnp")
    exec_jit = jax.jit(planner.execute)

    def frozen_plan():
        for x in xs:
            c = exec_jit(frozen, x, w)
        return c

    t_frozen = timeit(frozen_plan)
    row("plan_cache/frozen-plan", t_frozen,
        f"{derived};speedup={t_unplanned / t_frozen:.2f}x")


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
