"""Sparse plan execution: compacted work-list vs the dense-bitmap path.

Two costs per valid-fraction cell, sweeping τ so the surviving fraction
drops from dense-ish to heavily pruned:

  * plan construction — `plan()`'s compacted path (surviving triples from
    the hierarchical descent → `compact_from_triples`, O(V log V) in the
    V valid triples) vs the legacy dense path (materialize the (gm, gn, gk)
    bitmap, then `spamm_compact_ref`'s O(gm·gn·gk log gk) sort);
  * execution — the ragged work-list kernel (`spamm_mm_worklist`, grid =
    Σnvalid steps) vs the dense-grid kidx kernel (`spamm_mm`, grid =
    gm·gn·gk with invalid steps masked out), both in interpret mode so the
    exact kernel bodies run on CPU.

Each cell asserts bit-parity first (work-list result == dense-grid result,
work-derived kidx == `spamm_compact_ref`), so a compaction regression fails
the benchmark loudly instead of showing up as a silent slowdown — the CI
"not slow" lane runs the `--smoke` sweep for exactly that reason.

Derived column: valid=<fraction>;plan_speedup=<dense/compact>;
exec_speedup=<dense/worklist>.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import plan as planner
from repro.kernels import ref
from repro.kernels import spamm_mm as smm


def _banded(m: int, n: int, band: float, seed: int) -> jnp.ndarray:
    """Exponential-decay banded matrix (the paper's workload shape)."""
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(m, dtype=np.float32)[:, None]
               - np.arange(n, dtype=np.float32)[None, :])
    x = np.exp(-d / band) * rng.uniform(0.5, 1.0, (m, n)).astype(np.float32)
    return jnp.asarray(x.astype(np.float32))


def _tau_for(na, nb, frac: float) -> float:
    rng = np.random.default_rng(0)
    a, b = np.asarray(na), np.asarray(nb)
    i = rng.integers(0, a.shape[0], 4096)
    k = rng.integers(0, a.shape[1], 4096)
    j = rng.integers(0, b.shape[1], 4096)
    return float(np.quantile(a[i, k] * b[k, j], 1.0 - frac))


def _plan_cell(gm: int, gn: int, gk: int, frac: float, levels: int):
    """Plan-construction timing on synthetic banded normmaps."""
    band = max(gm // 16, 2)
    na = planner.NormPyramid.from_normmap(_banded(gm, gk, band, 1), levels)
    nb = planner.NormPyramid.from_normmap(_banded(gk, gn, band, 2), levels)
    tau = _tau_for(na.base, nb.base, frac)

    def compact():
        # the tentpole path: descent triples → work-list, no dense sort
        return planner.plan(None, None, tau, norm_a=na, norm_b=nb,
                            backend="interpret")

    def dense_bitmap():
        # the legacy path: dense bitmap, then the jnp sort compaction
        mask = planner.gate_mask(na.base, nb.base, tau)
        return ref.spamm_compact_ref(mask)

    p = compact()
    kidx_ref, nv_ref = ref.spamm_compact_ref(p.mask)
    gnb = gn  # block_n=1
    assert np.array_equal(planner.kidx_from_work(p.work, gm, gnb, gk),
                          np.asarray(kidx_ref)), "compaction parity"
    assert np.array_equal(np.asarray(p.nvalid), np.asarray(nv_ref))

    t_compact = timeit(compact)
    t_dense = timeit(dense_bitmap)
    valid = float(p.valid_fraction)
    derived = (f"valid={valid:.4f};grid={gm}x{gn}x{gk};"
               f"plan_speedup={t_dense / t_compact:.2f}x")
    row(f"sparse_exec/plan/compact/{gm}x{gn}x{gk}/f{frac}", t_compact, derived)
    row(f"sparse_exec/plan/dense/{gm}x{gn}x{gk}/f{frac}", t_dense, derived)


def _exec_cell(n: int, tile: int, frac: float):
    """Execution timing: ragged work-list kernel vs dense-grid kernel
    (interpret mode — the exact kernel bodies) at one valid fraction."""
    band = max(n // 8, tile)
    a = _banded(n, n, band, 3)
    b = _banded(n, n, band, 4)
    na = ref.tile_norms_ref(a, tile)
    nb = ref.tile_norms_ref(b, tile)
    tau = _tau_for(na, nb, frac)
    p = planner.plan(a, b, tau, tile=tile, backend="interpret")
    kidx, nvalid = ref.spamm_compact_ref(p.mask)

    def worklist():
        return planner.execute(p, a, b)

    def dense_grid():
        return smm.spamm_mm(a, b, kidx, nvalid, tile=tile, interpret=True)

    c_w = worklist()
    c_d = dense_grid()
    assert np.array_equal(np.asarray(c_w), np.asarray(c_d)), "exec parity"

    t_w = timeit(worklist)
    t_d = timeit(dense_grid)
    valid = float(p.valid_fraction)
    derived = (f"valid={valid:.4f};steps={int(p.work.num_valid)}/"
               f"{p.total_tiles};exec_speedup={t_d / t_w:.2f}x")
    row(f"sparse_exec/exec/worklist/n{n}/f{frac}", t_w, derived)
    row(f"sparse_exec/exec/dense/n{n}/f{frac}", t_d, derived)


def run(quick: bool = False):
    fracs = [0.3, 0.05] if quick else [0.6, 0.3, 0.1, 0.02]
    plan_grids = [(64, 64, 64)] if quick else [(128, 128, 128),
                                               (256, 256, 256)]
    for gm, gn, gk in plan_grids:
        for frac in fracs:
            _plan_cell(gm, gn, gk, frac, levels=3)
    n_exec = 128 if quick else 256
    for frac in fracs:
        _exec_cell(n_exec, 32, frac)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-friendly trimmed sweep (parity asserts still "
                         "run — a compaction regression fails the job)")
    args = ap.parse_args()
    from benchmarks.common import header

    header()
    run(quick=args.smoke)


if __name__ == "__main__":
    main()
