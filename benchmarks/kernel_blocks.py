"""Structural block-shape analysis for the spamm_mm Pallas kernel (the
"Pallas-specific" §Perf methodology: no TPU wall-clock exists here, so block
shapes are chosen by reasoning from VMEM footprint, MXU alignment and
arithmetic intensity — then validated for correctness in interpret mode).

v5e: ~128 MiB VMEM/core usable ≈ 64 MiB budget for a double-buffered
pipeline; MXU is 128×128 systolic.

Per grid step the kernel holds (double-buffered ×2):
  A block  (tile × tile)            dtype_bytes
  B block  (tile × tile·block_n)    dtype_bytes
  C scratch(tile × tile·block_n)    f32 (accumulator, single copy)
Arithmetic intensity per k-step = 2·tile²·(tile·block_n) FLOPs over
(tile² + tile²·block_n)·dtype_bytes fetched.
"""
from __future__ import annotations

from benchmarks.common import row

VMEM_BUDGET = 64 * 2**20
MXU = 128


def run(quick: bool = False):
    best = None
    for dtype_bytes, dname in ((4, "f32"), (2, "bf16")):
        for tile in (64, 128, 256, 512):
            for block_n in (1, 2, 4, 8):
                a_b = tile * tile * dtype_bytes
                b_b = tile * tile * block_n * dtype_bytes
                acc = tile * tile * block_n * 4
                vmem = 2 * (a_b + b_b) + acc  # double-buffered in, 1× scratch
                if vmem > VMEM_BUDGET:
                    continue
                flops = 2 * tile * tile * tile * block_n
                bytes_in = a_b + b_b
                ai = flops / bytes_in
                mxu_ok = tile % MXU == 0
                # ridge point of v5e: 197e12/819e9 ≈ 241 FLOP/byte
                compute_bound = ai >= 241
                row(
                    f"kernel_blocks/{dname}/tile={tile}/bn={block_n}",
                    0.0,
                    f"vmem={vmem/2**20:.1f}MiB;AI={ai:.0f}flop/B;"
                    f"mxu_aligned={mxu_ok};compute_bound={compute_bound}",
                )
                score = (compute_bound, mxu_ok, ai, -vmem)
                if mxu_ok and (best is None or score > best[0]):
                    best = (score, dname, tile, block_n)
    if best:
        _, dname, tile, bn = best
        row(
            "kernel_blocks/bandwidth_optimal",
            0.0,
            f"dtype={dname};tile={tile};block_n={bn} — crosses the v5e ridge "
            f"(AI≥241), but see granularity row below",
        )
        # Granularity counter-force (measured, EXPERIMENTS.md §Perf): at
        # fixed τ on an exponential-decay matrix, executed-tile fraction is
        # 0.85% @tile=64 vs 40.6% @tile=512 (N=2048) — 48× more work for the
        # same error. For decay matrices the skip granularity dominates the
        # 8× arithmetic-intensity gain: the paper's LoNum≈64–128 default is
        # the right choice on TPU as well; large tiles only pay off for
        # unstructured near-sparse operands (uniform tile norms).
        row(
            "kernel_blocks/granularity_optimal",
            0.0,
            "decay matrices: tile=64-128, block_n=2-4 (bound 5.6us vs 35.4us "
            "at tile=512 on the N=2048 exponential-decay workload)",
        )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
