"""Microbenchmarks of the two cuSpAMM kernels: pure-jnp oracle vs the Pallas
kernel body in interpret mode (CPU correctness path; interpret-mode timing
is NOT TPU performance — the TPU numbers are the §Roofline/§Perf analysis).
Derived column carries the tile-skip accounting the kernels achieve."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import spamm as cs
from repro.kernels import ops, ref


def run(quick: bool = False):
    n, tile = (512, 64) if quick else (1024, 64)
    a = jnp.asarray(cs.exponential_decay(n, lam=0.7, seed=0))
    b = jnp.asarray(cs.exponential_decay(n, lam=0.7, seed=1))

    t_norm_ref = timeit(jax.jit(lambda x: ref.tile_norms_ref(x, tile)), a)
    row("kernels/getnorm/jnp", t_norm_ref, f"N={n};tile={tile}")
    t_norm_pal = timeit(
        jax.jit(lambda x: ops.tile_norms(x, tile, backend="interpret")), a)
    row("kernels/getnorm/pallas-interpret", t_norm_pal,
        "interpret-mode (correctness path)")

    for tau, label in [(0.0, "dense-equivalent"), (1e-2, "gated")]:
        c, info = ops.spamm_matmul(a, b, tau, tile=tile, backend="jnp")
        t = timeit(
            jax.jit(lambda x, y: ops.spamm_matmul(x, y, tau, tile=tile,
                                                  backend="jnp")[0]), a, b)
        row(f"kernels/spamm_mm/jnp/tau={tau:g}", t,
            f"{label};valid={float(info['valid_fraction']):.3f}")


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
