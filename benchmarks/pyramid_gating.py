"""Plan-construction cost: flat vs. hierarchical (norm-pyramid) gating.

The flat gate always evaluates the full O(gm·gn·gk) product tensor; the
hierarchical planner gates the coarsest pyramid level first and refines only
inside surviving coarse blocks, so its cost tracks the surviving candidate
set instead of the grid volume. On banded-decay normmaps (the paper's
workload) the pruned fraction grows with the grid, which is where the
pyramid pays off — the sweep reports it per cell.

Cells sweep square tile grids and, in the full run, a 1024×1024 A-side tile
grid (the acceptance scale; gn kept moderate so the flat baseline stays
runnable at all). Both paths start from precomputed normmaps, so the timing
isolates plan construction (the get-norm pass is shared and identical).

Output derived column: valid=<fine valid fraction>;pruned=<fraction of
coarse blocks the coarse gate removed>;speedup=<flat/hier>.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import plan as planner


def _banded_norms(g1: int, g2: int, band: float, seed: int) -> jnp.ndarray:
    """Synthetic banded-decay normmap: exp(-|i-j|/band) with jitter — the
    normmap an exponential-decay matrix produces, generated directly so the
    sweep reaches 1024² tile grids without materializing a 65k² matrix."""
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(g1, dtype=np.float32)[:, None]
               - np.arange(g2, dtype=np.float32)[None, :])
    nm = np.exp(-d / band) * rng.uniform(0.5, 1.0, (g1, g2)).astype(np.float32)
    return jnp.asarray(nm.astype(np.float32))


def _tau_for(na, nb, frac: float) -> float:
    """τ putting ~frac of sampled norm products above threshold."""
    rng = np.random.default_rng(0)
    a, b = np.asarray(na), np.asarray(nb)
    i = rng.integers(0, a.shape[0], 4096)
    k = rng.integers(0, a.shape[1], 4096)
    j = rng.integers(0, b.shape[1], 4096)
    return float(np.quantile(a[i, k] * b[k, j], 1.0 - frac))


def run(quick: bool = False):
    # (gm, gn, gk) tile grids; band scales with grid so the valid band stays
    # a roughly constant tile-width (decay matrices at growing N)
    cells = [(64, 64, 64), (128, 128, 128)] if quick else [
        (128, 128, 128), (256, 256, 256), (512, 512, 512), (1024, 16, 1024),
    ]
    levels = 3
    for gm, gn, gk in cells:
        band = max(gm // 64, 2)
        na = _banded_norms(gm, gk, band, 1)
        nb = _banded_norms(gk, gn, band, 2)
        tau = _tau_for(na, nb, 0.02)

        def flat():
            return planner.plan(None, None, tau, norm_a=na, norm_b=nb,
                                backend="jnp")

        def hier():
            return planner.plan(None, None, tau, norm_a=na, norm_b=nb,
                                backend="jnp", levels=levels)

        p_flat = flat()
        p_hier = hier()
        assert np.array_equal(np.asarray(p_flat.mask), np.asarray(p_hier.mask))
        valid = float(p_hier.valid_fraction)

        pyr_a = planner.NormPyramid.from_normmap(na, levels)
        pyr_b = planner.NormPyramid.from_normmap(nb, levels)
        coarse = np.asarray(pyr_a.coarse)[:, None, :] * \
            np.asarray(pyr_b.coarse).T[None]
        pruned = float(np.mean(coarse < tau))

        t_flat = timeit(flat)
        t_hier = timeit(hier)
        derived = (f"grid={gm}x{gn}x{gk};valid={valid:.4f};"
                   f"pruned={pruned:.3f};speedup={t_flat / t_hier:.2f}x")
        row(f"pyramid_gating/flat/{gm}x{gn}x{gk}", t_flat, derived)
        row(f"pyramid_gating/hier/{gm}x{gn}x{gk}", t_hier, derived)


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
