"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON artifacts. Usage:
  PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import json
import os
import socket

from repro.configs import SHAPES, cells

HW = "197 TFLOP/s bf16 · 819 GB/s HBM · 50 GB/s/link ICI (v5e)"

# BENCH_*.json layout version. v2 wraps the payload in
# {"bench_schema_version", "name", "env", "data"} and stamps every row in
# data["cells"] with the environment it was measured in (backend, device
# kind, hostname) so the perf-trajectory gate (benchmarks.perf_gate) can
# refuse to compare numbers from different machines/backends. v1 files
# (bare payload, no env) predate the gate and are rejected by it.
BENCH_SCHEMA_VERSION = 2


def bench_env(backend: str | None = None) -> dict:
    """The environment stamp for one benchmark run: where these numbers
    came from. `backend` is the kernel backend the benchmark exercised
    (interpret/pallas/jnp) — wall-clock from different backends or device
    kinds is not comparable and the perf gate refuses to diff it;
    `hostname` is provenance only (CI runners are a fleet)."""
    from repro.core.cost import device_kind

    try:
        import jax

        jver = jax.__version__
    except Exception:
        jver = "none"
    return {
        "backend": backend or "unspecified",
        "device_kind": device_kind(),
        "hostname": socket.gethostname(),
        "jax": jver,
    }


def load(d, mesh, arch, shape):
    fn = os.path.join(d, mesh, f"{arch}__{shape}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


def fmt_b(x):
    if x is None:
        return "—"
    for u, s in [(2**40, "TiB"), (2**30, "GiB"), (2**20, "MiB")]:
        if abs(x) >= u:
            return f"{x/u:.2f}{s}"
    return f"{x:.0f}B"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def dryrun_table(d, mesh):
    rows = [
        "| arch | shape | compile | args/dev | peak-temp/dev | HLO GFLOP/dev | "
        "HBM GB/dev (staging%) | collective wire GB/dev | top collectives (count×op) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, skip in cells(include_skipped=True):
        if skip:
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                        f"SKIP (full attention @512k, DESIGN.md §6) |")
            continue
        j = load(d, mesh, arch, shape)
        if j is None:
            rows.append(f"| {arch} | {shape} | MISSING | | | | | | |")
            continue
        h = j["hlo"]
        coll = sorted(h["collectives"].items(),
                      key=lambda kv: -kv[1]["wire_bytes"])[:3]
        cstr = ", ".join(f"{int(v['count'])}×{k}" for k, v in coll) or "none"
        staging = (100.0 * h.get("hbm_staging_bytes_per_device", 0)
                   / max(h["hbm_bytes_per_device"], 1))
        rows.append(
            f"| {arch} | {shape} | {j['compile_s']:.0f}s "
            f"| {fmt_b(j['memory']['argument_bytes'])} "
            f"| {fmt_b(j['memory']['peak_bytes'])} "
            f"| {h['flops_per_device']/1e9:.0f} "
            f"| {h['hbm_bytes_per_device']/1e9:.0f} ({staging:.0f}%) "
            f"| {h['collective_wire_bytes_per_device']/1e9:.1f} "
            f"| {cstr} |"
        )
    return "\n".join(rows)


def roofline_table(d, mesh):
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS/HLO | roofline frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        ("memory_s", "train"): "bf16 param storage + dots-only remat (fewer f32 re-reads)",
        ("memory_s", "prefill"): "larger attention KV chunks; fused flash (Pallas) keeps probs in VMEM",
        ("memory_s", "decode"): "KV-cache quantization (int8/fp8) halves cache reads",
        ("collective_s", "train"): "sequence-parallel activations (psum→RS+AG) + bf16 FSDP gathers",
        ("collective_s", "prefill"): "shard seq over model for activations; defer TP psum",
        ("collective_s", "decode"): "replicate params over data for serving (no FSDP gathers/token)",
        ("compute_s", "train"): "causal-aware flash (skip masked KV blocks) halves attention FLOPs",
        ("compute_s", "prefill"): "causal-aware flash (skip masked KV blocks)",
        ("compute_s", "decode"): "already compute-light; batch more requests",
    }
    for arch, shape, skip in cells(include_skipped=True):
        if skip:
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                        f"SKIP (DESIGN.md §6) |")
            continue
        j = load(d, mesh, arch, shape)
        if j is None:
            rows.append(f"| {arch} | {shape} | MISSING | | | | | | |")
            continue
        r = j["roofline"]
        kind = SHAPES[shape].kind
        frac = r["compute_s"] / max(r["step_time_bound_s"], 1e-30)
        rows.append(
            f"| {arch} | {shape} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| {r['dominant'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.2f} | {frac:.3f} "
            f"| {hints.get((r['dominant'], kind), '—')} |"
        )
    return "\n".join(rows)


def write_bench_json(name: str, payload: dict, out_dir: str = ".", *,
                     backend: str | None = None, metrics=None) -> str:
    """Write one benchmark's machine-readable report as BENCH_<name>.json
    (schema v2: versioned, environment-stamped).

    These files are deliberately .gitignore'd: they are machine-local
    measurements, and the durable trajectory is the CI artifact upload plus
    the committed reference bounds under benchmarks/references/ that
    `benchmarks.perf_gate` diffs fresh runs against. Every row of
    payload["cells"] is stamped with the measuring environment; a row that
    already carries a "backend" key keeps it (a file may mix backends — the
    gate compares per row).

    `metrics` optionally attaches an observability snapshot to the document
    (a `repro.obs.MetricsRegistry` — its `.snapshot()` is taken — or an
    already-snapshotted dict). It rides under the top-level "metrics" key,
    OUTSIDE "data", so the perf gate's cell diffing never sees it. Returns
    the path."""
    env = bench_env(backend)
    if isinstance(payload.get("cells"), list):
        for cell in payload["cells"]:
            if isinstance(cell, dict):
                stamp = dict(env)
                if "backend" in cell:
                    stamp["backend"] = cell["backend"]
                cell.setdefault("env", stamp)
    doc = {
        "bench_schema_version": BENCH_SCHEMA_VERSION,
        "name": name,
        "env": env,
        "data": payload,
    }
    if metrics is not None:
        doc["metrics"] = (metrics.snapshot()
                          if hasattr(metrics, "snapshot") else metrics)
    fn = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(fn, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    for mesh in ("16x16", "2x16x16"):
        if args.section in ("all", "dryrun"):
            print(f"\n### Dry-run — mesh {mesh}\n")
            print(dryrun_table(args.dir, mesh))
    if args.section in ("all", "roofline"):
        print("\n### Roofline — single-pod 16×16 (hardware: " + HW + ")\n")
        print(roofline_table(args.dir, "16x16"))


if __name__ == "__main__":
    main()
