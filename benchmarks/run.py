"""Benchmark harness: one module per paper table/figure + roofline readout.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableN]

Prints ``name,us_per_call,derived`` CSV (one row per measured cell).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CPU-friendly trimmed sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="alias of --quick (the CI fast lane's spelling)")
    ap.add_argument("--only", default=None,
                    help="run a single module (table2|table3|table4|table5|"
                         "loadbalance|kernels|mixed_precision|roofline)")
    args = ap.parse_args()
    args.quick = args.quick or args.smoke

    from benchmarks import (frozen_prefill, kernel_blocks, kernels_micro,
                            loadbalance, mixed_precision, plan_cache,
                            pyramid_gating, roofline, sparse_exec,
                            table1_taus, table2_dense, table3_sparse,
                            table4_ergo, table5_vgg)
    from benchmarks.common import header

    mods = {
        "table1": table1_taus,
        "table2": table2_dense,
        "table3": table3_sparse,
        "table4": table4_ergo,
        "table5": table5_vgg,
        "loadbalance": loadbalance,
        "kernels": kernels_micro,
        "kernel_blocks": kernel_blocks,
        "plan_cache": plan_cache,
        "pyramid_gating": pyramid_gating,
        "sparse_exec": sparse_exec,
        "frozen_prefill": frozen_prefill,
        "mixed_precision": mixed_precision,
        "roofline": roofline,
    }
    header()
    for name, mod in mods.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---", flush=True)
        mod.run(quick=args.quick)


if __name__ == '__main__':
    main()
