"""Benchmark harness: one module per paper table/figure + roofline readout.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableN]

Prints ``name,us_per_call,derived`` CSV (one row per measured cell).
"""
from __future__ import annotations

import argparse
import importlib


# name the CLI exposes → module under benchmarks/. THE registry: the
# --only choices/help derive from these keys, so adding a module here is
# the whole registration (the old hand-written help string had drifted to
# listing 8 of 14 modules).
MODULES = {
    "table1": "table1_taus",
    "table2": "table2_dense",
    "table3": "table3_sparse",
    "table4": "table4_ergo",
    "table5": "table5_vgg",
    "loadbalance": "loadbalance",
    "kernels": "kernels_micro",
    "kernel_blocks": "kernel_blocks",
    "plan_cache": "plan_cache",
    "pyramid_gating": "pyramid_gating",
    "sparse_exec": "sparse_exec",
    "frozen_prefill": "frozen_prefill",
    "mixed_precision": "mixed_precision",
    "autotune": "autotune",
    "obs_overhead": "obs_overhead",
    "scenario_sweep": "scenario_sweep",
    "roofline": "roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CPU-friendly trimmed sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="alias of --quick (the CI fast lane's spelling)")
    ap.add_argument("--only", default=None, choices=sorted(MODULES),
                    help="run a single module (" + "|".join(MODULES) + ")")
    args = ap.parse_args()
    args.quick = args.quick or args.smoke

    from benchmarks.common import header

    header()
    for name, modname in MODULES.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---", flush=True)
        importlib.import_module(f"benchmarks.{modname}").run(quick=args.quick)


if __name__ == '__main__':
    main()
