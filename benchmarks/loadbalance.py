"""Paper Fig. 4 / §3.5.1: load-imbalance of contiguous vs cyclic tile-row
assignment across device counts, on a diagonal-heavy decay workload — plus
the equal-work extension: variable-width row strips cut by prefix sum over
the coarse work estimate (`schedule.equal_work_partition`).

The equal-work section is parity-asserting (CI runs it via --smoke): the
partition's predicted loads must conserve the total work, its imbalance must
never exceed the contiguous schedule's (uniform-split guard), executing the
partition strip-by-strip must reproduce the flat single-device `spamm()`
product, and on the stride-aliased banded grid — hot tile-rows recurring at
the cyclic stride, the structure BOTH uniform schedules lose on — the
equal-work imbalance must be strictly lower than contiguous AND cyclic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from benchmarks.report import write_bench_json
from repro.core import spamm as cs, schedule
from repro.kernels import ref

N, TILE = 1024, 32  # paper Fig. 4 uses 1024² with 32² tiles


def _aliased_banded(n: int, stride_rows: int, seed: int = 1) -> np.ndarray:
    """Banded decay matrix with DENSE tile-row stripes recurring at
    `stride_rows` tile-rows in the leading half (attention-sink-like global
    rows in an otherwise banded norm structure). The stripes alias the
    cyclic assignment's stride — strided sampling piles them onto few
    devices — while the uniform contiguous strips catch unequal stripe
    counts; only a variable-width cut balances both."""
    rng = np.random.default_rng(seed)
    a = cs.exponential_decay(n, lam=0.6, seed=0).copy()
    for r in range(0, n // 2, stride_rows * TILE):
        a[r:r + TILE] = 0.05 * rng.standard_normal((TILE, n)).astype(
            np.float32)
    return a


def _strip_times(a: np.ndarray, b: np.ndarray, tau: float, offsets,
                 repeat: int = 5, backend: str = "interpret") -> np.ndarray:
    """Median wall-clock µs of each strip's work-list EXECUTE on its own
    rows — the per-shard step time a lockstep shard_map mesh waits on (the
    slowest strip gates the step; `schedule.strip_tables` hands these exact
    strips to the distributed bodies and the pod-sharded engine). Planning
    happens once per strip outside the timer, mirroring the frozen-plan
    serving path where shards execute precomputed step tables. The default
    backend is the interpreted Pallas kernel: its cost is per-STEP
    dominated like a real accelerator's, where the jnp fallback's scatter
    overhead scales with rows and would mask the work imbalance."""
    from repro.core import plan as planner

    gm = a.shape[0] // TILE
    at = a.reshape(gm, TILE, a.shape[1])
    jb = jnp.asarray(b)
    exec_jit = jax.jit(planner.execute)
    ts = []
    for d in range(len(offsets) - 1):
        loc = jnp.asarray(np.ascontiguousarray(
            at[offsets[d]:offsets[d + 1]]).reshape(-1, a.shape[1]))
        p = planner.plan(loc, jb, tau, tile=TILE, backend=backend)
        ts.append(timeit(exec_jit, p, loc, jb, warmup=1, repeat=repeat))
    return np.asarray(ts, np.float64)


def _strip_exec_parity(a: np.ndarray, tau: float, offsets) -> None:
    """Executing the variable strips one by one ≡ flat single-device spamm
    (the distributed bodies compute exactly these strips)."""
    ja = jnp.asarray(a)
    flat, _ = cs.spamm(ja, ja, tau, tile=TILE, backend="jnp")
    gm = a.shape[0] // TILE
    at = a.reshape(gm, TILE, a.shape[1])
    parts = []
    for d in range(len(offsets) - 1):
        loc = at[offsets[d]:offsets[d + 1]].reshape(-1, a.shape[1])
        c, _ = cs.spamm(jnp.asarray(loc), ja, tau, tile=TILE, backend="jnp")
        parts.append(np.asarray(c))
    np.testing.assert_allclose(
        np.concatenate(parts), np.asarray(flat), atol=1e-5)


def run(quick: bool = False):
    a = jnp.asarray(cs.exponential_decay(N, lam=0.6, seed=0))
    na = ref.tile_norms_ref(a, TILE)
    v = schedule.v_matrix(na, na, 0.02)
    for ndev in (4, 8, 16, 64):
        imb_c = float(schedule.tile_imbalance(v, ndev, "contiguous"))
        imb_s = float(schedule.tile_imbalance(v, ndev, "cyclic"))
        imb_e = float(schedule.tile_imbalance(v, ndev, "equal_work"))
        row(
            f"loadbalance/tile-workers={ndev}",
            0.0,
            f"imbalance_contiguous={imb_c:.3f};imbalance_cyclic={imb_s:.3f};"
            f"imbalance_equal_work={imb_e:.3f};"
            f"improvement={imb_c/imb_s:.2f}x",
        )
    # row-strip variant (the §3.4 distributed partition): banded grid
    for ndev in (4, 8):
        imb_c = float(schedule.imbalance(v, ndev, "contiguous"))
        imb_s = float(schedule.imbalance(v, ndev, "cyclic"))
        imb_e = schedule.partition_imbalance(
            v, schedule.equal_work_partition(v, ndev))
        # uniform-split guard (compare in the same f64 attribution pipeline)
        lc = schedule.device_loads(v, ndev, "contiguous")
        assert imb_e <= lc.max() / max(lc.mean(), 1e-9) + 1e-9, (imb_e, lc)
        row(
            f"loadbalance/row-devices={ndev}",
            0.0,
            f"imbalance_contiguous={imb_c:.3f};imbalance_cyclic={imb_s:.3f};"
            f"imbalance_equal_work={imb_e:.3f};"
            f"improvement={imb_c/imb_s:.2f}x",
        )

    # equal-work vs contiguous/cyclic on the stride-aliased banded grid —
    # the structure both uniform schedules lose on. Parity-asserting: the
    # strict win below and the strip-execution identity are the CI gate.
    tau = 0.02
    cells = []
    aa = _aliased_banded(N, 4)
    bb = (0.05 * np.random.default_rng(2).standard_normal((N, N))).astype(
        np.float32)
    na_alias = ref.tile_norms_ref(jnp.asarray(aa), TILE)
    nb_dense = ref.tile_norms_ref(jnp.asarray(bb), TILE)
    for ndev in (4, 8):
        va = schedule.v_matrix(na_alias, nb_dense, tau)
        offs = schedule.equal_work_partition(va, ndev)
        loads = schedule.partition_loads(va, offs)
        total = float(np.asarray(jnp.sum(va, axis=1)).sum())
        assert abs(loads.sum() - total) < 1e-6 * max(total, 1.0)
        imb_c = float(schedule.imbalance(va, ndev, "contiguous"))
        imb_s = float(schedule.imbalance(va, ndev, "cyclic"))
        imb_e = schedule.partition_imbalance(va, offs)
        assert imb_e < imb_c and imb_e < imb_s, (imb_e, imb_c, imb_s)
        row(
            f"loadbalance/aliased-row-devices={ndev}",
            0.0,
            f"imbalance_contiguous={imb_c:.3f};imbalance_cyclic={imb_s:.3f};"
            f"imbalance_equal_work={imb_e:.3f};"
            f"improvement_vs_best_uniform={min(imb_c, imb_s)/imb_e:.2f}x",
        )
        cells.append({
            "name": f"aliased_predicted_ndev{ndev}", "n": N, "tile": TILE,
            "tau": tau, "ndev": ndev, "imbalance_contiguous": imb_c,
            "imbalance_cyclic": imb_s, "imbalance_equal_work": float(imb_e),
        })

    # MEASURED per-shard step time (the ROADMAP leftover): wall-clock each
    # strip's plan+execute under the equal-work cut vs the uniform
    # contiguous cut on the same aliased grid. A lockstep mesh waits on the
    # slowest shard, so max/mean of the measured strip times IS the step-
    # time imbalance; equal_work must be no worse than contiguous (small
    # slack for host-timing noise — the predicted assert above is strict).
    n_m = 512 if not quick else 256
    ndev_m = 4
    am = _aliased_banded(n_m, 4)
    bm = (0.05 * np.random.default_rng(3).standard_normal(
        (n_m, n_m))).astype(np.float32)
    vm = schedule.v_matrix(ref.tile_norms_ref(jnp.asarray(am), TILE),
                           ref.tile_norms_ref(jnp.asarray(bm), TILE), tau)
    gm_m = n_m // TILE
    offs_e = schedule.equal_work_partition(vm, ndev_m)
    offs_c = np.rint(np.arange(ndev_m + 1) * gm_m / ndev_m).astype(np.int64)
    t_e = _strip_times(am, bm, tau, offs_e)
    t_c = _strip_times(am, bm, tau, offs_c)
    imb_me = float(t_e.max() / t_e.mean())
    imb_mc = float(t_c.max() / t_c.mean())
    assert imb_me <= imb_mc * 1.10, (imb_me, imb_mc, t_e, t_c)
    row(
        f"loadbalance/measured-step-time-ndev={ndev_m}",
        float(t_e.max()),
        f"measured_imbalance_equal_work={imb_me:.3f};"
        f"measured_imbalance_contiguous={imb_mc:.3f};"
        f"slowest_strip_contiguous_us={t_c.max():.1f}",
    )
    cells.append({
        "name": f"aliased_measured_ndev{ndev_m}", "n": n_m, "tile": TILE,
        "tau": tau, "ndev": ndev_m,
        "strip_us_equal_work": [float(t) for t in t_e],
        "strip_us_contiguous": [float(t) for t in t_c],
        "measured_imbalance_equal_work": imb_me,
        "measured_imbalance_contiguous": imb_mc,
    })
    path = write_bench_json("loadbalance", {"cells": cells},
                            backend="interpret")
    print(f"# wrote {path}", flush=True)

    # strip execution ≡ flat spamm (small grid; ragged 3-device count)
    n_par = 256
    a_par = _aliased_banded(n_par, 4)
    v_par = schedule.v_matrix(
        ref.tile_norms_ref(jnp.asarray(a_par), TILE),
        ref.tile_norms_ref(jnp.asarray(a_par), TILE), tau)
    for ndev in (2, 3):
        _strip_exec_parity(a_par, tau, schedule.equal_work_partition(v_par, ndev))
    row("loadbalance/equal-work-parity", 0.0, "strip_exec=flat_spamm;ok=1")


if __name__ == "__main__":
    import argparse

    from benchmarks.common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: same sweep, asserts are the gate")
    args = ap.parse_args()
    header()
    run(quick=args.smoke)
