"""Paper Fig. 4 / §3.5.1: load-imbalance of contiguous vs cyclic tile-row
assignment across device counts, on a diagonal-heavy decay workload."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import spamm as cs, schedule
from repro.kernels import ref

N, TILE = 1024, 32  # paper Fig. 4 uses 1024² with 32² tiles


def run(quick: bool = False):
    a = jnp.asarray(cs.exponential_decay(N, lam=0.6, seed=0))
    na = ref.tile_norms_ref(a, TILE)
    v = schedule.v_matrix(na, na, 0.02)
    for ndev in (4, 8, 16, 64):
        imb_c = float(schedule.tile_imbalance(v, ndev, "contiguous"))
        imb_s = float(schedule.tile_imbalance(v, ndev, "cyclic"))
        row(
            f"loadbalance/tile-workers={ndev}",
            0.0,
            f"imbalance_contiguous={imb_c:.3f};imbalance_cyclic={imb_s:.3f};"
            f"improvement={imb_c/imb_s:.2f}x",
        )
    # row-strip variant (the §3.4 distributed partition)
    for ndev in (4, 8):
        imb_c = float(schedule.imbalance(v, ndev, "contiguous"))
        imb_s = float(schedule.imbalance(v, ndev, "cyclic"))
        row(
            f"loadbalance/row-devices={ndev}",
            0.0,
            f"imbalance_contiguous={imb_c:.3f};imbalance_cyclic={imb_s:.3f};"
            f"improvement={imb_c/imb_s:.2f}x",
        )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
