"""Telemetry overhead gate: instrumented engine vs the obs=False baseline.

Two engines over the same params and SpAMM config:

  * instrumented — the default `Engine(obs=None)` path: labeled Tap
    callbacks (fraction + bytes + cost prediction in ONE io_callback per
    gated GEMM), host spans around freeze/prefill/decode, TTFT and
    decode-step latency reads at the lockstep loop's own blocking points;
  * baseline — `Engine(obs=False)`: the hard-off bundle; spans and latency
    reads are skipped and the cost-prediction taps never embed, so the
    traced graphs are exactly the pre-telemetry computation.

The cell asserts (1) BIT-IDENTICAL tokens — telemetry must be pure
observation, never perturbing the computed values — and (2) instrumented
wall-clock within OVERHEAD_BUDGET (2%) of baseline, min-of-N per engine so
scheduler noise doesn't fail the gate spuriously. The timing design the
budget leans on: spans close retroactively at the loop's existing
`np.asarray(cur)` block (`SpanTracer.add_complete`), adding ZERO device
syncs; the per-GEMM telemetry rides the same single callback the
uninstrumented stats path already paid for.

Derived column: overhead=<frac>;budget=<frac>;identical=<bool>.

The BENCH json carries the instrumented run's full registry snapshot under
the top-level "metrics" key (write_bench_json(metrics=...)) — the artifact
doubles as a telemetry-schema example.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from benchmarks.report import write_bench_json
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=64, decode_seq_shard=False,
)

OVERHEAD_BUDGET = 0.02   # instrumented ≤ (1 + this) × baseline


def _wave(rng, cfg, batch, plen, max_new):
    return [Request(prompt=rng.integers(1, cfg.vocab, size=plen)
                    .astype(np.int32), max_new_tokens=max_new)
            for _ in range(batch)]


def _time_wave(eng, reqs):
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    jax.block_until_ready(outs)
    return time.perf_counter() - t0, outs


def _cell(arch: str, batch: int, plen: int, max_new: int, repeat: int):
    cfg = get_config(arch).reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.05, tile=4, backend="jnp")
    eng_i = Engine(cfg, PCFG, ctx, params, max_len=plen + max_new + 8,
                   spamm_cfg=sc)                # instrumented (obs default)
    eng_b = Engine(cfg, PCFG, ctx, params, max_len=plen + max_new + 8,
                   spamm_cfg=sc, obs=False)     # uninstrumented baseline
    rng = np.random.default_rng(0)
    prompts = _wave(rng, cfg, batch, plen, max_new)

    def fresh():
        # generate() writes Request.out — hand each engine its own copies
        return [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)
                for r in prompts]

    # warm both engines (freeze + compile lands outside the measurement)
    outs_i = eng_i.generate(fresh())
    outs_b = eng_b.generate(fresh())
    identical = all(np.array_equal(a, b) for a, b in zip(outs_i, outs_b))
    assert identical, "telemetry perturbed the generated tokens"
    assert eng_i.trace_counts == eng_b.trace_counts == \
        {"prefill": 1, "decode": 1}, (eng_i.trace_counts, eng_b.trace_counts)

    # alternate timed waves; min-of-N is the noise-robust estimator here
    # (the distributions overlap heavily — the minima compare the floors)
    t_i, t_b = [], []
    for _ in range(repeat):
        t_b.append(_time_wave(eng_b, fresh())[0])
        t_i.append(_time_wave(eng_i, fresh())[0])
    best_i, best_b = min(t_i), min(t_b)
    overhead = best_i / best_b - 1.0
    derived = (f"overhead={overhead:+.4f};budget={OVERHEAD_BUDGET};"
               f"identical={identical}")
    row(f"obs_overhead/instrumented/{arch}/b{batch}p{plen}n{max_new}",
        best_i * 1e6, derived)
    row(f"obs_overhead/baseline/{arch}/b{batch}p{plen}n{max_new}",
        best_b * 1e6, derived)
    assert overhead < OVERHEAD_BUDGET, (
        f"telemetry overhead {overhead:+.2%} exceeds the "
        f"{OVERHEAD_BUDGET:.0%} budget (instrumented {best_i:.4f}s vs "
        f"baseline {best_b:.4f}s)")
    return {
        "arch": arch, "batch": batch, "prompt_len": plen,
        "max_new": max_new, "backend": "jnp",
        "instrumented_s": best_i, "baseline_s": best_b,
        "overhead_frac": overhead, "identical_tokens": identical,
    }, eng_i


def run(quick: bool = False):
    cells = ([("musicgen-large", 4, 16, 8, 3)] if quick else
             [("musicgen-large", 4, 16, 8, 5),
              ("musicgen-large", 8, 32, 16, 5)])
    rows, eng = [], None
    for arch, b, p, n, rep in cells:
        cell, eng = _cell(arch, b, p, n, rep)
        rows.append(cell)
    write_bench_json("obs_overhead", {"cells": rows}, backend="jnp",
                     metrics=eng.obs.registry)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-friendly single cell (the bit-parity and "
                         "overhead asserts still run)")
    args = ap.parse_args()
    from benchmarks.common import header

    header()
    run(quick=args.smoke)


if __name__ == "__main__":
    main()
