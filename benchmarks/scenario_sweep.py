"""Mixed-workload serving scenario: chunked admission vs the wave baseline.

Two scenarios over the same params, SpAMM config, and token budget:

  * wave — the lockstep baseline: a uniform-length batch, one-shot prefill,
    every slot rides to the end of the wave;
  * chunked — heterogeneous prompt lengths through the slot scheduler
    (`prefill_chunk`, `max_slots` < batch): tile-aligned chunked prefill
    interleaved with decode, queued requests admitted into freed slots
    between decode steps.

The cell consumes the engine's EXISTING telemetry instead of growing its
own readouts: per-request `Request.out["spamm"]["latency"]` for TTFT and
decode-step wall-clock, the obs registry's serve_admissions_total /
serve_prefill_chunks_total counters, and `Engine.trace_counts` against
`cost.bucket_ladder` for the compile-count bound. Asserts:

  1. UNTRUNCATED — every mixed-length request returns its full max_new
     tokens (the old wave silently left-trimmed prompts; a truncated
     prompt at these sizes still "works", so the length check rides with
     the per-request metadata check that the engine saw every prompt at
     its true length);
  2. BUCKET BOUND — the mixed sweep compiles at most
     len(bucket_ladder(batch, 1)) prefill traces;
  3. DECODE BUDGET — the chunked scheduler's mean decode-step latency
     stays within DECODE_BUDGET × the wave baseline's (admission must not
     stall the decode plane).

Derived column: decode_ratio=<x>;budget=<x>;admissions=<n>;chunks=<n>.

The BENCH json carries the chunked run's full registry snapshot under the
top-level "metrics" key, so the CI artifact doubles as an admission-
telemetry example.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from benchmarks.report import write_bench_json
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core.cost import bucket_ladder
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=64, decode_seq_shard=False,
)

# chunked decode steps may pay admission bookkeeping between steps; on CPU
# the dispatch floor dominates and the slot pool is smaller than the wave
# batch, so a generous envelope still catches a stalled decode plane
DECODE_BUDGET = 1.75


def _mixed_lengths(rng, batch: int, plen: int):
    """Heterogeneous prompt lengths in [plen/2, plen] — the traffic shape
    the old wave silently truncated."""
    return rng.integers(max(1, plen // 2), plen + 1, size=batch)


def _gen(eng, reqs):
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    jax.block_until_ready(outs)
    return time.perf_counter() - t0, outs


def _lat(reqs, key):
    vals = [r.out["spamm"]["latency"].get(key) for r in reqs
            if r.out and r.out.get("spamm")]
    vals = [v for v in vals if v is not None]
    return float(np.mean(vals)) if vals else None


def _cell(arch: str, batch: int, plen: int, max_new: int, chunk: int):
    cfg = get_config(arch).reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = lambda: SpammConfig(enable=True, tau=0.05, tile=4, backend="jnp")
    max_len = plen + max_new + 8
    rng = np.random.default_rng(0)

    # -- wave baseline: uniform lengths, one-shot prefill -------------------
    eng_w = Engine(cfg, PCFG, ctx, params, max_len=max_len, spamm_cfg=sc())
    mk_wave = lambda: [Request(prompt=rng0.integers(1, cfg.vocab, plen)
                               .astype(np.int32), max_new_tokens=max_new)
                       for _ in range(batch)]
    rng0 = np.random.default_rng(1)
    wave_reqs = mk_wave()
    _gen(eng_w, wave_reqs)             # warm: freeze + compile
    rng0 = np.random.default_rng(1)
    wave_reqs = mk_wave()
    wave_s, wave_outs = _gen(eng_w, wave_reqs)
    wave_dec = _lat(wave_reqs, "decode_mean_s")

    # -- chunked + admission: mixed lengths through a capped slot pool ------
    eng_c = Engine(cfg, PCFG, ctx, params, max_len=max_len, spamm_cfg=sc(),
                   prefill_chunk=chunk, max_slots=max(1, batch // 2))
    plens = _mixed_lengths(rng, batch, plen)
    mk_mix = lambda r: [Request(prompt=r.integers(1, cfg.vocab, int(n))
                                .astype(np.int32), max_new_tokens=max_new)
                        for n in plens]
    _gen(eng_c, mk_mix(np.random.default_rng(2)))   # warm
    mix_reqs = mk_mix(np.random.default_rng(2))
    mix_s, mix_outs = _gen(eng_c, mix_reqs)
    mix_dec = _lat(mix_reqs, "decode_mean_s")

    # 1. untruncated: every request produced its full budget and the engine
    # recorded its tokens (the old silent-trim path can't get here — mixed
    # lengths either chunk or raise)
    assert all(len(o) == max_new for o in mix_outs), \
        [len(o) for o in mix_outs]
    assert all(r.out is not None and len(r.out["tokens"]) == max_new
               for r in mix_reqs)

    # 2. compile-count bound: the chunked plane is bucket-keyed
    ladder = bucket_ladder(batch, 1)
    assert eng_c.trace_counts["prefill"] <= len(ladder), \
        (eng_c.trace_counts, ladder)

    # 3. decode budget: admission must not stall the decode plane
    ratio = (mix_dec / wave_dec) if (mix_dec and wave_dec) else float("nan")
    assert not (ratio == ratio and ratio > DECODE_BUDGET), (
        f"chunked decode {mix_dec:.6f}s/step vs wave {wave_dec:.6f}s/step "
        f"— ratio {ratio:.2f} over the {DECODE_BUDGET} budget")

    reg = eng_c.obs.registry.snapshot()

    def _counter(name):
        series = reg.get(name, {}).get("series", {})
        return float(sum(v for v in series.values()
                         if isinstance(v, (int, float))))

    admissions = _counter("serve_admissions_total")
    chunks = _counter("serve_prefill_chunks_total")
    derived = (f"decode_ratio={ratio:.3f};budget={DECODE_BUDGET};"
               f"admissions={admissions:.0f};chunks={chunks:.0f}")
    tag = f"{arch}/b{batch}p{plen}n{max_new}c{chunk}"
    row(f"scenario_sweep/wave/{tag}", wave_s * 1e6, derived)
    row(f"scenario_sweep/chunked/{tag}", mix_s * 1e6, derived)
    return {
        "arch": arch, "batch": batch, "prompt_len": plen,
        "max_new": max_new, "chunk": chunk, "backend": "jnp",
        "wave_s": wave_s, "chunked_s": mix_s,
        "wave_decode_mean_s": wave_dec, "chunked_decode_mean_s": mix_dec,
        "decode_ratio": ratio, "decode_budget": DECODE_BUDGET,
        "admissions": admissions, "prefill_chunks": chunks,
        "prefill_traces": eng_c.trace_counts["prefill"],
        "bucket_ladder_size": len(ladder),
        "wave_tokens": int(sum(len(o) for o in wave_outs)),
        "chunked_tokens": int(sum(len(o) for o in mix_outs)),
    }, eng_c


def run(quick: bool = False):
    cells = ([("musicgen-large", 4, 16, 6, 8)] if quick else
             [("musicgen-large", 8, 32, 8, 8),
              ("starcoder2-7b", 4, 16, 6, 8)])
    rows, eng = [], None
    for arch, b, p, n, c in cells:
        cell, eng = _cell(arch, b, p, n, c)
        rows.append(cell)
    write_bench_json("scenario_sweep", {"cells": rows}, backend="jnp",
                     metrics=eng.obs.registry)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-friendly single cell (the untruncated, bucket-"
                         "bound, and decode-budget asserts still run)")
    args = ap.parse_args()
    from benchmarks.common import header

    header()
    run(quick=args.smoke)


if __name__ == "__main__":
    main()
