"""Paper Table 3: cuSpAMM vs truncation + sparse GEMM (cuSPARSE stand-in =
jax.experimental.sparse BCOO matmul) at MATCHED error levels.

Protocol (paper §4.2.2): truncate the decay matrix at TRUN (elements below →
zero), run sparse GEMM; pick SpAMM's τ so ‖E‖_F matches; report nz ratio,
valid ratio, both errors, and the time ratio. The paper's point — sparse
formats collapse on near-sparse operands (nz ≳ 25%) while SpAMM keeps
winning — shows up here as BCOO's wall-clock blowing up with nz ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

from benchmarks.common import row, timeit
from repro.core import spamm as cs

CASES = [  # (N, TRUN) chosen to land near the paper's nz ratios
    (1024, 0.05),
    (1024, 0.08),
    (2048, 0.05),
]
TILE = 64


def _match_tau(a, b, dense, target_err, lo=0.0, hi=None):
    """Binary-search τ whose ‖E‖_F matches the truncation error."""
    hi = hi if hi is not None else float(jnp.max(jnp.abs(a))) * a.shape[0]
    tau = hi / 2
    for _ in range(25):
        c, _ = cs.spamm(a, b, tau, tile=TILE, backend="jnp")
        err = float(jnp.linalg.norm(c - dense))
        if err > target_err:
            hi = tau
        else:
            lo = tau
        tau = 0.5 * (lo + hi)
    return tau


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    for n, trun in cases:
        a = jnp.asarray(cs.algebraic_decay(n, seed=0))
        b = jnp.asarray(cs.algebraic_decay(n, seed=1))
        dense = a @ b

        at = jnp.where(jnp.abs(a) >= trun, a, 0.0)
        bt = jnp.where(jnp.abs(b) >= trun, b, 0.0)
        nz = float(jnp.mean(at != 0.0))
        err_trunc = float(jnp.linalg.norm(at @ bt - dense))

        a_sp = jsparse.BCOO.fromdense(at)
        b_sp = jsparse.BCOO.fromdense(bt)

        @jax.jit
        def sparse_mm(a_sp, b_sp):
            return (a_sp @ b_sp).todense()

        t_sparse = timeit(sparse_mm, a_sp, b_sp)

        tau = _match_tau(a, b, dense, err_trunc)
        c, info = cs.spamm(a, b, tau, tile=TILE, backend="jnp")
        err_spamm = float(jnp.linalg.norm(c - dense))

        def spamm_fn(x, y, tau=tau):
            return cs.spamm(x, y, tau, tile=TILE, backend="jnp")[0]

        t_spamm = timeit(jax.jit(spamm_fn), a, b)
        row(
            f"table3/N={n}/nz={nz:.2%}",
            t_spamm,
            f"speedup_vs_sparse={t_sparse/t_spamm:.1f}x;"
            f"err_sparse={err_trunc:.3g};err_spamm={err_spamm:.3g};"
            f"valid_ratio={float(info.valid_fraction):.3f}",
        )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
