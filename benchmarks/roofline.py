"""Roofline report: reads the dry-run artifacts (experiments/dryrun/) and
prints the per-(arch × shape × mesh) three-term table that EXPERIMENTS.md
§Roofline embeds. Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
from __future__ import annotations

import json
import os

from benchmarks.common import row

from repro.configs import cells

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load(mesh: str, arch: str, shape: str):
    fn = os.path.join(DRYRUN_DIR, mesh, f"{arch}__{shape}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


def run(quick: bool = False):
    for mesh in ("16x16", "2x16x16"):
        for arch, shape, skip in cells(include_skipped=True):
            if skip:
                row(f"roofline/{mesh}/{arch}/{shape}", 0.0,
                    "SKIP(full-attention arch at 512k ctx; DESIGN.md §6)")
                continue
            d = load(mesh, arch, shape)
            if d is None:
                row(f"roofline/{mesh}/{arch}/{shape}", 0.0, "MISSING")
                continue
            r = d["roofline"]
            step_us = r["step_time_bound_s"] * 1e6
            row(
                f"roofline/{mesh}/{arch}/{shape}",
                step_us,
                f"compute={r['compute_s']:.3e}s;memory={r['memory_s']:.3e}s;"
                f"collective={r['collective_s']:.3e}s;dom={r['dominant']};"
                f"useful_flops_ratio={r['useful_flops_ratio']:.3f}",
            )


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
