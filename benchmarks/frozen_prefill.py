"""Frozen-plan serving: compiled prefill with vs without frozen weight plans.

Two engines over the same reduced model and SpAMM config:

  * frozen — the PR 4 tentpole path: weight-side plans precomputed once
    (`repro.plans.freeze_tree`) and passed into the jitted prefill as
    ARGUMENTS; the compiled graph traces only the activation-side gate and
    executes the frozen `SpammWork` step tables (zero weight get-norm /
    dense-bitmap-sort ops);
  * legacy — in-trace gating: the compiled prefill re-derives the weight
    normmaps and the gate on every call.

Each cell asserts bit-parity of the prefill logits first (the frozen path
must be bit-identical to in-trace gating), so a frozen-plan regression
fails the benchmark loudly instead of landing as a silent wrong answer —
the CI fast lane runs `--smoke` for exactly that reason. Also reports the
one-time freeze (plan-build) cost amortized away.

Derived column: speedup=<legacy/frozen>;gated=<gemms>;steps=<frozen bucket>.

Caveat on the speedup number: at the reduced (CPU smoke) sizes the weight
normmaps are a few dozen floats, so the get-norm work the frozen path
removes is ~free while its per-step gather/compare is not — expect ≤1×
here. The benchmark's CI job is the PARITY gate; the amortization win
scales with weight size (the K·N get-norm pass and the O(grid log) sort
the compiled graph no longer pays per call).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.plans.precompute import iter_gated_weights
from repro.serving.engine import Engine

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=64, decode_seq_shard=False,
)


def _cell(arch: str, batch: int, seq: int, tau: float, levels: int):
    cfg = get_config(arch).reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=tau, tile=16, backend="jnp",
                     levels=levels)
    eng_f = Engine(cfg, PCFG, ctx, params, max_len=seq + 8, spamm_cfg=sc)
    eng_l = Engine(cfg, PCFG, ctx, params, max_len=seq + 8, spamm_cfg=sc,
                   freeze_plans=False)
    rng = np.random.default_rng(0)
    batch_in = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab, size=(batch, seq)).astype(np.int32))}

    t_freeze = timeit(lambda: Engine(
        cfg, PCFG, ctx, params, max_len=seq + 8,
        spamm_cfg=sc)._frozen_for(batch * seq), warmup=0, repeat=1)
    frozen = eng_f._frozen_for(batch * seq)

    def prefill_frozen():
        return eng_f._prefill(eng_f.params, batch_in, frozen)[1]

    def prefill_legacy():
        return eng_l._prefill(eng_l.params, batch_in, {})[1]

    lf = np.asarray(prefill_frozen())
    ll = np.asarray(prefill_legacy())
    assert np.array_equal(lf, ll), "frozen prefill parity"

    t_f = timeit(prefill_frozen)
    t_l = timeit(prefill_legacy)
    n_gemms = sum(1 for _ in iter_gated_weights(params))
    derived = (f"speedup={t_l / t_f:.2f}x;gated_leaves={n_gemms};"
               f"freeze_once_us={t_freeze:.0f}")
    row(f"frozen_prefill/compiled/frozen/{arch}/b{batch}s{seq}/l{levels}",
        t_f, derived)
    row(f"frozen_prefill/compiled/legacy/{arch}/b{batch}s{seq}/l{levels}",
        t_l, derived)


def run(quick: bool = False):
    cells = ([("musicgen-large", 2, 32, 0.05, 1)] if quick else
             [("musicgen-large", 2, 32, 0.05, 1),
              ("musicgen-large", 4, 64, 0.05, 0),
              ("starcoder2-7b", 2, 48, 0.05, 1)])
    for arch, b, s, tau, levels in cells:
        _cell(arch, b, s, tau, levels)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-friendly single cell (the parity assert still "
                         "runs — a frozen-plan regression fails the job)")
    args = ap.parse_args()
    from benchmarks.common import header

    header()
    run(quick=args.smoke)


if __name__ == "__main__":
    main()
