"""Property-based tests (hypothesis) for the system's SpAMM invariants.

`hypothesis` is an optional dep: without it the @given tests SKIP (stub
decorators below) but the module still imports, so its plain tests — and
the seeded-sweep twins in test_equal_work.py — run everywhere. The old
module-level importorskip silently skipped those too."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: skip @given tests, keep the rest

    def settings(**kw):
        return lambda f: f

    def given(*a, **kw):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    class _Stub:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _Stub()

from repro.core import schedule, spamm as cs
from repro.kernels import ops, ref


def _mat(n, m, seed, decay=0.5):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(n)[:, None] - np.arange(m)[None, :])
    return ((0.3 / (d ** decay + 1)) * rng.standard_normal((n, m))).astype(
        np.float32
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([64, 128, 192]),
    tile=st.sampled_from([32, 64]),
    seed=st.integers(0, 10_000),
)
def test_tau_zero_is_exact(n, tile, seed):
    """paper §3.1: τ=0 ⇒ SpAMM ≡ GEMM (every norm product ≥ 0)."""
    a, b = _mat(n, n, seed), _mat(n, n, seed + 1)
    c, info = cs.spamm(jnp.asarray(a), jnp.asarray(b), 0.0, tile=tile,
                       backend="jnp")
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=1e-4, atol=1e-5)
    assert float(info.valid_fraction) == 1.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_error_and_work_monotone_in_tau(seed):
    """Larger τ ⇒ (weakly) fewer executed tiles and (weakly) larger error —
    the tradeoff curve behind paper Tables 2/4."""
    n, tile = 192, 32
    a, b = _mat(n, n, seed, 0.9), _mat(n, n, seed + 1, 0.9)
    dense = a @ b
    prev_frac, prev_err = 1.1, -1.0
    for tau in [0.0, 0.05, 0.2, 0.8, 3.2]:
        c, info = cs.spamm(jnp.asarray(a), jnp.asarray(b), tau, tile=tile,
                           backend="jnp")
        frac = float(info.valid_fraction)
        err = float(np.linalg.norm(np.asarray(c) - dense))
        assert frac <= prev_frac + 1e-9
        assert err >= prev_err - 1e-4
        prev_frac, prev_err = frac, err


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 1000),
    tau=st.floats(0.01, 2.0),
)
def test_flat_equals_recursive(seed, tau):
    """paper §3.1 equivalence claim: one-level leaf gating ≡ Algorithm 1's
    quad-tree recursion (ancestor norms dominate leaf norms)."""
    n, leaf = 128, 32
    a, b = _mat(n, n, seed, 0.8), _mat(n, n, seed + 1, 0.8)
    flat, _ = cs.spamm(jnp.asarray(a), jnp.asarray(b), tau, tile=leaf,
                       backend="jnp")
    rec = cs.recursive_spamm(a, b, tau, leaf=leaf)
    np.testing.assert_allclose(np.asarray(flat, np.float64), rec, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(10, 200),
    k=st.integers(10, 200),
    n=st.integers(10, 200),
    seed=st.integers(0, 1000),
)
def test_arbitrary_shapes_pad_unpad(m, k, n, seed):
    a, b = _mat(m, k, seed), _mat(k, n, seed + 1)
    c, _ = cs.spamm(jnp.asarray(a), jnp.asarray(b), 0.0, tile=64, backend="jnp")
    assert c.shape == (m, n)
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), tau=st.floats(0.0, 3.0))
def test_count_valid_matches_mask(seed, tau):
    """The memory-light searchsorted counter == the materialized mask sum."""
    na = jnp.asarray(np.random.default_rng(seed).uniform(0, 1, (7, 5)),
                     jnp.float32)
    nb = jnp.asarray(np.random.default_rng(seed + 1).uniform(0, 1, (5, 9)),
                     jnp.float32)
    want = int(np.sum(np.asarray(ref.spamm_mask_ref(na, nb, jnp.float32(tau)))))
    got = int(cs.count_valid(na, nb, tau))
    assert got == want


@settings(max_examples=30, deadline=None)
@given(
    gm=st.integers(2, 48),
    gn=st.integers(1, 12),
    num_devices=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_equal_work_partition_properties(gm, gn, num_devices, seed):
    """The §3.5.1 load-balance extension's invariants: for any random V the
    equal-work strips cover [0, gm) exactly once, every strip is non-empty,
    and the predicted imbalance never exceeds the contiguous schedule's
    (the uniform-split guard makes the bound structural, all-zero V
    included)."""
    num_devices = min(num_devices, gm)
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.integers(0, 50, (gm, gn)).astype(np.float32))
    offs = schedule.equal_work_partition(v, num_devices)
    assert offs.shape == (num_devices + 1,)
    assert offs[0] == 0 and offs[-1] == gm
    assert np.all(np.diff(offs) >= 1)  # every strip non-empty
    rows = np.concatenate(
        [schedule.rows_for_partition(d, offs) for d in range(num_devices)])
    np.testing.assert_array_equal(rows, np.arange(gm))  # exact cover, once
    imb_eq = schedule.partition_imbalance(v, offs)
    loads_c = schedule.device_loads(v, num_devices, "contiguous")
    imb_c = loads_c.max() / max(loads_c.mean(), 1e-9)
    assert imb_eq <= imb_c + 1e-9


def test_effective_flops_equals_valid_fraction():
    """The work-reduction mechanism behind paper Table 2: executed FLOPs are
    exactly valid_fraction × dense FLOPs."""
    n, tile = 256, 64
    a, b = _mat(n, n, 3, 0.9), _mat(n, n, 4, 0.9)
    c, info = cs.spamm(jnp.asarray(a), jnp.asarray(b), 0.5, tile=tile,
                       backend="jnp")
    frac = float(info.valid_fraction)
    assert 0.0 < frac < 1.0  # non-trivial case
    assert float(info.effective_flops) == pytest.approx(frac * 2 * n**3)


# ---------------------------------------------------------------------------
# mixed-precision gating: the widened-τ quantized gate is a SUPERSET
# ---------------------------------------------------------------------------

def _banded(n, m, seed, width=12):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(n)[:, None] - np.arange(m)[None, :])
    return np.where(d <= width, rng.standard_normal((n, m)), 0.0).astype(
        np.float32
    )


def _skewed(n, m, seed):
    # tile magnitudes spanning ~6 orders of magnitude: the adversarial case
    # for per-tile int8 scales (tiny tiles quantize to mostly zeros)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, m)).astype(np.float32)
    return x * np.float32(10.0) ** rng.integers(-4, 2, size=(n, m))


_GENS = {"random": _mat, "banded": _banded, "skewed": _skewed}


@pytest.mark.parametrize("kind", sorted(_GENS))
@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
def test_quantized_gate_is_superset_of_f32_gate(kind, dtype):
    """kernels.quantize guarantee: with norms from the quantized view and τ
    widened by the analytic bound, every tile pair the f32 gate keeps stays
    kept — low precision may only ADD work, never silently drop it."""
    from repro.core import plan as cplan

    n, tile = 128, 32
    for seed in range(5):
        for tau in (1e-3, 0.05, 0.5):
            a, b = _GENS[kind](n, n, seed), _GENS[kind](n, n, seed + 100)
            p32 = cplan.plan(jnp.asarray(a), jnp.asarray(b), tau, tile=tile,
                             backend="jnp")
            pq = cplan.plan(jnp.asarray(a), jnp.asarray(b), tau, tile=tile,
                            backend="jnp", compute_dtype=dtype)
            kept32 = np.asarray(p32.mask)
            keptq = np.asarray(pq.mask)
            dropped = kept32 & ~keptq
            assert not dropped.any(), (
                f"{dtype}/{kind}/seed{seed}/tau{tau}: quantized gate "
                f"dropped {int(dropped.sum())} f32-kept tile pairs")


def test_quantized_gate_tau_nonpositive_unchanged():
    """τ ≤ 0 keeps everything in f32; widening must not flip that (the
    widened τ' = τ·(1-e)² would move a negative τ TOWARD zero — the
    implementation leaves τ ≤ 0 alone instead)."""
    from repro.core import plan as cplan
    from repro.kernels.quantize import widen_tau

    assert widen_tau(0.0, "int8", 32) == 0.0
    assert widen_tau(-1.0, "bfloat16", 32) == -1.0
    a, b = _mat(64, 64, 0), _mat(64, 64, 1)
    pq = cplan.plan(jnp.asarray(a), jnp.asarray(b), 0.0, tile=32,
                    backend="jnp", compute_dtype="int8")
    assert np.asarray(pq.mask).all()
