"""Training-loop behavior: loss decreases, checkpoint/restart resumes
deterministically after an injected failure, gradient compression converges."""
import os

import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, TrainConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.train.loop import train

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=64, decode_seq_shard=False,
)


def _cfg():
    return get_config("musicgen-large").reduced()  # small vocab → fast CE


def test_loss_decreases(tmp_path):
    tcfg = TrainConfig(lr=1e-3, total_steps=30, warmup=3, ckpt_every=0,
                       ckpt_dir=str(tmp_path))
    res = train(_cfg(), PCFG, tcfg, make_ctx(make_host_mesh()),
                global_batch=4, seq_len=64, log_every=0)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.05, (first, last)


@pytest.mark.slow
def test_failure_restart_resumes(tmp_path):
    """Inject a crash at step 20; resume must continue from the last
    checkpoint and land near the uninterrupted run."""
    ctx = make_ctx(make_host_mesh())
    tcfg = TrainConfig(lr=1e-3, total_steps=30, warmup=3, ckpt_every=10,
                       ckpt_dir=str(tmp_path / "ckpt"))
    # uninterrupted reference
    ref = train(_cfg(), PCFG, tcfg, ctx, global_batch=4, seq_len=64,
                log_every=0)
    # crashed run
    tcfg2 = TrainConfig(lr=1e-3, total_steps=30, warmup=3, ckpt_every=10,
                        ckpt_dir=str(tmp_path / "ckpt2"))
    with pytest.raises(RuntimeError, match="injected failure"):
        train(_cfg(), PCFG, tcfg2, ctx, global_batch=4, seq_len=64,
              fail_at_step=20, log_every=0)
    # resume from latest (step 20 checkpoint)
    res = train(_cfg(), PCFG, tcfg2, ctx, global_batch=4, seq_len=64,
                resume=True, log_every=0)
    assert res.final_step == 30
    assert abs(res.losses[-1] - ref.losses[-1]) < 0.15, (
        res.losses[-1], ref.losses[-1])


def test_int8_ef_compression_converges(tmp_path):
    pc = ParallelConfig(
        compute_dtype="float32", param_dtype="float32", remat="none",
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=64,
        decode_seq_shard=False, grad_compression="int8_ef",
    )
    tcfg = TrainConfig(lr=1e-3, total_steps=30, warmup=3, ckpt_every=0,
                       ckpt_dir=str(tmp_path))
    res = train(_cfg(), pc, tcfg, make_ctx(make_host_mesh()),
                global_batch=4, seq_len=64, log_every=0)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5]) - 0.03
