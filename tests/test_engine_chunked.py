"""Chunked prefill + slot admission: the serving engine's second data plane.

Covers the mixed-length truncation regression (the old wave silently
left-trimmed every prompt to the shortest in the batch), the chunked/one-shot
bit-parity contract, queue-driven slot admission, the jit-cache bucket bound,
and loud rejection everywhere the engine cannot serve a batch faithfully.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import run_subprocess
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core.cost import bucket_ladder
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

PCFG = ParallelConfig(compute_dtype="float32", remat="none",
                      attn_q_chunk=8, attn_kv_chunk=8, decode_seq_shard=False)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    return cfg, ctx, params


def _sc():
    return SpammConfig(enable=True, tau=0.05, tile=4, backend="jnp")


def _engine(setup, **kw):
    cfg, ctx, params = setup
    kw.setdefault("max_len", 64)
    kw.setdefault("spamm_cfg", _sc())
    return Engine(cfg, PCFG, ctx, params, **kw)


def _solo_reference(setup, prompt, max_new):
    """One-shot b=1 generation — the ground truth a mixed-length batch
    must reproduce per request (no token of any prompt dropped)."""
    eng = _engine(setup)
    return eng.generate([Request(prompt=prompt, max_new_tokens=max_new)])[0]


# ---------------------------------------------------------------------------
# the truncation regression (satellite 1)
# ---------------------------------------------------------------------------

def test_mixed_lengths_no_token_dropped(setup):
    """The old wave left-trimmed to min(plen): request i's tokens matched a
    TRUNCATED prompt's generation. Now every request must match its own
    full-prompt solo run bit for bit."""
    cfg, _, _ = setup
    rng = np.random.default_rng(0)
    mix = [rng.integers(1, cfg.vocab, n).astype(np.int32)
           for n in (5, 16, 23)]
    eng = _engine(setup)
    reqs = [Request(prompt=p, max_new_tokens=4) for p in mix]
    outs = eng.generate(reqs)
    for p, o, r in zip(mix, outs, reqs):
        np.testing.assert_array_equal(_solo_reference(setup, p, 4), o)
        np.testing.assert_array_equal(r.out["tokens"], o)


def test_mixed_lengths_no_spamm_engine(setup):
    """Gating off (tile=1): the auto chunked path still serves mixed
    lengths untruncated."""
    cfg, ctx, params = setup
    rng = np.random.default_rng(1)
    mix = [rng.integers(1, cfg.vocab, n).astype(np.int32) for n in (7, 19)]
    eng = Engine(cfg, PCFG, ctx, params, max_len=64)
    outs = eng.generate([Request(prompt=p, max_new_tokens=3) for p in mix])
    for p, o in zip(mix, outs):
        ref = Engine(cfg, PCFG, ctx, params, max_len=64).generate(
            [Request(prompt=p, max_new_tokens=3)])[0]
        np.testing.assert_array_equal(ref, o)


def test_overlong_prompt_rejected_loudly(setup):
    eng = _engine(setup)
    with pytest.raises(ValueError, match="does not fit"):
        eng.generate([Request(prompt=np.arange(1, 200, dtype=np.int32))])


def test_mixed_rejected_when_chunking_disabled(setup):
    cfg, _, _ = setup
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, n).astype(np.int32),
                    max_new_tokens=2) for n in (8, 12)]
    eng = _engine(setup, prefill_chunk=0)
    with pytest.raises(ValueError, match="prefill_chunk=0"):
        eng.generate(reqs)


def test_recurrent_stack_rejects_mixed_and_chunking():
    cfg = get_config("mamba2-1.3b").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    with pytest.raises(ValueError, match="attention stack"):
        Engine(cfg, PCFG, ctx, params, max_len=64, prefill_chunk=8)
    eng = Engine(cfg, PCFG, ctx, params, max_len=64)
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, n).astype(np.int32),
                    max_new_tokens=2) for n in (8, 12)]
    with pytest.raises(ValueError, match="cannot chunk"):
        eng.generate(reqs)


# ---------------------------------------------------------------------------
# the bit-parity contract
# ---------------------------------------------------------------------------

def test_chunked_bit_identical_to_oneshot(setup):
    """Tile-aligned equal-length prompts: chunk cuts on tile boundaries
    reproduce the one-shot wave's tokens exactly (fully masked KV blocks
    are bitwise neutral in the online softmax)."""
    cfg, _, _ = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab, 16).astype(np.int32)
               for _ in range(4)]
    base = _engine(setup).generate(
        [Request(prompt=p, max_new_tokens=5) for p in prompts])
    eng = _engine(setup, prefill_chunk=8)
    got = eng.generate([Request(prompt=p, max_new_tokens=5) for p in prompts])
    for a, g in zip(base, got):
        np.testing.assert_array_equal(a, g)


def test_chunked_parity_partial_final_chunk(setup):
    """plen=20 with chunk=8 ends in a 4-token partial chunk: the sentinel
    tail (dropped writes, clamp-padded gate rows) must not perturb the
    real rows."""
    cfg, _, _ = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, 20).astype(np.int32)
               for _ in range(2)]
    base = _engine(setup).generate(
        [Request(prompt=p, max_new_tokens=4) for p in prompts])
    got = _engine(setup, prefill_chunk=8).generate(
        [Request(prompt=p, max_new_tokens=4) for p in prompts])
    for a, g in zip(base, got):
        np.testing.assert_array_equal(a, g)


# ---------------------------------------------------------------------------
# slot admission
# ---------------------------------------------------------------------------

def test_slot_capped_admission_queue(setup):
    """6 requests through a 2-slot pool: freed slots admit queued requests
    between decode steps, and every request still matches its solo run."""
    cfg, _, _ = setup
    rng = np.random.default_rng(6)
    mix = [rng.integers(1, cfg.vocab, n).astype(np.int32)
           for n in (5, 16, 23, 9, 12, 30)]
    eng = _engine(setup, prefill_chunk=8, max_slots=2)
    outs = eng.generate([Request(prompt=p, max_new_tokens=4) for p in mix])
    for p, o in zip(mix, outs):
        np.testing.assert_array_equal(_solo_reference(setup, p, 4), o)
    # every request was admitted through the slot pool
    assert eng._m_admit.value() >= len(mix)
    assert eng._m_chunks.value() > 0


def test_windowed_arch_window_ge_max_len_chunked(setup):
    """sliding_window >= max_len keeps layer_decode's ring condition True on
    the chunked engine's full-length LINEAR cache. During decode steps a
    still-prefilling lane carries the position sentinel (pos == max_len),
    whose write must DROP — the old ring modulo wrapped it to slot 0 and
    silently clobbered that lane's token-0 K/V."""
    cfg, ctx, params = setup
    wcfg = dataclasses.replace(cfg, sliding_window=64)
    rng = np.random.default_rng(9)
    # lane 0 (len 5) finishes prefill and decodes while lane 1 (len 23) is
    # still chunking — the corruption window the regression needs
    mix = [rng.integers(1, cfg.vocab, n).astype(np.int32) for n in (5, 23)]
    eng = Engine(wcfg, PCFG, ctx, params, max_len=64, spamm_cfg=_sc(),
                 prefill_chunk=8)
    outs = eng.generate([Request(prompt=p, max_new_tokens=4) for p in mix])
    for p, o in zip(mix, outs):
        solo = Engine(wcfg, PCFG, ctx, params, max_len=64, spamm_cfg=_sc())
        ref = solo.generate([Request(prompt=p, max_new_tokens=4)])[0]
        np.testing.assert_array_equal(ref, o)


def test_non_pow2_max_slots_floors_not_rounds_up(setup):
    """max_slots=3 must not run 4 concurrent slots: the pool floors to the
    largest power of two <= the cap so the documented slot/KV budget is
    never exceeded."""
    from repro.serving.engine import _floor_pow2
    assert [_floor_pow2(n) for n in (1, 2, 3, 4, 5, 6, 7, 8)] == \
        [1, 2, 2, 4, 4, 4, 4, 8]
    cfg, _, _ = setup
    rng = np.random.default_rng(10)
    mix = [rng.integers(1, cfg.vocab, n).astype(np.int32)
           for n in (5, 16, 23, 9)]
    eng = _engine(setup, prefill_chunk=8, max_slots=3)
    widths = []
    orig = eng._chunk

    def spy(params, batch, *a):
        widths.append(int(batch["tokens"].shape[0]))
        return orig(params, batch, *a)

    eng._chunk = spy
    outs = eng.generate([Request(prompt=p, max_new_tokens=4) for p in mix])
    assert widths and set(widths) == {2}, widths
    for p, o in zip(mix, outs):
        np.testing.assert_array_equal(_solo_reference(setup, p, 4), o)


def test_eos_frees_slot_midwave(setup):
    """A slot that emits EOS frees early — the engine's continuous-batching
    claim. The EOS request's output ends at the EOS token; others run to
    their budget."""
    cfg, _, _ = setup
    rng = np.random.default_rng(7)
    mix = [rng.integers(1, cfg.vocab, n).astype(np.int32) for n in (8, 14)]
    eng = _engine(setup, prefill_chunk=8)
    free = eng.generate([Request(prompt=p, max_new_tokens=6) for p in mix])
    eos = int(free[0][1])   # the model's own 2nd token: EOS fires mid-wave
    outs = eng.generate([Request(prompt=mix[0], max_new_tokens=6, eos_id=eos),
                         Request(prompt=mix[1], max_new_tokens=6)])
    assert len(outs[0]) == 2 and int(outs[0][-1]) == eos
    np.testing.assert_array_equal(outs[1], free[1])


# ---------------------------------------------------------------------------
# jit-cache bucket bound (the O(buckets)-not-O(shapes) claim)
# ---------------------------------------------------------------------------

def test_trace_counts_bounded_by_bucket_ladder(setup):
    """A sweep of >= 6 distinct (b, plen) shapes through one chunked engine
    compiles at most len(bucket_ladder(max_b, 1)) prefill traces — the slot
    pool is power-of-two bucketed, so the jit cache keys on the bucket."""
    cfg, _, _ = setup
    rng = np.random.default_rng(8)
    shapes = [(1, 5), (2, 16), (3, 23), (4, 9), (5, 12), (6, 30)]
    eng = _engine(setup, prefill_chunk=8)
    for b, plen in shapes:
        prompts = [rng.integers(1, cfg.vocab, plen).astype(np.int32)
                   for _ in range(b)]
        outs = eng.generate([Request(prompt=p, max_new_tokens=2)
                             for p in prompts])
        assert all(len(o) == 2 for o in outs)
    ladder = bucket_ladder(max(b for b, _ in shapes), 1)
    assert len(set(shapes)) >= 6
    assert eng.trace_counts["prefill"] <= len(ladder)
    assert eng.trace_counts["decode"] <= len(ladder)


# ---------------------------------------------------------------------------
# pod-sharded chunk loop (subprocess: 4 fake host devices)
# ---------------------------------------------------------------------------

CODE_SHARDED = r"""
import jax, numpy as np
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

pcfg = ParallelConfig(compute_dtype="float32", remat="none",
                      attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
                      decode_seq_shard=False)
cfg = get_config("musicgen-large").reduced()
ctx = make_ctx(make_host_mesh())
params = M.init_params(cfg, pcfg, jax.random.key(0))
sc = lambda: SpammConfig(enable=True, tau=2.0, tile=4, backend="jnp")
rng = np.random.default_rng(0)
plen, max_new, b = 32, 5, 16
prompts = [rng.integers(1, cfg.vocab, plen).astype(np.int32)
           for _ in range(b)]

ref = Engine(cfg, pcfg, ctx, params, max_len=96, spamm_cfg=sc(),
             mesh_devices=4)
base = ref.generate([Request(prompt=p, max_new_tokens=max_new)
                     for p in prompts])
# chunk divides plen AND a chunk size that leaves a sentinel tail
for chunk in (16, 24):
    eng = Engine(cfg, pcfg, ctx, params, max_len=96, spamm_cfg=sc(),
                 mesh_devices=4, prefill_chunk=chunk)
    got = eng.generate([Request(prompt=p, max_new_tokens=max_new)
                        for p in prompts])
    for a, g in zip(base, got):
        np.testing.assert_array_equal(a, g)
    assert eng.trace_counts == {"prefill": 1, "decode": 1}, eng.trace_counts
print("SHARDED-CHUNK-OK")
"""


@pytest.mark.slow
def test_sharded_chunk_prefill_bit_parity_4dev():
    out = run_subprocess(CODE_SHARDED, devices=4)
    assert "SHARDED-CHUNK-OK" in out
