"""Attention-path equivalences: packed vs masked causal flash, windowed vs
naive, ring-buffer decode vs linear-cache decode (hypothesis sweeps)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.models.attention import (decode_attention, flash_attention)


def _naive(q, k, v, window=None):
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    qh = q.reshape(b, s, hk, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k) / math.sqrt(d)
    qpos = jnp.arange(s)
    ok = qpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= qpos[None, :] > (qpos[:, None] - window)
    sc = jnp.where(ok, sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(b, s, hq, d)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([64, 128, 192]),
    chunk=st.sampled_from([32, 64]),
    hq=st.sampled_from([2, 4]),
    hk=st.sampled_from([1, 2]),
    seed=st.integers(0, 100),
)
def test_packed_equals_masked_equals_naive(s, chunk, hq, hk, seed):
    if hq % hk:
        return
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((2, s, hq, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, s, hk, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, s, hk, 8)), jnp.float32)
    ref = _naive(q, k, v)
    om = flash_attention(q, k, v, causal=True, q_chunk=chunk, kv_chunk=chunk,
                         packed=False)
    op = flash_attention(q, k, v, causal=True, q_chunk=chunk, kv_chunk=chunk,
                         packed=True)
    np.testing.assert_allclose(np.asarray(om), np.asarray(ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(op), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    window=st.sampled_from([16, 32, 48]),
    seed=st.integers(0, 100),
)
def test_windowed_flash_equals_naive(window, seed):
    s = 128
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, s, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, 2, 8)), jnp.float32)
    ref = _naive(q, k, v, window=window)
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    w=st.sampled_from([8, 16, 32]),
    extra=st.integers(0, 40),
    seed=st.integers(0, 100),
)
def test_ring_decode_equals_linear_decode(w, extra, seed):
    """A ring cache of width W must reproduce a linear cache + window mask
    for any position, including pre-wrap and multi-wrap positions."""
    rng = np.random.default_rng(seed)
    total = w + extra + 1
    b, hk, d = 2, 2, 8
    ks = jnp.asarray(rng.standard_normal((b, total, hk, d)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((b, total, hk, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, 2 * hk, d)), jnp.float32)
    pos = total - 1

    # linear cache with window mask = ground truth
    ref = decode_attention(q, ks, vs, pos + 1, window=w)

    # ring cache: slot t % w holds the latest token t
    ring_k = jnp.zeros((b, w, hk, d), jnp.float32)
    ring_v = jnp.zeros((b, w, hk, d), jnp.float32)
    for t in range(total):
        ring_k = ring_k.at[:, t % w].set(ks[:, t])
        ring_v = ring_v.at[:, t % w].set(vs[:, t])
    got = decode_attention(q, ring_k, ring_v, pos + 1, window=w, ring=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
