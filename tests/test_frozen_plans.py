"""Frozen-plan runtime — ISSUE 4 tentpole coverage.

The frozen-weight path (plans as jit inputs) must be bit-identical to the
eager plan()+execute() pipeline under jit and nested jit; the PlanStore must
hit/miss/refuse correctly (content addressing + version/backend guards); a
frozen-weight trace must contain zero weight-side get-norm calls and zero
dense-bitmap sorts (monkeypatch guard); and the serving engine must
warm-start from a precomputed store with store misses only on first
population, reproducing the same outputs.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core import plan as pl
from repro.core.module import SpammContext
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.plans import (FrozenWeight, PLAN_FORMAT_VERSION, PlanStore,
                         PlanStoreError, fingerprint, freeze_tree,
                         iter_gated_weights, populate, stack_plans)
from repro.serving.engine import Engine, Request

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32, decode_seq_shard=False,
)


def _decay(m, n, seed, scale=0.4):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(m)[:, None] - np.arange(n)[None, :])
    base = (scale / (d ** 0.5 + 1)).astype(np.float32)
    return jnp.asarray(base * rng.standard_normal((m, n)).astype(np.float32))


TAU = 4.0  # gates a real (partial) fraction on _decay operands at tile=32


# ---------------------------------------------------------------------------
# frozen path ≡ eager plan+execute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "interpret"])
@pytest.mark.parametrize("block_n", [1, 2])
@pytest.mark.parametrize("levels", [0, 1])
def test_frozen_bit_identical_to_eager_under_jit(backend, block_n, levels):
    a, b = _decay(96, 128, 0), _decay(128, 192, 1)
    ap = pl.pad_to_tile(a, 32)
    bp = pl.pad_to_tile(b, 32, 32 * block_n)
    p_e = pl.plan(ap, bp, TAU, tile=32, block_n=block_n, backend=backend,
                  levels=levels)
    want = pl.execute(p_e, ap, bp)
    assert 0 < int(p_e.valid_tiles) < p_e.total_tiles  # a real partial gate

    fw = FrozenWeight.build(b, TAU, tile=32, block_n=block_n, levels=levels,
                            backend=backend)
    fp = fw.for_rows(ap.shape[0] // 32)

    # eager frozen
    got = pl.execute(pl.plan(ap, frozen_weight=fp), ap, bp)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    # jitted: the FrozenPlan is a jit ARGUMENT (a pytree of arrays)
    @jax.jit
    def run(x, w, f):
        p = pl.plan(x, frozen_weight=f)
        return pl.execute(p, x, w), p.valid_tiles

    got_j, vt = run(ap, bp, fp)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got_j))
    assert int(vt) == int(p_e.valid_tiles)

    # nested jit
    @jax.jit
    def run2(x, w, f):
        return run(x, w, f)[0]

    np.testing.assert_array_equal(np.asarray(want),
                                  np.asarray(run2(ap, bp, fp)))


def test_frozen_edge_cases():
    b = _decay(64, 64, 2)
    # all-pruned activation (zeros) → zero output, correct shape
    fw = FrozenWeight.build(b, 0.5, tile=32, backend="interpret")
    z = jnp.zeros((64, 64), jnp.float32)
    c = pl.execute(pl.plan(z, frozen_weight=fw.for_rows(2)), z, b)
    np.testing.assert_array_equal(np.asarray(c), np.zeros((64, 64)))
    # fully-pruned weight (τ > 0 and zero weight): empty kj list
    fw0 = FrozenWeight.build(jnp.zeros((64, 64)), 0.5, tile=32,
                             backend="interpret")
    assert fw0.num_kj == 0
    x = _decay(64, 64, 3)
    c0 = pl.execute(pl.plan(x, frozen_weight=fw0.for_rows(2)), x,
                    jnp.zeros((64, 64)))
    np.testing.assert_array_equal(np.asarray(c0), np.zeros((64, 64)))
    # τ ≤ 0: everything passes, == dense
    fwn = FrozenWeight.build(b, 0.0, tile=32, backend="jnp")
    p = pl.plan(x, frozen_weight=fwn.for_rows(2))
    assert int(p.valid_tiles) == p.total_tiles


def test_frozen_plan_rejects_wrong_row_grid():
    fw = FrozenWeight.build(_decay(64, 64, 4), TAU, tile=32, backend="jnp")
    with pytest.raises(ValueError, match="specialized"):
        pl.plan(_decay(96, 64, 5), frozen_weight=fw.for_rows(2))


def test_frozen_weight_carries_its_own_tau():
    fw = FrozenWeight.build(_decay(64, 64, 6), TAU, tile=32, backend="jnp")
    with pytest.raises(ValueError, match="its own tau"):
        pl.plan(_decay(64, 64, 7), None, TAU, frozen_weight=fw.for_rows(2))


def test_stacked_frozen_plans_ride_a_scan():
    """Per-layer plans stacked to one common bucket ride lax.scan as xs and
    gate each layer with ITS weight's norms — the engine's scan shape."""
    x = _decay(64, 64, 42)
    fws = [FrozenWeight.build(_decay(64, 64, s), 2.0, tile=32,
                              backend="interpret") for s in (7, 8, 9)]
    bucket = max(pl._bucket(2 * fw.num_kj) for fw in fws)
    stacked = stack_plans([fw.for_rows(2, min_steps=bucket) for fw in fws])

    @jax.jit
    def scan_counts(stk):
        def body(c, f):
            return c, pl.plan(x, frozen_weight=f).valid_tiles

        return jax.lax.scan(body, 0, stk)[1]

    counts = scan_counts(stacked)
    for i, fw in enumerate(fws):
        pe = pl.plan(x, None, 2.0, norm_b=fw.norm_b, tile=32,
                     backend="interpret")
        assert int(counts[i]) == int(pe.valid_tiles)


# ---------------------------------------------------------------------------
# monkeypatch guard: nothing weight-side is recomputed inside the trace
# ---------------------------------------------------------------------------

def _counting_backend(name, calls):
    orig = kops.BACKENDS[name]

    def norms(x, tile, use_mxu=False):
        calls.append(tuple(x.shape))
        return orig.norms(x, tile, use_mxu=use_mxu)

    return dataclasses.replace(orig, norms=norms)


def test_no_getnorm_and_no_dense_sort_in_frozen_trace(monkeypatch):
    """Tracing a frozen-weight product runs ZERO get-norm calls when the
    activation norms are supplied, only activation-shaped ones otherwise,
    and never touches the dense-bitmap sort (`spamm_compact_ref`)."""
    a, b = _decay(96, 64, 10), _decay(64, 128, 11)
    fw = FrozenWeight.build(b, TAU, tile=32, backend="interpret")
    fp = fw.for_rows(3)

    calls = []
    monkeypatch.setitem(kops.BACKENDS, "interpret",
                        _counting_backend("interpret", calls))

    def boom(*a_, **k_):
        raise AssertionError("dense-bitmap sort inside a frozen trace")

    monkeypatch.setattr(ref, "spamm_compact_ref", boom)

    @jax.jit
    def run(x, w, f):
        return pl.execute(pl.plan(x, frozen_weight=f), x, w)

    run(a, b, fp)  # traces here
    assert calls == [(96, 64)], calls  # the activation gate, nothing else

    calls.clear()
    na = kops.BACKENDS["interpret"].norms(a, 32)
    calls.clear()

    @jax.jit
    def run_prenormed(x, w, f, n):
        return pl.execute(pl.plan(x, frozen_weight=f, norm_a=n), x, w)

    run_prenormed(a, b, fp, na)
    assert calls == [], calls  # zero get-norm ops in the traced graph


# ---------------------------------------------------------------------------
# PlanStore: hit / miss / invalidation / refusal
# ---------------------------------------------------------------------------

def _mk_fw(b, **kw):
    cfg = dict(tau=TAU, tile=32, block_n=1, levels=1, backend="jnp")
    cfg.update(kw)
    return FrozenWeight.build(b, cfg.pop("tau"), weight_hash=fingerprint(b),
                              **cfg), cfg


def test_store_roundtrip_hit_and_config_invalidation(tmp_path):
    b = _decay(64, 96, 20)
    st = PlanStore(str(tmp_path))
    fw, _ = _mk_fw(b)
    st.put(fw)
    base = dict(tau=TAU, tile=32, block_n=1, levels=1, backend="jnp")

    got = st.get(fingerprint(b), **base)
    assert got is not None and st.hits == 1 and st.misses == 0
    np.testing.assert_array_equal(np.asarray(got.nbmax), np.asarray(fw.nbmax))
    np.testing.assert_array_equal(np.asarray(got.kj_k), np.asarray(fw.kj_k))
    for l in range(len(fw.levels)):
        np.testing.assert_array_equal(np.asarray(got.levels[l]),
                                      np.asarray(fw.levels[l]))
    # loaded artifact plans identically to the freshly built one
    x = _decay(64, 64, 21)
    p1 = pl.plan(x, frozen_weight=fw.for_rows(2))
    p2 = pl.plan(x, frozen_weight=got.for_rows(2))
    np.testing.assert_array_equal(np.asarray(p1.mask), np.asarray(p2.mask))

    # the weight changing is a miss (content addressing) ...
    b2 = b.at[0, 0].add(1.0)
    assert st.get(fingerprint(b2), **base) is None
    # ... and so is ANY config field changing (incl. the get-norm variant)
    for field, val in [("tau", TAU * 2), ("tile", 16), ("block_n", 2),
                       ("levels", 0), ("backend", "interpret"),
                       ("use_mxu", True)]:
        assert st.get(fingerprint(b), **{**base, field: val}) is None, field


def test_store_refuses_version_and_backend_mismatch(tmp_path):
    import json

    b = _decay(64, 64, 22)
    st = PlanStore(str(tmp_path))
    fw, _ = _mk_fw(b)
    key = st.put(fw)
    mpath = os.path.join(str(tmp_path), key, "manifest.json")
    base = dict(tau=TAU, tile=32, block_n=1, levels=1, backend="jnp")

    with open(mpath) as f:
        man = json.load(f)
    man["format_version"] = PLAN_FORMAT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(man, f)
    with pytest.raises(PlanStoreError, match="format version"):
        st.get(fingerprint(b), **base)

    man["format_version"] = PLAN_FORMAT_VERSION
    man["backend"] = "not-a-backend"
    with open(mpath, "w") as f:
        json.dump(man, f)
    with pytest.raises(PlanStoreError, match="not registered"):
        st.get(fingerprint(b), **base)


def test_weight_plan_cache_is_memory_tier_above_store(tmp_path):
    b = _decay(64, 64, 23)
    st = PlanStore(str(tmp_path))
    cache = pl.WeightPlanCache(store=st)
    kw = dict(tau=TAU, tile=32, levels=1, backend="jnp")
    fw1 = cache.frozen_weight(b, **kw)
    assert cache.frozen_misses == 1 and st.misses == 1 and len(st) == 1
    fw2 = cache.frozen_weight(b, **kw)           # memory hit
    assert fw2 is fw1 and cache.frozen_hits == 1 and st.hits == 0
    cache2 = pl.WeightPlanCache(store=st)        # cold memory, warm store
    fw3 = cache2.frozen_weight(b, **kw)
    assert st.hits == 1 and st.misses == 1       # loaded, not rebuilt
    np.testing.assert_array_equal(np.asarray(fw3.nbmax), np.asarray(fw1.nbmax))


# ---------------------------------------------------------------------------
# engine integration: warm start, parity, phase-tagged telemetry
# ---------------------------------------------------------------------------

def _mk_engine(params, cfg, ctx, sc, **kw):
    return Engine(cfg, PCFG, ctx, params, max_len=64, spamm_cfg=sc, **kw)


def test_engine_frozen_prefill_matches_legacy_and_walks_gated_weights():
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.05, tile=16, backend="jnp", levels=1)
    rng = np.random.default_rng(0)
    reqs = lambda: [Request(prompt=rng.integers(1, cfg.vocab, size=24).astype(
        np.int32), max_new_tokens=4) for _ in range(2)]
    rng = np.random.default_rng(0)
    r_legacy = reqs()
    rng = np.random.default_rng(0)
    r_frozen = reqs()
    outs_l = _mk_engine(params, cfg, ctx, sc, freeze_plans=False).generate(
        r_legacy)
    eng = _mk_engine(params, cfg, ctx, sc)
    outs_f = eng.generate(r_frozen)
    for a, b in zip(outs_l, outs_f):
        np.testing.assert_array_equal(a, b)
    # the walker found the gated GEMM weights (4 attn + 2 gelu_mlp weights)
    paths = {p[-2:] for p, _ in iter_gated_weights(params)}
    assert paths == {("mix", "wq"), ("mix", "wk"), ("mix", "wv"),
                     ("mix", "wo"), ("mlp", "w1"), ("mlp", "w2")}
    sp = r_frozen[0].out["spamm"]
    assert sp["gated_gemms"] > 0
    assert sp["decode_gated_gemms"] > 0          # decode taps, tagged apart
    assert sp["valid_fraction"] is not None
    assert sp["decode_valid_fraction"] is not None


def test_engine_warm_starts_from_precomputed_store(tmp_path, monkeypatch):
    """precompute CLI path → fresh engine with --plan-store: same outputs,
    store misses only during population, and the frozen-weight warm start
    runs ZERO get-norm calls on weight shapes (the guard satellite, at the
    engine level) and never the dense-bitmap sort."""
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.05, tile=16, backend="interpret")
    store = PlanStore(str(tmp_path))
    n = populate(store, params, sc)              # the offline pass
    expected = sum(
        int(np.prod(w.shape[:-2], dtype=np.int64)) if w.ndim > 2 else 1
        for _, w in iter_gated_weights(params))
    assert n == expected == 6 * cfg.num_layers
    assert store.misses == n and store.hits == 0 and len(store) > 0

    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab, size=24).astype(np.int32)
               for _ in range(2)]
    mk_reqs = lambda: [Request(prompt=p, max_new_tokens=3) for p in prompts]

    baseline = _mk_engine(params, cfg, ctx, sc).generate(mk_reqs())

    # weight shapes in this reduced config, to tell apart from activations
    weight_shapes = {tuple(w.shape[-2:]) for _, w in
                     iter_gated_weights(params)}
    calls = []
    monkeypatch.setitem(kops.BACKENDS, "interpret",
                        _counting_backend("interpret", calls))

    def boom(*a_, **k_):
        raise AssertionError("dense-bitmap sort in a frozen-weight engine")

    monkeypatch.setattr(ref, "spamm_compact_ref", boom)

    store2 = PlanStore(str(tmp_path))
    warm_reqs = mk_reqs()
    eng = _mk_engine(params, cfg, ctx, sc, plan_store=store2)
    warm = eng.generate(warm_reqs)
    for a, b in zip(baseline, warm):
        np.testing.assert_array_equal(a, b)
    assert store2.misses == 0 and store2.hits == n  # warm: loads only
    assert not any(s in weight_shapes for s in calls), calls
    sp = warm_reqs[0].out["spamm"]
    assert sp["plan_store_hits"] == n and sp["plan_store_misses"] == 0
    # store counters are per-WAVE deltas: a second wave never re-touches the
    # store (frozen plans cached in memory) and must report 0/0
    reqs2 = mk_reqs()
    eng.generate(reqs2)
    sp2 = reqs2[0].out["spamm"]
    assert sp2["plan_store_hits"] == 0 and sp2["plan_store_misses"] == 0


def test_engine_frozen_parity_on_hybrid_arch():
    """Hybrid (rec, rec, attn) stacks thread frozen plans through the
    grouped scan: only the attn sub-layer's projections and every
    sub-layer's MLP carry plans; rec mixers have no gated GEMMs."""
    cfg = get_config("recurrentgemma-9b").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(1))
    sc = SpammConfig(enable=True, tau=0.05, tile=16, backend="jnp")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab, size=20).astype(np.int32)
               for _ in range(2)]
    mk = lambda: [Request(prompt=p, max_new_tokens=3) for p in prompts]
    outs_l = _mk_engine(params, cfg, ctx, sc, freeze_plans=False).generate(mk())
    outs_f = _mk_engine(params, cfg, ctx, sc).generate(mk())
    for a, b in zip(outs_l, outs_f):
        np.testing.assert_array_equal(a, b)


def test_freeze_tree_covers_hybrid_groups():
    """Reduced recurrentgemma is one (rec, rec, attn) group with no tail:
    only the attn sub-layer contributes wq..wo, every sub-layer an MLP."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.1, tile=16, backend="jnp")
    tree, count = freeze_tree(params, sc)
    assert "groups" in tree
    attn = tree["groups"]["l2"]["mix"]
    assert set(attn) == {"wq", "wk", "wv", "wo"}
    assert isinstance(attn["wq"], list)          # stacked → per-layer list
    assert set(tree["groups"]["l0"]) == {"mlp"}  # rec sub-layer: MLP only
    assert count == 4 + 3 * len(tree["groups"])  # 4 attn + 3 SwiGLU per sub


# ---------------------------------------------------------------------------
# checkpoint pointer round-trip
# ---------------------------------------------------------------------------

def test_checkpoint_plan_store_pointer_roundtrip(tmp_path):
    store_dir = tmp_path / "plans"
    ckpt_dir = str(tmp_path / "ckpt")
    b = _decay(64, 64, 30)
    st = PlanStore(str(store_dir))
    fw, _ = _mk_fw(b)
    st.put(fw)

    ck.save(ckpt_dir, 10, {"w": jnp.ones(3)}, plan_store=st)
    ptr = ck.plan_store_pointer(ckpt_dir, 10)
    assert ptr == {"path": os.path.abspath(str(store_dir)),
                   "format_version": PLAN_FORMAT_VERSION}
    st2 = ck.open_plan_store(ckpt_dir, 10)
    assert st2 is not None and len(st2) == 1
    got = st2.get(fingerprint(b), tau=TAU, tile=32, block_n=1, levels=1,
                  backend="jnp")
    assert got is not None                        # restored server finds plans

    # checkpoints without a pointer stay None (back-compat)
    ck.save(ckpt_dir, 20, {"w": jnp.ones(3)})
    assert ck.plan_store_pointer(ckpt_dir, 20) is None
    assert ck.open_plan_store(ckpt_dir, 20) is None


# ---------------------------------------------------------------------------
# train-step telemetry export
# ---------------------------------------------------------------------------

def test_train_loop_exports_spamm_stats(tmp_path):
    from repro.configs.base import TrainConfig
    from repro.train.loop import train

    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    tcfg = TrainConfig(total_steps=2, warmup=1, ckpt_every=0,
                       ckpt_dir=str(tmp_path))
    sc = SpammConfig(enable=True, tau=0.05, tile=16, backend="jnp")
    res = train(cfg, PCFG, tcfg, ctx, global_batch=2, seq_len=32,
                spamm_cfg=sc, log_every=0)
    assert len(res.spamm_stats) == 2
    for s in res.spamm_stats:
        assert s["gated_gemms"] > 0
        assert s["valid_fraction"] is not None
        assert 0.0 < s["valid_fraction"] <= 1.0
    # without SpAMM the export stays empty
    res0 = train(cfg, PCFG, tcfg, ctx, global_batch=2, seq_len=32,
                 log_every=0)
    assert res0.spamm_stats == []


def test_store_refuses_pre_dtype_legacy_root(tmp_path):
    """ISSUE 6 regression: a store root populated under the pre-dtype
    format (version < 2: artifact dirs but no STORE_FORMAT.json marker)
    must refuse at OPEN time with PlanStoreError — dtype is part of every
    key now, so the legacy artifacts would otherwise read as clean misses
    and a warm start would silently refreeze everything."""
    import json
    import shutil

    # fabricate a legacy root: one artifact dir, no marker
    legacy = tmp_path / "legacy"
    art = legacy / "deadbeefdeadbeef"
    art.mkdir(parents=True)
    with open(art / "manifest.json", "w") as f:
        json.dump({"format_version": PLAN_FORMAT_VERSION - 1}, f)
    with pytest.raises(PlanStoreError, match="predates compute-dtype"):
        PlanStore(str(legacy))

    # a marker with the wrong version refuses too
    vers = tmp_path / "versioned"
    vers.mkdir()
    with open(vers / "STORE_FORMAT.json", "w") as f:
        json.dump({"format_version": PLAN_FORMAT_VERSION - 1}, f)
    with pytest.raises(PlanStoreError, match="fresh root"):
        PlanStore(str(vers))

    # fresh roots self-mark and reopen cleanly (crash-leftover .tmp_* dirs
    # don't count as artifacts)
    fresh = tmp_path / "fresh"
    st = PlanStore(str(fresh))
    assert (fresh / "STORE_FORMAT.json").is_file()
    (fresh / ".tmp_junk").mkdir()
    shutil.rmtree(str(fresh / ".tmp_junk"))
    st2 = PlanStore(str(fresh))
    b = _decay(64, 64, 30)
    fw, _ = _mk_fw(b)
    st2.put(fw)
    # and a third open of the now-populated, marked root still succeeds
    assert len(PlanStore(str(fresh))) == 1
