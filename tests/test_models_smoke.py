"""Per-architecture smoke tests (assigned deliverable f): REDUCED same-family
configs, one forward/train step on CPU, output shapes + no NaNs; decode path
consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

import repro.models.layers as L
from repro.configs import ARCH_IDS, ParallelConfig, TrainConfig, get_config
from repro.models import model as M
from repro.models.transformer import NetCtx
from repro.optim.adamw import AdamW

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32, decode_seq_shard=False,
)
B, S = 2, 64


def _ctx():
    from repro.launch.mesh import make_mesh  # AxisType compat shim

    return NetCtx(mesh=make_mesh((1, 1), ("data", "model")))


def _inputs(cfg, key=1):
    if cfg.frontend:
        return {"embeds": 0.5 * jax.random.normal(
            jax.random.key(key), (B, S, cfg.d_model), jnp.float32)}
    return {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                         cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    ctx = _ctx()
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    inp = _inputs(cfg)
    batch = dict(inp, labels=jnp.ones((B, S), jnp.int32))

    h, aux = jax.jit(lambda p, b: M.forward_hidden(cfg, PCFG, ctx, p, b))(
        params, inp)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))

    opt = AdamW(TrainConfig(total_steps=10, warmup=1))
    step = jax.jit(M.make_train_step(cfg, PCFG, ctx, opt))
    p2, o2, met = step(params, opt.init(params), batch, jnp.int32(0))
    assert bool(jnp.isfinite(met["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # avoid capacity drops confounding the check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    ctx = _ctx()
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    inp = _inputs(cfg)

    h, _ = jax.jit(lambda p, b: M.forward_hidden(cfg, PCFG, ctx, p, b))(
        params, inp)
    h_last = L.rms_norm(h[:, -1], params["final_norm"], cfg.norm_eps)
    lg_ref = (h_last @ params["unembed"]["kernel"]).astype(jnp.float32)

    prefill = jax.jit(M.make_prefill_step(cfg, PCFG, ctx))
    decode = jax.jit(M.make_decode_step(cfg, PCFG, ctx))
    if cfg.frontend:
        b1 = {"embeds": inp["embeds"][:, : S - 1]}
        last = inp["embeds"][:, S - 1 : S]
    else:
        b1 = {"tokens": inp["tokens"][:, : S - 1]}
        last = inp["tokens"][:, S - 1 : S]
    cache, _ = prefill(params, b1)

    def grow_kv(path, t):
        keys = [getattr(k, "key", None) for k in path]
        if keys and keys[-1] in ("k", "v") and t.shape[-3] == S - 1:
            pad = [(0, 0)] * t.ndim
            pad[-3] = (0, 1)
            return jnp.pad(t, pad)
        return t

    cache = jtu.tree_map_with_path(grow_kv, cache)
    lg_dec, _ = decode(params, last, cache, jnp.int32(S - 1))
    rel = float(jnp.max(jnp.abs(lg_dec - lg_ref))) / (
        float(jnp.max(jnp.abs(lg_ref))) + 1e-9)
    assert rel < 5e-4, rel


def test_spamm_enabled_forward_matches_dense_at_tau0():
    """The paper's technique as a config switch: τ=0 must be bit-compatible
    with the dense path (same GEMMs, gated at 100% valid)."""
    from repro.configs import SpammConfig

    cfg = get_config("codeqwen1.5-7b").reduced()
    ctx = _ctx()
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    inp = _inputs(cfg)
    batch = dict(inp, labels=jnp.ones((B, S), jnp.int32))
    l0, _ = jax.jit(lambda p, b: M.loss_fn(cfg, PCFG, ctx, p, b))(params, batch)
    sp = SpammConfig(enable=True, tau=0.0, tile=32, backend="jnp")
    l1, _ = jax.jit(
        lambda p, b: M.loss_fn(cfg, PCFG, ctx, p, b, spamm_cfg=sp)
    )(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4, (float(l0), float(l1))


def test_spamm_moe_bmm_forward_matches_dense_at_tau0():
    """Batched spamm_bmm execution of the MoE grouped FFN (per-expert weight
    plans) must also be exact at τ=0."""
    from repro.configs import SpammConfig

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    ctx = _ctx()
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    inp = _inputs(cfg)
    h0, _ = M.forward_hidden(cfg, PCFG, ctx, params, inp)
    sp = SpammConfig(enable=True, tau=0.0, tile=16, backend="jnp",
                     moe_bmm=True)
    h1, _ = M.forward_hidden(cfg, PCFG, ctx, params, inp, spamm_cfg=sp)
    assert float(jnp.max(jnp.abs(h0 - h1))) < 1e-4
