"""Pod-sharded serving: shard_map'd prefill/decode driven by per-shard
frozen plans, placed by the live equal-work offsets.

Fast units cover the host-side slicing layer (`FrozenWeight.slice_rows` /
`shard_by_offsets`, `schedule.strip_tables` / `rescale_offsets`) plus the
engine's construction-time rejections. The multi-device contract — the
sharded engine on 4 fake host devices is BIT-identical to the
single-device engine across prefill and ≥ 8 decode steps, including a
`ReshardController`-triggered mid-generation re-cut that provably causes
zero recompilations of `_prefill`/`_decode` — runs in a subprocess (the
device count is locked at first jax init), mirroring
tests/test_distributed_spamm.py."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import schedule as S
from repro.plans import FrozenWeight


def _decay(m, n, seed):
    rng = np.random.default_rng(seed)
    i = np.arange(m)[:, None]
    j = np.arange(n)[None, :]
    a = np.exp(-0.05 * np.abs(i - j)) * rng.standard_normal((m, n))
    return jnp.asarray(a.astype(np.float32))


# ---------------------------------------------------------------------------
# frozen-plan shard slicing (host-side, no mesh needed)
# ---------------------------------------------------------------------------


def test_slice_rows_matches_for_rows_prefix():
    """A strip's real step content depends only on its width: the weight-
    side pair list is activation-row-agnostic, so slice_rows(lo, hi) ==
    for_rows(hi - lo) in real steps, clamp-padded to the local grid."""
    fw = FrozenWeight.build(_decay(128, 128, 3), 0.5, tile=32, backend="jnp")
    full = fw.for_rows(4)
    sl = fw.slice_rows(1, 3, gm=4)
    w = fw.num_kj
    real = int(np.asarray(sl.step_real).sum())
    assert real == 2 * w
    assert sl.gm == 4  # clamp-padded local grid, not the strip width
    np.testing.assert_array_equal(np.asarray(sl.step_i)[:real],
                                  np.asarray(full.step_i)[:real])
    np.testing.assert_array_equal(np.asarray(sl.step_j)[:real],
                                  np.asarray(full.step_j)[:real])
    np.testing.assert_array_equal(np.asarray(sl.step_k)[:real],
                                  np.asarray(full.step_k)[:real])
    # no step may target a tile beyond the strip: pad rows do zero work
    assert int(np.asarray(sl.step_i)[np.asarray(sl.step_real)].max()) < 2
    with pytest.raises(ValueError):
        fw.slice_rows(2, 1)
    with pytest.raises(ValueError):
        fw.slice_rows(0, 4, gm=2)


def test_shard_by_offsets_stacks_static_shapes():
    """Variable-width strips stack into ONE pytree: identical static
    metadata and step shapes per shard, real step counts = width · W."""
    fw = FrozenWeight.build(_decay(128, 128, 4), 0.5, tile=32, backend="jnp")
    offs = np.array([0, 2, 5, 6])
    sh = fw.shard_by_offsets(offs, width=3)
    w = fw.num_kj
    assert np.asarray(sh.step_i).shape[0] == 3          # leading shard dim
    reals = np.asarray(sh.step_real).sum(axis=1)
    np.testing.assert_array_equal(reals, np.diff(offs) * w)
    assert sh.gm == 3
    with pytest.raises(ValueError):
        fw.shard_by_offsets(offs, width=2)   # narrower than widest strip
    with pytest.raises(ValueError):
        fw.shard_by_offsets(np.array([0, 2, 2, 6]))     # empty strip


# ---------------------------------------------------------------------------
# shared strip-table construction + offset rescaling (schedule layer)
# ---------------------------------------------------------------------------


def test_strip_tables_enumerates_rows_once():
    offsets = np.array([0, 2, 5, 6])
    idx, keep = S.strip_tables(offsets, 6, 3)
    w = 3  # widest strip
    assert idx.shape == (3 * w,) and keep.shape == (3 * w,)
    # kept slots in (device, slot) order enumerate 0..5 exactly once, in order
    np.testing.assert_array_equal(idx[keep], np.arange(6))
    # pad slots clamp to their strip's last row (live data, no garbage)
    assert idx.reshape(3, w)[0, 2] == 1
    idx4, keep4 = S.strip_tables(offsets, 6, 3, width=4)
    assert idx4.shape == (12,)
    np.testing.assert_array_equal(idx4[keep4], np.arange(6))
    with pytest.raises(ValueError):
        S.strip_tables(offsets, 6, 3, width=2)
    # distributed.spamm_rowpart's private helper is the same construction
    from repro.core import distributed

    i1, k1 = distributed._strip_tables(offsets, 6, 3)
    np.testing.assert_array_equal(i1, idx)
    np.testing.assert_array_equal(k1, keep)


def test_rescale_offsets_preserves_cut_and_clamps():
    # proportional re-expression on a finer grid
    out = S.rescale_offsets(np.array([0, 2, 5, 6]), 12)
    np.testing.assert_array_equal(out, [0, 4, 10, 12])
    # a lopsided cut on a grid too coarse to express it still yields
    # monotone non-empty strips (the forward/backward clamp passes)
    out = S.rescale_offsets(np.array([0, 1, 2, 160]), 3)
    np.testing.assert_array_equal(out, [0, 1, 2, 3])
    # empty source strips are malformed, not silently repaired
    with pytest.raises(ValueError):
        S.rescale_offsets(np.array([0, 0, 0, 6]), 6)
    # width clamp: no strip wider than max_width
    out = S.rescale_offsets(np.array([0, 1, 2, 160]), 8, max_width=3)
    assert (np.diff(out) <= 3).all() and (np.diff(out) >= 1).all()
    assert out[0] == 0 and out[-1] == 8
    with pytest.raises(ValueError):
        S.rescale_offsets(np.array([0, 1, 4]), 1)        # fewer rows than parts
    with pytest.raises(ValueError):
        S.rescale_offsets(np.array([0, 1, 4]), 8, max_width=3)  # infeasible


def test_reshard_controller_records_loads():
    ctl = S.ReshardController(S.ReshardConfig(num_devices=2, every=1))
    assert ctl.live_loads is None
    v = jnp.asarray(np.ones((8, 8), np.float32))
    ctl.probe(v, 0)
    loads = ctl.live_loads
    assert loads is not None and loads.shape == (2,)
    np.testing.assert_allclose(loads.sum(), np.ones((8, 8)).sum())


# ---------------------------------------------------------------------------
# engine construction-time rejections (no mesh needed: checked first)
# ---------------------------------------------------------------------------


def test_engine_rejects_unfrozen_and_moe():
    import jax

    from repro.configs import ParallelConfig, SpammConfig, get_config
    from repro.launch.mesh import make_ctx, make_host_mesh
    from repro.models import model as M
    from repro.serving.engine import Engine

    pcfg = ParallelConfig(compute_dtype="float32", remat="none",
                          decode_seq_shard=False)
    ctx = make_ctx(make_host_mesh())
    cfg = get_config("musicgen-large").reduced()
    params = M.init_params(cfg, pcfg, jax.random.key(0))
    with pytest.raises(ValueError, match="frozen plans"):
        Engine(cfg, pcfg, ctx, params, mesh_devices=2)
    moe_cfg = get_config("mixtral-8x22b").reduced()
    moe_params = M.init_params(moe_cfg, pcfg, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.5, tile=4, backend="jnp")
    with pytest.raises(ValueError, match="MoE"):
        Engine(moe_cfg, pcfg, ctx, moe_params, spamm_cfg=sc, mesh_devices=2)


# ---------------------------------------------------------------------------
# the multi-device contract (subprocess: 4 fake host devices)
# ---------------------------------------------------------------------------

CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core import schedule as S
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

assert len(jax.devices()) == 4, jax.devices()

pcfg = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    decode_seq_shard=False,
)
cfg = get_config("musicgen-large").reduced()
ctx = make_ctx(make_host_mesh())
params = M.init_params(cfg, pcfg, jax.random.key(0))
# strong id->norm profile so the token distribution drives the work
# estimate (same trick as tests/test_equal_work.py): cold ids ~0.05x,
# hot ids ~5x
emb = np.asarray(params["embed"]["embedding"])
scale = np.where(np.arange(cfg.vocab) < cfg.vocab // 2, 0.05, 5.0)
params["embed"]["embedding"] = jnp.asarray(emb * scale[:, None])

TILE = 4
sc = lambda: SpammConfig(enable=True, tau=2.0, tile=TILE, backend="jnp")
# probe_window pins the probe grid (per-request most-recent window), so
# successive probes stay comparable and drift can actually trigger re-cuts
# (a probe on a different grid resets like a first probe instead)
rcfg = S.ReshardConfig(num_devices=4, every=2, drift_threshold=1.0,
                       probe_window=32)
eng = Engine(cfg, pcfg, ctx, params, max_len=96, spamm_cfg=sc(),
             reshard_cfg=rcfg, mesh_devices=4)
ref = Engine(cfg, pcfg, ctx, params, max_len=96, spamm_cfg=sc())

rng = np.random.default_rng(0)
plen, max_new = 32, 9   # 1 prefill + >= 8 decode steps

def wave(b, mix):
    # mix: fraction of requests drawing hot ids — skews the equal-work cut
    hot = int(b * mix)
    prompts = [rng.integers(cfg.vocab // 2, cfg.vocab, plen).astype(np.int32)
               if i < hot else
               rng.integers(1, cfg.vocab // 2, plen).astype(np.int32)
               for i in range(b)]
    reqs = [Request(prompt=p.copy(), max_new_tokens=max_new) for p in prompts]
    refs = [Request(prompt=p.copy(), max_new_tokens=max_new) for p in prompts]
    out = eng.generate(reqs)
    out_ref = ref.generate(refs)
    for o, r in zip(out, out_ref):
        np.testing.assert_array_equal(o, r)   # tokens BIT-identical
    return reqs

# wave A: uniform cold tokens (near-uniform cut), b=16 -> G=4 groups
wave(16, 0.0)
counts_a = dict(eng.trace_counts)
assert counts_a == {"prefill": 1, "decode": 1}, counts_a
offs_a = None if eng.partition_offsets is None else np.asarray(
    eng.partition_offsets).copy()

# wave B: work concentrates in the leading half -> the controller must
# re-cut mid-run, and the swap must not re-trace either step fn
wave(16, 0.5)
sp = eng.trace_counts
assert sp == {"prefill": 1, "decode": 1}, (
    "re-cut recompiled a step fn", sp)
resharded_total = eng._resharder.resharded
assert resharded_total >= 1, (
    "controller never re-cut", resharded_total, eng._resharder.history)
# at least one re-cut fired MID-generation (wave B's decode loop runs at
# engine steps > 10; its pre-prefill probe is step 10), proving the live
# swap happened between decode steps with a populated cache
assert any(h["resharded"] and h["step"] > 10
           for h in eng._resharder.history), eng._resharder.history
offs_b = np.asarray(eng.partition_offsets)
assert offs_a is None or not np.array_equal(offs_a, offs_b), (offs_a, offs_b)
# jit cache itself: one compiled entry per step fn across both waves
for fn in (eng._prefill, eng._decode):
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1, fn._cache_size()
# the live layout honors the skew: with half the requests hot, the cut is
# NOT the uniform one
lay = eng.shard_layout
assert lay is not None and sum(lay["real"]) == 16
assert eng.gm_histogram, eng.gm_histogram

# ragged group count: b=24 -> G=6 groups over 4 shards (6 % 4 != 0)
wave(24, 0.25)

# alignment rejections: the gate is per row tile, so misaligned batches
# must be refused loudly rather than silently change results
try:
    eng.generate([Request(prompt=np.ones(plen, np.int32), max_new_tokens=2)
                  for _ in range(6)])
    raise SystemExit("b % tile accepted")
except ValueError as e:
    assert "batch % tile" in str(e), e
try:
    eng.generate([Request(prompt=np.ones(30, np.int32), max_new_tokens=2)
                  for _ in range(16)])
    raise SystemExit("plen % tile accepted")
except ValueError as e:
    assert "prompt length" in str(e), e

print("SHARDED-OK", resharded_total, eng.gm_histogram)
"""


@pytest.mark.slow
def test_sharded_engine_bit_parity_4dev():
    out = run_subprocess(CODE, devices=4)
    assert "SHARDED-OK" in out
