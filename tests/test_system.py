"""End-to-end behaviour tests for the paper's system.

The system claim: SpAMM replaces dense GEMMs with norm-gated approximate
GEMMs inside a real application and (a) cuts executed FLOPs roughly in
proportion to the valid ratio while (b) keeping application-level quality
(paper §4.3: ergo matrix powers; VGG13 accuracy)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core import spamm as cs
from repro.core.module import spamm_linear
from repro.data.pipeline import ergo_like, relu_sparse_matrix, vgg_im2col_shapes
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32, decode_seq_shard=False,
)


def test_matrix_power_ergo_style():
    """§4.3.1 analogue: A² via SpAMM on an exponential-decay matrix keeps
    relative error ≪ 1 at small τ while skipping a large share of tiles."""
    n = 1024
    a = ergo_like(n, lam=0.7)
    dense = a.astype(np.float64) @ a.astype(np.float64)
    c, info = cs.spamm(jnp.asarray(a), jnp.asarray(a), 1e-3, tile=64,
                       backend="jnp")
    rel = np.linalg.norm(np.asarray(c, np.float64) - dense) / np.linalg.norm(dense)
    assert rel < 1e-5
    assert float(info.valid_fraction) < 0.5


def test_vgg_im2col_gemm_quality():
    """§4.3.2 analogue: conv21/conv31-shaped GEMMs with ReLU-sparse inputs.

    For unstructured (non-decay) operands the skipped tiles carry mass in
    proportion to their count, so the mechanism predicts
    rel_err ≈ sqrt(1 − valid_ratio); SpAMM must track that curve (it always
    skips the SMALLEST-norm products first — anything above the curve would
    mean the gating is broken) and be exact at ratio → 1."""
    for name, (m, k, n) in vgg_im2col_shapes().items():
        n = min(n, 4096)  # CPU-sized slice of the layer
        x = relu_sparse_matrix(m, k, sparsity=0.55, seed=1)
        w = np.random.default_rng(2).standard_normal((k, n)).astype(np.float32)
        w *= (np.abs(w) > 0.8)  # pruned weights (paper §1)
        dense = x @ w
        prev = -1.0
        for ratio in (0.99, 0.85, 0.63):
            c, info = cs.spamm(jnp.asarray(x), jnp.asarray(w),
                               valid_ratio=ratio, tile=64, backend="jnp")
            rel = np.linalg.norm(np.asarray(c) - dense) / np.linalg.norm(dense)
            bound = np.sqrt(1 - float(info.valid_fraction)) * 1.2 + 1e-3
            assert rel <= bound, (name, ratio, rel, bound)
            assert rel >= prev - 1e-6  # monotone in skipped work
            prev = rel


def test_spamm_in_model_quality_knob():
    """SpAMM as a first-class feature: with small τ the LM loss moves only
    slightly; with τ=∞ (all tiles skipped) it collapses to ~uniform."""
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    rng = jax.random.key(1)
    batch = {
        "embeds": 0.5 * jax.random.normal(rng, (2, 64, cfg.d_model)),
        "labels": jax.random.randint(jax.random.key(2), (2, 64), 0, cfg.vocab),
    }
    base, _ = M.loss_fn(cfg, PCFG, ctx, params, batch)
    small = SpammConfig(enable=True, tau=1e-3, tile=16, backend="jnp")
    l_small, _ = M.loss_fn(cfg, PCFG, ctx, params, batch, spamm_cfg=small)
    huge = SpammConfig(enable=True, tau=1e9, tile=16, backend="jnp")
    l_huge, _ = M.loss_fn(cfg, PCFG, ctx, params, batch, spamm_cfg=huge)
    assert abs(float(l_small) - float(base)) < 0.05 * float(base)
    assert abs(float(l_huge) - np.log(cfg.vocab)) < 0.5  # GEMMs gone ⇒ uniform


def test_spamm_linear_grad_flow():
    """Training-integration contract: dense-backward gradients are exact."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32)
    w = jnp.asarray(0.05 * rng.standard_normal((64, 96)), jnp.float32)

    def f_spamm(x, w):
        return jnp.sum(spamm_linear(x, w, jnp.float32(0.0), 32, "jnp") ** 2)

    def f_dense(x, w):
        return jnp.sum((x @ w) ** 2)

    gs = jax.grad(f_spamm, (0, 1))(x, w)
    gd = jax.grad(f_dense, (0, 1))(x, w)
    for a, b in zip(gs, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
