"""The roofline HLO walker: trip-count multiplication, dot FLOPs, collective
accounting (the dry-run's measurement instrument must itself be tested)."""
from conftest import run_subprocess

CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import HloAnalysis
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))

def scanned(x, ws):
    def body(c, w):
        return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return jnp.sum(y)

x = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
ws = jax.ShapeDtypeStruct((7, 512, 512), jnp.bfloat16)
with mesh:
    comp = jax.jit(
        scanned,
        in_shardings=(NamedSharding(mesh, P("data", None)),
                      NamedSharding(mesh, P(None, "data", "model"))),
    ).lower(x, ws).compile()
an = HloAnalysis(comp.as_text(), 8)
t = an.totals()

# per-device: 7 iterations x dot of (64,512)@(512,256)
expected = 7 * 2 * 64 * 512 * 256
assert abs(t["flops_per_device"] - expected) / expected < 1e-6, t["flops_per_device"]
# two all-gathers per iteration (w over data, x over model)
assert t["collectives"]["all-gather"]["count"] == 14, t["collectives"]
# loss reduction all-reduce present
assert "all-reduce" in t["collectives"]
assert not t["warnings"], t["warnings"]
print("OK")
"""


def test_hlo_walker_on_sharded_scan():
    out = run_subprocess(CODE, devices=8)
    assert "OK" in out
