"""Multi-device SpAMM (§3.4 row-partition + §3.5.1 load balance + the
beyond-paper 2-D SUMMA variant) on 8 fake host devices (subprocess: the
device count is locked at first jax init)."""
import pytest

from conftest import run_subprocess

CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import spamm as cs, distributed, schedule
from repro.kernels import ref
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
n, tile, tau = 512, 64, 0.02
a = cs.exponential_decay(n, lam=0.6, seed=0)
b = cs.exponential_decay(n, lam=0.6, seed=1)
ja, jb = jnp.asarray(a), jnp.asarray(b)

ref_c, info = cs.spamm(ja, jb, tau, tile=tile, backend="jnp")
assert 0.0 < float(info.valid_fraction) < 1.0, float(info.valid_fraction)

for sched in ("contiguous", "cyclic", "auto"):
    c, frac = distributed.spamm_rowpart(ja, jb, tau, mesh, axis="data",
                                        tile=tile, backend="jnp", schedule=sched)
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref_c), atol=1e-4)

for sched in ("contiguous", "auto"):
    c2, _ = distributed.spamm_2d(ja, jb, tau, mesh, tile=tile, backend="jnp",
                                 schedule=sched)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(ref_c), atol=1e-4)

# the auto pick itself: banded inputs are row-balanced -> contiguous; a
# top-heavy A (coarse V concentrated in the leading strips) -> cyclic
heavy = np.asarray(a).copy(); heavy[n // 4:] *= 1e-4
sched_b = distributed._resolve_schedule(ja, jb, tau, 4, tile=tile,
                                        backend="jnp", sched_levels=3)
sched_h = distributed._resolve_schedule(jnp.asarray(heavy), jb, tau, 4,
                                        tile=tile, backend="jnp",
                                        sched_levels=3)
assert sched_b == "contiguous", sched_b
assert sched_h == "cyclic", sched_h

# §3.5.1: cyclic assignment improves balance when workers own individual
# C tiles (the paper's one-thread-block-per-tile setting: Fig. 4) — use a
# finer tiling so workers < tiles.
na32 = ref.tile_norms_ref(ja, 32); nb32 = ref.tile_norms_ref(jb, 32)
v = schedule.v_matrix(na32, nb32, tau)   # 16x16 tiles
imb_c = float(schedule.tile_imbalance(v, 64, "contiguous"))
imb_s = float(schedule.tile_imbalance(v, 64, "cyclic"))
assert imb_s < imb_c, (imb_c, imb_s)
assert imb_c > 1.2, f"workload not diagonal-heavy enough: {imb_c}"
print("OK", imb_c, imb_s)
"""


@pytest.mark.slow
def test_distributed_spamm_8dev():
    out = run_subprocess(CODE, devices=8)
    assert "OK" in out
