"""Checkpoint roundtrip/atomicity/GC + serving-engine behavior."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ck
from repro.configs import ParallelConfig, get_config
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32, decode_seq_shard=False,
)


def test_roundtrip_and_gc(tmp_path):
    d = str(tmp_path)
    state = {
        "params": {"a": jnp.arange(12.0).reshape(3, 4),
                   "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)}},
        "step": jnp.int32(7),
    }
    for s in [10, 20, 30, 40]:
        ck.save(d, s, state, keep=2)
    assert ck.all_steps(d) == [30, 40]
    like = jax.eval_shape(lambda: state)
    out = ck.restore(d, 40, like)
    np.testing.assert_array_equal(out["params"]["a"],
                                  np.arange(12.0).reshape(3, 4))
    assert out["params"]["nested"]["b"].dtype == jnp.bfloat16


def test_async_save(tmp_path):
    d = str(tmp_path)
    t = ck.save(d, 5, {"x": jnp.ones(3)}, async_=True)
    t.join()
    assert ck.latest_step(d) == 5


def test_tmp_dirs_never_visible(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, ".tmp_step_99"))  # simulated crash leftovers
    ck.save(d, 1, {"x": jnp.ones(2)})
    assert ck.all_steps(d) == [1]


def test_engine_greedy_matches_manual_decode():
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    eng = Engine(cfg, PCFG, ctx, params, max_len=96)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=24).astype(np.int32)
               for _ in range(3)]
    outs = eng.generate([Request(prompt=p, max_new_tokens=6) for p in prompts])
    assert all(len(o) == 6 for o in outs)

    # manual greedy for request 0 must match slot 0 of the batch exactly
    # (batch composition must not change a slot's tokens)
    outs_single = eng.generate([Request(prompt=prompts[0], max_new_tokens=6)])
    np.testing.assert_array_equal(outs[0], outs_single[0])


def test_engine_spamm_telemetry_on_request_out():
    """With SpAMM enabled, every request's `out` metadata carries the wave's
    gating stats (valid_fraction over the gated prefill GEMMs, plan-cache
    deltas) — surfaced through the jitted, scan-over-layers prefill via the
    context's io_callback taps."""
    from repro.configs import SpammConfig

    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.05, tile=16, backend="jnp", levels=1)
    eng = Engine(cfg, PCFG, ctx, params, max_len=64, spamm_cfg=sc)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=24).astype(np.int32),
                    max_new_tokens=4) for _ in range(2)]
    outs = eng.generate(reqs)
    for r, o in enumerate(outs):
        meta = reqs[r].out
        np.testing.assert_array_equal(meta["tokens"], o)
        sp = meta["spamm"]
        assert sp["gated_gemms"] > 0
        assert sp["valid_fraction"] is not None
        assert 0.0 < sp["valid_fraction"] <= 1.0
        assert sp["plan_cache_hits"] >= 0 and sp["plan_cache_misses"] >= 0
    # stats are per wave, not cumulative: a second wave reports afresh
    eng.generate(reqs)
    assert reqs[0].out["spamm"]["gated_gemms"] == sp["gated_gemms"]

    # spamm disabled: metadata still present, stats absent
    eng2 = Engine(cfg, PCFG, ctx, params, max_len=64)
    (o2,) = eng2.generate([Request(prompt=reqs[0].prompt, max_new_tokens=3)])
    assert eng2.spamm_ctx is None


def test_engine_eos_frees_early():
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    eng = Engine(cfg, PCFG, ctx, params, max_len=64)
    p = np.arange(1, 17, dtype=np.int32)
    (full,) = eng.generate([Request(prompt=p, max_new_tokens=8)])
    eos = int(full[2])
    (cut,) = eng.generate([Request(prompt=p, max_new_tokens=8, eos_id=eos)])
    assert len(cut) == 3 and cut[-1] == eos
