"""Equal-work pyramid re-sharding: variable-width strip cutting
(`schedule.equal_work_partition`), the variable-partition diagnostics, the
distributed execution parity against the single-device oracle, and the
drift-triggered re-sharding control plane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import schedule as S

# ---------------------------------------------------------------------------
# partition properties
# ---------------------------------------------------------------------------


def _profiles(gm, rng):
    """Skewed / banded / uniform per-row work profiles (the three norm
    structures the partition must absorb)."""
    band = np.clip(8 - np.abs(np.arange(gm) - gm / 2) / 2, 1, None)
    skew = np.exp(-np.arange(gm) / max(gm / 3, 1)) * 50 + 1
    unif = np.full(gm, 5.0)
    noisy = rng.integers(0, 40, gm).astype(float)
    return {"banded": band, "skewed": skew, "uniform": unif, "random": noisy}


def _v_of(profile):
    return jnp.asarray(np.outer(profile, np.ones(4)).astype(np.float32))


def test_partition_covers_once_and_nonempty():
    rng = np.random.default_rng(0)
    for gm in (4, 7, 9, 16, 33):
        for name, prof in _profiles(gm, rng).items():
            v = _v_of(prof)
            for ndev in (1, 2, 3, 4):
                offs = S.equal_work_partition(v, ndev)
                assert offs.shape == (ndev + 1,), (name, gm, ndev)
                assert offs[0] == 0 and offs[-1] == gm
                assert np.all(np.diff(offs) >= 1), (name, gm, ndev, offs)
                # strips cover [0, gm) exactly once
                rows = np.concatenate(
                    [S.rows_for_partition(d, offs) for d in range(ndev)])
                np.testing.assert_array_equal(rows, np.arange(gm))


def test_all_zero_v_falls_back_to_uniform_strips():
    v = jnp.zeros((9, 5), jnp.int32)
    for ndev in (1, 2, 3, 4):
        offs = S.equal_work_partition(v, ndev)
        assert np.all(np.diff(offs) >= 1), offs  # never empty strips
        # ... and the fallback is exactly the contiguous uniform split
        want = np.concatenate([[0], np.cumsum(
            [len(S.rows_for_device(d, ndev, 9, "contiguous"))
             for d in range(ndev)])])
        np.testing.assert_array_equal(offs, want)


def test_partition_never_worse_than_contiguous():
    """Seeded sweep of the property-test invariant (the hypothesis variant
    lives in test_spamm_properties.py; this runs without the optional dep):
    predicted imbalance of the equal-work cut ≤ the contiguous schedule's,
    on any random V — the uniform-split guard makes this structural."""
    rng = np.random.default_rng(7)
    for _ in range(300):
        gm = int(rng.integers(2, 40))
        ndev = int(rng.integers(1, min(gm, 8) + 1))
        v = jnp.asarray(
            rng.integers(0, 50, (gm, int(rng.integers(1, 9)))).astype(
                np.float32))
        offs = S.equal_work_partition(v, ndev)
        assert offs[0] == 0 and offs[-1] == gm and np.all(np.diff(offs) >= 1)
        imb_eq = S.partition_imbalance(v, offs)
        lc = S.device_loads(v, ndev, "contiguous")
        imb_c = lc.max() / max(lc.mean(), 1e-9)
        assert imb_eq <= imb_c + 1e-9, (gm, ndev, offs, imb_eq, imb_c)


def test_too_few_rows_raises():
    with pytest.raises(ValueError):
        S.equal_work_partition(jnp.ones((2, 2)), 3)
    with pytest.raises(ValueError):
        S.rows_for_device(0, 2, 8, "equal_work")  # needs an offset table


# ---------------------------------------------------------------------------
# variable-width diagnostics: straddling coarse rows (regression)
# ---------------------------------------------------------------------------


def test_device_loads_offsets_straddle_coarse_rows():
    """device_loads with an explicit variable partition must split a coarse
    row's work across the strips that own its fine rows — the uniform-shape
    assumption (rows_for_device) would misattribute it wholesale."""
    # gm=18 fine rows, level=2 (4 fine rows per coarse row, ceil → 5 coarse
    # rows); all work in coarse row 2 = fine rows 8..11, spread 5 each.
    v = np.zeros((5, 5), np.int64)
    v[2, :] = 4
    v = jnp.asarray(v)
    # boundary at 9 cuts the coarse row 1:3
    loads = S.device_loads(v, 2, "equal_work", level=2, fine_rows=18,
                           offsets=np.array([0, 9, 18]))
    np.testing.assert_allclose(loads, [5.0, 15.0])
    # boundary at 10 cuts it 2:2
    loads = S.device_loads(v, 2, "equal_work", level=2, fine_rows=18,
                           offsets=np.array([0, 10, 18]))
    np.testing.assert_allclose(loads, [10.0, 10.0])
    # three strips, boundaries 9 and 11: splits 1:2:1
    loads = S.device_loads(v, 3, "equal_work", level=2, fine_rows=18,
                           offsets=np.array([0, 9, 11, 18]))
    np.testing.assert_allclose(loads, [5.0, 10.0, 5.0])
    # the cut itself lands inside the hot coarse row and balances it
    offs = S.equal_work_partition(v, 2, level=2, fine_rows=18)
    np.testing.assert_allclose(
        S.partition_loads(v, offs, level=2, fine_rows=18), [10.0, 10.0])


def test_partition_imbalance_matches_device_loads():
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.integers(0, 9, (16, 6)).astype(np.int32))
    offs = S.equal_work_partition(v, 4)
    loads = S.device_loads(v, 4, "equal_work", offsets=offs)
    want = loads.max() / max(loads.mean(), 1e-9)
    assert S.partition_imbalance(v, offs) == pytest.approx(want)
    # schedule-name route and explicit-offsets route agree
    np.testing.assert_allclose(
        S.device_loads(v, 4, "equal_work"), loads)
    # imbalance() speaks variable partitions too
    assert float(S.imbalance(v, 4, "equal_work")) == pytest.approx(want)


def test_tile_imbalance_equal_work_variable_runs():
    """tile_imbalance grows an 'equal_work' mode: variable-length contiguous
    tile runs, no truncation to a worker multiple (the uniform modes drop
    trailing tiles; v here has 35 — indivisible by 4)."""
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.integers(0, 20, (5, 7)).astype(np.int32))
    imb_eq = float(S.tile_imbalance(v, 4, "equal_work"))
    imb_c = float(S.tile_imbalance(v, 4, "contiguous"))
    assert imb_eq >= 1.0
    # hot tiles aliased to the cyclic stride: equal_work must beat both
    hot = np.ones(36, np.float32)
    hot[0:16:4] = 50.0
    v_hot = jnp.asarray(hot.reshape(6, 6))
    imb = {s: float(S.tile_imbalance(v_hot, 4, s))
           for s in ("contiguous", "cyclic", "equal_work")}
    assert imb["equal_work"] < imb["contiguous"]
    assert imb["equal_work"] < imb["cyclic"]


# ---------------------------------------------------------------------------
# auto-schedule: equal_work only when both uniform schedules lose
# ---------------------------------------------------------------------------


def test_auto_schedule_picks_equal_work_on_aliased_hot_rows():
    gm = 32
    w = np.ones(gm, np.float32)
    w[0:16:4] = 9.0  # hot rows at the cyclic stride, first half only
    v = _v_of(w)
    assert S.auto_schedule(v, 4) == "equal_work"
    assert S.auto_schedule(v, 4, allow_equal_work=False) in (
        "contiguous", "cyclic")
    # smooth top-heavy profile: cyclic already balances it (stride sampling)
    skew = np.full(gm, 1e-3, np.float32)
    skew[: gm // 4] = 10.0
    assert S.auto_schedule(_v_of(skew), 4) == "cyclic"
    # flat profile: nothing to fix
    assert S.auto_schedule(jnp.ones((gm, 4), jnp.int32), 4) == "contiguous"


# ---------------------------------------------------------------------------
# ReshardController: cadence + drift threshold
# ---------------------------------------------------------------------------


def _aliased_v(gm, phase):
    w = np.ones(gm, np.float32)
    w[phase:gm // 2 + phase:4] = 9.0
    return _v_of(w)


def test_reshard_controller_cadence_and_drift():
    rc = S.ReshardController(
        S.ReshardConfig(num_devices=4, every=2, drift_threshold=1.05))
    assert rc.due(0) and not rc.due(1) and rc.due(2)
    v0 = _aliased_v(32, 0)
    o0 = rc.probe(v0, 0)
    # first probe cuts the initial partition — not a re-shard event
    assert rc.probes == 1 and rc.resharded == 0
    assert o0[0] == 0 and o0[-1] == 32
    # same estimate again: live == fresh, no event
    rc.probe(v0, 2)
    assert rc.resharded == 0
    # drifted estimate (work mass moved to the other half): re-cut
    v1 = _v_of(np.concatenate([np.ones(16, np.float32),
                               np.full(16, 9.0, np.float32)]))
    o1 = rc.probe(v1, 4)
    assert rc.resharded == 1 and not np.array_equal(o0, o1)
    assert rc.live_imbalance is not None
    assert [h["resharded"] for h in rc.history] == [False, False, True]


def test_reshard_controller_resets_on_grid_change():
    """A probe on a different row grid (serving waves grow/shrink the token
    count) resets the partition instead of comparing incomparable offsets —
    the stale cut clipped to the new grid would read as phantom zero-load
    strips and fire a spurious drift event."""
    rc = S.ReshardController(
        S.ReshardConfig(num_devices=2, every=1, drift_threshold=1.0))
    rc.probe(jnp.ones((10, 4), jnp.float32), 0)   # uniform: cut [0, 5, 10]
    np.testing.assert_array_equal(rc.offsets, [0, 5, 10])
    rc.probe(jnp.ones((4, 4), jnp.float32), 1)    # shrunk, still uniform
    assert rc.resharded == 0, rc.history          # reset, NOT a drift event
    np.testing.assert_array_equal(rc.offsets, [0, 2, 4])
    assert rc.history[-1]["grid"] == 4
    assert rc.history[-1]["live_imbalance"] == pytest.approx(1.0)


def test_reshard_controller_rejects_unresolved_device_count():
    """num_devices=0 means 'owner defaults it from the mesh'; building a
    controller before resolving it must fail loudly, not ZeroDivisionError
    inside the first probe."""
    with pytest.raises(ValueError):
        S.ReshardController(S.ReshardConfig())


def test_strip_tables_reject_stale_offset_tables():
    """A frozen offset table cut for a different grid or device count must
    be rejected, not silently shard strips across the wrong devices."""
    from repro.core import distributed as D

    with pytest.raises(ValueError):  # 2 strips on a 4-device mesh
        D._strip_tables(np.array([0, 4, 8]), 8, 4)
    with pytest.raises(ValueError):  # wrong grid extent
        D._strip_tables(np.array([0, 4, 8]), 10, 2)
    with pytest.raises(ValueError):  # empty strip
        D._strip_tables(np.array([0, 4, 4, 8]), 8, 3)
    perm, keep = D._strip_tables(np.array([0, 3, 8]), 8, 2)
    np.testing.assert_array_equal(perm[np.flatnonzero(keep)], np.arange(8))


def test_supplied_offsets_force_equal_work_path():
    """offsets= routes through the equal_work path whatever `schedule` says
    — a frozen partition must never be silently dropped."""
    from repro.core import distributed as D
    from repro.launch.mesh import make_host_mesh

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32) * 0.1)
    mesh = make_host_mesh()
    ref_c, _ = D.spamm_rowpart(a, a, 0.0, mesh, tile=32, backend="jnp")
    c, _ = D.spamm_rowpart(a, a, 0.0, mesh, tile=32, backend="jnp",
                           schedule="contiguous",  # overridden by offsets
                           offsets=np.array([0, 4]))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(ref_c))
    with pytest.raises(ValueError):  # stale table: wrong strip count
        D.spamm_rowpart(a, a, 0.0, mesh, tile=32, backend="jnp",
                        offsets=np.array([0, 2, 4]))


def test_reshard_controller_sticky_below_threshold():
    """A huge drift threshold keeps the first cut forever (telemetry still
    records the widening live-vs-fresh gap)."""
    rc = S.ReshardController(
        S.ReshardConfig(num_devices=4, every=1, drift_threshold=100.0))
    o0 = rc.probe(_aliased_v(32, 0), 0)
    for step, phase in ((1, 1), (2, 2), (3, 3)):
        assert np.array_equal(rc.probe(_aliased_v(32, phase), step), o0)
    assert rc.resharded == 0 and rc.probes == 4
    assert rc.history[-1]["live_imbalance"] >= rc.history[-1]["fresh_imbalance"]


# ---------------------------------------------------------------------------
# serving engine: drift-triggered re-sharding is pure control plane
# ---------------------------------------------------------------------------


def test_engine_reshard_cadence_and_bit_identity():
    """A drifting-activation serving run re-cuts at the configured cadence,
    outputs stay bit-identical to the never-reshard run, and
    Request.out["spamm"] counts the events."""
    from repro.configs import ParallelConfig, SpammConfig, get_config
    from repro.launch.mesh import make_ctx, make_host_mesh
    from repro.models import model as M
    from repro.serving.engine import Engine, Request

    pcfg = ParallelConfig(
        compute_dtype="float32", param_dtype="float32", remat="none",
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
        decode_seq_shard=False,
    )
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, pcfg, jax.random.key(0))
    # give the embedding a strong id→norm profile so changing the token
    # distribution between waves drifts the activation-side work estimate
    emb = np.asarray(params["embed"]["embedding"])
    scale = np.where(np.arange(cfg.vocab) < cfg.vocab // 2, 0.05, 5.0)
    params["embed"]["embedding"] = jnp.asarray(emb * scale[:, None])

    # τ sits between the cold-row (~0.2) and hot-row (~20) norm products of
    # the probe GEMM, so the work estimate follows the token distribution
    sc = SpammConfig(enable=True, tau=2.0, tile=16, backend="jnp")
    rcfg = S.ReshardConfig(num_devices=2, every=2, drift_threshold=1.0)
    eng = Engine(cfg, pcfg, ctx, params, max_len=96, spamm_cfg=sc,
                 reshard_cfg=rcfg)
    eng_ref = Engine(cfg, pcfg, ctx, params, max_len=96,
                     spamm_cfg=SpammConfig(enable=True, tau=2.0, tile=16,
                                           backend="jnp"))

    rng = np.random.default_rng(0)
    max_new = 5

    def wave(lo, hi):
        prompts = [rng.integers(lo, hi, size=32).astype(np.int32)
                   for _ in range(2)]
        reqs = [Request(prompt=p.copy(), max_new_tokens=max_new)
                for p in prompts]
        refs = [Request(prompt=p.copy(), max_new_tokens=max_new)
                for p in prompts]
        out = eng.generate(reqs)
        out_ref = eng_ref.generate(refs)
        # pure control plane: re-sharding never changes a single bit
        for o, r in zip(out, out_ref):
            np.testing.assert_array_equal(o, r)
        return reqs

    # wave A: cold tokens (uniform low-norm rows)
    reqs_a = wave(1, cfg.vocab // 2)
    sp = reqs_a[0].out["spamm"]
    assert {"resharded", "reshard_probes", "partition_imbalance"} <= set(sp)
    # engine steps per wave: 1 prefill + (max_new - 1) decode; cadence 2
    steps = 1 + (max_new - 1)
    assert sp["reshard_probes"] == len(
        [s for s in range(steps) if s % rcfg.every == 0])
    assert eng.partition_offsets is not None
    # wave B: slot 0 jumps to hot ids, slot 1 stays cold — the work profile
    # concentrates in the leading rows and the live cut must drift
    prompts = [rng.integers(cfg.vocab // 2, cfg.vocab, 32).astype(np.int32),
               rng.integers(1, cfg.vocab // 2, 32).astype(np.int32)]
    reqs_b = [Request(prompt=p.copy(), max_new_tokens=max_new)
              for p in prompts]
    refs_b = [Request(prompt=p.copy(), max_new_tokens=max_new)
              for p in prompts]
    out_b = eng.generate(reqs_b)
    out_bref = eng_ref.generate(refs_b)
    for o, r in zip(out_b, out_bref):
        np.testing.assert_array_equal(o, r)
    sp_b = reqs_b[0].out["spamm"]
    assert sp_b["reshard_probes"] >= 1
    assert eng._resharder.resharded >= 1, eng._resharder.history
    assert sp_b["resharded"] == eng._resharder.resharded - (
        reqs_a[0].out["spamm"]["resharded"])
    assert sp_b["partition_imbalance"] is not None
    # a no-reshard engine reports no reshard keys
    assert "resharded" not in refs_b[0].out["spamm"]


# ---------------------------------------------------------------------------
# distributed parity: every sharding path pins to the single-device oracle
# ---------------------------------------------------------------------------

CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import spamm as cs, distributed, schedule
from repro.launch.mesh import make_mesh

n, tile, tau = 256, 32, 0.02
gm = n // tile
devs = jax.devices()

rng = np.random.default_rng(0)
banded = cs.exponential_decay(n, lam=0.6, seed=0)
skewed = banded * np.exp(-np.arange(n) / n * 4)[:, None]
uniform = (0.05 * rng.standard_normal((n, n))).astype(np.float32)
aliased = banded.copy()
for r in range(0, n, 4 * tile):  # hot tile-rows at the cyclic stride
    aliased[r:r + tile] *= 8.0
b = cs.exponential_decay(n, lam=0.6, seed=1)
jb = jnp.asarray(b)

def strip_oracle(ja, offsets):
    # single-device spamm() run strip-by-strip with the SAME clamp-padded
    # local shapes the shard_map bodies see; pads dropped on the way back
    ndev = len(offsets) - 1
    perm, keep = distributed._strip_tables(offsets, gm, ndev)
    wmax = len(perm) // ndev
    outs = []
    a_t = np.asarray(ja).reshape(gm, tile, n)
    for d in range(ndev):
        a_loc = a_t[perm[d * wmax:(d + 1) * wmax]].reshape(wmax * tile, n)
        c_loc, _ = cs.spamm(jnp.asarray(a_loc), jb, tau, tile=tile,
                            backend="jnp")
        outs.append(np.asarray(c_loc).reshape(wmax, tile, -1))
    return np.concatenate(outs)[np.flatnonzero(keep)].reshape(n, -1)

for name, a in (("banded", banded), ("skewed", skewed),
                ("uniform", uniform), ("aliased", aliased)):
    ja = jnp.asarray(a)
    ref_c, _ = cs.spamm(ja, jb, tau, tile=tile, backend="jnp")
    for ndev in (1, 2, 3, 4):
        mesh = make_mesh((ndev,), ("data",),
                         devices=np.array(devs[:ndev]))
        offs = distributed._equal_work_offsets(
            ja, jb, tau, ndev, tile=tile, backend="jnp", sched_levels=3,
            gm=gm)
        c, frac = distributed.spamm_rowpart(
            ja, jb, tau, mesh, axis="data", tile=tile, backend="jnp",
            schedule="equal_work", offsets=offs)
        # bit-identity to the strip-wise single-device oracle (same local
        # computation); the FULL single-device product differs by XLA's
        # shape-dependent einsum contraction order (~1e-7, pre-existing for
        # every distributed schedule), so it gets a tight allclose
        assert np.array_equal(np.asarray(c), strip_oracle(ja, offs)), (
            name, ndev)
        np.testing.assert_allclose(np.asarray(c), np.asarray(ref_c),
                                   atol=1e-5)
        # auto may pick any schedule; parity must hold regardless
        c2, _ = distributed.spamm_rowpart(ja, jb, tau, mesh, axis="data",
                                          tile=tile, backend="jnp",
                                          schedule="auto")
        np.testing.assert_allclose(np.asarray(c2), np.asarray(ref_c),
                                   atol=1e-5)
print("matrix grid OK")

# ragged gm % ndev != 0 (gm=8, ndev=3): only equal_work can cover it
ja = jnp.asarray(banded)
ref_c, _ = cs.spamm(ja, jb, tau, tile=tile, backend="jnp")
mesh3 = make_mesh((3,), ("data",), devices=np.array(devs[:3]))
for sched in ("equal_work", "auto"):
    c, _ = distributed.spamm_rowpart(ja, jb, tau, mesh3, axis="data",
                                     tile=tile, backend="jnp", schedule=sched)
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref_c), atol=1e-5)
print("ragged OK")

# frozen offset table (what the re-sharding controller feeds) + uniform
# offsets reproduce the contiguous path BIT-identically (the gather is
# numerically inert)
mesh2 = make_mesh((2,), ("data",), devices=np.array(devs[:2]))
c_frozen, _ = distributed.spamm_rowpart(
    ja, jb, tau, mesh2, axis="data", tile=tile, backend="jnp",
    schedule="equal_work", offsets=np.array([0, 3, 8]))
np.testing.assert_allclose(np.asarray(c_frozen), np.asarray(ref_c),
                           atol=1e-5)
c_cont, _ = distributed.spamm_rowpart(ja, jb, tau, mesh2, axis="data",
                                      tile=tile, backend="jnp",
                                      schedule="contiguous")
c_eq_uni, _ = distributed.spamm_rowpart(
    ja, jb, tau, mesh2, axis="data", tile=tile, backend="jnp",
    schedule="equal_work", offsets=np.array([0, 4, 8]))
assert np.array_equal(np.asarray(c_eq_uni), np.asarray(c_cont))
print("frozen/uniform offsets OK")

# degenerate all-zero V (everything gated off): uniform strips, zero C
offs0 = distributed._equal_work_offsets(ja, jb, 1e9, 3, tile=tile,
                                        backend="jnp", sched_levels=3, gm=gm)
np.testing.assert_array_equal(offs0, [0, 3, 6, 8])
c0, _ = distributed.spamm_rowpart(ja, jb, 1e9, mesh3, axis="data", tile=tile,
                                  backend="jnp", schedule="equal_work")
assert float(jnp.max(jnp.abs(c0))) == 0.0
print("all-zero-V OK")

# 2-D SUMMA path with equal-work row strips (ragged rows over 3 devices)
mesh2d = make_mesh((3, 2), ("data", "model"), devices=np.array(devs[:6]))
for sched in ("equal_work", "auto"):
    c2d, _ = distributed.spamm_2d(ja, jb, tau, mesh2d, tile=tile,
                                  backend="jnp", schedule=sched)
    np.testing.assert_allclose(np.asarray(c2d), np.asarray(ref_c), atol=1e-4)
print("2d OK")
"""


@pytest.mark.slow
def test_equal_work_distributed_parity():
    out = run_subprocess(CODE, devices=12)
    assert "matrix grid OK" in out
    assert "ragged OK" in out
    assert "frozen/uniform offsets OK" in out
    assert "all-zero-V OK" in out
    assert "2d OK" in out
