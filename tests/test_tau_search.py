"""valid-ratio → τ search (paper §3.5.2 / §4.1): ≤20 binary iterations reach
the requested ratio within tolerance on the paper's synthesized ensemble."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spamm as cs
from repro.core.tau_search import search_tau
from repro.kernels import ref


@pytest.mark.parametrize("target", [0.30, 0.25, 0.20, 0.15, 0.10, 0.05])
def test_paper_synthesized_ensemble(target):
    """Paper §4.1: a_ij = 0.1/(|i-j|^0.1+1), N=1024; their reported ratio
    errors are <1% within 20 iterations."""
    n, tile = 1024, 64
    a = cs.algebraic_decay(n, c=0.1, lam=0.1, seed=0)
    b = cs.algebraic_decay(n, c=0.1, lam=0.1, seed=1)
    na = ref.tile_norms_ref(jnp.asarray(a), tile)
    nb = ref.tile_norms_ref(jnp.asarray(b), tile)
    tau, res = search_tau(na, nb, target, tol=0.01, max_iters=20)
    assert abs(float(res.achieved_ratio) - target) < 0.015, (
        float(res.achieved_ratio), target)
    assert int(res.iterations) <= 40  # expansion + binary


def test_expanding_upper_bound():
    """Targets so small that τ must exceed ave (k must expand past 1)."""
    n, tile = 512, 64
    a = cs.exponential_decay(n, lam=0.5, seed=0)
    na = ref.tile_norms_ref(jnp.asarray(a), tile)
    tau, res = search_tau(na, na, 0.02, tol=0.005, max_iters=30)
    assert float(res.achieved_ratio) <= 0.05


def test_monotone_interface():
    n, tile = 256, 64
    a = cs.algebraic_decay(n, seed=2)
    na = ref.tile_norms_ref(jnp.asarray(a), tile)
    taus = []
    for target in [0.5, 0.2, 0.05]:
        tau, _ = search_tau(na, na, target)
        taus.append(float(tau))
    assert taus[0] <= taus[1] <= taus[2]  # smaller ratio ⇒ larger τ


def test_degenerate_all_zero_operands_early_exit():
    """All-zero operands give ave == 0: the expansion loop used to evaluate
    ratio(0) up to the k < 1024 cap and then bisect the empty [0, 0]
    bracket for max_iters more evaluations. Both now early-exit with τ=0."""
    z = jnp.zeros((8, 8), jnp.float32)
    tau, res = search_tau(z, z, 0.3)
    assert float(tau) == 0.0
    assert int(res.iterations) <= 2  # one probe, no expansion/bisection spin


def test_degenerate_all_zero_pyramid_early_exit():
    from repro.core.plan import NormPyramid
    from repro.core.tau_search import search_tau_pyramid

    z = jnp.zeros((8, 8), jnp.float32)
    pyr = NormPyramid.from_normmap(z, 2)
    tau, res = search_tau_pyramid(pyr, pyr, 0.3)
    assert float(tau) == 0.0
    # coarse probe + fine probe; the 8-round doubling guard never spins
    assert int(res.iterations) <= 4


def test_degenerate_plan_valid_ratio_on_zero_matrix():
    """plan(valid_ratio=...) on a zero matrix terminates fast with τ=0 and
    a full mask (every zero product passes τ=0)."""
    from repro.core import plan as pl

    z = jnp.zeros((64, 64), jnp.float32)
    p = pl.plan(z, z, valid_ratio=0.5, tile=32, backend="jnp")
    assert float(p.tau) == 0.0
    assert float(p.valid_fraction) == 1.0
