"""Roofline cost model + autotuner — ISSUE 7 tentpole coverage.

Pins the contracts the tuner rests on: the analytic byte counts ARE
`SpammPlan.bytes_moved()` (one formula, `core.cost.gemm_bytes`) across
dtype × block_n × levels; tuning is deterministic under a fixed profile
and never predicted slower than the hardcoded defaults; `TunedParams`
round-trips through the `PlanStore` manifest while legacy artifacts
(no tuned record) still load; the fused int8 getnorm+absmax kernel is
bit-identical to the unfused quantize→dequantize→getnorm pipeline; and
the perf-trajectory gate (`benchmarks.perf_gate`) fails on an injected
slowdown and refuses cross-environment comparisons.
"""
import json
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost
from repro.core import plan as pl
from repro.core.spamm import exponential_decay
from repro.kernels import ops as kops
from repro.kernels import quantize as kquant
from repro.plans.frozen import FrozenWeight
from repro.plans.store import PlanStore, fingerprint

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:  # benchmarks.* imports when pytest cwd ≠ repo root
    sys.path.insert(0, REPO)

N, TILE, TAU, LAM = 128, 32, 0.05, 0.8


def _pair(n=N, lam=LAM):
    a = jnp.asarray(exponential_decay(n, lam=lam, seed=0))
    b = jnp.asarray(exponential_decay(n, lam=lam, seed=1))
    return a, b


def _flat(norm):
    return np.asarray(norm.levels[0] if hasattr(norm, "levels") else norm)


# ---------------------------------------------------------------------------
# counts: the model's bytes ARE the plan's bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("block_n", [1, 2])
@pytest.mark.parametrize("levels", [0, 1])
def test_predicted_bytes_equal_plan_bytes_moved(dtype, block_n, levels):
    a, b = _pair()
    p = pl.plan(a, b, TAU, tile=TILE, block_n=block_n, levels=levels,
                backend="interpret", compute_dtype=dtype)
    # the plan stores the WIDENED τ and the quantized-view normmaps — the
    # exact inputs the gate ran on, so the model must reproduce it exactly
    counts = cost.predict_counts(
        _flat(p.norm_a), _flat(p.norm_b), float(p.tau), tile=TILE,
        block_n=block_n, dtype=dtype, levels=levels, mode="eager")
    assert counts.steps_real == int(p.valid_tiles)
    assert counts.gemm_bytes == pytest.approx(float(p.bytes_moved()), rel=0,
                                              abs=0.5)
    # and the formula itself is shared, not duplicated
    pairs = int(np.sum(np.asarray(p.nvalid) > 0))
    assert counts.pairs == pairs
    assert counts.gemm_bytes == cost.gemm_bytes(
        counts.steps_real, pairs, TILE, block_n, dtype)


def test_gemm_bytes_dtype_itemsize_aware():
    v, pairs = 10.0, 4.0
    b32 = cost.gemm_bytes(v, pairs, TILE, 1, "float32")
    b16 = cost.gemm_bytes(v, pairs, TILE, 1, "bfloat16")
    b8 = cost.gemm_bytes(v, pairs, TILE, 1, "int8")
    flush = pairs * TILE * TILE * 4.0  # f32 output flush, dtype-independent
    assert (b32 - flush) == 2 * (b16 - flush) == 4 * (b8 - flush)


def test_bucket_min_threads_through_plan():
    a, b = _pair()
    p16 = pl.plan(a, b, TAU, tile=TILE, backend="interpret")
    p256 = pl.plan(a, b, TAU, tile=TILE, backend="interpret",
                   bucket_min=256)
    assert p16.work.step_i.shape[0] == cost.bucket(int(p16.valid_tiles))
    assert p256.work.step_i.shape[0] == 256
    np.testing.assert_array_equal(np.asarray(pl.execute(p16, a, b)),
                                  np.asarray(pl.execute(p256, a, b)))


# ---------------------------------------------------------------------------
# tuner: deterministic, never predicted slower than the defaults
# ---------------------------------------------------------------------------

def _fixed_profile():
    prof = cost.CostProfile()
    prof.put("interpret", cost.CostCoeffs(2.0e9, 1.0e10, 4.0e-5, 3.0e-4,
                                          2.0e8, calibrated=True),
             kind="testkind")
    return prof


def test_tune_weight_deterministic_and_never_worse():
    _, b = _pair()
    prof = _fixed_profile()
    tps = [cost.tune_weight(b, TAU, tile=TILE, dtype="int8",
                            backend="interpret", profile=prof)
           for _ in range(2)]
    assert tps[0] == tps[1]
    tp = tps[0]
    assert tp.predicted_us <= tp.default_predicted_us
    assert tp.block_n in cost.BLOCK_N_CHOICES
    assert tp.levels in cost.LEVELS_CHOICES
    assert tp.bucket in cost.BUCKET_CHOICES
    assert tp.profile_key == "interpret/testkind"


def test_tune_defaults_always_in_search_space():
    # when the caller's defaults ARE the argmin, the tuner must return them
    # exactly (defaults are always a candidate, strict-< to replace) — so a
    # tuned pick can never be predicted slower than what it replaces
    _, b = _pair()
    prof = _fixed_profile()
    best = cost.tune_weight(b, TAU, tile=TILE, backend="interpret",
                            profile=prof)
    tp = cost.tune_weight(b, TAU, tile=TILE, backend="interpret",
                          profile=prof,
                          defaults=(best.block_n, best.levels, best.bucket))
    assert (tp.block_n, tp.levels, tp.bucket) == (
        best.block_n, best.levels, best.bucket)
    assert tp.predicted_us == tp.default_predicted_us == best.predicted_us


def test_profile_json_round_trip(tmp_path):
    prof = _fixed_profile()
    path = prof.save(str(tmp_path / "prof.json"))
    back = cost.CostProfile.load(path)
    assert back.coeffs("interpret") == prof.coeffs("interpret")
    assert back.coeffs("interpret").calibrated
    # schema guard: a future-schema file must refuse, not half-load
    with open(path) as f:
        doc = json.load(f)
    doc["schema"] = cost.COST_SCHEMA_VERSION + 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="schema"):
        cost.CostProfile.load(str(bad))
    # load_or_default: missing path → usable nominal profile
    nominal = cost.CostProfile.load_or_default(str(tmp_path / "nope.json"))
    assert nominal.coeffs("interpret") == cost.DEFAULT_COEFFS["interpret"]


# ---------------------------------------------------------------------------
# persistence: TunedParams through FrozenWeight aux + PlanStore manifest
# ---------------------------------------------------------------------------

def _tuned(block_n=2, levels=0, bucket=64):
    return cost.TunedParams(block_n=block_n, levels=levels, bucket=bucket,
                            predicted_us=12.5, default_predicted_us=20.0,
                            profile_key="interpret/testkind")


def test_planstore_round_trips_tuned_fields(tmp_path):
    _, b = _pair()
    tp = _tuned()
    fw = FrozenWeight.build(b, TAU, tile=TILE, block_n=tp.block_n,
                            levels=tp.levels, backend="interpret",
                            weight_hash=fingerprint(b), tuned=tp)
    assert fw.tuned == tp
    assert fw.bucket_floor == tp.bucket
    store = PlanStore(str(tmp_path / "store"))
    store.put(fw)
    back = PlanStore(str(tmp_path / "store")).get(  # fresh handle: disk only
        fingerprint(b), tau=TAU, tile=TILE, block_n=tp.block_n,
        levels=tp.levels, backend="interpret")
    assert back is not None
    assert back.tuned == tp
    assert back.bucket_floor == tp.bucket
    # the tuned bucket floors the step tables of every row-grid plan
    assert back.for_rows(2).num_steps >= tp.bucket


def test_planstore_legacy_artifacts_load_without_tuned(tmp_path):
    _, b = _pair()
    fw = FrozenWeight.build(b, TAU, tile=TILE, backend="interpret",
                            weight_hash=fingerprint(b))
    store = PlanStore(str(tmp_path / "store"))
    store.put(fw)
    # the manifest of an un-tuned artifact has NO tuned key (format
    # unchanged — old readers keep working on new stores)
    mans = [os.path.join(r, f) for r, _, fs in os.walk(str(tmp_path))
            for f in fs if f.endswith(".json")]
    assert mans
    for m in mans:
        with open(m) as f:
            assert "tuned" not in json.load(f)
    back = PlanStore(str(tmp_path / "store")).get(
        fingerprint(b), tau=TAU, tile=TILE, block_n=1, levels=0,
        backend="interpret")
    assert back is not None
    assert back.tuned is None
    assert back.bucket_floor == 16


def test_frozen_execute_matches_eager_at_tuned_params():
    a, b = _pair()
    tp = cost.tune_weight(b, TAU, tile=TILE, dtype="int8",
                          backend="interpret", profile=_fixed_profile())
    fw = FrozenWeight.build(b, TAU, tile=TILE, block_n=tp.block_n,
                            levels=tp.levels, backend="interpret",
                            compute_dtype="int8", tuned=tp)
    p_frozen = pl.plan(a, frozen_weight=fw, tile=TILE, backend="interpret")
    p_eager = pl.plan(a, b, TAU, tile=TILE, block_n=tp.block_n,
                      levels=tp.levels, backend="interpret",
                      compute_dtype="int8", bucket_min=tp.bucket)
    np.testing.assert_array_equal(np.asarray(pl.execute(p_frozen, a, b)),
                                  np.asarray(pl.execute(p_eager, a, b)))


# ---------------------------------------------------------------------------
# fused int8 getnorm+absmax kernel (satellite): bit-parity with unfused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["interpret", "jnp"])
def test_fused_int8_norms_match_unfused(backend):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((96, 64)).astype(np.float32))
    tile = 32
    norms, scales = kops.int8_norms_and_scales(x, tile, backend=backend)
    bk = kops.get_backend(backend)
    q, s_ref = kquant.quantize_tiles(x, tile)
    dq = kquant.dequantize_tiles(q, s_ref, tile)
    norms_ref = bk.norms(dq, tile)
    np.testing.assert_array_equal(np.asarray(norms), np.asarray(norms_ref))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(s_ref))
    assert norms.shape == (96 // tile, 64 // tile)


def test_fused_path_registered_only_where_it_exists():
    assert kops.BACKENDS["interpret"].norms_quant is not None
    assert kops.BACKENDS["pallas"].norms_quant is not None
    assert kops.BACKENDS["jnp"].norms_quant is None  # falls back, same bits


# ---------------------------------------------------------------------------
# perf-trajectory gate (benchmarks.perf_gate) + env-stamped reports
# ---------------------------------------------------------------------------

def test_write_bench_json_stamps_env(tmp_path):
    from benchmarks.report import BENCH_SCHEMA_VERSION, write_bench_json

    path = write_bench_json("stamptest", {"cells": [{"n": 1, "us": 2.0}]},
                            out_dir=str(tmp_path), backend="interpret")
    with open(path) as f:
        doc = json.load(f)
    assert doc["bench_schema_version"] == BENCH_SCHEMA_VERSION
    for env in (doc["env"], doc["data"]["cells"][0]["env"]):
        assert env["backend"] == "interpret"
        assert env["device_kind"]
        assert env["hostname"]


def test_perf_gate_fails_injected_slowdown_and_refuses_env_mismatch():
    from benchmarks import perf_gate

    ref = perf_gate._synthetic_doc()
    clean = perf_gate.compare_docs(ref, perf_gate._synthetic_doc(), "t")
    assert clean.ok and clean.checked > 0

    slow = perf_gate.compare_docs(
        ref,
        perf_gate._synthetic_doc(
            us=100.0 * (1 + perf_gate.WALL_CLOCK_REL_TOL) * 1.01), "t")
    assert not slow.ok
    assert any("wall-clock regressed" in p for p in slow.problems)

    moved = perf_gate.compare_docs(
        ref, perf_gate._synthetic_doc(device_kind="TPU v5e"), "t")
    assert moved.refusals and not moved.problems and not moved.ok

    # deterministic outputs gate BOTH directions — silent improvements
    # also demand a conscious reference update
    drift = perf_gate.compare_docs(
        ref, perf_gate._synthetic_doc(bytes_moved=0.9e6), "t")
    assert not drift.ok


def test_perf_gate_full_selftest():
    from benchmarks import perf_gate

    assert perf_gate.selftest() == 0


# ---------------------------------------------------------------------------
# freeze_tree autotune integration: stacked leaves share ONE tuning
# ---------------------------------------------------------------------------

def test_freeze_tree_autotune_attaches_shared_tuned(tmp_path):
    from repro.configs import SpammConfig
    from repro.plans.precompute import freeze_tree

    rng = np.random.default_rng(0)
    params = {"layers": {"mlp": {
        "w1": rng.standard_normal((2, 64, 64)).astype(np.float32),
        "w2": rng.standard_normal((64, 64)).astype(np.float32),
    }}}
    scfg = SpammConfig(enable=True, tau=0.02, tile=32, backend="interpret",
                       autotune=True)
    tree, count = freeze_tree(params, scfg)
    assert count == 3
    stacked = tree["layers"]["mlp"]["w1"]
    single = tree["layers"]["mlp"]["w2"]
    assert all(fw.tuned is not None for fw in stacked)
    # one tuning shared across the stack: stacked plans must agree on
    # block_n/levels/bucket to ride one lax.scan
    assert len({fw.tuned for fw in stacked}) == 1
    assert all(fw.block_n == fw.tuned.block_n for fw in stacked)
    assert single.tuned is not None
    assert single.tuned.predicted_us <= single.tuned.default_predicted_us
