import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run `code` in a fresh python with N fake XLA host devices.

    Multi-device tests must run out-of-process: XLA locks the device count at
    first jax init, and the main pytest process must keep 1 device (the smoke
    tests are specified to see a single device).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout
