"""Telemetry subsystem — ISSUE 9 tentpole coverage.

Fast units pin the host-side sinks (registry semantics + Prometheus
round-trip, span tracer + Chrome-trace export, cost-residual tracker) and
the zero-graph-cost identity `predict_plan_static` + `finish_plan_time_s`
== `predict_plan_time_s` that lets the cost channel arm without changing
the traced graphs. Integration tests drive real engine waves / train steps
and assert the labeled-tap contract: per-(layer, site) cells exist under
`lax.scan`-stacked layers (dense and hybrid stacks) and under `grad`
(custom_vjp fwd path), their sums reproduce the existing per-wave
aggregates EXACTLY, and the instrumentation changes neither tokens nor
trace counts (`obs=False` A/B). The 4-fake-device sharded contract runs in
a subprocess (device count locks at first jax init), mirroring
tests/test_sharded_engine.py.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core import cost
from repro.core import plan as pl
from repro.core import schedule as S
from repro.core.spamm import exponential_decay
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.obs import (CostResidualTracker, Histogram, MetricsRegistry,
                       Observability, SpanTracer, maybe_span,
                       parse_prometheus)
from repro.serving.engine import Engine, Request

PCFG = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32, decode_seq_shard=False,
)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_labels_and_monotonicity():
    reg = MetricsRegistry()
    c = reg.counter("spamm_widgets_total", "w", labelnames=("phase",))
    c.inc(phase="prefill")
    c.inc(2.5, phase="prefill")
    c.inc(phase="decode")
    assert c.value(phase="prefill") == 3.5
    assert c.value(phase="decode") == 1.0
    assert c.value(phase="never") == 0.0          # untouched series reads 0
    with pytest.raises(ValueError):
        c.inc(-1.0, phase="prefill")              # counters only go up
    with pytest.raises(ValueError):
        c.inc()                                    # missing label
    with pytest.raises(ValueError):
        c.inc(phase="prefill", layer=0)            # undeclared label


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("live_imbalance")
    assert g.value() is None
    g.set(1.5)
    g.set(1.2)
    assert g.value() == 1.2


def test_histogram_buckets_quantile_and_recent():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0), keep_recent=3)
    for v in (1.0, 3.0):                           # le=1 and le=4 buckets
        h.observe(v)
    assert h.count() == 2 and h.sum() == 4.0
    # rank interpolation: p50 lands at the first bucket's upper bound,
    # p100 at the second occupied bucket's
    assert h.quantile(0.5) == pytest.approx(1.0)
    assert h.quantile(1.0) == pytest.approx(4.0)
    h.observe(100.0)                               # +Inf bucket...
    assert h.quantile(1.0) == 4.0                  # ...clamps to top finite
    for v in (5.0, 6.0, 7.0, 8.0):
        h.observe(v)
    assert h.recent() == [6.0, 7.0, 8.0]           # bounded raw-sample tail
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))       # must ascend


def test_registry_get_or_create_and_conflicts():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "help", labelnames=("a",))
    c2 = reg.counter("x_total", "other", labelnames=("a",))
    assert c1 is c2                                # cached by name
    with pytest.raises(ValueError):
        reg.gauge("x_total")                       # kind conflict
    with pytest.raises(ValueError):
        reg.counter("x_total", labelnames=("b",))  # label-set conflict
    with pytest.raises(ValueError):
        reg.counter("0bad name")                   # invalid metric name


def test_prometheus_render_parse_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("serve_waves_total", "waves", labelnames=("phase",))
    c.inc(3, phase="prefill")
    h = reg.histogram("serve_ttft_seconds", "ttft", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = reg.render_prometheus()
    back = parse_prometheus(text)
    assert back["serve_waves_total"]["type"] == "counter"
    assert back["serve_waves_total"]["samples"][
        'serve_waves_total{phase="prefill"}'] == 3
    hs = back["serve_ttft_seconds"]["samples"]
    assert hs['serve_ttft_seconds_bucket{le="0.1"}'] == 1
    assert hs['serve_ttft_seconds_bucket{le="1"}'] == 2   # cumulative
    assert hs['serve_ttft_seconds_bucket{le="+Inf"}'] == 2
    assert hs["serve_ttft_seconds_count"] == 2
    assert hs["serve_ttft_seconds_sum"] == pytest.approx(0.55)
    # snapshot is JSON-able (rides write_bench_json(metrics=...))
    json.dumps(reg.snapshot())
    # the end-of-run table mentions every metric with samples
    table = reg.summary_table()
    assert "serve_waves_total" in table and "serve_ttft_seconds" in table


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


def test_tracer_spans_and_chrome_export(tmp_path):
    tr = SpanTracer(process_name="repro-test")
    with tr.span("freeze", n=3):
        pass
    tr.add_complete("decode_step", 1_000, 4_000, step=0)
    tr.instant("reshard_committed")
    assert tr.span_names() == {"freeze", "decode_step", "reshard_committed"}
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "repro-test"
    dec = next(e for e in evs if e["name"] == "decode_step")
    assert dec["ph"] == "X" and dec["dur"] == pytest.approx(3.0)  # µs
    assert dec["args"] == {"step": 0}
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        assert json.load(f) == doc                 # valid Perfetto JSON

    off = SpanTracer(enabled=False)
    with off.span("x"):
        pass
    off.add_complete("y", 0, 1)
    assert off.events == []                        # hard-off records nothing
    with maybe_span(None, "z"):                    # None-tracer helper
        pass


def test_tracer_bounds_event_count():
    tr = SpanTracer(max_events=2)
    for i in range(5):
        tr.instant(f"e{i}")
    assert len(tr.events) == 2                     # never grows unbounded


# ---------------------------------------------------------------------------
# cost-residual tracker
# ---------------------------------------------------------------------------


def test_cost_residual_tracker_records_log2_ratio():
    reg = MetricsRegistry()
    tk = CostResidualTracker(reg)
    r = tk.record("prefill", predicted_s=0.5, measured_s=1.0)
    assert r == pytest.approx(1.0)                 # measured 2x slower
    assert tk.hist.count(phase="prefill") == 1
    assert tk.predicted_s.value(phase="prefill") == 0.5
    assert tk.measured_s.value(phase="prefill") == 1.0
    # non-positive sides (no gated GEMM ran) record nothing
    assert tk.record("decode", 0.0, 1.0) is None
    assert tk.record("decode", 1.0, 0.0) is None
    assert tk.hist.count(phase="decode") == 0


# ---------------------------------------------------------------------------
# cost channel: static-split prediction == the in-trace twin
# ---------------------------------------------------------------------------


def test_predict_plan_static_finish_matches_in_trace_prediction():
    """The telemetry taps price a GEMM as predict_plan_static (host, trace
    time) + finish_plan_time_s (host, callback time). The split must equal
    predict_plan_time_s on the same plan EXACTLY — this identity is what
    lets armed and unarmed contexts trace identical graphs."""
    a = jnp.asarray(exponential_decay(128, lam=0.8, seed=0))
    b = jnp.asarray(exponential_decay(128, lam=0.8, seed=1))
    coeffs = cost.DEFAULT_COEFFS["interpret"]
    for block_n, levels in ((1, 0), (2, 1)):
        p = pl.plan(a, b, 0.05, tile=32, block_n=block_n, levels=levels,
                    backend="interpret")
        static = cost.predict_plan_static(p, coeffs)
        assert static is not None
        got = cost.finish_plan_time_s(static, float(p.valid_fraction),
                                      float(p.bytes_moved()), coeffs)
        want = float(cost.predict_plan_time_s(p, coeffs))
        # same formula, but the in-trace twin evaluates in f32 (its
        # operands are traced arrays) — agree to f32 precision
        assert got == pytest.approx(want, rel=1e-6)

    class _NoWork:                                 # dense-bitmap shape
        work = None

    assert cost.predict_plan_static(_NoWork(), coeffs) is None


# ---------------------------------------------------------------------------
# reshard controller -> registry publishing
# ---------------------------------------------------------------------------


def test_reshard_publish_incremental_and_idempotent():
    ctl = S.ReshardController(S.ReshardConfig(num_devices=2, every=1))
    v = jnp.asarray(np.ones((8, 8), np.float32))
    ctl.probe(v, 0)
    ctl.probe(v, 1)
    reg = MetricsRegistry()
    ctl.publish(reg)
    probes = reg.counter("spamm_reshard_probes_total")
    events = reg.counter("spamm_reshard_events_total")
    imb = reg.histogram("spamm_partition_imbalance")
    assert probes.value() == 2
    assert events.value() == 0                     # uniform v never re-cuts
    assert imb.count() == 2
    assert reg.gauge("spamm_partition_imbalance_live").value() is not None
    ctl.publish(reg)                               # cursor: no double count
    assert probes.value() == 2
    ctl.probe(v, 2)
    ctl.publish(reg)                               # only the delta lands
    assert probes.value() == 3 and imb.count() == 3


# ---------------------------------------------------------------------------
# engine integration: labeled taps under the scanned stack
# ---------------------------------------------------------------------------


def _mk_reqs(rng, cfg, b, plen, max_new):
    return [Request(prompt=rng.integers(1, cfg.vocab, size=plen)
                    .astype(np.int32), max_new_tokens=max_new)
            for _ in range(b)]


def _run_wave(arch="musicgen-large", obs=None, max_new=4, plen=16, b=2,
              tile=4, seed=0):
    cfg = get_config(arch).reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, PCFG, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=0.05, tile=tile, backend="jnp")
    eng = Engine(cfg, PCFG, ctx, params, max_len=plen + max_new + 8,
                 spamm_cfg=sc, obs=obs)
    reqs = _mk_reqs(np.random.default_rng(seed), cfg, b, plen, max_new)
    outs = eng.generate(reqs)
    return cfg, eng, reqs, outs


def _assert_cells_sum_to_aggregates(sp):
    """Per-(layer, site) cells must reproduce the wave aggregates exactly:
    the breakdown re-bins the SAME taps, so counts/bytes sum and nothing
    leaks (a cell landing outside the aggregate, or an unlabeled tap
    silently entering a cell, both break the equality)."""
    cells = [c for sites in sp["per_layer"].values() for c in sites.values()]
    assert sum(c["gated_gemms"] for c in cells) == sp["gated_gemms"]
    assert sum(c["decode_gated_gemms"] for c in cells) == \
        sp["decode_gated_gemms"]
    total_bytes = sum(c["gemm_bytes_moved"] or 0.0 for c in cells)
    want_bytes = (sp["gemm_bytes_moved"] or 0.0) + \
        (sp["decode_gemm_bytes_moved"] or 0.0)
    assert total_bytes == pytest.approx(want_bytes, rel=1e-9)
    for c in cells:
        for k in ("valid_fraction", "decode_valid_fraction"):
            if c[k] is not None:
                assert 0.0 <= c[k] <= 1.0


def test_engine_per_layer_attribution_under_scan():
    cfg, eng, reqs, _ = _run_wave(max_new=4)
    sp = reqs[0].out["spamm"]
    # every scanned layer shows up, labeled 0..L-1, with named GEMM sites
    assert set(sp["per_layer"]) == set(range(cfg.num_layers))
    for sites in sp["per_layer"].values():
        assert set(sites) <= {"wq", "wk", "wv", "wo", "w1", "w2", "w3"}
        assert sites                                # never an empty layer
    _assert_cells_sum_to_aggregates(sp)
    # latency channel: TTFT plus per-decode-step stats from the wave
    lat = sp["latency"]
    assert lat["ttft_s"] > 0.0
    assert lat["decode_steps"] == 3                 # max_new-1 measured gaps
    assert lat["decode_mean_s"] > 0.0
    assert lat["decode_p50_s"] <= lat["decode_p95_s"]
    # cost channel: per-phase predicted/measured pairing with log2 residual
    cres = sp["cost_residual"]
    assert set(cres) <= {"prefill", "decode"} and cres
    for ph in cres.values():
        assert ph["predicted_s"] > 0.0 and ph["measured_s"] > 0.0
        assert math.isfinite(ph["log2_ratio"])
    # instrumentation never re-traces the step functions
    assert eng.trace_counts == {"prefill": 1, "decode": 1}


def test_engine_registry_and_spans_cross_check():
    cfg, eng, reqs, _ = _run_wave(max_new=4)
    sp = reqs[0].out["spamm"]
    reg = eng.obs.registry
    # the registry's labeled counter re-aggregates to the wave totals
    gemms = reg.counter("spamm_gated_gemms_total", "",
                        labelnames=("phase", "layer", "site"))
    assert sum(gemms.series().values()) == \
        sp["gated_gemms"] + sp["decode_gated_gemms"]
    assert reg.histogram("serve_ttft_seconds").count() == 1
    assert reg.histogram("serve_decode_step_seconds").count() == \
        sp["latency"]["decode_steps"]
    assert reg.counter("serve_waves_total").value() == 1
    # spans cover the wave's host phases and export as valid Chrome JSON
    names = eng.obs.tracer.span_names()
    assert {"freeze", "prefill", "decode_step", "wave"} <= names
    json.dumps(eng.obs.tracer.chrome_trace())
    # Prometheus dump of a real run round-trips through the CI parser
    back = parse_prometheus(reg.render_prometheus())
    assert "spamm_valid_fraction" in back
    assert "spamm_gemm_bytes_total" in back


def test_engine_obs_false_is_bit_identical_and_silent():
    """obs=False is the A/B baseline: same tokens, same trace counts, no
    spans, no latency/cost channels — the exact pre-telemetry path."""
    _, eng_i, reqs_i, outs_i = _run_wave(max_new=4, seed=3)
    _, eng_b, reqs_b, outs_b = _run_wave(max_new=4, seed=3, obs=False)
    for a, b in zip(outs_i, outs_b):
        np.testing.assert_array_equal(a, b)
    assert eng_b.trace_counts == eng_i.trace_counts == \
        {"prefill": 1, "decode": 1}
    sp_b = reqs_b[0].out["spamm"]
    assert "latency" not in sp_b and "cost_residual" not in sp_b
    assert eng_b.obs.tracer.events == []
    assert eng_b.obs.registry.metrics() != eng_i.obs.registry.metrics()
    # the uninstrumented wave still reports the tap-backed gating stats
    assert sp_b["gated_gemms"] == reqs_i[0].out["spamm"]["gated_gemms"]
    _assert_cells_sum_to_aggregates(sp_b)


def test_engine_per_layer_on_hybrid_arch():
    """Hybrid (rec, rec, attn) stacks scan over GROUPS: layer labels are
    group indices; only the attn sub-layer carries projections but every
    sub-layer's MLP is gated — labels must stay stable and the cells must
    still sum to the aggregates."""
    cfg, eng, reqs, _ = _run_wave(arch="recurrentgemma-9b", max_new=3,
                                  plen=16, tile=16)
    sp = reqs[0].out["spamm"]
    assert sp["per_layer"], "hybrid stack lost its layer labels"
    assert all(layer >= 0 for layer in sp["per_layer"])
    _assert_cells_sum_to_aggregates(sp)
    assert eng.trace_counts == {"prefill": 1, "decode": 1}


# ---------------------------------------------------------------------------
# train loop: labeled taps under grad (custom_vjp fwd path)
# ---------------------------------------------------------------------------


def test_train_per_layer_attribution_under_grad(tmp_path):
    from repro.configs.base import TrainConfig
    from repro.train.loop import train

    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    tcfg = TrainConfig(total_steps=2, warmup=1, ckpt_every=0,
                       ckpt_dir=str(tmp_path))
    sc = SpammConfig(enable=True, tau=0.05, tile=16, backend="jnp")
    res = train(cfg, PCFG, tcfg, ctx, global_batch=2, seq_len=32,
                spamm_cfg=sc, log_every=0)
    assert len(res.spamm_stats) == 2
    for s in res.spamm_stats:
        per = s["per_layer"]
        assert set(per) == set(range(cfg.num_layers))
        # the scan ys carry (sum, count) per layer: the count-weighted mean
        # of the layer fractions IS the aggregate fraction
        tot = sum(c["gated_gemms"] for c in per.values())
        assert tot == s["gated_gemms"]
        mean = sum(c["valid_fraction"] * c["gated_gemms"]
                   for c in per.values()) / tot
        assert mean == pytest.approx(s["valid_fraction"], rel=1e-6)
    # the loop's own telemetry: one timed span + histogram sample per step
    assert isinstance(res.obs, Observability)
    assert res.obs.registry.histogram("train_step_seconds").count() == 2
    assert "train_step" in res.obs.tracer.span_names()
    # hard-off train run: same export shape, no spans
    res0 = train(cfg, PCFG, tcfg, ctx, global_batch=2, seq_len=32,
                 spamm_cfg=sc, log_every=0, obs=False)
    assert res0.obs.tracer.events == []
    assert res0.spamm_stats[0]["per_layer"].keys() == per.keys()


# ---------------------------------------------------------------------------
# the 4-device sharded contract (subprocess: fake host devices)
# ---------------------------------------------------------------------------

CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ParallelConfig, SpammConfig, get_config
from repro.core import schedule as S
from repro.launch.mesh import make_ctx, make_host_mesh
from repro.models import model as M
from repro.serving.engine import Engine, Request

assert len(jax.devices()) == 4, jax.devices()

pcfg = ParallelConfig(
    compute_dtype="float32", param_dtype="float32", remat="none",
    attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    decode_seq_shard=False,
)
cfg = get_config("musicgen-large").reduced()
ctx = make_ctx(make_host_mesh())
params = M.init_params(cfg, pcfg, jax.random.key(0))

TILE = 4
sc = SpammConfig(enable=True, tau=0.5, tile=TILE, backend="jnp")
rcfg = S.ReshardConfig(num_devices=4, every=2, drift_threshold=1.2,
                       probe_window=32)
eng = Engine(cfg, pcfg, ctx, params, max_len=64, spamm_cfg=sc,
             reshard_cfg=rcfg, mesh_devices=4)

rng = np.random.default_rng(0)
plen, max_new = 32, 6
prompts = [rng.integers(1, cfg.vocab, plen).astype(np.int32)
           for _ in range(16)]
reqs = [Request(prompt=p, max_new_tokens=max_new) for p in prompts]
eng.generate(reqs)
sp = reqs[0].out["spamm"]

# layer labels survive shard_map: every scanned layer present, and the
# per-cell sums reproduce the (per-shard-scaled) wave aggregates exactly
assert set(sp["per_layer"]) == set(range(cfg.num_layers)), sp["per_layer"]
cells = [c for sites in sp["per_layer"].values() for c in sites.values()]
assert sum(c["gated_gemms"] for c in cells) == sp["gated_gemms"]
assert sum(c["decode_gated_gemms"] for c in cells) == \
    sp["decode_gated_gemms"]
# taps fire once per mesh device: counts are divisible by the shard count
assert sp["gated_gemms"] % 4 == 0, sp["gated_gemms"]

# telemetry adds no traces in sharded mode either
assert eng.trace_counts == {"prefill": 1, "decode": 1}, eng.trace_counts

# latency + cost channels populated; reshard history published to registry
assert sp["latency"]["ttft_s"] > 0.0
assert sp["latency"]["decode_steps"] == max_new - 1
reg = eng.obs.registry
assert reg.counter("spamm_reshard_probes_total").value() >= 1
assert {"freeze", "plan_assembly", "prefill", "decode_step",
        "reshard_probe", "wave"} <= eng.obs.tracer.span_names()
import json
json.dumps(eng.obs.tracer.chrome_trace())

print("OBS-SHARDED-OK", sp["gated_gemms"])
"""


@pytest.mark.slow
def test_sharded_engine_per_layer_telemetry_4dev():
    out = run_subprocess(CODE, devices=4)
    assert "OBS-SHARDED-OK" in out
