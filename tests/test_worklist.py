"""Compacted (work-list) plan execution — ISSUE 3 tentpole coverage.

The work-list path must be bit-identical to the dense-mask/dense-kidx
oracles across block_n, ragged shapes, empty and full masks; the plan's
`work` field must agree with the legacy `spamm_compact_ref` compaction on
random masks; and the block_n padding fix must make odd-N products work
through every entry point.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import module as mod
from repro.core import plan as pl
from repro.core import spamm as cs
from repro.kernels import ops, ref
from repro.kernels import spamm_mm as smm


def _decay(m, n, seed, scale=0.4):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(m)[:, None] - np.arange(n)[None, :])
    base = (scale / (d ** 0.5 + 1)).astype(np.float32)
    return jnp.asarray(base * rng.standard_normal((m, n)).astype(np.float32))


TAU32 = 4.0  # gates a real fraction on the _decay operands at tile=32


# ---------------------------------------------------------------------------
# work-list vs dense oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_n", [1, 2, 4])
@pytest.mark.parametrize("levels", [0, 2])
def test_worklist_bit_identical_to_dense_grid_kernel(block_n, levels):
    """The ragged kernel (Σnvalid-step grid) is bit-identical to the
    dense-grid kidx kernel on the same mask: same f32 accumulator, same
    ascending-k order, only the grid shape differs."""
    a, b = _decay(128, 160, 0), _decay(160, 256, 1)
    p = pl.plan(a, b, TAU32, tile=32, block_n=block_n, backend="interpret",
                levels=levels)
    assert p.work is not None  # concrete plans are compacted-first
    got = pl.execute(p, a, b)
    kidx, nv = ref.spamm_compact_ref(p.mask)
    want = smm.spamm_mm(a, b, kidx, nv, tile=32, block_n=block_n,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_n", [1, 2])
def test_worklist_matches_jnp_masked_einsum(block_n):
    a, b = _decay(96, 128, 2), _decay(128, 192, 3)
    p_i = pl.plan(a, b, TAU32, tile=32, block_n=block_n, backend="interpret")
    p_j = pl.plan(a, b, TAU32, tile=32, block_n=block_n, backend="jnp")
    np.testing.assert_array_equal(np.asarray(p_i.mask), np.asarray(p_j.mask))
    np.testing.assert_allclose(
        np.asarray(pl.execute(p_i, a, b)),
        np.asarray(pl.execute(p_j, a, b)),
        atol=2e-4,
    )


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_empty_mask_all_zero_output(backend):
    a, b = _decay(96, 96, 4), _decay(96, 96, 5)
    p = pl.plan(a, b, 1e9, tile=32, backend=backend)
    assert int(p.valid_tiles) == 0
    if p.work is not None and p.work.step_flags is not None:
        assert p.work.num_valid == 0 and p.work.num_pairs == 0
        # the first padding step must still init+flush so block (0, 0) is
        # WRITTEN with zeros on real TPU (its VMEM window is copied back
        # even when the kernel never stores)
        flags = np.asarray(p.work.step_flags)
        assert flags[0] == (smm.STEP_INIT | smm.STEP_FLUSH)
        assert np.all(flags[1:] == 0)
    c = pl.execute(p, a, b)
    assert np.all(np.asarray(c) == 0.0)


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
@pytest.mark.parametrize("block_n", [1, 2])
def test_full_mask_equals_dense_matmul(backend, block_n):
    a, b = _decay(64, 96, 6), _decay(96, 128, 7)
    p = pl.plan(a, b, -1.0, tile=32, block_n=block_n, backend=backend)
    assert float(p.valid_fraction) == 1.0
    c = pl.execute(p, a, b)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b), atol=2e-3)


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_ragged_shapes_through_spamm(backend):
    """Arbitrary (non-tile-multiple) shapes pad, execute, un-pad — identical
    to the reference blocked masked einsum on the padded operands."""
    a, b = _decay(70, 45, 8), _decay(45, 90, 9)
    c, info = cs.spamm(a, b, 1.5, tile=32, backend=backend)
    want = ref.spamm_matmul_ref(pl.pad_to_tile(a, 32), pl.pad_to_tile(b, 32),
                                1.5, 32)[:70, :90]
    assert 0.0 < float(info.valid_fraction) < 1.0
    np.testing.assert_allclose(np.asarray(c), np.asarray(want), atol=2e-4)


def test_plan_work_agrees_with_spamm_compact_ref():
    """Random masks: kidx/nvalid derived from `plan().work` equal the legacy
    dense-bitmap sort compaction, including padding-slot layout."""
    rng = np.random.default_rng(10)
    for trial in range(5):
        gm, gn, gk = rng.integers(1, 7, 3)
        na = jnp.asarray(rng.uniform(0, 1, (gm, gk)).astype(np.float32))
        nb = jnp.asarray(rng.uniform(0, 1, (gk, gn)).astype(np.float32))
        tau = float(rng.uniform(0.05, 0.8))
        p = pl.plan(None, None, tau, norm_a=na, norm_b=nb, tile=32,
                    backend="interpret")
        kidx_ref, nv_ref = ref.spamm_compact_ref(
            ref.spamm_mask_ref(na, nb, jnp.float32(tau)))
        np.testing.assert_array_equal(
            pl.kidx_from_work(p.work, gm, gn, gk), np.asarray(kidx_ref))
        np.testing.assert_array_equal(
            np.asarray(p.nvalid), np.asarray(nv_ref))
        # pair/step views are mutually consistent
        w = p.work
        assert int(np.asarray(w.offsets)[-1]) == w.num_valid
        assert int(p.valid_tiles) == w.num_valid


def test_worklist_step_tables_bucketed_and_flagged():
    a, b = _decay(128, 128, 11), _decay(128, 128, 12)
    p = pl.plan(a, b, TAU32, tile=32, backend="interpret")
    w = p.work
    s = w.step_i.shape[0]
    assert s >= w.num_valid and (s & (s - 1)) == 0  # power-of-two bucket
    flags = np.asarray(w.step_flags)
    assert np.all(flags[w.num_valid:] == 0)  # padding steps are inert
    # each pair opens with INIT and closes with FLUSH exactly once
    assert np.sum((flags & smm.STEP_INIT) != 0) == w.num_pairs
    assert np.sum((flags & smm.STEP_FLUSH) != 0) == w.num_pairs
    assert np.sum((flags & smm.STEP_ACC) != 0) == w.num_valid


@pytest.mark.parametrize("block_n", [1, 2])
def test_concrete_and_traced_flat_plans_gate_identically(block_n):
    """The concrete host gate (numpy products + nonzero scan) and the traced
    `gate_mask` are two renderings of ONE gating rule — lock them together
    so a future edit to either cannot silently diverge the plans."""
    a, b = _decay(96, 128, 40), _decay(128, 128, 41)
    p_eager = pl.plan(a, b, TAU32, tile=32, block_n=block_n,
                      backend="interpret")
    traced_mask = jax.jit(
        lambda a_, b_: pl.plan(a_, b_, TAU32, tile=32, block_n=block_n,
                               backend="interpret").mask
    )(a, b)
    np.testing.assert_array_equal(np.asarray(p_eager.mask),
                                  np.asarray(traced_mask))


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_hier_plan_with_concrete_operands_under_outer_jit(backend):
    """Under an enclosing jit, nested-jit kernels return tracers even for
    concrete operands — the planner must fall back to the traced gate
    instead of crashing in the host descent (regression)."""
    a, b = _decay(96, 96, 44), _decay(96, 96, 45)

    @jax.jit
    def frac(s):
        p = pl.plan(a, b, TAU32, tile=32, backend=backend, levels=2)
        return p.valid_fraction + s

    got = float(frac(0.0))
    want = float(pl.plan(a, b, TAU32, tile=32, backend=backend,
                         levels=2).valid_fraction)
    assert got == pytest.approx(want)


def test_reading_lazy_mask_keeps_plan_treedef_stable():
    """Materializing the derived mask must not change the plan's pytree
    structure — jit caches are keyed on it."""
    a, b = _decay(96, 96, 42), _decay(96, 96, 43)
    p = pl.plan(a, b, TAU32, tile=32, backend="interpret")
    td_before = jax.tree_util.tree_structure(p)
    _ = p.mask  # materialize the cache
    td_after = jax.tree_util.tree_structure(p)
    assert td_before == td_after


def test_worklist_plan_is_a_pytree_through_jit():
    a, b = _decay(96, 96, 13), _decay(96, 96, 14)
    p = pl.plan(a, b, TAU32, tile=32, backend="interpret")
    c1 = pl.execute(p, a, b)
    c2 = jax.jit(pl.execute)(p, a, b)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_plan_on_concrete_operands_never_sorts_dense_bitmap(monkeypatch):
    """Acceptance: the concrete planning path must not fall back to the
    O(gm·gn·gk log gk) dense-bitmap sort (`spamm_compact_ref`)."""
    calls = []
    real = ref.spamm_compact_ref
    monkeypatch.setattr(ref, "spamm_compact_ref",
                        lambda m: calls.append(1) or real(m))
    a, b = _decay(96, 96, 15), _decay(96, 96, 16)
    for levels in (0, 2):
        p = pl.plan(a, b, TAU32, tile=32, backend="interpret", levels=levels)
        pl.execute(p, a, b)
    assert not calls


# ---------------------------------------------------------------------------
# block_n padding regression (odd N) across the three entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_spamm_odd_n_block_n(backend):
    """N % (tile·block_n) != 0 used to trip the `gn % block_n` assert; the
    weight side now pads to tile·block_n and un-pads the output."""
    m, k, n = 96, 128, 160  # n/tile = 5 column tiles, block_n = 2 → ragged
    a, b = _decay(m, k, 20), _decay(k, n, 21)
    c, info = cs.spamm(a, b, TAU32, tile=32, block_n=2, backend=backend)
    assert c.shape == (m, n)
    # zero-padding must be invisible: same result as an explicitly padded
    # product, sliced back
    bp = pl.pad_to_tile(b, 32, 64)
    c_pad, _ = cs.spamm(a, bp, TAU32, tile=32, block_n=2, backend=backend)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_pad[:, :n]))
    # and the super-column mask is a superset of the fine mask: the result
    # must match the jnp masked-einsum oracle on the plan's own mask
    p = pl.plan(pl.pad_to_tile(a, 32), bp, TAU32, tile=32, block_n=2,
                backend="jnp")
    want = ops.get_backend("jnp").matmul(
        pl.pad_to_tile(a, 32), bp, p.mask, None, None, 32, 2, jnp.float32)
    np.testing.assert_allclose(np.asarray(c), np.asarray(want)[:m, :n],
                               atol=2e-4)


@pytest.mark.parametrize("use_ctx", [False, True])
def test_spamm_linear_odd_n_block_n(use_ctx):
    from repro.configs import SpammConfig

    x, w = _decay(80, 128, 22), _decay(128, 160, 23)
    ctx = None
    if use_ctx:
        ctx = mod.SpammContext(
            SpammConfig(enable=True, tau=TAU32, tile=32, backend="jnp",
                        block_n=2))
    y = mod.spamm_linear(x, w, jnp.float32(TAU32), 32, "jnp", "dense", 2,
                         ctx, 0)
    assert y.shape == (80, 160)
    y2, _ = cs.spamm(x, w, TAU32, tile=32, block_n=2, backend="jnp")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=2e-4)


def test_spamm_linear_odd_n_block_n_bwd_spamm():
    """The bwd="spamm" replan path pads g and w consistently with the
    forward's block_n-padded normmaps."""
    x, w = _decay(64, 96, 24), _decay(96, 160, 25)

    def loss(x_, w_):
        y = mod.spamm_linear(x_, w_, jnp.float32(TAU32), 32, "jnp", "spamm",
                             2, None, 0)
        return jnp.sum(y * y)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert dx.shape == x.shape and dw.shape == w.shape
    assert np.all(np.isfinite(np.asarray(dx)))
    assert np.all(np.isfinite(np.asarray(dw)))


@pytest.mark.parametrize("shared_w", [True, False])
def test_spamm_bmm_odd_n_block_n(shared_w):
    bsz, m, k, n = 2, 64, 96, 160
    x = jnp.stack([_decay(m, k, 30 + i) for i in range(bsz)])
    if shared_w:
        w = _decay(k, n, 32)
    else:
        w = jnp.stack([_decay(k, n, 33 + i) for i in range(bsz)])
    c, info = pl.spamm_bmm(x, w, TAU32, tile=32, block_n=2, backend="jnp")
    assert c.shape == (bsz, m, n)
    for i in range(bsz):
        w_i = w if shared_w else w[i]
        want, _ = cs.spamm(x[i], w_i, TAU32, tile=32, block_n=2,
                           backend="jnp")
        np.testing.assert_allclose(np.asarray(c[i]), np.asarray(want),
                                   atol=2e-4)
