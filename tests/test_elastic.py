"""Elastic re-mesh: save on one mesh, reshard+resume on a smaller surviving
device set (DESIGN.md §9) — 8 fake devices, subprocess."""
import pytest

from conftest import run_subprocess

CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ParallelConfig, get_config
from repro.distributed import elastic
from repro.models import model as M

pcfg = ParallelConfig(compute_dtype="float32", param_dtype="float32",
                      remat="none", decode_seq_shard=False)
cfg = get_config("starcoder2-7b").reduced()

# full mesh: 4 data x 2 model
mesh8 = elastic.build_elastic_mesh(jax.devices(), model_parallel=2)
assert dict(mesh8.shape) == {"data": 4, "model": 2}
params = M.init_params(cfg, pcfg, jax.random.key(0))
state = {"params": params}
sharded = elastic.reshard_state(state, cfg, pcfg, mesh8)

# two "nodes" die -> 6 devices survive -> best grid is 3x2
mesh6 = elastic.build_elastic_mesh(jax.devices()[:6], model_parallel=2)
assert dict(mesh6.shape) == {"data": 3, "model": 2}
resharded = elastic.reshard_state(sharded, cfg, pcfg, mesh6)

for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# and the model still steps on the shrunken mesh
from repro.launch.mesh import make_ctx
ctx = make_ctx(mesh6)
inp = {"tokens": jnp.zeros((6, 32), jnp.int32) + 3,
       "labels": jnp.ones((6, 32), jnp.int32)}
with mesh6:
    loss, _ = jax.jit(lambda p, b: M.loss_fn(cfg, pcfg, ctx, p, b))(
        resharded["params"], inp)
assert bool(jnp.isfinite(loss))
print("OK", float(loss))
"""


@pytest.mark.slow
def test_elastic_reshard_8_to_6():
    out = run_subprocess(CODE, devices=8)
    assert "OK" in out
