"""MoE strategy equivalence: TP (ff-sharded) and EP (expert-sharded) must
compute the SAME function — they differ only in collective schedule.
Subprocess with 8 fake devices (mesh 2×4: data=2, model=4)."""
from conftest import run_subprocess

CODE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_mesh
from repro.models import moe as moe_mod

mesh = make_mesh((2, 4), ("data", "model"))
d, e, ff, topk = 32, 8, 16, 2
base = MoEConfig(num_experts=e, top_k=topk, expert_ff=ff, impl="tp",
                 capacity_factor=8.0)  # no drops → exact equivalence

key = jax.random.key(0)
p_tp = moe_mod.moe_params(key, base, d, jnp.float32, model_axis_size=4)
cfg_ep = dataclasses.replace(base, impl="ep")
p_ep = moe_mod.moe_params(key, cfg_ep, d, jnp.float32, model_axis_size=4)
# same expert weights (EP pads expert dim to a multiple of model axis = 8 ✓)
for k in ("router", "w1", "w3", "w2"):
    np.testing.assert_array_equal(np.asarray(p_tp[k]), np.asarray(p_ep[k]))

x = 0.5 * jax.random.normal(jax.random.key(1), (4, 16, d), jnp.float32)
with mesh:
    y_tp, aux_tp = jax.jit(lambda p, x: moe_mod.moe_block(
        p, x, base, "silu", mesh=mesh, batch_axes=("data",)))(p_tp, x)
    y_ep, aux_ep = jax.jit(lambda p, x: moe_mod.moe_block(
        p, x, cfg_ep, "silu", mesh=mesh, batch_axes=("data",)))(p_ep, x)
np.testing.assert_allclose(np.asarray(y_tp), np.asarray(y_ep), atol=2e-5)
np.testing.assert_allclose(float(aux_tp), float(aux_ep), atol=1e-5)

# and both match a plain dense per-token expert evaluation
def dense_moe(p, x):
    t = x.reshape(-1, d)
    logits = t @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, topk)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(t)
    for slot in range(topk):
        w1 = p["w1"][idx[:, slot]]; w3 = p["w3"][idx[:, slot]]; w2 = p["w2"][idx[:, slot]]
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", t, w1)) * jnp.einsum("td,tdf->tf", t, w3)
        out += gates[:, slot:slot+1] * jnp.einsum("tf,tfd->td", h, w2)
    return out.reshape(x.shape)

ref = dense_moe(p_tp, x)
np.testing.assert_allclose(np.asarray(y_tp), np.asarray(ref), atol=2e-5)
print("OK")
"""


def test_tp_ep_equivalence():
    out = run_subprocess(CODE, devices=8)
    assert "OK" in out
