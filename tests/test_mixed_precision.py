"""Mixed-precision SpAMM — ISSUE 6 tentpole coverage.

The dtype contract across the stack: bf16 execution is bit-identical to
f32 on bf16-representable inputs (and reproduces the bf16-rounded oracle
otherwise); the int8 worklist kernel reproduces the f32 kernel run on its
own dequantized operands to a few ulps; quantization round-trips are
idempotent and bounded; the frozen-plan runtime carries dtype end to end
(scale tables persisted, store keyed on dtype, requested-τ vs widened
gate-τ separation); and the serving engine reports dtype + bytes-moved
telemetry per wave.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as pl
from repro.core import spamm as cs
from repro.kernels import quantize as kq
from repro.plans import FrozenWeight, PlanStore, fingerprint


def _decay(m, n, seed, scale=0.4):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(m)[:, None] - np.arange(n)[None, :])
    base = (scale / (d ** 0.5 + 1)).astype(np.float32)
    return jnp.asarray(base * rng.standard_normal((m, n)).astype(np.float32))


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_bounded_and_idempotent():
    x = _decay(96, 128, 0)
    q, s = kq.quantize_tiles(x, 32)
    assert q.dtype == jnp.int8 and s.shape == (3, 4)
    deq = kq.dequantize_tiles(q, s, 32)
    # per-tile bound: |x − deq| ≤ scale/2 elementwise (symmetric rounding)
    bound = jnp.repeat(jnp.repeat(s, 32, 0), 32, 1) * 0.5 + 1e-7
    assert bool(jnp.all(jnp.abs(x - deq) <= bound))
    # idempotent: re-quantizing the dequantized view with the SAME scales
    # reproduces the codes exactly (what execute() relies on for plan-time
    # scale reuse)
    q2, s2 = kq.quantize_tiles(deq, 32, scales=s)
    assert bool(jnp.all(q2 == q)) and bool(jnp.all(s2 == s))


def test_widen_tau_math():
    e8 = kq.gate_eps("bfloat16", 32)
    assert e8 == pytest.approx(2.0 ** -8)
    ei = kq.gate_eps("int8", 32)
    assert ei == pytest.approx(min(1.0, np.sqrt(32 * 32) / 254.0))
    assert kq.gate_eps("float32", 32) == 0.0
    t = kq.widen_tau(1.0, "bfloat16", 32)
    assert t == pytest.approx((1 - e8) ** 2)
    assert kq.widen_tau(1.0, "float32", 32) == 1.0
    # traced τ widens inside jit too
    tj = jax.jit(lambda x: kq.widen_tau(x, "int8", 32))(jnp.float32(1.0))
    assert float(tj) == pytest.approx((1 - ei) ** 2, rel=1e-6)


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

def test_bf16_bit_identical_on_representable_inputs():
    """bf16-representable operands (already rounded) through the bf16 path
    give the BIT-IDENTICAL result to the f32 path: every a·b product of two
    bf16 values is exact in the f32 accumulator and the accumulation order
    is the same kernel's."""
    a = _decay(128, 128, 1).astype(jnp.bfloat16).astype(jnp.float32)
    b = _decay(128, 128, 2).astype(jnp.bfloat16).astype(jnp.float32)
    for backend in ("jnp", "interpret"):
        p32 = pl.plan(a, b, 0.05, tile=32, backend=backend)
        pbf = pl.plan(a, b, 0.05, tile=32, backend=backend,
                      compute_dtype="bfloat16")
        c32 = pl.execute(p32, a, b)
        cbf = pl.execute(pbf, a, b)
        # representable inputs ⇒ same gate (norms identical) ⇒ same work
        assert bool(jnp.all(p32.mask == pbf.mask)), backend
        np.testing.assert_array_equal(np.asarray(c32), np.asarray(cbf),
                                      err_msg=backend)


def test_int8_kernel_matches_dequantized_oracle():
    """The int8 worklist kernel ≈ the f32 kernel on the dequantized
    operands with the same plan (a few ulps: the int32 tile dots are exact
    where the f32 oracle rounds)."""
    a, b = _decay(128, 128, 3), _decay(128, 128, 4)
    p8 = pl.plan(a, b, 0.02, tile=32, backend="interpret",
                 compute_dtype="int8")
    c8 = pl.execute(p8, a, b)
    adq = kq.quantized_view(a, "int8", 32)
    bdq = kq.quantized_view(b, "int8", 32)
    p32 = pl.SpammPlan(p8.tau, p8.norm_a, p8.norm_b, p8.mask, p8.kidx,
                       p8.nvalid, p8.valid_tiles, p8.work, tile=p8.tile,
                       block_n=p8.block_n, backend=p8.backend,
                       levels=p8.levels)
    oracle = pl.execute(p32, adq, bdq)
    scale = float(jnp.max(jnp.abs(oracle))) or 1.0
    assert float(jnp.max(jnp.abs(c8 - oracle))) <= 1e-5 * scale


def test_jnp_fallback_matches_worklist_kernels():
    """Backends without the int8/worklist entry points (jnp) widen to f32 on
    the quantized views — same numerics-of-record as the kernels within
    float tolerance, for every dtype."""
    a, b = _decay(128, 192, 5), _decay(192, 128, 6)
    for dtype in ("bfloat16", "int8"):
        cs_j = pl.execute(
            pl.plan(a, b, 0.05, tile=32, backend="jnp", compute_dtype=dtype),
            a, b)
        cs_i = pl.execute(
            pl.plan(a, b, 0.05, tile=32, backend="interpret",
                    compute_dtype=dtype),
            a, b)
        np.testing.assert_allclose(np.asarray(cs_j), np.asarray(cs_i),
                                   rtol=1e-5, atol=1e-5, err_msg=dtype)


def test_block_n_int8_scales_per_fine_tile():
    """block_n > 1 super-columns must still apply b's scale PER FINE TILE
    (the kernel's static unroll), not per super-column."""
    a, b = _decay(64, 64, 7), _decay(64, 128, 8)
    for block_n in (1, 2):
        p = pl.plan(a, b, 0.02, tile=32, block_n=block_n,
                    backend="interpret", compute_dtype="int8")
        c = pl.execute(p, a, b)
        adq = kq.quantized_view(a, "int8", 32)
        bdq = kq.quantized_view(b, "int8", 32)
        ref = adq @ bdq
        # τ small enough that everything executes → compare to full product
        assert float(p.valid_fraction) == 1.0
        np.testing.assert_allclose(np.asarray(c), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# bytes-moved accounting
# ---------------------------------------------------------------------------

def test_bytes_moved_ratio():
    a, b = _decay(256, 256, 9), _decay(256, 256, 10)
    by = {}
    for dtype in ("float32", "bfloat16", "int8"):
        p = pl.plan(a, b, 0.05, tile=32, backend="jnp", compute_dtype=dtype)
        by[dtype] = float(p.bytes_moved())
    # same work-list (representability aside the gates here coincide or are
    # supersets); operand bytes shrink 2× / 4× while flush writes stay f32
    assert by["float32"] / by["bfloat16"] >= 1.5
    assert by["float32"] / by["int8"] >= 1.5
    assert by["bfloat16"] > by["int8"]


# ---------------------------------------------------------------------------
# frozen-plan runtime carries dtype
# ---------------------------------------------------------------------------

def test_frozen_weight_carries_dtype_and_widens_gate_tau():
    w = _decay(128, 128, 11)
    fw = FrozenWeight.build(w, tau=0.05, tile=32, backend="interpret",
                            compute_dtype="int8")
    assert fw.compute_dtype == "int8"
    assert fw.b_scale is not None and fw.b_scale.shape == (4, 4)
    # FrozenWeight keeps the REQUESTED τ (store addressing)…
    assert float(np.asarray(fw.tau)) == pytest.approx(0.05)
    fp = fw.for_rows(2)
    # …and for_rows bakes the WIDENED gate τ into the runtime plan
    e = kq.gate_eps("int8", 32)
    assert float(np.asarray(fp.tau)) == pytest.approx(0.05 * (1 - e) ** 2,
                                                      rel=1e-5)
    x = _decay(64, 128, 12)
    p = pl.plan(x, None, None, tile=32, backend="interpret", frozen_weight=fp)
    c = pl.execute(p, x, w)
    # parity vs the unfrozen int8 path at the same config
    p_live = pl.plan(x, w, 0.05, tile=32, backend="interpret",
                     compute_dtype="int8")
    c_live = pl.execute(p_live, x, w)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_live),
                               rtol=1e-5, atol=1e-6)


def test_store_keys_on_dtype_and_persists_scales(tmp_path):
    w = _decay(96, 96, 13)
    st = PlanStore(str(tmp_path))
    h = fingerprint(w)
    cfg = dict(tau=0.05, tile=32, block_n=1, levels=0, backend="jnp")
    for dtype in ("float32", "int8"):
        fw = FrozenWeight.build(w, weight_hash=h, compute_dtype=dtype, **cfg)
        st.put(fw)
    got8 = st.get(h, dtype="int8", **cfg)
    got32 = st.get(h, dtype="float32", **cfg)
    assert got8.compute_dtype == "int8" and got8.b_scale is not None
    assert got32.compute_dtype == "float32" and got32.b_scale is None
    np.testing.assert_array_equal(
        np.asarray(got8.b_scale),
        np.asarray(FrozenWeight.build(w, compute_dtype="int8",
                                      **cfg).b_scale))
    # bf16 was never put: clean miss, not a wrong-dtype hit
    assert st.get(h, dtype="bfloat16", **cfg) is None


# ---------------------------------------------------------------------------
# engine telemetry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
def test_engine_reports_dtype_and_bytes(dtype):
    from repro.configs import ParallelConfig, SpammConfig, get_config
    from repro.launch.mesh import make_ctx, make_host_mesh
    from repro.models import model as M
    from repro.serving.engine import Engine, Request

    pcfg = ParallelConfig(
        compute_dtype="float32", param_dtype="float32", remat="none",
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
        decode_seq_shard=False,
    )
    cfg = get_config("musicgen-large").reduced()
    ctx = make_ctx(make_host_mesh())
    params = M.init_params(cfg, pcfg, jax.random.key(0))
    sc = SpammConfig(enable=True, tau=1e-3, tile=16, backend="jnp",
                     dtype=dtype)
    eng = Engine(cfg, pcfg, ctx, params, max_len=48, spamm_cfg=sc)
    reqs = [Request(prompt=list(range(1, 17)), max_new_tokens=3)]
    eng.generate(reqs)
    sp = reqs[0].out["spamm"]
    assert sp["compute_dtype"] == dtype
    assert sp["gemm_bytes_moved"] is not None and sp["gemm_bytes_moved"] > 0
    assert (sp["decode_gemm_bytes_moved"] is not None
            and sp["decode_gemm_bytes_moved"] > 0)
    # tokens must match the f32 engine's at this tiny τ (quantization noise
    # is far below the greedy-argmax margin on a reduced random-init model)
    sc32 = SpammConfig(enable=True, tau=1e-3, tile=16, backend="jnp")
    eng32 = Engine(cfg, pcfg, ctx, params, max_len=48, spamm_cfg=sc32)
    reqs32 = [Request(prompt=list(range(1, 17)), max_new_tokens=3)]
    eng32.generate(reqs32)
    b32 = reqs32[0].out["spamm"]["gemm_bytes_moved"]
    assert b32 / sp["gemm_bytes_moved"] >= 1.5
