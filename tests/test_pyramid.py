"""Norm-pyramid gating: the exactness invariant and its riders.

(a) pyramid level-l normmaps equal a direct get-norm pass at tile·2^l
    (within fp tolerance — the pyramid is ONE pass + cheap poolings);
(b) the hierarchical mask is bit-identical to flat `gate_mask` for random
    and banded-decay matrices on the jnp and interpret backends (eager
    sparse descent AND the traced dense refinement);
(c) the layers that ride on the pyramid: coarse-first τ-search, coarse
    work estimates / auto schedule, pyramid-caching WeightPlanCache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as pl
from repro.core import schedule
from repro.core import spamm as cs
from repro.core.tau_search import search_tau, search_tau_pyramid
from repro.kernels import ops, ref

BACKENDS = ("jnp", "interpret")


def _random(m, n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))


def _banded(n, seed, lam=0.6):
    return jnp.asarray(cs.exponential_decay(n, lam=lam, seed=seed))


# ---------------------------------------------------------------------------
# (a) pyramid levels == direct get-norm at the coarse tile
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_pyramid_levels_match_direct_tile_norms(backend):
    """levels[l] must equal tile_norms at tile·2^l (dims chosen divisible so
    the direct pass exists), within fp tolerance."""
    tile, levels = 32, 2
    for x in (_random(256, 512, 0), _banded(256, 1)):
        pyr = ops.pyramid_norms(x, tile, levels, backend=backend)
        assert len(pyr) == levels + 1
        for l in range(levels + 1):
            want = ref.tile_norms_ref(x, tile * 2 ** l)
            np.testing.assert_allclose(
                np.asarray(pyr[l]), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_pyramid_ragged_edges_zero_padded(backend):
    """Odd grid dims: the coarse level pools a phantom zero row/col, so the
    surviving entries still match sqrt-sumsq of the real children."""
    x = _random(96, 160, 2)  # grids (3, 5) -> (2, 3) -> (1, 2)
    pyr = ops.pyramid_norms(x, 32, 2, backend=backend)
    assert pyr[0].shape == (3, 5)
    assert pyr[1].shape == (2, 3) and pyr[2].shape == (1, 2)
    np.testing.assert_allclose(
        np.asarray(pyr[1]), np.asarray(ref.pool_norms_ref(pyr[0])), rtol=1e-6)


def test_pyramid_backend_parity():
    """jnp and interpret (exact Pallas kernel body) pyramids agree."""
    x = _banded(192, 3)
    pj = ops.pyramid_norms(x, 32, 2, backend="jnp")
    pi = ops.pyramid_norms(x, 32, 2, backend="interpret")
    for a, b in zip(pj, pi):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_coarse_norm_upper_bounds_children():
    """The pruning lever: every coarse entry >= each descendant tile norm."""
    x = _random(256, 256, 4)
    pyr = pl.NormPyramid.build(x, 2, tile=32, backend="jnp")
    for l in range(1, 3):
        fine = np.asarray(pyr.levels[l - 1])
        coarse = np.asarray(pyr.levels[l])
        gm, gk = fine.shape
        up = np.repeat(np.repeat(coarse, 2, 0), 2, 1)[:gm, :gk]
        assert (up >= fine * (1 - 1e-6)).all()


# ---------------------------------------------------------------------------
# (b) the exactness invariant: hierarchical mask ≡ flat mask, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("levels", [1, 2, 3])
def test_hier_mask_bit_identical_random(backend, levels):
    a, b = _random(256, 256, 10), _random(256, 256, 11)
    na = ops.tile_norms(a, 32, backend=backend)
    nb = ops.tile_norms(b, 32, backend=backend)
    # τ exactly equal to a product value present in the tensor — the
    # boundary case where a sloppy coarse test would flip bits
    prods = np.asarray(na)[:, None, :] * np.asarray(nb).T[None]
    tau = float(np.median(prods))
    p0 = pl.plan(a, b, tau, tile=32, backend=backend)
    pL = pl.plan(a, b, tau, tile=32, backend=backend, levels=levels)
    assert 0.0 < float(p0.valid_fraction) < 1.0
    np.testing.assert_array_equal(np.asarray(p0.mask), np.asarray(pL.mask))
    np.testing.assert_array_equal(
        np.asarray(pl.execute(p0, a, b)), np.asarray(pl.execute(pL, a, b)))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("block_n", [1, 2])
def test_hier_mask_bit_identical_banded(backend, block_n):
    a, b = _banded(512, 20), _banded(512, 21)
    p0 = pl.plan(a, b, 0.02, tile=32, block_n=block_n, backend=backend)
    pL = pl.plan(a, b, 0.02, tile=32, block_n=block_n, backend=backend,
                 levels=3)
    assert 0.0 < float(p0.valid_fraction) < 1.0
    np.testing.assert_array_equal(np.asarray(p0.mask), np.asarray(pL.mask))
    assert pL.levels == 3 and p0.levels == 0


def test_hier_mask_traced_path_matches_eager():
    """The dense traced refinement (hier_gate_mask under jit) must equal
    both the eager sparse descent and flat gating; and plan(levels=...)
    under jit — which downgrades to flat, since the mask is identical and
    the descent can't run there — must agree too."""
    a, b = _banded(256, 22), _banded(256, 23)
    pyr_a = pl.NormPyramid.build(a, 2, tile=32, backend="jnp")
    pyr_b = pl.NormPyramid.build(b, 2, tile=32, backend="jnp")

    m_traced = np.asarray(
        jax.jit(pl.hier_gate_mask)(pyr_a, pyr_b, jnp.float32(0.02)))

    @jax.jit
    def traced_plan_mask(a_, b_):
        p = pl.plan(a_, b_, 0.02, tile=32, backend="jnp", levels=2)
        return p.mask

    m_plan_jit = np.asarray(traced_plan_mask(a, b))
    m_eager = np.asarray(
        pl.plan(a, b, 0.02, tile=32, backend="jnp", levels=2).mask)
    m_flat = np.asarray(pl.plan(a, b, 0.02, tile=32, backend="jnp").mask)
    np.testing.assert_array_equal(m_traced, m_eager)
    np.testing.assert_array_equal(m_plan_jit, m_eager)
    np.testing.assert_array_equal(m_traced, m_flat)


def test_search_tau_pyramid_explicit_tol():
    """tol passed explicitly reaches the jitted search as a tracer — must
    not crash (regression: Python max() on a traced tol)."""
    na = ref.tile_norms_ref(
        jnp.asarray(cs.algebraic_decay(256, c=0.1, lam=0.1, seed=28)), 32)
    pa = pl.NormPyramid.from_normmap(na, 2, tile=32)
    tau, res = search_tau_pyramid(pa, pa, 0.3, tol=0.005)
    # lands where the flat search lands with the same explicit tol
    _, res_f = search_tau(na, na, 0.3, tol=0.005)
    assert abs(float(res.achieved_ratio) -
               float(res_f.achieved_ratio)) < 0.03


def test_hier_plan_from_pyramid_operands():
    """plan() accepts NormPyramid operands directly (the cached-weight
    shape) and deepens a too-shallow pyramid instead of failing."""
    a, b = _banded(256, 24), _banded(256, 25)
    pyr_a = pl.NormPyramid.build(a, 2, tile=32, backend="jnp")
    pyr_b = pl.NormPyramid.build(b, 1, tile=32, backend="jnp")  # shallower
    p = pl.plan(None, None, 0.02, norm_a=pyr_a, norm_b=pyr_b, tile=32,
                backend="jnp")
    p0 = pl.plan(a, b, 0.02, tile=32, backend="jnp")
    np.testing.assert_array_equal(np.asarray(p.mask), np.asarray(p0.mask))
    assert p.levels == 2


def test_hier_fully_pruned_and_fully_dense():
    a, b = _banded(128, 26), _banded(128, 27)
    hi = pl.plan(a, b, 1e9, tile=32, backend="jnp", levels=2)
    assert int(hi.valid_tiles) == 0
    lo = pl.plan(a, b, 0.0, tile=32, backend="jnp", levels=2)
    assert int(lo.valid_tiles) == lo.total_tiles


# ---------------------------------------------------------------------------
# (c) riders: τ-search, schedule estimates, weight cache, spamm_bmm
# ---------------------------------------------------------------------------

def test_search_tau_pyramid_hits_target():
    n, tile = 512, 32
    a = cs.algebraic_decay(n, c=0.1, lam=0.1, seed=0)
    b = cs.algebraic_decay(n, c=0.1, lam=0.1, seed=1)
    na = ref.tile_norms_ref(jnp.asarray(a), tile)
    nb = ref.tile_norms_ref(jnp.asarray(b), tile)
    pa = pl.NormPyramid.from_normmap(na, 2, tile=tile)
    pb = pl.NormPyramid.from_normmap(nb, 2, tile=tile)
    for target in (0.3, 0.15, 0.05):
        tau_h, res_h = search_tau_pyramid(pa, pb, target)
        assert abs(float(res_h.achieved_ratio) - target) < 0.02
        # the flat search agrees on the achieved ratio at the found τ
        tau_f, res_f = search_tau(na, nb, target)
        assert abs(float(res_f.achieved_ratio) -
                   float(res_h.achieved_ratio)) < 0.03


def test_plan_valid_ratio_with_levels():
    a = jnp.asarray(cs.algebraic_decay(256, c=0.1, lam=0.1, seed=30))
    b = jnp.asarray(cs.algebraic_decay(256, c=0.1, lam=0.1, seed=31))
    p = pl.plan(a, b, valid_ratio=0.3, tile=32, backend="jnp", levels=2)
    assert 0.2 < float(p.valid_fraction) < 0.4
    # and on a nastier (step-quantized) banded input the hierarchical search
    # lands exactly where the flat search lands
    a2, b2 = _banded(256, 30), _banded(256, 31)
    pf = pl.plan(a2, b2, valid_ratio=0.3, tile=32, backend="jnp")
    ph = pl.plan(a2, b2, valid_ratio=0.3, tile=32, backend="jnp", levels=2)
    assert float(pf.valid_fraction) == pytest.approx(
        float(ph.valid_fraction), abs=0.05)


def test_v_matrix_accepts_pyramids_and_levels():
    a, b = _banded(512, 32), _banded(512, 33)
    pa = pl.NormPyramid.build(a, 2, tile=32, backend="jnp")
    pb = pl.NormPyramid.build(b, 2, tile=32, backend="jnp")
    v0 = schedule.v_matrix(pa, pb, 0.02, level=0)
    v_flat = schedule.v_matrix(pa.base, pb.base, 0.02)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v_flat))
    v2 = schedule.v_matrix(pa, pb, 0.02, level=2)
    assert v2.shape == (4, 4)  # 16×16 grid pooled twice
    # coarse estimate sees work where fine work exists
    assert int(jnp.sum(v2)) > 0
    # unequal depths clamp jointly to the shallower side (no shape crash)
    pb1 = pl.NormPyramid.build(b, 1, tile=32, backend="jnp")
    v1 = schedule.v_matrix(pa, pb1, 0.02, level=2)
    assert v1.shape == (8, 8)
    # one plain side forces the base level
    v_mixed = schedule.v_matrix(pa, pb.base, 0.02, level=2)
    np.testing.assert_array_equal(np.asarray(v_mixed), np.asarray(v0))


def test_auto_schedule_picks_cyclic_only_when_it_helps():
    g = 32
    skew = np.full((g, g), 1e-4, np.float32)
    skew[: g // 4] = 10.0  # top-heavy rows → contiguous strips imbalanced
    v_skew = schedule.v_matrix(
        jnp.asarray(skew), jnp.asarray(np.ones((g, g), np.float32)), 0.5)
    assert schedule.auto_schedule(v_skew, 4) == "cyclic"
    assert schedule.auto_schedule(jnp.ones((g, g), jnp.int32), 4) == \
        "contiguous"
    # fewer row groups than devices: nothing to reassign
    assert schedule.auto_schedule(jnp.ones((2, 2), jnp.int32), 4) == \
        "contiguous"


def test_coarse_loads_attributed_through_fine_shard_boundaries():
    """A coarse row straddling a fine shard boundary splits its work across
    the devices that own its fine rows; array_split over coarse rows gave it
    wholly to one side and could mis-pick the schedule."""
    # gm=18 fine rows, level=2 (4 fine rows per coarse row, ceil → 5 coarse
    # rows), 2 devices: the fine boundary at row 9 cuts coarse row 2 (fine
    # rows 8–11) 1:3. All work in that row:
    v = np.zeros((5, 5), np.int64)
    v[2, :] = 4
    v = jnp.asarray(v)
    contig = schedule.device_loads(v, 2, "contiguous", level=2, fine_rows=18)
    np.testing.assert_allclose(contig, [5.0, 15.0])
    cyc = schedule.device_loads(v, 2, "cyclic", level=2, fine_rows=18)
    np.testing.assert_allclose(cyc, [10.0, 10.0])
    # the coarse-row array_split saw [20, 0] for BOTH schedules (coarse
    # cyclic reshuffles whole coarse rows) and kept contiguous; the fine
    # attribution sees the real 1.5× imbalance that cyclic fixes
    assert schedule.auto_schedule(v, 2) == "contiguous"
    assert schedule.auto_schedule(v, 2, level=2, fine_rows=18) == "cyclic"


def test_fine_attribution_matches_flat_at_level_zero():
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.integers(0, 9, (16, 16)).astype(np.int32))
    for sched in ("contiguous", "cyclic"):
        loads = schedule.device_loads(v, 4, sched)
        want = [float(jnp.sum(jnp.sum(v, 1)[np.asarray(
            schedule.rows_for_device(d, 4, 16, sched))])) for d in range(4)]
        np.testing.assert_allclose(loads, want)
    assert schedule.auto_schedule(v, 4) == \
        schedule.auto_schedule(v, 4, fine_rows=16)


def test_weight_cache_holds_pyramid():
    w = _banded(256, 40)
    cache = pl.WeightPlanCache()
    wp1, nw1 = cache.weight_side(w, tile=32, backend="jnp", levels=2)
    wp2, nw2 = cache.weight_side(w, tile=32, backend="jnp", levels=2)
    assert cache.hits == 1 and cache.misses == 1
    assert isinstance(nw1, pl.NormPyramid) and nw1 is nw2
    assert nw1.num_levels == 2
    # different levels is a different cache entry, not a stale hit
    _, nw0 = cache.weight_side(w, tile=32, backend="jnp")
    assert cache.misses == 2 and not isinstance(nw0, pl.NormPyramid)
    np.testing.assert_array_equal(np.asarray(nw0), np.asarray(nw1.base))


def test_cached_hier_plan_matches_flat_result():
    x, w = _banded(192, 41), _banded(192, 42)
    cache = pl.WeightPlanCache()
    xp = pl.pad_to_tile(x, 32)
    p, wp = cache.plan_for(xp, w, 0.02, tile=32, backend="jnp", levels=2)
    got = pl.execute(p, xp, wp)[: x.shape[0], : w.shape[1]]
    want, _ = cs.spamm(x, w, 0.02, tile=32, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", BACKENDS)
def test_spamm_bmm_levels_matches_flat(backend):
    x = jnp.stack([_banded(96, 50 + i) for i in range(2)])[:, :, :64]
    w = _banded(96, 52)[:64, :]
    c0, i0 = pl.spamm_bmm(x, w, 0.02, tile=32, backend=backend)
    cL, iL = pl.spamm_bmm(x, w, 0.02, tile=32, backend=backend, levels=2)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(cL))
    assert float(i0.valid_fraction) == float(iL.valid_fraction)


def test_pyramid_is_a_pytree():
    pyr = pl.NormPyramid.build(_banded(128, 60), 2, tile=32, backend="jnp")
    leaves, treedef = jax.tree_util.tree_flatten(pyr)
    assert len(leaves) == 3
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.tile == pyr.tile and back.num_levels == 2

    @jax.jit
    def through_jit(p):
        return p.coarse

    np.testing.assert_allclose(np.asarray(through_jit(pyr)),
                               np.asarray(pyr.coarse), rtol=1e-6)
