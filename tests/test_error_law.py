"""Error-behavior validation against the paper's §5.1 citation (Artemov
2019): for exponential-decay matrices, ‖E‖_F = O(√N · τ^{p/2}) with p < 2 —
i.e. log‖E‖ grows sub-linearly in log τ with slope ≤ ~1, and the relative
error stays tiny for small τ (paper Table 4 behavior)."""
import jax.numpy as jnp
import numpy as np

from repro.core import spamm as cs


def _run(n, tau, lam=0.8, tile=32, compute_dtype="float32"):
    a = cs.exponential_decay(n, lam=lam, seed=0)
    b = cs.exponential_decay(n, lam=lam, seed=1)
    dense = a.astype(np.float64) @ b.astype(np.float64)
    c, info = cs.spamm(jnp.asarray(a), jnp.asarray(b), tau, tile=tile,
                       backend="jnp", compute_dtype=compute_dtype)
    err = np.linalg.norm(np.asarray(c, np.float64) - dense)
    return err, np.linalg.norm(dense), float(info.valid_fraction)


def test_error_slope_in_tau():
    taus = [1e-4, 1e-3, 1e-2, 1e-1]
    errs = []
    for t in taus:
        err, normc, frac = _run(512, t)
        errs.append(max(err, 1e-14))
    logs = np.log10(errs)
    # O(τ^{p/2}), p<2 ⇒ AVERAGE slope ≤ ~1 per decade of τ (individual
    # decades staircase with the discrete tile structure)
    avg_slope = (logs[-1] - logs[0]) / (len(logs) - 1)
    assert avg_slope <= 1.2, (avg_slope, logs)
    # error must actually grow over 3 decades and never shrink
    assert logs[-1] > logs[0]
    assert np.all(np.diff(logs) >= -1e-9)


def test_relative_error_small_at_small_tau():
    """Table 4 behavior: ‖E‖/‖C‖ ≪ 1 at τ=1e-4 while work drops."""
    err, normc, frac = _run(1024, 1e-4, lam=0.7)
    assert err / normc < 1e-4
    assert frac < 0.6  # meaningful skipping


def test_error_norm_scaling_with_n():
    """√N scaling: quadrupling N should grow error by ≲ 4× at fixed τ."""
    e1, _, _ = _run(256, 1e-2)
    e2, _, _ = _run(1024, 1e-2)
    assert e2 < 8 * max(e1, 1e-12)


def test_low_precision_error_is_gating_plus_quantization():
    """Mixed-precision error law: ‖C_dtype − C_dense‖ ≤ ‖C_f32 − C_dense‖ +
    the quantization term. The quantization term is bounded by the relative
    per-element error of the format (bf16: 2⁻⁸; int8 per-tile: ≈ 1/127 of
    the tile max) times the product's own scale — low precision must not
    change the ERROR REGIME, only add a precision-sized floor."""
    n, tau = 512, 1e-2
    e32, normc, _ = _run(n, tau)
    # first-order bound on ||A@B − Aq@Bq||_F: eps·(||A||·||B|| + ...)
    a = cs.exponential_decay(n, lam=0.8, seed=0)
    b = cs.exponential_decay(n, lam=0.8, seed=1)
    opn = np.linalg.norm(a) * np.linalg.norm(b)
    for dtype, eps in (("bfloat16", 2.0 ** -8), ("int8", 1.0 / 127.0)):
        eq, _, _ = _run(n, tau, compute_dtype=dtype)
        bound = e32 + 3.0 * eps * opn
        assert eq <= bound, (dtype, eq, e32, bound)
        # and the quantization floor is real but small relative to C
        assert eq / normc < 0.02, (dtype, eq / normc)


def test_low_precision_error_still_monotone_in_tau():
    """The τ-sweep slope survives quantization: above the precision floor,
    error still grows with τ and never shrinks (the widened gate keeps the
    work a superset, so more τ ⇒ weakly more skipping at every dtype)."""
    for dtype in ("bfloat16", "int8"):
        errs = [_run(256, t, compute_dtype=dtype)[0]
                for t in (1e-3, 1e-2, 1e-1)]
        # allow the flat region where the quantization floor dominates τ
        assert errs[-1] >= errs[0] - 1e-9, (dtype, errs)
        assert np.all(np.diff(np.log10(np.maximum(errs, 1e-14))) >= -0.05), (
            dtype, errs)
