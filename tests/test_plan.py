"""Plan/execute layer: plan reuse is bit-identical to the unplanned call,
the WeightPlanCache actually hits, and batched execution (`spamm_bmm`)
matches a per-slice dense-oracle loop on both jnp and interpret backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import module as mod
from repro.core import plan as pl
from repro.core import spamm as cs
from repro.kernels import ops, ref

BACKENDS = ("jnp", "interpret")


def _decay(m, n, seed, scale=0.4):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(m)[:, None] - np.arange(n)[None, :])
    base = (scale / (d ** 0.5 + 1)).astype(np.float32)
    return jnp.asarray(base * rng.standard_normal((m, n)).astype(np.float32))


# taus that gate a real fraction (~0.5) of tiles on the _decay operands
TAU64 = 8.0   # at tile=64
TAU32 = 4.0   # at tile=32


@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_reuse_bit_identical(backend):
    """plan+execute == unplanned spamm_matmul, and executing the SAME plan
    twice returns bit-identical results (the plan is pure data)."""
    a, b = _decay(192, 256, 0), _decay(256, 320, 1)
    c_ref, info = ops.spamm_matmul(a, b, TAU64, tile=64, backend=backend)
    assert 0.0 < float(info["valid_fraction"]) < 1.0  # actually gated

    p = pl.plan(a, b, TAU64, tile=64, backend=backend)
    c1 = pl.execute(p, a, b)
    c2 = pl.execute(p, a, b)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_from_norms_matches_plan_from_matrices(backend):
    a, b = _decay(128, 192, 2), _decay(192, 128, 3)
    na = ops.tile_norms(a, 64, backend=backend)
    nb = ops.tile_norms(b, 64, backend=backend)
    p1 = pl.plan(a, b, TAU64, tile=64, backend=backend)
    p2 = pl.plan(None, None, TAU64, norm_a=na, norm_b=nb, tile=64,
                 backend=backend)
    np.testing.assert_array_equal(np.asarray(p1.mask), np.asarray(p2.mask))
    np.testing.assert_array_equal(
        np.asarray(pl.execute(p1, a, b)), np.asarray(pl.execute(p2, a, b))
    )


def test_plan_block_n_super_column_granularity():
    """block_n > 1 plans gate at super-column granularity — same mask the
    old inlined ops.spamm_matmul grouping produced, and a superset of the
    fine mask per member column."""
    a, b = _decay(256, 256, 4), _decay(256, 256, 5)
    p1 = pl.plan(a, b, TAU64, tile=64, block_n=1, backend="jnp")
    p2 = pl.plan(a, b, TAU64, tile=64, block_n=2, backend="jnp")
    m1, m2 = np.asarray(p1.mask), np.asarray(p2.mask)
    assert m2.shape == (4, 2, 4)
    # grouped ⊇ fine for each member column
    grouped_expanded = np.repeat(m2, 2, axis=1)
    assert (grouped_expanded | m1).sum() == grouped_expanded.sum()


def test_spamm_matmul_info_carries_nvalid():
    """The docstring has always promised `nvalid` in the info dict; it must
    be there on both the compacting (interpret) and bitmap-gating (jnp)
    backends, and equal the per-(i, j) valid-k count of the mask."""
    a, b = _decay(128, 128, 90), _decay(128, 128, 91)
    p = pl.plan(a, b, TAU64, tile=64, backend="jnp")
    want = np.asarray(p.mask).sum(-1)
    for backend in BACKENDS:
        _, info = ops.spamm_matmul(a, b, TAU64, tile=64, backend=backend)
        np.testing.assert_array_equal(np.asarray(info["nvalid"]), want)


def test_plan_valid_ratio_routes_tau_search():
    a, b = _decay(256, 256, 6), _decay(256, 256, 7)
    p = pl.plan(a, b, valid_ratio=0.5, tile=32, backend="jnp")
    assert 0.3 < float(p.valid_fraction) < 0.7


def test_weight_plan_cache_hits_on_repeated_weight():
    w = _decay(256, 192, 8)
    cache = pl.WeightPlanCache()
    wp1, nw1 = cache.weight_side(w, tile=64, backend="jnp")
    wp2, nw2 = cache.weight_side(w, tile=64, backend="jnp")
    assert cache.hits == 1 and cache.misses == 1
    assert wp1 is wp2 and nw1 is nw2
    np.testing.assert_allclose(
        np.asarray(nw1), np.asarray(ref.tile_norms_ref(w, 64)), rtol=1e-6
    )
    # a different weight misses; a different tile of the same weight misses
    cache.weight_side(_decay(256, 192, 9), tile=64, backend="jnp")
    cache.weight_side(w, tile=32, backend="jnp")
    assert cache.misses == 3 and cache.hits == 1


def test_weight_plan_cache_not_poisoned_by_tracers():
    cache = pl.WeightPlanCache()
    w = _decay(64, 64, 10)

    @jax.jit
    def through_jit(w_):
        wp, nw = cache.weight_side(w_, tile=32, backend="jnp")
        return nw

    through_jit(w)
    assert len(cache) == 0 and cache.hits == cache.misses == 0


def test_cached_plan_result_matches_uncached():
    x, w = _decay(96, 256, 11), _decay(256, 128, 12)
    cache = pl.WeightPlanCache()
    xp = pl.pad_to_tile(x, 64)
    for _ in range(2):
        p, wp = cache.plan_for(xp, w, TAU64, tile=64, backend="jnp")
        got = pl.execute(p, xp, wp)[: x.shape[0], : w.shape[1]]
        want, _ = cs.spamm(x, w, TAU64, tile=64, backend="jnp")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert cache.hits == 1


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("shared_w", [True, False])
def test_spamm_bmm_matches_dense_oracle_per_slice(backend, shared_w):
    """spamm_bmm == a python loop of single-product SpAMM oracles, for both
    the shared-weight (B,M,K)@(K,N) and per-batch (B,M,K)@(B,K,N) shapes."""
    bsz, m, k, n = 3, 96, 128, 160
    rng = np.random.default_rng(13)
    x = jnp.asarray(
        np.stack([np.asarray(_decay(m, k, 20 + i)) for i in range(bsz)])
    )
    if shared_w:
        w = _decay(k, n, 14)
        w_i = lambda i: w
    else:
        w = jnp.asarray(
            np.stack([np.asarray(_decay(k, n, 30 + i)) for i in range(bsz)])
        )
        w_i = lambda i: w[i]

    got, info = pl.spamm_bmm(x, w, TAU32, tile=32, backend=backend)
    assert 0.0 < float(info.valid_fraction) < 1.0  # actually gated
    assert got.shape == (bsz, m, n)
    for i in range(bsz):
        # dense oracle: blocked masked einsum on the padded slice
        want = ref.spamm_matmul_ref(x[i], w_i(i), TAU32, 32)
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want), atol=2e-4
        )


def test_spamm_bmm_shared_weight_uses_cache():
    x = jnp.stack([_decay(64, 128, 40 + i) for i in range(2)])
    w = _decay(128, 96, 41)
    cache = pl.WeightPlanCache()
    c1, _ = pl.spamm_bmm(x, w, TAU32, tile=32, backend="jnp", cache=cache)
    c2, _ = pl.spamm_bmm(x, w, TAU32, tile=32, backend="jnp", cache=cache)
    assert cache.hits == 1 and cache.misses == 1
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_spamm_bmm_per_batch_weights_use_cache():
    """The MoE-shaped (B, K, N) weight side is cacheable too: one reshaped
    get-norm pass, cached on identity, results unchanged."""
    x = jnp.stack([_decay(64, 128, 42 + i) for i in range(2)])
    wb = jnp.stack([_decay(128, 96, 44 + i) for i in range(2)])
    cache = pl.WeightPlanCache()
    c1, _ = pl.spamm_bmm(x, wb, TAU32, tile=32, backend="jnp", cache=cache)
    c2, _ = pl.spamm_bmm(x, wb, TAU32, tile=32, backend="jnp", cache=cache)
    assert cache.hits == 1 and cache.misses == 1
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    c3, _ = pl.spamm_bmm(x, wb, TAU32, tile=32, backend="jnp")
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c3))


def test_spamm_bmm_valid_ratio_requires_shared_weight():
    x = jnp.stack([_decay(64, 64, 50), _decay(64, 64, 51)])
    wb = jnp.stack([_decay(64, 64, 52), _decay(64, 64, 53)])
    with pytest.raises(ValueError):
        pl.spamm_bmm(x, wb, valid_ratio=0.5, tile=32, backend="jnp")


def test_plan_is_a_pytree():
    """Plans pass through jit: execute can be jitted with the plan as arg."""
    a, b = _decay(128, 128, 60), _decay(128, 128, 61)
    p = pl.plan(a, b, TAU32, tile=32, backend="jnp")
    jit_exec = jax.jit(pl.execute)
    c1 = jit_exec(p, a, b)
    c2 = pl.execute(p, a, b)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)


def test_spamm_linear_with_context_matches_config_path():
    from repro.configs import SpammConfig

    x = _decay(80, 128, 70)
    w = _decay(128, 96, 71)
    cfg = SpammConfig(enable=True, tau=TAU32, tile=32, backend="jnp")
    y_cfg = mod.maybe_spamm_matmul(x, w, cfg)
    ctx = mod.SpammContext(cfg)
    y_ctx1 = mod.maybe_spamm_matmul(x, w, ctx)
    y_ctx2 = mod.maybe_spamm_matmul(x, w, ctx)  # second call hits the cache
    np.testing.assert_array_equal(np.asarray(y_cfg), np.asarray(y_ctx1))
    np.testing.assert_array_equal(np.asarray(y_ctx1), np.asarray(y_ctx2))
    assert ctx.cache.hits >= 1


def test_count_valid_large_grid_no_int32_overflow():
    """gm·gk·gn > 2³¹: the ratio must come back ≈ 1.0 at τ=0, not garbage
    from an int32 wraparound."""
    g = 1300  # 1300³ ≈ 2.2e9 > 2³¹
    na = jnp.ones((g, g), jnp.float32)
    nb = jnp.ones((g, g), jnp.float32)
    ratio = float(cs.valid_ratio_of(na, nb, 0.0))
    assert abs(ratio - 1.0) < 1e-3, ratio
