"""Unit tests for the HLO analyzer's parsing primitives (shape bytes, dot
FLOPs, wire-byte model, group-size parsing) — the §Roofline instrument."""
import pytest

from repro.launch.hlo_analysis import (_group_size, _wire_bytes, shape_bytes,
                                       shape_dims)


def test_shape_bytes():
    assert shape_bytes("f32[256,512]{1,0}") == 256 * 512 * 4
    assert shape_bytes("bf16[2,3,4]{2,1,0}") == 24 * 2
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("(s32[], bf16[64,256]{1,0})") == 4 + 64 * 256 * 2
    assert shape_bytes("token[]") == 0


def test_shape_dims():
    dims, dt = shape_dims("f32[7,128,256]{2,1,0}")
    assert dims == [7, 128, 256] and dt == "f32"
    assert shape_dims("s32[]")[0] == []


def test_group_size_iota_and_list():
    assert _group_size("replica_groups=[4,2]<=[8]", 99) == 2
    assert _group_size("replica_groups=[2,4]<=[4,2]T(1,0)", 99) == 4
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 99) == 4
    assert _group_size("no groups here", 7) == 7


def test_wire_bytes_ring_model():
    g = 4
    assert _wire_bytes("all-gather", 100, 400, g) == 400 * 3 / 4
    assert _wire_bytes("all-reduce", 400, 400, g) == 2 * 400 * 3 / 4
    assert _wire_bytes("reduce-scatter", 400, 100, g) == 400 * 3 / 4
    assert _wire_bytes("collective-permute", 256, 256, g) == 256
    assert _wire_bytes("all-reduce", 400, 400, 1) == 0.0
