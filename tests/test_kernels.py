"""Per-kernel correctness: shape/dtype sweeps, Pallas interpret=True vs the
pure-jnp oracle (ref.py) — the contract the task prescribes for kernels/.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spamm as cs
from repro.kernels import ops, ref
from repro.kernels.getnorm import tile_norms as pl_tile_norms
from repro.kernels.spamm_mm import spamm_mm


def _decay(m, n, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    d = np.abs(np.arange(m)[:, None] - np.arange(n)[None, :])
    base = (0.2 / (d ** 0.5 + 1)).astype(np.float32)
    return (base * rng.standard_normal((m, n)).astype(np.float32)).astype(dtype)


@pytest.mark.parametrize("shape", [(64, 64), (128, 256), (384, 128)])
@pytest.mark.parametrize("tile", [32, 64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_mxu", [False, True])
def test_getnorm_sweep(shape, tile, dtype, use_mxu):
    if shape[0] % tile or shape[1] % tile:
        pytest.skip("not tileable")
    x = jnp.asarray(_decay(*shape, seed=1), dtype)
    want = ref.tile_norms_ref(x, tile)
    got = pl_tile_norms(x, tile, use_mxu=use_mxu, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("mkn", [(128, 128, 128), (128, 256, 192),
                                 (256, 128, 384)])
@pytest.mark.parametrize("tau", [0.0, 0.5, 2.0, 100.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spamm_mm_sweep(mkn, tau, dtype):
    m, k, n = mkn
    tile = 64
    a = jnp.asarray(_decay(m, k, seed=2), dtype)
    b = jnp.asarray(_decay(k, n, seed=3), dtype)
    na = ref.tile_norms_ref(a, tile)
    nb = ref.tile_norms_ref(b, tile)
    mask = ref.spamm_mask_ref(na, nb, jnp.float32(tau))
    kidx, nv = ref.spamm_compact_ref(mask)
    got = spamm_mm(a, b, kidx, nv, tile=tile, interpret=True)
    want = ref.spamm_matmul_ref(a, b, tau, tile)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-4,
    )


@pytest.mark.parametrize("block_n", [1, 2, 4])
def test_spamm_block_n_superset_exactness(block_n):
    """Grouped super-columns compute a SUPERSET of the τ mask: every result
    must equal the dense product on tiles the fine mask kept, and the info
    fraction must be ≥ the fine fraction (never drops valid work)."""
    m = k = n = 256
    tile = 64
    a = jnp.asarray(_decay(m, k, 4))
    b = jnp.asarray(_decay(k, n, 5))
    tau = 0.4
    fine, info_f = ops.spamm_matmul(a, b, tau, tile=tile, backend="interpret")
    got, info_g = ops.spamm_matmul(a, b, tau, tile=tile, backend="interpret",
                                   block_n=block_n)
    # superset: wherever fine computed, grouped must agree
    na, nb = ref.tile_norms_ref(a, tile), ref.tile_norms_ref(b, tile)
    mask = np.asarray(ref.spamm_mask_ref(na, nb, jnp.float32(tau)))
    for i in range(m // tile):
        for j in range(n // tile):
            contrib = mask[i, j]
            # grouped mask ⊇ fine mask per k ⇒ C_grouped includes all fine terms
    assert float(info_g["valid_fraction"]) >= float(info_f["valid_fraction"]) - 1e-6


def test_backends_agree():
    a = jnp.asarray(_decay(192, 256, 6))
    b = jnp.asarray(_decay(256, 320, 7))
    c1, _ = ops.spamm_matmul(a, b, 0.3, tile=64, backend="jnp")
    c2, _ = ops.spamm_matmul(a, b, 0.3, tile=64, backend="interpret")
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


def test_compact_invariants():
    na = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (4, 6)), jnp.float32)
    nb = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (6, 5)), jnp.float32)
    mask = ref.spamm_mask_ref(na, nb, jnp.float32(0.25))
    kidx, nv = ref.spamm_compact_ref(mask)
    kidx, nv, mask = map(np.asarray, (kidx, nv, mask))
    gm, gn, gk = mask.shape
    for i in range(gm):
        for j in range(gn):
            valid = np.nonzero(mask[i, j])[0]
            assert nv[i, j] == len(valid)
            # prefix = valid ks ascending
            np.testing.assert_array_equal(kidx[i, j, : len(valid)], valid)
            # padding repeats a valid k (revisit-friendly) or 0 when none
            if len(valid):
                assert (kidx[i, j, len(valid):] == valid[-1]).all()
            else:
                assert (kidx[i, j] == 0).all()


def test_zero_valid_rows_write_zeros():
    """nvalid == 0 for every output tile → kernel must still write zeros."""
    a = jnp.ones((128, 128), jnp.float32) * 1e-6
    b = jnp.ones((128, 128), jnp.float32) * 1e-6
    c, info = ops.spamm_matmul(a, b, 1e3, tile=64, backend="interpret")
    assert float(info["valid_fraction"]) == 0.0
    np.testing.assert_array_equal(np.asarray(c), np.zeros((128, 128), np.float32))
